"""§Roofline table generator: reads experiments/dryrun/*.json and emits the
per-(arch x shape x mesh) roofline terms as markdown + CSV.

    python -m benchmarks.roofline_table [--dir experiments/dryrun]
                                        [--mesh 16x16] [--md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(dir_: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_md(rows: List[Dict], mesh: str) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| roofline_frac | MODEL/HLO flops | zero | micro |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                       f"{r.get('error','?')[:60]} | | | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
            f"| {r['dominant']} | {r['roofline_fraction']:.3f} "
            f"| {r['model_flops_util']:.2f} | z{r.get('zero_stage','-')} "
            f"| {r.get('microbatches','-')} |")
    return "\n".join(out)


def fmt_csv(rows: List[Dict]) -> str:
    cols = ("arch", "shape", "mesh", "status", "compute_s", "memory_s",
            "collective_s", "dominant", "roofline_fraction",
            "model_flops_util", "zero_stage", "microbatches", "compile_s")
    out = [",".join(cols)]
    for r in rows:
        out.append(",".join(str(r.get(c, "")) for c in cols))
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir)
    if args.md:
        print(fmt_md(rows, args.mesh))
    else:
        print(fmt_csv(rows))


if __name__ == "__main__":
    main()
