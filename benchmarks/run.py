"""Benchmark harness: one function per paper table/figure.

Each ``bench_*`` reproduces one COMET case study through the analytical
pipeline and prints CSV rows (figure, key, metric, value, paper_claim).
``python -m benchmarks.run [--only figN] [--processes N] [--engine E]`` —
``--processes`` fans study cells over a fork pool (§V-E) and, on fig15,
also reports the measured fork-pool speedup; ``--engine compiled`` runs
every study through the vectorized compiled evaluator (same numbers within
1e-9, several times faster — docs/perf.md).

``--json PATH`` writes the machine-readable engine perf trajectory (the
fig15 transformer study timed serial vs compiled vs compiled + fork pool,
with an equivalence check) instead of the CSV benches; ``--smoke`` shrinks
it to a small grid for CI.

The §Roofline table from the measured dry-run lives in
``benchmarks/roofline_table.py`` (reads experiments/dryrun/*.json).
"""

from __future__ import annotations

import argparse
import json
import math
import time
from typing import List

from repro.configs import get_config, get_dlrm_config
from repro.configs.base import SHAPES, ShapeConfig
from repro.core import dse
from repro.core.cluster import BASELINE_DGX_A100, TPU_V5E_POD
from repro.core.simulator import simulate_iteration
from repro.core.strategy import footprint_table
from repro.core.study import ParallelSpec, StudySpec, run_study
from repro.core.workload import decompose

SHAPE_1T = ShapeConfig("paper", 2048, 1024, "train")
GB = 1e9

# Set by main() from --processes / --engine; every study in this harness
# runs through _run() so the fork pool and engine apply uniformly.
PROCESSES = None
ENGINE = "reference"

Row = tuple


def _run(spec):
    return run_study(spec, processes=PROCESSES, engine=ENGINE)


def _rows_fig6() -> List[Row]:
    """Fig 6: per-node model-state footprint vs MP degree x ZeRO stage."""
    cfg = get_config("transformer-1t")
    tab = footprint_table(cfg, SHAPE_1T, 1024)
    rows = []
    for label in ("MP1024_DP1", "MP256_DP4", "MP64_DP16", "MP16_DP64",
                  "MP8_DP128", "MP1_DP1024"):
        for z, v in tab[label].items():
            rows.append(("fig6", label, f"zero{z}_gb", round(v / GB, 1),
                         "ZeRO-3 flat; baseline grows as MP shrinks"))
    return rows


def _rows_fig8() -> List[Row]:
    """Fig 8: MP/DP sweep on the 1024-GPU DGX-A100 baseline."""
    cfg = get_config("transformer-1t")
    res = _run(dse.mpdp_study(cfg, SHAPE_1T, BASELINE_DGX_A100))
    rows = [("fig8", "best_strategy", "label", res.best().record["strategy"],
             "paper: MP8_DP128")]
    for c in res:
        r = c.record
        rows.append(("fig8", r["strategy"], "total_s",
                     round(r["total"], 2), ""))
        rows.append(("fig8", r["strategy"], "exposed_comm_s",
                     round(r["fp_exposed_comm"] + r["ig_exposed_comm"]
                           + r["wg_exposed_comm"], 2), ""))
        rows.append(("fig8", r["strategy"], "footprint_gb",
                     round(r["footprint_bytes"] / GB, 1), ""))
    return rows


def _rows_fig9() -> List[Row]:
    """Fig 9: expanded-memory bandwidth heatmap (normalized to MP64_DP16)."""
    cfg = get_config("transformer-1t")
    base = _run(StudySpec(
        name="fig9-baseline", model=cfg, shape=SHAPE_1T,
        cluster=BASELINE_DGX_A100,
        strategies=ParallelSpec(mp=64, dp=16))).cells[0].record["total"]
    hm = _run(dse.memory_expansion_study(
        cfg, SHAPE_1T, BASELINE_DGX_A100,
        em_bandwidths_gbs=(100, 250, 500, 1000, 2000),
        strategies=[(32, 32), (16, 64), (8, 128)],
    )).pivot(index="strategy", columns="bw_em_gbs")
    rows = [("fig9", "baseline_MP64_DP16", "total_s", round(base, 2),
             "rows beat 1.0 above their break-even bw")]
    breakeven = None
    for label, row in hm.items():
        for bw, t in sorted(row.items()):
            rows.append(("fig9", label, f"norm_runtime@{int(bw)}GBs",
                         round(t / base, 3), ""))
            if label == "MP8_DP128" and t <= base and breakeven is None:
                breakeven = bw
    rows.append(("fig9", "MP8_DP128", "break_even_GBs", breakeven,
                 "paper Ex.1: 500 GB/s (model-detail dependent, see "
                 "EXPERIMENTS.md)"))
    return rows


def _rows_fig10() -> List[Row]:
    """Fig 10: per-node compute-capability scaling (MP8_DP128)."""
    cfg = get_config("transformer-1t")
    cs = _run(dse.compute_scaling_study(
        cfg, SHAPE_1T, BASELINE_DGX_A100, 8, 128,
        compute_factors=(0.5, 1.0, 2.0, 4.0, 8.0),
        em_bandwidths_gbs=(500, 1000, 2000),
    )).pivot(index="compute_x", columns="bw_em_gbs")
    base = cs[1.0][2000]
    rows = []
    for f, row in cs.items():
        for bw, t in sorted(row.items()):
            claim = ("halving hurts more than doubling gains; diminishing"
                     if f in (0.5, 2.0) and bw == 2000 else "")
            rows.append(("fig10", f"compute_x{f}", f"norm@{int(bw)}GBs",
                         round(t / base, 3), claim))
    return rows


def _rows_fig11() -> List[Row]:
    """Fig 11: intra-/inter-pod bandwidth scaling."""
    cfg = get_config("transformer-1t")
    rows = []
    for (mp, dp) in ((64, 16), (8, 128)):
        ns = {(c.point["intra_x"], c.point["inter_x"]): c.record["total"]
              for c in _run(dse.network_scaling_study(
                  cfg, SHAPE_1T, BASELINE_DGX_A100, mp, dp))}
        base = ns[(1.0, 1.0)]
        for (fi, fo), t in sorted(ns.items()):
            claim = ("paper: 2x both => ~27% gain at MP64"
                     if (mp, fi, fo) == (64, 2.0, 2.0) else "")
            rows.append(("fig11", f"MP{mp}_DP{dp}",
                         f"norm@intra_x{fi}_inter_x{fo}",
                         round(t / base, 3), claim))
    return rows


def _rows_fig12() -> List[Row]:
    """Fig 12: fixed-aggregate bandwidth rebalance."""
    cfg = get_config("transformer-1t")
    rows = []
    for (mp, dp) in ((64, 16), (8, 128)):
        rb = {c.point["ratio"]: c.record["total"]
              for c in _run(dse.bandwidth_rebalance_study(
                  cfg, SHAPE_1T, BASELINE_DGX_A100, mp, dp))}
        base = rb[9.6]
        best = min(rb, key=rb.get)
        rows.append(("fig12", f"MP{mp}_DP{dp}", "best_ratio_1:r", best,
                     "paper: ~1:6 interior optimum" if mp == 64 else ""))
        for r, t in sorted(rb.items()):
            rows.append(("fig12", f"MP{mp}_DP{dp}", f"norm@1:{r}",
                         round(t / base, 3), ""))
    return rows


def _rows_fig13() -> List[Row]:
    """Fig 13: DLRM cluster-size sweep + memory-expansion turnaround."""
    dlrm = get_dlrm_config()
    rows = []
    sw = {c.point["nodes"]: c.record
          for c in _run(dse.dlrm_cluster_size_study(
              dlrm, BASELINE_DGX_A100, global_batch=65536))}
    for n, d in sw.items():
        rows.append(("fig13a", f"nodes{n}", "total_ms",
                     round(d["total"] * 1e3, 2), ""))
        rows.append(("fig13a", f"nodes{n}", "exposed_comm_ms",
                     round((d["fp_exposed_comm"] + d["ig_exposed_comm"]
                            + d["wg_exposed_comm"]) * 1e3, 2),
                     "comm shrinks once an instance fits one pod"
                     if n == 8 else ""))
    me = _run(dse.dlrm_memory_expansion_study(
        dlrm, BASELINE_DGX_A100, global_batch=65536,
    )).pivot(index="nodes_per_inst", columns="bw_em_gbs",
             values="turnaround")
    base = me[64][2000]
    for n, row in me.items():
        for bw, t in sorted(row.items()):
            claim = ("paper: ~1.5x with 1.5TB/s EM on small instances"
                     if (n, bw) == (8, 1500) else "")
            rows.append(("fig13b", f"nodes_per_inst{n}",
                         f"speedup@{int(bw)}GBs", round(base / t, 3), claim))
    return rows


def _rows_fig15() -> List[Row]:
    """Fig 15 / Table III: 11-cluster comparison (+ fork-pool speedup
    when --processes is given)."""
    tcfg = get_config("transformer-1t")
    cmp = dse.cluster_comparison(tcfg, SHAPE_1T, get_dlrm_config(),
                                 dlrm_batch=65536, processes=PROCESSES,
                                 engine=ENGINE)
    a0 = cmp["A0"]
    rows = []
    if PROCESSES and PROCESSES > 1:
        t_study, _ = dse.cluster_comparison_studies(
            tcfg, SHAPE_1T, get_dlrm_config(), 65536)
        t0 = time.monotonic()
        run_study(t_study, engine=ENGINE)
        t_serial = time.monotonic() - t0
        t0 = time.monotonic()
        run_study(t_study, processes=PROCESSES, engine=ENGINE)
        t_par = time.monotonic() - t0
        rows.append(("fig15", "engine", "fork_speedup",
                     round(t_serial / t_par, 2),
                     f"serial {t_serial:.1f}s vs {PROCESSES} procs "
                     f"{t_par:.1f}s on the fig15 transformer study"))
    for name, r in cmp.items():
        tf = a0["transformer-1t"] / r["transformer-1t"]
        dl = a0["dlrm"] / r["dlrm"]
        claim = {
            "B1": "paper: 7.2x transformer",
            "C1": "paper: 12.5x transformer",
            "C2": "paper: 14.3x transformer / 2.7x dlrm",
            "A2": "paper: 1.8x dlrm; A2/A1 ~ 1.64x",
        }.get(name, "")
        rows.append(("fig15", name, "transformer_speedup", round(tf, 2),
                     claim))
        rows.append(("fig15", name, "dlrm_speedup", round(dl, 2), ""))
        rows.append(("fig15", name, "avg_speedup", round((tf + dl) / 2, 2),
                     "paper: best GPU avg ~7.7x (C-class)"
                     if name == "C0" else ""))
    return rows


def _rows_pp_ep() -> List[Row]:
    """Beyond Fig. 8: MoE transformer over the native MP x DP x PP x EP
    product on a bandwidth-starved (A0) and a memory-expanded (B1) cluster
    (ISSUE 3 tentpole: PP stages + EP expert sharding in the default
    workload builder)."""
    ranked = dse.pp_ep_ranking(processes=PROCESSES, engine=ENGINE)
    rows = []
    for cl in ("A0", "B1"):
        per = [r for r in ranked if r["cluster"] == cl]
        if not per:
            rows.append(("pp_ep", cl, "best_strategy", "infeasible",
                         "no four-axis cell fits this cluster"))
            continue
        best = per[0]
        base = next((r for r in per if r["pp"] == 1 and r["ep"] == 1), None)
        rows.append(("pp_ep", cl, "best_strategy", best["strategy"],
                     "best cell should use pp>1 or ep>1 on A0/B1"))
        if base is not None:
            rows.append(("pp_ep", cl, "speedup_vs_best_mpdp",
                         round(base["total"] / best["total"], 3),
                         "four-axis sweep beats the MP x DP slice"))
        for r in per[:5]:
            rows.append(("pp_ep", cl, f"total_s@{r['strategy']}",
                         round(r["total"], 3),
                         f"bubble={round(r['bubble_fraction'], 3)}"))
    return rows


def _rows_v5e_archs() -> List[Row]:
    """Beyond paper: COMET analytics for the 10 assigned archs on the
    production v5e pod (the analytical cross-check of the dry-run table)."""
    from repro.configs import ASSIGNED_ARCHS
    rows = []
    shape = SHAPES["train_4k"]
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        wl = decompose(cfg, shape, mp=16, dp=16)
        br = simulate_iteration(wl, TPU_V5E_POD)
        d = br.as_dict()
        rows.append(("v5e-comet", arch, "iter_s", round(d["total"], 3), ""))
        rows.append(("v5e-comet", arch, "exposed_comm_s",
                     round(d["fp_exposed_comm"] + d["ig_exposed_comm"]
                           + d["wg_exposed_comm"], 3), ""))
        rows.append(("v5e-comet", arch, "tokens_per_s_per_chip",
                     round(shape.tokens / max(d["total"], 1e-9) / 256, 1),
                     ""))
    return rows


def _rows_placement() -> List[Row]:
    """ISSUE 4 tentpole: EM-aware pipeline-stage placement on mixed
    A100+EM fleets — perf-per-TCO-dollar of the best cell per (EM-pod
    fraction, placement), plus the study's wall-clock."""
    t0 = time.monotonic()
    ranked = dse.placement_ranking(processes=PROCESSES, engine=ENGINE)
    dt = time.monotonic() - t0
    best: dict = {}
    for r in ranked:   # ranked best-first: first hit per key wins
        best.setdefault((r["em_pod_frac"], r["placement"]), r)
    rows = [("placement", "study", "wallclock_s", round(dt, 1),
             f"{len(ranked)} feasible cells")]
    top = ranked[0] if ranked else None
    if top is not None:
        rows.append(("placement", "best", "cell",
                     f"em{top['em_pod_frac']}_{top['placement']}_"
                     f"{top['strategy']}",
                     "mixed fleet + em-aware should top perf/$"))
    for (frac, pl), r in sorted(best.items()):
        rows.append(("placement", f"em{frac}_{pl}", "perf_per_tco_usd",
                     f"{r['perf_per_dollar']:.3e}",
                     "partial EM wasted under paper placement"
                     if pl == "paper" and 0 < frac < 1 else ""))
        rows.append(("placement", f"em{frac}_{pl}", "best_total_s",
                     round(r["total"], 2), r["strategy"]))
    mt = dse.multi_tenant_ranking()
    for r in mt[:3]:
        rows.append(("placement", f"tenant_npi{r['nodes_per_inst']}"
                     f"_{r['placement']}", "turnaround_ms",
                     round(r["turnaround"] * 1e3, 2),
                     "em-aware schedules hungry instances on EM pods"))
    return rows


def _rows_serving() -> List[Row]:
    """ISSUE 7 tentpole: serving-fleet DSE — colocated vs disaggregated
    prefill/decode goodput-per-dollar over the em_pod_frac x rate grid,
    plus the study's wall-clock."""
    t0 = time.monotonic()
    ranked = dse.serving_ranking(processes=PROCESSES)
    dt = time.monotonic() - t0
    rows = [("serving", "study", "wallclock_s", round(dt, 1),
             f"{len(ranked)} feasible cells")]
    top = ranked[0] if ranked else None
    if top is not None:
        rows.append(("serving", "best", "cell",
                     f"em{top['em_pod_frac']}_rate{int(top['rate'])}_"
                     f"{top['placement']}",
                     "disaggregated should top goodput/$ at high rate"))
    best: dict = {}
    for r in ranked:   # ranked best-first: first hit per key wins
        best.setdefault((r["rate"], r["placement"]), r)
    for (rate, pl), r in sorted(best.items()):
        rows.append(("serving", f"rate{int(rate)}_{pl}",
                     "goodput_per_tco_usd",
                     f"{r['goodput_per_dollar']:.3e}",
                     "colocated prefill stalls blow the TPOT SLO here"
                     if pl == "colocated" and rate == max(
                         k[0] for k in best) else ""))
        rows.append(("serving", f"rate{int(rate)}_{pl}", "tpot_ms",
                     round(r["tpot"] * 1e3, 1),
                     f"em_pod_frac={r['em_pod_frac']}"))
    return rows


def _rows_fleet() -> List[Row]:
    """ISSUE 9 tentpole: elastic-fleet DSE — the mixed-tenant trace
    replayed under each fleet policy on the half-EM fleet, ranked by
    turnaround-p99, plus the elastic+burst-vs-static headline ratios."""
    t0 = time.monotonic()
    ranked = dse.fleet_ranking(processes=PROCESSES)
    dt = time.monotonic() - t0
    rows = [("fleet", "study", "wallclock_s", round(dt, 1),
             f"{len(ranked)} feasible policy cells")]
    for r in ranked:
        rows.append(("fleet", r["policy"], "turnaround_p99_s",
                     round(r["turnaround_p99"], 1),
                     "timeline policies beat the static allocation"
                     if r["policy"] != "static" else ""))
        rows.append(("fleet", r["policy"], "perf_per_tco_usd",
                     f"{r['perf_per_dollar']:.3e}",
                     f"pre={r['preemptions']} rs={r['resize_events']} "
                     f"bu={r['burst_events']}"))
    if ranked:
        head = dse.fleet_headline(ranked)
        rows.append(("fleet", "headline", "p99_win_x",
                     round(head["turnaround_p99_ratio"], 2),
                     "elastic+burst >= 1.3x over static (ISSUE 9)"))
        rows.append(("fleet", "headline", "perf_per_dollar_win_x",
                     round(head["perf_per_dollar_ratio"], 2), ""))
    return rows


def _rows_reliability() -> List[Row]:
    """ISSUE 10 tentpole: failure-aware cluster DSE — the Daly-vs-naive
    checkpoint cadence win, the goodput-per-dollar ranking flip between
    the two closed-form cluster designs, and the wait-vs-shrink
    turnaround-p99 fault-injection headline."""
    t0 = time.monotonic()
    ranked = dse.reliability_ranking(processes=PROCESSES)
    dt = time.monotonic() - t0
    rows = [("reliability", "study", "wallclock_s", round(dt, 1),
             f"{len(ranked)} feasible cells")]
    for r in ranked:
        key = (f"{r['cluster']}_mtbf{r['mtbf_hours']:g}"
               f"_int{r['ckpt_interval']:g}")
        rows.append(("reliability", key, "goodput_per_tco_usd",
                     f"{r['goodput_per_dollar']:.3e}", ""))
        rows.append(("reliability", key, "goodput_frac",
                     round(r["goodput_frac"], 4),
                     f"restarts={round(r['expected_restarts'], 1)}"))
    head = dse.reliability_headline(ranked)
    rows.append(("reliability", "headline", "daly_vs_naive_x",
                 round(head["daly_vs_naive"], 3),
                 "Young-Daly cadence beats the naive fixed interval"))
    rows.append(("reliability", "headline", "ranking_flips",
                 head["ranking_flips"],
                 f"failure-free {head['best_failure_free']} vs "
                 f"failure-aware {head['best_failure_aware']}"))
    fleet_ranked = dse.reliability_fleet_ranking(processes=PROCESSES)
    fhead = dse.reliability_fleet_headline(fleet_ranked)
    for r in fleet_ranked:
        rows.append(("reliability", f"fleet_{r['degradation']}",
                     "turnaround_p99_s", round(r["turnaround_p99"], 1),
                     f"failures={r['failures']} "
                     f"goodput={round(r['goodput'], 3)}"))
    rows.append(("reliability", "headline", "shrink_vs_wait_p99_x",
                 round(fhead["p99_ratio"], 2),
                 "shrink-to-survive beats wait-for-repair (ISSUE 10)"))
    return rows


def _rows_tco() -> List[Row]:
    """Beyond paper: heterogeneous A100+EM pod mix ranked perf-per-dollar
    (§V-D's qualitative perf/$ argument, quantified)."""
    tcfg = get_config("transformer-1t")
    ranked = dse.hetero_cost_ranking(
        tcfg, SHAPE_1T, processes=PROCESSES, engine=ENGINE,
        em_pod_fractions=(0.0, 0.5, 1.0),
        strategies=[(64, 16), (16, 64), (8, 128)])
    rows = []
    for i, r in enumerate(ranked):
        claim = ("full EM + small MP should lead (B1-vs-B0, Fig. 15)"
                 if i == 0 else "")
        rows.append(("tco", f"em{r['em_pod_frac']}_{r['strategy']}",
                     "perf_per_tco_usd", f"{r['perf_per_dollar']:.3e}",
                     claim))
        rows.append(("tco", f"em{r['em_pod_frac']}_{r['strategy']}",
                     "tco_musd", round(r["tco"] / 1e6, 2), ""))
    return rows


BENCHES = {
    "fig6": _rows_fig6,
    "fig8": _rows_fig8,
    "fig9": _rows_fig9,
    "fig10": _rows_fig10,
    "fig11": _rows_fig11,
    "fig12": _rows_fig12,
    "fig13": _rows_fig13,
    "fig15": _rows_fig15,
    "pp_ep": _rows_pp_ep,
    "placement": _rows_placement,
    "serving": _rows_serving,
    "fleet": _rows_fleet,
    "reliability": _rows_reliability,
    "tco": _rows_tco,
    "v5e-comet": _rows_v5e_archs,
}


# --------------------------------------------------------------------- #
# Engine perf trajectory (--json): fig15 transformer study, reference vs
# compiled, serial vs fork pool, with a record-equivalence check.  The
# CI bench smoke runs the --smoke grid and fails if the compiled engine
# is not at least as fast as the reference on it.
# --------------------------------------------------------------------- #

SMOKE_CLUSTERS = ("A0", "B0", "B1", "C2")


def _max_rel_err(ref, comp) -> float:
    worst = 0.0
    for ra, rb in zip(ref.records, comp.records):
        for k, va in ra.items():
            vb = rb[k]
            if isinstance(va, float) and isinstance(vb, float):
                if not (math.isfinite(va) and math.isfinite(vb)):
                    # inf/nan must agree exactly (infeasible markers);
                    # one-sided nan/inf is a divergence, not a skip.
                    if str(va) != str(vb):
                        return float("inf")
                    continue
                worst = max(worst,
                            abs(va - vb) / max(abs(va), abs(vb), 1e-30))
            elif va != vb:
                raise AssertionError(
                    f"engines disagree on non-float column {k!r}: "
                    f"{va!r} vs {vb!r}")
    return worst


def perf_trajectory(processes: int = 8, smoke: bool = False) -> dict:
    """Wall-clock the fig15 transformer study through both engines.

    Returns the BENCH_5-format dict: seconds per (engine, processes) leg,
    derived speedups, and the compiled-vs-reference max relative record
    error.  ``smoke`` restricts the cluster axis to a 4-entry grid so the
    CI job finishes in seconds."""
    from repro.core.cluster import TABLE_III_CLUSTERS
    tcfg = get_config("transformer-1t")
    clusters = ({k: TABLE_III_CLUSTERS[k] for k in SMOKE_CLUSTERS}
                if smoke else None)
    study, _ = dse.cluster_comparison_studies(
        tcfg, SHAPE_1T, get_dlrm_config(), 65536, clusters=clusters)

    def best_of(n, **kw):
        best, result = float("inf"), None
        for _ in range(n):
            t0 = time.monotonic()
            result = run_study(study, **kw)
            best = min(best, time.monotonic() - t0)
        return best, result

    run_study(study, engine="compiled")        # warm imports / caches
    reps = 1 if smoke else 2
    t_ref, ref = best_of(reps, engine="reference")
    t_comp, comp = best_of(reps, engine="compiled")
    # Fork-pool legs run before anything touches JAX: os.fork() after
    # the jit runtime spins up its thread pool is deadlock-prone.
    t_ref_p, _ = best_of(reps, engine="reference", processes=processes)
    t_comp_p, comp_p = best_of(reps, engine="compiled", processes=processes)
    run_study(study, engine="jax")             # warm jit compiles
    t_jax, jaxr = best_of(reps, engine="jax")
    assert comp.records == comp_p.records, \
        "compiled engine: fork and serial records differ"
    serving = _serving_trajectory(smoke=smoke)
    fleet = _fleet_trajectory(smoke=smoke)
    reliability = _reliability_trajectory(smoke=smoke)
    return {
        "bench": "fig15-transformer" + ("-smoke" if smoke else ""),
        "cells": len(ref),
        "processes": processes,
        "reference_serial_s": round(t_ref, 3),
        "compiled_serial_s": round(t_comp, 3),
        "jax_serial_s": round(t_jax, 3),
        "reference_procs_s": round(t_ref_p, 3),
        "compiled_procs_s": round(t_comp_p, 3),
        "compiled_serial_speedup": round(t_ref / t_comp, 2),
        "compiled_procs_speedup_vs_reference_serial":
            round(t_ref / t_comp_p, 2),
        "compiled_procs_speedup_vs_reference_procs":
            round(t_ref_p / t_comp_p, 2),
        "max_rel_err": _max_rel_err(ref, comp),
        "jax_max_rel_err": _max_rel_err(ref, jaxr),
        "jax_grid": _jax_grid_trajectory(smoke=smoke),
        "serving": serving,
        "fleet": fleet,
        "reliability": reliability,
    }


def _jax_grid_trajectory(smoke: bool = False) -> dict:
    """The ISSUE 8 acceptance grid: the fig15 transformer strategies
    against a dense (peak_flops x local_bw x intra_bw) scaling
    cross-product — 12,288 cells full (3 x 16^3), 1,536 smoke — timed
    through ``time_compiled`` on the NumPy vs the jit/vmap backend.

    The study-level fig15 legs share per-cell Python costs (record
    assembly, spec plumbing) that cap any engine ratio near 1x; this leg
    times the evaluator itself, where the jit/vmap path must be >= 3x
    the PR-5 serial engine.  Divergence is checked two ways: jax vs the
    NumPy engine on every grid cell, and jax vs the *reference* event
    loop on an 8-environment subgrid per strategy (the reference walk is
    per-cell Python, pricing the full grid with it would take hours)."""
    import dataclasses
    import itertools

    from repro.core import simulator

    tcfg = get_config("transformer-1t")
    base = BASELINE_DGX_A100
    n = 8 if smoke else 16
    step = 4.0 / n

    def env(i: int, j: int, k: int):
        node = dataclasses.replace(
            base.node,
            peak_flops=base.node.peak_flops * (0.5 + step * i),
            local_bw=base.node.local_bw * (0.5 + step * j))
        topo = dataclasses.replace(
            base.topology,
            intra_bw=base.topology.intra_bw * (0.5 + step * k))
        return node, topo

    envs = [env(i, j, k)
            for i, j, k in itertools.product(range(n), repeat=3)]
    strategies = ((64, 16), (16, 64), (8, 128))
    wls = [decompose(tcfg, SHAPE_1T, mp=mp, dp=dp)
           for mp, dp in strategies]
    cws = [wl.compiled() for wl in wls]

    def leg(backend: str):
        return [simulator.time_compiled(cw, envs, backend=backend)
                for cw in cws]

    leg("numpy")
    leg("jax")                       # warm: imports + jit compiles

    def best_of(backend: str, reps: int = 3):
        best, out = float("inf"), None
        for _ in range(reps):
            t0 = time.monotonic()
            out = leg(backend)
            best = min(best, time.monotonic() - t0)
        return best, out

    t_np, np_out = best_of("numpy")
    t_jx, jx_out = best_of("jax")

    def err(pairs) -> float:
        worst = 0.0
        for a, b in pairs:
            da, db = a.as_dict(), b.as_dict()
            for key, va in da.items():
                vb = db[key]
                if not (math.isfinite(va) and math.isfinite(vb)):
                    if str(va) != str(vb):
                        return float("inf")
                    continue
                worst = max(worst,
                            abs(va - vb) / max(abs(va), abs(vb), 1e-30))
        return worst

    err_np = max(err(zip(a, b)) for a, b in zip(np_out, jx_out))
    sub = envs[:: max(1, len(envs) // 8)][:8]
    err_ref = 0.0
    for wl, cw in zip(wls, cws):
        jx = simulator.time_compiled(cw, sub, backend="jax")
        ref = [simulate_iteration(
            wl, dataclasses.replace(base, node=node, topology=topo))
            for node, topo in sub]
        err_ref = max(err_ref, err(zip(ref, jx)))
    return {
        "cells": len(envs) * len(strategies),
        "compiled_serial_s": round(t_np, 3),
        "jax_s": round(t_jx, 3),
        "jax_speedup": round(t_np / t_jx, 2),
        "max_rel_err_vs_compiled": err_np,
        "max_rel_err_vs_reference": err_ref,
    }


def _serving_trajectory(smoke: bool = False) -> dict:
    """Serving leg of the perf artifact: colocated vs disaggregated
    goodput-per-dollar at the grid's top rate, plus wall-clock.  The CI
    smoke gate asserts both placements produce goodput and the study
    stays fast."""
    kwargs = (dict(em_pod_fractions=(0.0, 0.5), rates=(120.0, 440.0),
                   num_requests=800) if smoke else {})
    t0 = time.monotonic()
    ranked = dse.serving_ranking(**kwargs)
    dt = time.monotonic() - t0
    top_rate = max(r["rate"] for r in ranked) if ranked else 0.0

    def best(placement: str) -> float:
        return max((r["goodput_per_dollar"] for r in ranked
                    if r["placement"] == placement
                    and r["rate"] == top_rate), default=0.0)

    return {
        "wallclock_s": round(dt, 3),
        "cells": len(ranked),
        "top_rate": top_rate,
        "colocated_goodput_per_dollar": best("colocated"),
        "disaggregated_goodput_per_dollar": best("disaggregated"),
    }


def _fleet_trajectory(smoke: bool = False) -> dict:
    """Fleet leg of the perf artifact: timeline replay speed
    (events/sec over every policy cell) plus the elastic+burst-vs-static
    headline ratio the CI smoke gate asserts stays >= 1.3x."""
    from repro.core.study import run_study
    spec = dse.fleet_study(**(dict(num_jobs=8) if smoke else {}))
    t0 = time.monotonic()
    res = run_study(spec)
    dt = time.monotonic() - t0
    records = [c.record for c in res]
    feasible = [r for r in records if r["feasible"]]
    events = sum(r["n_events"] for r in records)
    head = (dse.fleet_headline(feasible)
            if {"static", "elastic+burst"}
            <= {r["policy"] for r in feasible} else {})
    return {
        "wallclock_s": round(dt, 3),
        "cells": len(records),
        "timeline_events": events,
        "events_per_sec": round(events / dt, 1) if dt > 0 else 0.0,
        "jobs_completed": sum(r["jobs_completed"] for r in feasible),
        "headline_ratio": round(max(
            head.get("turnaround_p99_ratio", 0.0),
            head.get("perf_per_dollar_ratio", 0.0)), 3),
    }


def _reliability_trajectory(smoke: bool = False) -> dict:
    """Reliability leg of the perf artifact: the closed-form Daly-vs-
    naive goodput win and the fault-injection shrink-vs-wait p99 win the
    CI smoke gate asserts stay >= 1x, plus both studies' wall-clock."""
    t0 = time.monotonic()
    ranked = dse.reliability_ranking()
    head = dse.reliability_headline(ranked)
    fleet_kwargs = dict(num_iters_scale=0.5) if smoke else {}
    fhead = dse.reliability_fleet_headline(
        dse.reliability_fleet_ranking(**fleet_kwargs))
    dt = time.monotonic() - t0
    return {
        "wallclock_s": round(dt, 3),
        "cells": len(ranked),
        "daly_vs_naive": round(head["daly_vs_naive"], 3),
        "ranking_flips": head["ranking_flips"],
        "shrink_vs_wait_p99": round(fhead["p99_ratio"], 3),
        "shrink_goodput": round(fhead["shrink_goodput"], 4),
        "wait_goodput": round(fhead["wait_goodput"], 4),
    }


def main() -> None:
    global PROCESSES, ENGINE
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--processes", type=int, default=None,
                    help="fan study cells over a fork pool (POSIX)")
    ap.add_argument("--engine", default="reference",
                    choices=("reference", "compiled", "jax"),
                    help="study evaluator for every bench (docs/perf.md)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the engine perf trajectory (fig15 serial "
                         "vs compiled vs compiled+fork) to PATH and exit")
    ap.add_argument("--smoke", action="store_true",
                    help="with --json: small 4-cluster grid for CI")
    args = ap.parse_args()
    PROCESSES = args.processes
    ENGINE = args.engine
    if args.json:
        out = perf_trajectory(processes=args.processes or 8,
                              smoke=args.smoke)
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
            f.write("\n")
        for k, v in out.items():
            print(f"{k}: {v}")
        return
    print("figure,key,metric,value,paper_claim,bench_ms")
    for name, fn in BENCHES.items():
        if args.only and args.only != name:
            continue
        t0 = time.monotonic()
        rows = fn()
        dt_ms = (time.monotonic() - t0) * 1e3
        for i, (fig, key, metric, value, claim) in enumerate(rows):
            stamp = round(dt_ms, 1) if i == 0 else ""
            print(f'{fig},{key},{metric},{value},"{claim}",{stamp}')


if __name__ == "__main__":
    main()
