"""Design-space exploration example: evaluate a NEW cluster you are
considering building — the core COMET use case, on the declarative
Study API (repro.core.study).

Here: would a hypothetical v5e-like pod with double HBM bandwidth, or one
with CXL-style 1TB/s expanded memory, train the assigned archs faster?
Each upgrade is one value of a single "variant" Axis; dotted-path
overrides ("node.local_bw", "topology.link_bw") replace hand-rolled
``dataclasses.replace`` loops.

Run: PYTHONPATH=src python examples/cluster_dse.py
"""

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core.cluster import TPU_V5E_POD
from repro.core.study import (
    Axis,
    ParallelSpec,
    StudySpec,
    run_study,
    set_by_path,
)

GB = 1e9
shape = SHAPES["train_4k"]

VARIANTS = {
    "v5e-pod (baseline)": lambda cl: cl,
    "2x HBM bandwidth": lambda cl: set_by_path(cl, "node.local_bw", 2 * 819e9),
    "+CXL 1TB/s x 64GB": lambda cl: cl.with_node(
        cl.node.with_expansion(cap=64 * GB, bw=1000 * GB)),
    "2x ICI bandwidth": lambda cl: set_by_path(cl, "topology.link_bw", 100e9),
}


def upgrade_study(arch: str) -> StudySpec:
    return StudySpec(
        name=f"v5e-upgrade:{arch}",
        model=get_config(arch), shape=shape, cluster=TPU_V5E_POD,
        strategies=ParallelSpec(mp=16, dp=16),
        axes=[Axis("variant", tuple(VARIANTS),
                   apply=lambda cl, v: VARIANTS[v](cl))])


archs = ["internlm2-20b", "llama4-maverick-400b-a17b", "mamba2-780m",
         "internvl2-76b"]
print(f"{'arch':<28}" + "".join(f"{v:>22}" for v in VARIANTS))
for arch in archs:
    res = run_study(upgrade_study(arch))
    base = res.cells[0].record["total"]
    row = f"{arch:<28}"
    for c in res:
        t = c.record["total"]
        row += f"{t:>14.2f}s ({base/t:4.2f}x)"
    print(row)

print("\nReading: speedup vs baseline per cluster variant — the COMET "
      "answer to 'which upgrade moves which workload'.")
