"""Design-space exploration example: evaluate a NEW cluster you are
considering building — the core COMET use case.

Here: would a hypothetical v5e-like pod with double HBM bandwidth, or one
with CXL-style 1TB/s expanded memory, train the assigned archs faster?

Run: PYTHONPATH=src python examples/cluster_dse.py
"""

import dataclasses

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import SHAPES
from repro.core.cluster import TPU_V5E_POD
from repro.core.simulator import simulate_iteration
from repro.core.workload import decompose

GB = 1e9
shape = SHAPES["train_4k"]

variants = {
    "v5e-pod (baseline)": TPU_V5E_POD,
    "2x HBM bandwidth": TPU_V5E_POD.with_node(
        dataclasses.replace(TPU_V5E_POD.node, local_bw=2 * 819e9)),
    "+CXL 1TB/s x 64GB": TPU_V5E_POD.with_node(
        TPU_V5E_POD.node.with_expansion(cap=64 * GB, bw=1000 * GB)),
    "2x ICI bandwidth": TPU_V5E_POD.with_topology(
        dataclasses.replace(TPU_V5E_POD.topology, link_bw=100e9)),
}

archs = ["internlm2-20b", "llama4-maverick-400b-a17b", "mamba2-780m",
         "internvl2-76b"]
print(f"{'arch':<28}" + "".join(f"{v:>22}" for v in variants))
for arch in archs:
    cfg = get_config(arch)
    wl = decompose(cfg, shape, mp=16, dp=16)
    row = f"{arch:<28}"
    base = None
    for name, cl in variants.items():
        t = simulate_iteration(wl, cl).total
        base = base or t
        row += f"{t:>14.2f}s ({base/t:4.2f}x)"
    print(row)

print("\nReading: speedup vs baseline per cluster variant — the COMET "
      "answer to 'which upgrade moves which workload'.")
