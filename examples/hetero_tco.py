"""Heterogeneous-cluster + cost/TCO example: the COMET §V-D
perf-per-dollar question, made quantitative.

Should you buy expanded memory for none, half, or all of a 64-pod A100
cluster?  Each mix is one ``ClusterSpec`` (plain pods + memory-expanded
pods over the same interconnect); the cost model prices nodes, HBM,
expanded memory, links and energy, and the study engine emits
``cost_usd`` / ``tco`` / ``perf_per_dollar`` columns per cell.

Synchronous-training semantics: every node holds the same shard, so a
strategy is feasible only if it fits the *least-capable* pod group — the
study shows partial EM deployment buys nothing for one big synchronous
job (you pay for EM the small-MP strategies still can't use), while full
EM unlocks MP8_DP128 and wins perf-per-dollar outright.

Run: PYTHONPATH=src python examples/hetero_tco.py
"""

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.cluster import get_cluster
from repro.core.dse import hetero_cost_study
from repro.core.study import run_study

cfg = get_config("transformer-1t")
shape = ShapeConfig("paper", 2048, 1024, "train")

res = run_study(hetero_cost_study(
    cfg, shape, em_pod_fractions=(0.0, 0.25, 0.5, 1.0),
    strategies=[(64, 16), (32, 32), (16, 64), (8, 128)]))

print(f"{'em_frac':>8} {'strategy':>12} {'feasible':>9} {'iter_s':>8} "
      f"{'capex_M$':>9} {'tco_M$':>8} {'perf/$':>11}")
for c in res:
    r = c.record
    print(f"{r['em_pod_frac']:>8} {r['strategy']:>12} "
          f"{str(r['feasible']):>9} {r['total']:>8.2f} "
          f"{r['cost_usd'] / 1e6:>9.2f} {r['tco'] / 1e6:>8.2f} "
          f"{r['perf_per_dollar']:>11.3e}")

best = res.select(feasible=True).best("perf_per_dollar", maximize=True)
print(f"\nBest perf-per-TCO-dollar: {best.record['strategy']} at "
      f"em_pod_frac={best.record['em_pod_frac']} "
      f"({best.record['perf_per_dollar']:.3e} iters/s/$).")

# The same cost knobs are sweepable axes: how cheap must EM get before the
# all-EM cluster beats B0 on *capex* alone?  (cost.usd_per_gb_em is a
# dotted path into the frozen config tree, like any other Axis.)
b1 = get_cluster("B1")
print(f"\nB1 capex at $8/GB EM: ${b1.cost.capex(b1) / 1e6:.1f}M "
      f"(vs B0 ${get_cluster('B0').cost.capex(get_cluster('B0')) / 1e6:.1f}M)"
      " — sweep Axis('em_usd', values, path='cost.usd_per_gb_em') to find"
      " the break-even price.")
