"""EM-aware placement: when does a *partially* memory-expanded fleet win?

PR 2's heterogeneous cost study answered "never" — under the paper's
fixed placement every pod group must hold every shard, so a mixed
A100 + EM fleet is gated by its plain pods and partial EM is money
wasted (only all-EM pays off).  This example sweeps the same fleet mix
with the placement itself as a study axis:

  * ``PaperPlacement``   — the fixed MP->EP->DP->PP mapping (default);
  * ``EMAwarePlacement`` — memory-hungry pipeline stages go to the EM
    pods, each stage gated by *its own* group.

The punchline: with stages placed memory-aware, a half-EM fleet runs
the ZeRO-heavy low-MP pipeline strategies the plain fleet cannot fit at
nearly all-EM speed but well below all-EM TCO — and tops
perf-per-dollar over both endpoints.  A second, multi-tenant sweep
(Fig. 13b generalized) shows the same lever for DLRM instances: the
scheduler places memory-hungry small instances on the EM pods only.

Run: PYTHONPATH=src python examples/placement_study.py
"""

from repro.core import dse

# ----- single-job pipeline placement: perf/$ over (EM fraction, placement)
ranked = dse.placement_ranking()
best = {}
for r in ranked:                       # best-first: first hit per key wins
    best.setdefault((r["em_pod_frac"], r["placement"]), r)

print("=== Transformer-1T pipeline stages on a B0 (plain) + B1 (EM) mix ===")
print(f"{'em_frac':>8}{'placement':>11}{'best cell':>20}{'iter_s':>9}"
      f"{'TCO_M$':>8}{'perf/$':>12}")
for (frac, pl), r in sorted(best.items()):
    print(f"{frac:>8}{pl:>11}{r['strategy']:>20}{r['total']:>9.1f}"
          f"{r['tco'] / 1e6:>8.1f}{r['perf_per_dollar']:>12.3e}")

top = ranked[0]
print(f"\nWinner: {top['em_pod_frac']:.0%} EM pods under "
      f"{top['placement']} placement ({top['strategy']}) — beats all-plain "
      "and all-EM on perf-per-TCO-dollar; the same fraction under the "
      "paper placement cannot even fit these strategies.")

# ----- multi-tenant: 8 DLRM instances on a half-EM 64-node fleet
print("\n=== 8 DLRM instances on a half-EM fleet (Fig. 13b, generalized) ===")
from repro.core.study import run_study   # noqa: E402

res = run_study(dse.multi_tenant_study())
print(f"{'nodes/inst':>11}{'placement':>11}{'feasible':>10}{'conc':>6}"
      f"{'waves':>7}{'turnaround_ms':>15}")
for c in res:
    r = c.record
    print(f"{r['nodes_per_inst']:>11}{r['placement']:>11}"
          f"{str(r['feasible']):>10}{r['concurrent_instances']:>6}"
          f"{r['waves']:>7}{r['turnaround'] * 1e3:>15.2f}")

print("\nReading: the paper placement spreads instances over pods that "
      "cannot hold them (nothing feasible on the mixed fleet); the "
      "EM-aware scheduler confines the memory-hungry instances to the EM "
      "pods — fewer concurrent, more waves, but actually runnable.")
