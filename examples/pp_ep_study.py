"""Four-axis parallelization sweep: MP x DP x PP x EP on one engine.

COMET's §V methodology jointly sweeps parallelization strategies and
cluster resources, but the paper's strategy axis stops at (MP, DP).  This
example runs the full Megatron-style four-axis product — pipeline stages
with their microbatch bubble and p2p boundary transfers, expert-parallel
MoE sharding with all-to-all dispatch/combine — through the *default*
analytical workload builder: no custom ``StudySpec.workload`` needed.

The punchline: on a bandwidth-starved cluster (Table III "A0"), pipeline
and expert degrees beat every pure MP x DP strategy, because p2p boundary
traffic and EP all-to-alls are far cheaper than giant MP all-reduces over
a 6.25 GB/s inter-pod network.

Run: PYTHONPATH=src python examples/pp_ep_study.py
"""

from repro.core import dse

ranked = dse.pp_ep_ranking(clusters=("A0", "B1"))

for cluster in ("A0", "B1"):
    per = [r for r in ranked if r["cluster"] == cluster]
    if not per:
        print(f"\n=== {cluster}: no feasible four-axis cell ===")
        continue
    print(f"\n=== {cluster}: top 5 of {len(per)} feasible four-axis cells ===")
    print(f"{'strategy':<26}{'iter_s':>9}{'bubble':>8}{'microbatches':>14}")
    for r in per[:5]:
        print(f"{r['strategy']:<26}{r['total']:>9.2f}"
              f"{r['bubble_fraction']:>8.3f}{r['num_microbatches']:>14}")
    best_mpdp = next((r for r in per if r["pp"] == 1 and r["ep"] == 1), None)
    if best_mpdp is not None:
        print(f"best MP x DP-only cell: {best_mpdp['strategy']} "
              f"({best_mpdp['total']:.2f}s) -> four-axis best is "
              f"{best_mpdp['total'] / per[0]['total']:.2f}x faster")

print("\nReading: the paper's (MP, DP) slice leaves performance on the "
      "table once PP bubbles and EP all-to-alls are modeled natively.")
