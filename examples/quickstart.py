"""Quickstart: the COMET methodology in ~40 lines.

1. Pick a model + cluster.
2. Sweep (MP, DP) parallelization strategies (paper Fig. 8).
3. Ask a what-if: how much expanded-memory bandwidth makes the
   memory-hungry strategy worthwhile? (paper Fig. 9 / Ex. 1)

Run: PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.cluster import BASELINE_DGX_A100
from repro.core.dse import memory_expansion_heatmap, mpdp_sweep
from repro.core.memory import per_node_footprint
from repro.core.workload import decompose

GB = 1e9

model = get_config("transformer-1t")
shape = ShapeConfig("train", seq_len=2048, global_batch=1024, kind="train")
cluster = BASELINE_DGX_A100

print(f"model: {model.arch_id} ({model.param_count()/1e12:.2f}T params)")
print(f"cluster: {cluster.name} ({cluster.num_nodes} x {cluster.node.name})\n")

# ---- step 2: strategy sweep -------------------------------------------
results = mpdp_sweep(model, shape, cluster)
print(f"{'strategy':>14} {'iter_s':>9} {'exposed_comm_s':>15} {'mem_GB':>8}")
for r in results:
    d = r.breakdown.as_dict()
    comm = d["fp_exposed_comm"] + d["ig_exposed_comm"] + d["wg_exposed_comm"]
    print(f"{r.label:>14} {d['total']:9.2f} {comm:15.2f} "
          f"{r.footprint_bytes/GB:8.1f}")
best = min(results, key=lambda r: r.total)
print(f"\nbest strategy: {best.label} "
      f"(paper's answer: MP8_DP128)\n")

# ---- step 3: memory-expansion what-if ---------------------------------
wl = decompose(model, shape, mp=64, dp=16)
baseline = [r for r in results if r.label == "MP64_DP16"][0]
need = per_node_footprint(decompose(model, shape, mp=8, dp=128),
                          cluster.node).total
print(f"MP8_DP128 needs {need/GB:.0f} GB/node (local: "
      f"{cluster.node.local_cap/GB:.0f} GB) -> requires memory expansion")
hm = memory_expansion_heatmap(model, shape, cluster,
                              em_bandwidths_gbs=(100, 250, 500, 1000, 2000),
                              strategies=[(8, 128)])
print(f"{'EM bandwidth':>14} {'runtime vs MP64_DP16 baseline':>30}")
for bw, t in sorted(hm["MP8_DP128"].items()):
    tag = "  <- expansion wins" if t < baseline.total else ""
    print(f"{bw:>11.0f} GB/s {t/baseline.total:>24.2f}x{tag}")
