"""Serving example: batched requests through the continuous-batching engine.

Mixed prompt lengths, staggered admission, greedy decoding — and a
self-check that multi-slot batching reproduces single-request decoding
exactly.

Run: PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.serve import Engine, EngineConfig, Request

cfg = get_config("smollm-135m", reduced=True)
model = get_model(cfg)
rng = jax.random.PRNGKey(0)
params = model.init_params(rng, cfg, dtype=jnp.float32)

engine = Engine(cfg, params, EngineConfig(max_batch=4, max_seq=96),
                dtype=jnp.float32)
rs = np.random.RandomState(0)
t0 = time.monotonic()
for i in range(10):
    plen = int(rs.randint(3, 20))
    engine.submit(Request(uid=i,
                          prompt=rs.randint(0, cfg.vocab_size, plen),
                          max_new_tokens=12))
done = engine.run_until_drained()
dt = time.monotonic() - t0
tok = sum(len(r.out_tokens) for r in done)
print(f"served {len(done)} requests / {tok} tokens in {dt:.2f}s "
      f"({tok/dt:.0f} tok/s on CPU)")

# self-check: slot batching == single-request decode
req0 = [r for r in done if r.uid == 0][0]
solo = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=96),
              dtype=jnp.float32)
solo.submit(Request(uid=0, prompt=req0.prompt, max_new_tokens=12))
want = solo.run_until_drained()[0].out_tokens
assert req0.out_tokens == want, "batched decode must match solo decode"
print("batched == solo decode: OK")
