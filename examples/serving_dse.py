"""Serving-fleet DSE: when does prefill/decode disaggregation pay?

A serving replica runs two phases on opposite ends of the roofline —
prefill (compute-bound prompt pass) and decode (bandwidth-bound, one
token per KV slot per tick).  Colocated replicas (the actual
``repro.serve.engine`` behavior) stall their whole decode batch for
every admission's prefill, so past a traffic knee the time-per-output-
token blows through the SLO even though raw capacity remains; a
disaggregated fleet dedicates pods to prefill and pods to decode (KV
caches handed over the pod fabric) and keeps decode at pure-tick
cadence.

This example sweeps ``em_pod_frac x arrival rate x placement`` over a
small mixed B0 (plain) + B1 (memory-expanded) fleet serving
internlm2-20b under a {TTFT <= 1s, TPOT <= 35ms} SLO, and ranks by
goodput-per-TCO-dollar.

Run: PYTHONPATH=src python examples/serving_dse.py
"""

from repro.core import dse

ranked = dse.serving_ranking()
best = {}
for r in ranked:                       # best-first: first hit per key wins
    best.setdefault((r["em_pod_frac"], r["rate"], r["placement"]), r)

print("=== internlm2-20b on a 4-pod B0+B1 fleet, SLO: TTFT 1s / TPOT 35ms ===")
print(f"{'em_frac':>8}{'rate':>7}{'placement':>15}{'goodput':>9}"
      f"{'tpot_ms':>9}{'ttft_p99':>10}{'goodput/$':>12}")
for (frac, rate, pl), r in sorted(best.items()):
    print(f"{frac:>8}{rate:>7.0f}{pl:>15}{r['goodput']:>9.1f}"
          f"{r['tpot'] * 1e3:>9.1f}{r['ttft_p99']:>10.3f}"
          f"{r['goodput_per_dollar']:>12.3e}")

top = ranked[0]
print(f"\nWinner: {top['placement']} at {top['rate']:.0f} req/s on a "
      f"{top['em_pod_frac']:.0%}-EM fleet — {top['goodput']:.0f} good "
      f"req/s at {top['tpot'] * 1e3:.0f}ms TPOT.")
print("Reading: at low rates the placements tie (prefill stalls are "
      "absorbed by idle ticks).  At the top rate the colocated fleet's "
      "admission stalls push TPOT past the SLO and its goodput collapses, "
      "while disaggregated decode pods never stall; a single EM decode "
      "pod (em_frac=0.25, auto phase plan) shows the opposite failure — "
      "decode-starved, every slot saturated, TPOT explodes instead.")
