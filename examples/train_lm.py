"""End-to-end training driver: a real LM trained for a few hundred steps
with checkpointing, auto-resume, and the synthetic-but-learnable pipeline.

Defaults to the reduced smollm config so it finishes on a laptop-class CPU;
pass ``--full`` for the real 135M configuration (same code path — on the
production mesh this is what launch/dryrun.py lowers at 4k context).

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, DataIterator
from repro.parallel import plan_memory
from repro.train import (
    AdamWConfig,
    Trainer,
    TrainerConfig,
    init_train_state,
    make_train_step,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("smollm-135m", reduced=not args.full)
    plan = plan_memory(cfg, tp=1, dp=1)
    print(f"training {cfg.arch_id}: {cfg.param_count()/1e6:.1f}M params, "
          f"plan: zero-{plan.zero_stage} {plan.opt_dtype} remat={plan.remat}")
    opt = AdamWConfig(lr=3e-3, warmup_steps=args.steps // 20,
                      total_steps=args.steps)
    rng = jax.random.PRNGKey(0)
    state = init_train_state(cfg, plan, rng, opt, dtype=jnp.float32)
    step_fn = jax.jit(make_train_step(cfg, plan, opt))
    data = DataIterator(DataConfig(vocab_size=cfg.vocab_size,
                                   seq_len=args.seq_len,
                                   global_batch=args.global_batch))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(step_fn, state, data, TrainerConfig(
            total_steps=args.steps, ckpt_dir=ckpt_dir, ckpt_interval=100,
            log_interval=20))
        summary = trainer.run(rng)
    print(f"\nfinal loss {summary['final_loss']:.3f} after "
          f"{summary['final_step']} steps "
          f"(median step {summary['median_step_s']*1e3:.0f} ms, "
          f"stragglers: {summary['straggler_steps']})")
    assert summary["final_loss"] < 7.0, "loss should drop on Markov data"


if __name__ == "__main__":
    main()
