"""§Perf hillclimb driver: named variants per cell, before/after roofline.

Each variant = (name, hypothesis, cfg_transform, plan_transform). The sweep
is a repro.core.study StudySpec with a "variant" Axis and a custom
``evaluate`` that runs the measured dry-run frontend (lower_cell) instead of
the analytical simulator — same engine, different evaluator.

Usage: python experiments/hillclimb_run.py <arch:shape> <variant>[,<variant>...]
Results saved to experiments/hillclimb/<cell>_<variant>.json.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses, json, sys, time

from repro.core.study import Axis, StudySpec, run_study
from repro.launch.dryrun import lower_cell

CELL = sys.argv[1]          # e.g. internlm2-20b:train_4k
NAMES = sys.argv[2].split(",")  # one or more variant names

arch, shape = CELL.split(":")

def remat_blocks(plan):
    return dataclasses.replace(plan, remat="blocks")

def remat_dots(plan):
    return dataclasses.replace(plan, remat="dots")

def micro(n):
    return lambda plan: dataclasses.replace(plan, microbatches=n)

def moe_dense(cfg):
    return dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, dispatch="dense"))

def bshard(cfg):
    return dataclasses.replace(cfg, attn_batch_shard=True)

def cap(f):
    return lambda cfg: dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=f))

def chain(*fns):
    def t(x):
        for f in fns:
            x = f(x)
        return x
    return t

VARIANTS = {
    "baseline": (None, None),
    "remat-blocks": (None, remat_blocks),
    "remat-dots": (None, remat_dots),
    "micro8": (None, micro(8)),
    "moe-dense": (moe_dense, None),
    "moe-dense-blocks": (moe_dense, remat_blocks),
    "cap1.0": (cap(1.0), None),
    "micro16": (None, micro(16)),
    "micro16-blocks": (None, lambda p: remat_blocks(micro(16)(p))),
    "moe-dense-micro8": (moe_dense, micro(8)),
    "moe-dense-micro8-blocks": (moe_dense, lambda p: remat_blocks(micro(8)(p))),
    "blocks-micro8": (None, lambda p: remat_blocks(micro(8)(p))),
    "moe-dense-bshard": (lambda c: bshard(moe_dense(c)), None),
    "bshard": (bshard, None),
    "moe-dense-bshard-blocks": (lambda c: bshard(moe_dense(c)), remat_blocks),
    "bshard-micro16": (bshard, micro(16)),
    "bshard-blocks": (bshard, remat_blocks),
    "bshard-cap1": (lambda c: bshard(cap(1.0)(c)), None),
    "bshard-micro16-blocks": (bshard, lambda p: remat_blocks(micro(16)(p))),
    "bshard-micro4": (bshard, micro(4)),
    "blocks": (None, remat_blocks),
    "dots": (None, remat_dots),
}


unknown = [n for n in NAMES if n not in VARIANTS]
if unknown:
    sys.exit(f"unknown variant(s) {unknown}; available: {sorted(VARIANTS)}")

os.makedirs("experiments/hillclimb", exist_ok=True)


def _evaluate(ctx):
    # Persist + report per variant as soon as it finishes: a crash in a
    # later variant must not discard earlier multi-minute dry-run results.
    variant = ctx.point["variant"]
    cfg_t, plan_t = VARIANTS[variant]
    t0 = time.monotonic()
    _, info = lower_cell(arch, shape, multi_pod=False,
                         cfg_transform=cfg_t, plan_transform=plan_t)
    info["variant"] = variant
    info["wall_s"] = time.monotonic() - t0
    tag = f"{arch}_{shape}_{variant}"
    with open(f"experiments/hillclimb/{tag}.json", "w") as f:
        json.dump(info, f, indent=1, default=str)
    print(f"{tag}: compute={info['compute_s']:.3f}s memory={info['memory_s']:.3f}s "
          f"collective={info['collective_s']:.3f}s dom={info['dominant']} "
          f"frac={info['roofline_fraction']:.3f} util={info['model_flops_util']:.3f} "
          f"[{info['wall_s']:.0f}s]", flush=True)
    return info


spec = StudySpec(name=f"hillclimb:{CELL}",
                 axes=[Axis("variant", tuple(NAMES))], evaluate=_evaluate)
run_study(spec)
