"""Static analysis over COMET IR: workloads, compiled workloads, studies,
clusters — checked before anything is simulated.

Five rule packs (codes grouped by hundreds digit):

* ``W1xx`` (:mod:`repro.analysis.rules_workload`) — Workload invariants,
* ``C1xx`` (:mod:`repro.analysis.rules_compiled`) — CompiledWorkload vs.
  its source,
* ``S1xx`` (:mod:`repro.analysis.rules_study`) — StudySpec executability,
* ``K1xx`` (:mod:`repro.analysis.rules_cluster`) — cluster well-formedness,
* ``V1xx`` (:mod:`repro.analysis.rules_serving`) — ServingSpec
  servability (KV fits, SLO/trace sane, decode groups exist),
* ``R1xx`` (:mod:`repro.analysis.rules_search`) — search objective sets
  and Pareto-frontier annotations (degenerate objectives, non-finite
  values, dominance consistency),
* ``F1xx`` (:mod:`repro.analysis.rules_fleet`) — FleetSpec timeline
  sanity (jobs fit some group, positive trace, burst windows, finite
  preemption/resize costs),
* ``Y1xx`` (:mod:`repro.analysis.rules_reliability`) — failure models
  and traces (positive finite MTBF/MTTR/checkpoint-bw, fixed interval
  shorter than the run, non-empty traces, blast radius in range).

Entry points: the ``analyze_*`` helpers below, the ``validate=`` gate on
:func:`repro.core.study.run_study`, and the registry sweep CLI
(``python -m repro.analysis --all-registry``).  See docs/analysis_api.md.
"""

from repro.analysis.diagnostics import (
    AnalysisError,
    Diagnostic,
    Rule,
    RuleConfig,
    SEVERITIES,
    format_report,
    has_errors,
    list_rules,
    max_severity,
    rule,
    run_pack,
)
from repro.analysis.rules_cluster import analyze_cluster
from repro.analysis.rules_compiled import analyze_compiled
from repro.analysis.rules_fleet import analyze_fleet
from repro.analysis.rules_reliability import analyze_reliability
from repro.analysis.rules_search import SearchTarget, analyze_search
from repro.analysis.rules_serving import analyze_serving
from repro.analysis.rules_study import analyze_study
from repro.analysis.rules_workload import analyze_workload

__all__ = [
    "AnalysisError",
    "Diagnostic",
    "Rule",
    "RuleConfig",
    "SEVERITIES",
    "SearchTarget",
    "analyze_cluster",
    "analyze_compiled",
    "analyze_fleet",
    "analyze_reliability",
    "analyze_search",
    "analyze_serving",
    "analyze_study",
    "analyze_workload",
    "format_report",
    "has_errors",
    "list_rules",
    "max_severity",
    "rule",
    "run_pack",
]
