"""Registry sweep CLI: ``python -m repro.analysis --all-registry``.

Statically checks, without running the simulator:

* every registry cluster (K1xx);
* every registry model decomposed under the default strategy space at
  each distinct registry cluster size (W1xx on the Workload, C1xx on its
  compiled lowering), with a same-(mp, dp*ep) baseline decomposition
  enabling the W103 conservation check;
* a default StudySpec per (model, cluster) pair plus the seven
  paper-figure studies (S1xx, and K1xx on their base clusters);
* the default ``dse.serving_study`` spec (V1xx on the ServingSpec plus
  S1xx on its lowered StudySpec);
* the default ``dse.fleet_study`` spec (F1xx on the FleetSpec plus
  S1xx on its lowered StudySpec);
* the default ``dse.reliability_study`` and ``dse.reliability_fleet_study``
  specs (Y1xx on the failure model/trace plus S1xx/F1xx on the carriers);
* the search pack (R1xx) over a deterministic synthetic Pareto
  annotation — a live gate on the dominance logic.

Exits 1 if any error-severity diagnostic fires (the CI gate), 0
otherwise.  ``--json`` writes the full report for artifact upload.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analysis.diagnostics import (Diagnostic, RuleConfig, format_report,
                                        has_errors, list_rules)
from repro.analysis.rules_cluster import analyze_cluster
from repro.analysis.rules_compiled import analyze_compiled
from repro.analysis.rules_study import analyze_study
from repro.analysis.rules_workload import analyze_workload
from repro.configs import get_config, list_configs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.cluster import ClusterLike, get_cluster, list_clusters
from repro.core.study import PowerOfTwoSpace, StudySpec
from repro.core.workload import InfeasibleStrategyError, Workload, decompose

# A modest paper-style training shape: big enough to exercise every layer
# family, small enough that ~2k decompositions stay interactive.
SWEEP_SHAPE = ShapeConfig("analysis", seq_len=2048, global_batch=512,
                          kind="train")

# The default sweep space: the paper's power-of-two (MP, DP) enumeration,
# extended with one nontrivial PP and EP split so the stage/boundary (W104)
# and expert-gradient (edp) paths are exercised statically.
DEFAULT_SPACE = PowerOfTwoSpace(pp=(1, 2), ep=(1, 2))


def _parse_config(disable: Sequence[str],
                  severity: Sequence[str]) -> RuleConfig:
    overrides: Dict[str, str] = {}
    for item in severity:
        code, _, sev = item.partition("=")
        if not sev:
            raise SystemExit(f"--severity wants CODE=LEVEL, got {item!r}")
        overrides[code] = sev
    return RuleConfig(disable=frozenset(disable), severity=overrides)


def _decompose(cfg: ModelConfig, mp: int, dp: int, pp: int,
               ep: int) -> Optional[Workload]:
    try:
        return decompose(cfg, SWEEP_SHAPE, mp=mp, dp=dp, pp=pp, ep=ep)
    except InfeasibleStrategyError:
        return None


def sweep(models: Sequence[str], clusters: Sequence[str],
          config: Optional[RuleConfig] = None) -> List[Diagnostic]:
    """The full static sweep; pure (no simulator, no files)."""
    diags: List[Diagnostic] = []
    cluster_objs: Dict[str, ClusterLike] = {n: get_cluster(n)
                                            for n in clusters}
    for name in clusters:
        diags += analyze_cluster(cluster_objs[name], config)

    sizes = sorted({cl.num_nodes for cl in cluster_objs.values()})
    for arch in models:
        cfg = get_config(arch)
        baselines: Dict[Tuple[int, int], Optional[Workload]] = {}
        seen: set = set()
        for n in sizes:
            for s in DEFAULT_SPACE.specs(n):
                key = (s.mp, s.dp, s.pp, s.ep)
                if key in seen:
                    continue
                seen.add(key)
                wl = _decompose(cfg, s.mp, s.dp, s.pp, s.ep)
                if wl is None:
                    continue
                bkey = (s.mp, s.dp * s.ep)
                if bkey not in baselines:
                    baselines[bkey] = _decompose(cfg, s.mp, s.dp * s.ep,
                                                 1, 1)
                diags += analyze_workload(wl, baselines[bkey], config)
                diags += analyze_compiled(wl.compiled(), config=config)

    for arch in models:
        cfg = get_config(arch)
        for name in clusters:
            spec = StudySpec(name=f"registry:{arch}@{name}", model=cfg,
                             shape=SWEEP_SHAPE, cluster=cluster_objs[name],
                             strategies=DEFAULT_SPACE)
            diags += analyze_study(spec, config)

    from repro.core.dse import figure_studies, serving_study
    for spec in figure_studies().values():
        diags += analyze_study(spec, config)
        if spec.cluster is not None:
            diags += analyze_cluster(spec.cluster, config)

    from repro.analysis.rules_serving import analyze_serving
    sspec = serving_study()
    diags += analyze_serving(sspec, config)
    diags += analyze_study(sspec.to_study(), config)

    from repro.analysis.rules_fleet import analyze_fleet
    from repro.core.dse import fleet_study
    fspec = fleet_study()
    diags += analyze_fleet(fspec, config)
    diags += analyze_study(fspec.to_study(), config)

    from repro.analysis.rules_reliability import analyze_reliability
    from repro.core.dse import reliability_fleet_study, reliability_study
    rspec = reliability_study()
    diags += analyze_reliability(rspec, config)
    diags += analyze_study(rspec, config)
    rfspec = reliability_fleet_study()
    diags += analyze_reliability(rfspec, config)
    diags += analyze_fleet(rfspec, config)
    diags += analyze_study(rfspec.to_study(), config)

    # Search pack (R1xx) over a deterministic synthetic frontier: annotate
    # a fixed record set through the real pareto_front path, then check
    # the annotations.  Pure (no simulator), and a live gate on the
    # dominance logic itself: a broken pareto_rank trips R103 here.
    from repro.analysis.rules_search import analyze_search
    from repro.core.search import DEFAULT_OBJECTIVES, pareto_front
    from repro.core.study import CellResult, StudyResult
    demo = [
        {"feasible": True, "total": 1.0, "tco": 9.0, "energy_usd": 2.0},
        {"feasible": True, "total": 3.0, "tco": 4.0, "energy_usd": 1.0},
        {"feasible": True, "total": 3.5, "tco": 9.5, "energy_usd": 2.5},
        {"feasible": False, "total": 0.5, "tco": 1.0, "energy_usd": 0.1},
    ]
    res = StudyResult(
        spec=StudySpec(name="search-demo", evaluate=lambda ctx: {}),
        cells=[CellResult(None, {}, None, None, None, r) for r in demo])
    pareto_front(res, DEFAULT_OBJECTIVES)
    diags += analyze_search(res, DEFAULT_OBJECTIVES, config,
                            name="registry-demo")
    return diags


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static diagnostics over the model/cluster registries.")
    ap.add_argument("--all-registry", action="store_true",
                    help="sweep every registry model x default strategy "
                         "space x registry cluster")
    ap.add_argument("--models", nargs="*", default=None,
                    help="restrict to these registry models")
    ap.add_argument("--clusters", nargs="*", default=None,
                    help="restrict to these registry clusters")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the diagnostic report as JSON")
    ap.add_argument("--disable", nargs="*", default=(),
                    metavar="CODE", help="skip these rule codes")
    ap.add_argument("--severity", nargs="*", default=(), metavar="CODE=LEVEL",
                    help="override a rule's severity (e.g. W102=error)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print every registered rule and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in list_rules():
            print(f"{r.code}  {r.pack:<8} {r.severity:<8} {r.description}")
        return 0

    if not (args.all_registry or args.models or args.clusters):
        ap.print_help()
        return 0

    models = args.models if args.models else list_configs()
    clusters = args.clusters if args.clusters else list_clusters()
    config = _parse_config(args.disable, args.severity)
    diags = sweep(models, clusters, config)

    if args.json:
        report: Dict[str, Any] = {
            "models": list(models),
            "clusters": list(clusters),
            "diagnostics": [d.to_dict() for d in diags],
            "errors": sum(d.severity == "error" for d in diags),
            "warnings": sum(d.severity == "warning" for d in diags),
        }
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)

    if diags:
        print(format_report(diags))
    else:
        print(f"OK: no diagnostics over {len(models)} model(s) x "
              f"{len(clusters)} cluster(s).")
    return 1 if has_errors(diags) else 0


if __name__ == "__main__":
    sys.exit(main())
