"""Diagnostics framework: rule registry, severities, reports.

A *rule* is a pure function over existing IR (a Workload, a
CompiledWorkload, a StudySpec, a cluster) that yields findings without
running the simulator.  Rules register under a short code (``W101``,
``C103``, ...) grouped into packs; :func:`run_pack` executes one pack
against a target and returns :class:`Diagnostic` records.  Per-rule
enable/severity overrides live in :class:`RuleConfig`.

Severity contract:

* ``error``   — the object violates an invariant the engines rely on; a
  study over it would crash or produce wrong numbers.  The CLI (and the
  CI gate) exit non-zero on any error-severity finding.
* ``warning`` — suspicious but representable (a degenerate communicator,
  an empty strategy space, a bandwidth inversion).
* ``info``    — advisory (e.g. a cluster with no cost model attached).
"""

from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, FrozenSet, Iterable, List, Mapping,
                    Optional, Sequence, Tuple)

SEVERITIES: Tuple[str, ...] = ("info", "warning", "error")
_SEV_RANK: Dict[str, int] = {s: i for i, s in enumerate(SEVERITIES)}

PACKS: Tuple[str, ...] = ("workload", "compiled", "study", "cluster",
                          "serving", "search", "fleet", "reliability")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: a rule code, its effective severity, where, and what."""

    code: str
    severity: str
    location: str
    message: str

    def __str__(self) -> str:
        return f"{self.severity}[{self.code}] {self.location}: {self.message}"

    def to_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)


# A check receives (target, context) and yields (location, message) pairs.
CheckFn = Callable[[Any, Dict[str, Any]], Iterable[Tuple[str, str]]]


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    pack: str
    severity: str          # default severity; RuleConfig may override
    description: str
    check: CheckFn


_REGISTRY: Dict[str, Rule] = {}


def rule(code: str, pack: str, severity: str,
         description: str) -> Callable[[CheckFn], CheckFn]:
    """Register a check function under ``code`` in ``pack``."""
    if severity not in SEVERITIES:
        raise ValueError(f"unknown severity {severity!r} "
                         f"(expected one of {SEVERITIES})")
    if pack not in PACKS:
        raise ValueError(f"unknown pack {pack!r} (expected one of {PACKS})")

    def deco(fn: CheckFn) -> CheckFn:
        if code in _REGISTRY:
            raise ValueError(f"duplicate rule code {code!r}")
        _REGISTRY[code] = Rule(code, pack, severity, description, fn)
        return fn

    return deco


def list_rules(pack: Optional[str] = None) -> List[Rule]:
    """All registered rules (optionally one pack), sorted by code."""
    rules = sorted(_REGISTRY.values(), key=lambda r: r.code)
    if pack is None:
        return rules
    return [r for r in rules if r.pack == pack]


@dataclasses.dataclass(frozen=True)
class RuleConfig:
    """Per-rule suppression and severity overrides.

    ``disable`` names rule codes to skip entirely; ``severity`` remaps a
    rule's default severity (e.g. promote ``W102`` to ``error`` in a
    strict CI lane, or demote ``K102`` to ``info`` for a deliberately
    inverted hierarchy)."""

    disable: FrozenSet[str] = frozenset()
    severity: Mapping[str, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        for code, sev in self.severity.items():
            if sev not in SEVERITIES:
                raise ValueError(f"unknown severity {sev!r} for {code!r}")

    def enabled(self, code: str) -> bool:
        return code not in self.disable

    def severity_of(self, r: Rule) -> str:
        return self.severity.get(r.code, r.severity)


def run_pack(pack: str, target: Any,
             ctx: Optional[Dict[str, Any]] = None,
             config: Optional[RuleConfig] = None) -> List[Diagnostic]:
    """Run every enabled rule of ``pack`` against ``target``."""
    cfg = config if config is not None else RuleConfig()
    context = ctx if ctx is not None else {}
    out: List[Diagnostic] = []
    for r in list_rules(pack):
        if not cfg.enabled(r.code):
            continue
        sev = cfg.severity_of(r)
        for location, message in r.check(target, context):
            out.append(Diagnostic(r.code, sev, location, message))
    return out


def max_severity(diags: Sequence[Diagnostic]) -> Optional[str]:
    if not diags:
        return None
    return max((d.severity for d in diags), key=lambda s: _SEV_RANK[s])


def has_errors(diags: Sequence[Diagnostic]) -> bool:
    return any(d.severity == "error" for d in diags)


def format_report(diags: Sequence[Diagnostic]) -> str:
    """Human-readable report, most severe first, stable within severity."""
    ordered = sorted(enumerate(diags),
                     key=lambda p: (-_SEV_RANK[p[1].severity], p[0]))
    lines = [str(d) for _, d in ordered]
    counts = {s: sum(1 for d in diags if d.severity == s) for s in SEVERITIES}
    summary = ", ".join(f"{counts[s]} {s}" for s in reversed(SEVERITIES))
    lines.append(f"-- {len(diags)} diagnostic(s): {summary}")
    return "\n".join(lines)


class AnalysisError(RuntimeError):
    """Raised by ``run_study(validate='error')`` on error-severity findings.

    Carries the full diagnostic list (not just the errors) on
    ``.diagnostics``."""

    def __init__(self, diagnostics: Sequence[Diagnostic]) -> None:
        self.diagnostics: List[Diagnostic] = list(diagnostics)
        errors = [d for d in self.diagnostics if d.severity == "error"]
        super().__init__(
            f"{len(errors)} error-severity diagnostic(s):\n"
            + "\n".join(str(d) for d in errors))
