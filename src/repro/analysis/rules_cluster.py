"""Cluster rules (K1xx): a ClusterConfig / ClusterSpec is well-formed.

======  ========  =====================================================
code    severity  invariant
======  ========  =====================================================
K101    warning   pod_size divides every node group's node count
K102    warning   hop bandwidths non-increasing fast -> slow
K103    error*    CostModel fields nonnegative, amortization positive
                  (*missing cost model is info; all-zero prices warn)
K104    error     node parameters positive; EM bandwidth present when
                  EM capacity is
======  ========  =====================================================
"""

from __future__ import annotations

import math
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.analysis.diagnostics import Diagnostic, RuleConfig, rule, run_pack
from repro.core.cluster import ClusterLike, CostModel, NodeConfig

# SingleSwitch models "everything in one pod" with this sentinel.
_UNBOUNDED_POD = 1 << 20


def _name(cluster: ClusterLike) -> str:
    return f"cluster {cluster.name!r}"


@rule("K101", "cluster", "warning",
      "pod_size divides every node group's node count")
def _check_pods(cluster: ClusterLike,
                ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    for g, group in enumerate(cluster.node_groups):
        pod = group.topology.pod_size
        if pod <= 0:
            yield (f"{_name(cluster)} group[{g}]",
                   f"pod_size = {pod} (must be positive)")
            continue
        if pod >= _UNBOUNDED_POD or group.num_nodes <= pod:
            continue
        if group.num_nodes % pod:
            yield (f"{_name(cluster)} group[{g}]",
                   f"{group.num_nodes} nodes is not a multiple of "
                   f"pod_size {pod} — the last pod is ragged and "
                   "placement/collective models assume full pods")


@rule("K102", "cluster", "warning",
      "hop bandwidths non-increasing from fastest to slowest tier")
def _check_hierarchy(cluster: ClusterLike,
                     ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    for g, group in enumerate(cluster.node_groups):
        hops = group.topology.hops
        for near, far in zip(hops, hops[1:]):
            if far.bw > near.bw:
                yield (f"{_name(cluster)} group[{g}]",
                       f"hop {far.name!r} ({far.bw:.3g} B/s) is faster than "
                       f"the nearer hop {near.name!r} ({near.bw:.3g} B/s) — "
                       "inverted bandwidth hierarchy")
            if far.latency < near.latency:
                yield (f"{_name(cluster)} group[{g}]",
                       f"hop {far.name!r} ({far.latency:.3g} s) has lower "
                       f"latency than the nearer hop {near.name!r} "
                       f"({near.latency:.3g} s)")


def _cost_findings(cost: CostModel, loc: str) -> Iterator[Tuple[str, str]]:
    dollar_fields = ("usd_per_node", "usd_per_gb_local", "usd_per_gb_em",
                     "usd_per_link", "usd_per_kwh")
    for field in dollar_fields:
        v = getattr(cost, field)
        if not math.isfinite(v) or v < 0:
            yield loc, f"{field} = {v!r}"
    if not cost.amortization_years > 0:
        yield loc, (f"amortization_years = {cost.amortization_years!r} "
                    "(must be positive)")


@rule("K103", "cluster", "error",
      "CostModel complete: nonnegative prices, positive amortization")
def _check_cost(cluster: ClusterLike,
                ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    cost = cluster.cost
    if cost is None:
        return
    yield from _cost_findings(cost, f"{_name(cluster)} cost")


@rule("K104", "cluster", "error",
      "node parameters positive; EM bandwidth present when capacity is")
def _check_nodes(cluster: ClusterLike,
                 ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    for g, group in enumerate(cluster.node_groups):
        loc = f"{_name(cluster)} group[{g}] node"
        node: NodeConfig = group.node
        if group.num_nodes < 1:
            yield f"{_name(cluster)} group[{g}]", \
                f"num_nodes = {group.num_nodes}"
        for field in ("peak_flops", "local_cap", "local_bw", "sram_bytes"):
            v = getattr(node, field)
            if not math.isfinite(v) or v <= 0:
                yield loc, f"{field} = {v!r} (must be positive and finite)"
        for field in ("exp_cap", "exp_bw", "tdp_watts"):
            v = getattr(node, field)
            if not math.isfinite(v) or v < 0:
                yield loc, f"{field} = {v!r} (must be nonnegative and finite)"
        if node.exp_cap > 0 and node.exp_bw <= 0:
            yield loc, (f"exp_cap = {node.exp_cap:.3g} B with exp_bw = "
                        f"{node.exp_bw!r} — expanded memory that can never "
                        "be read")


def analyze_cluster(cluster: ClusterLike,
                    config: Optional[RuleConfig] = None) -> List[Diagnostic]:
    """Run the K1xx pack against one cluster."""
    diags = run_pack("cluster", cluster, {}, config)
    cfg = config if config is not None else RuleConfig()
    if cluster.cost is None and cfg.enabled("K103"):
        diags.append(Diagnostic(
            "K103", "info", _name(cluster),
            "no CostModel attached — cost_usd/tco/perf_per_dollar columns "
            "will be empty"))
    return diags
