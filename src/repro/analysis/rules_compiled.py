"""Compiled rules (C1xx): a CompiledWorkload structurally mirrors its source.

The compiled engine's dynamic guarantee (timings within 1e-9 of the
reference event loop, ``tests/test_compiled.py`` + the bench-smoke gate)
is checked per cell at runtime.  These rules are its *static* shadow:
they re-derive, from the Workload's layer lists, what the flat arrays
must contain — so a stale or hand-mutated ``CompiledWorkload`` is caught
before any cell is timed.

======  ========  =====================================================
code    severity  invariant
======  ========  =====================================================
C101    error     one CompiledStage per pipeline stage
C102    error     per-(collective, scope) event counts match the source
C103    error     per-(collective, scope) total bytes match the source
C104    error     delay-class coverage: seq/count totals, index ranges
C105    error     optimizer byte totals match the layer list
======  ========  =====================================================
"""

from __future__ import annotations

import math
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.analysis.diagnostics import Diagnostic, RuleConfig, rule, run_pack
from repro.core.compiled import (CompiledStage, CompiledWorkload,
                                 pass_event_totals)
from repro.core.workload import LayerSpec, Workload

_REL_TOL = 1e-9


def _source(cw: CompiledWorkload, ctx: Dict[str, Any]) -> Workload:
    wl = ctx.get("workload")
    return wl if wl is not None else cw.workload


def _stage_pairs(cw: CompiledWorkload, ctx: Dict[str, Any]
                 ) -> Iterator[Tuple[int, CompiledStage, List[LayerSpec]]]:
    groups = _source(cw, ctx).stage_layers()
    for s, (stage, layers) in enumerate(zip(cw.stages, groups)):
        yield s, stage, layers


def _workload_event_totals(layers: List[LayerSpec]
                           ) -> Dict[Tuple[str, str], Tuple[int, float]]:
    """Repeat-weighted (count, bytes) per (collective, scope) that the
    reference event loop would issue for one stage."""
    totals: Dict[Tuple[str, str], List[float]] = {}
    for layer in layers:
        for events in (layer.comm_fwd, layer.comm_ig, layer.comm_wg):
            for ev in events:
                cell = totals.setdefault((ev.collective, ev.scope), [0, 0.0])
                cell[0] += layer.repeat
                cell[1] += ev.size_bytes * layer.repeat
    return {k: (int(c), b) for k, (c, b) in totals.items()}


@rule("C101", "compiled", "error",
      "one CompiledStage per pipeline stage of the source workload")
def _check_stage_count(cw: CompiledWorkload,
                       ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    wl = _source(cw, ctx)
    want = len(wl.stage_layers())
    if len(cw.stages) != want:
        yield (f"compiled {wl.name!r}",
               f"{len(cw.stages)} compiled stage(s) for {want} pipeline "
               f"stage(s) (pp={wl.pp})")


@rule("C102", "compiled", "error",
      "per-(collective, scope) event counts equal the source workload's")
def _check_event_counts(cw: CompiledWorkload,
                        ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    wl = _source(cw, ctx)
    for s, stage, layers in _stage_pairs(cw, ctx):
        want = _workload_event_totals(layers)
        got = pass_event_totals(stage)
        for key in sorted(set(want) | set(got)):
            kind, scope = key
            n_want = want.get(key, (0, 0.0))[0]
            n_got = got.get(key, (0, 0.0))[0]
            if n_want != n_got:
                yield (f"compiled {wl.name!r} stage[{s}]",
                       f"{kind}@{scope}: {n_got} stream event(s) vs "
                       f"{n_want} in the layer list")


@rule("C103", "compiled", "error",
      "per-(collective, scope) total bytes equal the source workload's")
def _check_event_bytes(cw: CompiledWorkload,
                       ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    wl = _source(cw, ctx)
    for s, stage, layers in _stage_pairs(cw, ctx):
        want = _workload_event_totals(layers)
        got = pass_event_totals(stage)
        for key in sorted(set(want) | set(got)):
            kind, scope = key
            b_want = want.get(key, (0, 0.0))[1]
            b_got = got.get(key, (0, 0.0))[1]
            if not math.isclose(b_want, b_got, rel_tol=_REL_TOL, abs_tol=0.5):
                yield (f"compiled {wl.name!r} stage[{s}]",
                       f"{kind}@{scope}: {b_got:.6g} stream bytes vs "
                       f"{b_want:.6g} in the layer list")


@rule("C104", "compiled", "error",
      "delay-class coverage: sequence lengths, phase counts, index ranges")
def _check_classes(cw: CompiledWorkload,
                   ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    wl = _source(cw, ctx)
    for s, stage, layers in _stage_pairs(cw, ctx):
        loc = f"compiled {wl.name!r} stage[{s}]"
        repeats = sum(layer.repeat for layer in layers)
        ncls = stage.n_classes
        if stage.flops.shape != (ncls,) or stage.base_traffic.shape != (ncls,):
            yield (loc, f"delay tables sized {stage.flops.shape} / "
                        f"{stage.base_traffic.shape} for {ncls} class(es)")
        if stage.counts.shape != (3, ncls):
            yield loc, f"counts shaped {stage.counts.shape}, want (3, {ncls})"
        else:
            for p, phase in enumerate(("fp", "ig", "wg")):
                total = float(stage.counts[p].sum())
                if not math.isclose(total, repeats, rel_tol=_REL_TOL):
                    yield (loc, f"{phase} class counts sum to {total:.6g}, "
                                f"want {repeats} (repeat-weighted layers)")
        for name, p, want_len in (("fwd", stage.fwd, repeats),
                                  ("bwd", stage.bwd, 2 * repeats)):
            if p.seq.size != want_len:
                yield (loc, f"{name} sequence has {p.seq.size} compute "
                            f"step(s), want {want_len}")
            if p.seq.size and not (0 <= p.seq.min()
                                   and int(p.seq.max()) < ncls):
                yield loc, f"{name} sequence indexes outside [0, {ncls})"
            ncomm = stage.comm_sizes.shape[0]
            if p.ev_comm.size and not (0 <= p.ev_comm.min()
                                       and int(p.ev_comm.max()) < ncomm):
                yield loc, f"{name} events reference comm rows >= {ncomm}"
            if p.ev_pos.size and not (0 <= p.ev_pos.min()
                                      and int(p.ev_pos.max()) <= p.seq.size):
                yield (loc, f"{name} event positions outside "
                            f"[0, {p.seq.size}]")


@rule("C105", "compiled", "error",
      "optimizer-update byte totals match the layer list")
def _check_optimizer(cw: CompiledWorkload,
                     ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    wl = _source(cw, ctx)
    for s, stage, layers in _stage_pairs(cw, ctx):
        dense = sum((layer.weight_bytes - layer.expert_bytes) * layer.repeat
                    for layer in layers if layer.optim_bytes is None)
        expert = sum(layer.expert_bytes * layer.repeat
                     for layer in layers if layer.optim_bytes is None)
        sparse = sum(layer.optim_bytes * layer.repeat
                     for layer in layers if layer.optim_bytes is not None)
        for name, got, want in (("dense_w", stage.dense_w, dense),
                                ("expert_w", stage.expert_w, expert),
                                ("sparse", stage.sparse, sparse)):
            if not math.isclose(got, want, rel_tol=_REL_TOL, abs_tol=0.5):
                yield (f"compiled {wl.name!r} stage[{s}]",
                       f"{name} = {got:.6g}, layer list says {want:.6g}")


def analyze_compiled(cw: CompiledWorkload,
                     workload: Optional[Workload] = None,
                     config: Optional[RuleConfig] = None) -> List[Diagnostic]:
    """Run the C1xx pack against ``cw`` (vs. ``workload``, default the one
    it was lowered from)."""
    return run_pack("compiled", cw, {"workload": workload}, config)
