"""Fleet rules (F1xx): a FleetSpec can run its timeline before any cell
simulates.

``run_study`` runs these (through the lowered
:class:`repro.fleet.FleetStudy`) under its ``validate=`` gate; the
registry sweep CLI runs them over the default ``dse.fleet_study``.

======  ========  =====================================================
code    severity  invariant
======  ========  =====================================================
F101    error     every job template can hold one instance in some group
F102    error     the trace (and any swept rate) is positive/non-empty
F103    error     priority/burst sanity: burst jobs are single-instance
                  with a window inside their iteration budget, widths
                  divisible by mp
F104    error     preemption/resize costs are finite and positive
======  ========  =====================================================
"""

from __future__ import annotations

import math
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.analysis.diagnostics import (Diagnostic, RuleConfig, rule,
                                        run_pack)
from repro.fleet.spec import FleetSpec, is_fleet_axis


def _swept(spec: FleetSpec, path: str) -> List[Any]:
    """Values an axis sweeps onto ``path`` (empty if not swept)."""
    out: List[Any] = []
    for axis in spec.axes:
        if is_fleet_axis(axis) and axis.path == path and axis.mode == "set":
            out.extend(axis.values)
    return out


@rule("F101", "fleet", "error",
      "every job template can hold one instance in some node group")
def _check_jobs_fit(spec: FleetSpec,
                    ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    if spec.cluster is None:
        return
    groups = spec.cluster.node_groups
    biggest = max(g.num_nodes for g in groups)
    for job in spec.jobs:
        loc = f"fleet study {spec.name!r} job {job.name!r}"
        narrowest = min(job.width_menu)
        if narrowest > biggest:
            yield (loc,
                   f"narrowest width {narrowest} exceeds every group "
                   f"(largest has {biggest} nodes) — the job can only run "
                   "under the oversubscribed legacy convention")
        if job.max_nodes and narrowest > job.max_nodes:
            yield (loc,
                   f"narrowest width {narrowest} exceeds the job's own "
                   f"max_nodes={job.max_nodes} cap — it can never place")


@rule("F102", "fleet", "error",
      "fleet trace rates/durations are positive")
def _check_trace(spec: FleetSpec,
                 ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    loc = f"fleet study {spec.name!r} ftrace"
    if spec.ftrace.kind != "static":
        for r in [spec.ftrace.rate] + _swept(spec, "ftrace.rate"):
            if not r > 0:
                yield loc, f"arrival rate must be > 0 jobs/s, got {r!r}"
        for n in [spec.ftrace.num_jobs] + _swept(spec, "ftrace.num_jobs"):
            if not n > 0:
                yield loc, f"trace needs num_jobs > 0, got {n!r}"
    for job in spec.jobs:
        if not job.iterations > 0:
            yield (f"fleet study {spec.name!r} job {job.name!r}",
                   f"iterations must be > 0, got {job.iterations!r}")


@rule("F103", "fleet", "error",
      "priority/burst sanity: single-instance bursts inside the "
      "iteration budget, widths divisible by mp")
def _check_burst(spec: FleetSpec,
                 ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    for job in spec.jobs:
        loc = f"fleet study {spec.name!r} job {job.name!r}"
        if job.burst_iters > 0:
            if job.instances != 1:
                yield (loc,
                       f"burst-parallel jobs must be single-instance, got "
                       f"instances={job.instances} — the lend/return "
                       "hand-off is per training state, not per replica")
            if job.burst_iters > job.iterations:
                yield (loc,
                       f"burst window ({job.burst_iters} iters) exceeds "
                       f"the job's whole run ({job.iterations} iters)")
            if not job.elastic:
                yield (loc,
                       "burst_iters set but the width menu is static — "
                       "bursting needs wider widths to borrow into "
                       "(set FleetJobSpec.widths)")
        if not job.model.startswith("dlrm"):
            for w in job.width_menu:
                if w % job.mp != 0:
                    yield (loc,
                           f"width {w} not divisible by mp={job.mp} — "
                           "elastic DP cannot re-decompose there")


@rule("F104", "fleet", "error",
      "preemption/resize costs are finite and positive")
def _check_costs(spec: FleetSpec,
                 ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    loc = f"fleet study {spec.name!r} fleet"
    for field in ("checkpoint_bw", "reshard_bw"):
        for v in [getattr(spec.fleet, field)] \
                + _swept(spec, f"fleet.{field}"):
            if not (v > 0 and math.isfinite(v)):
                yield (loc,
                       f"{field} must be finite and > 0 bytes/s, got {v!r} "
                       "— every preempt/resize would stall forever")
    for v in [spec.fleet.lend_overhead] + _swept(spec, "fleet.lend_overhead"):
        if not (v >= 0 and math.isfinite(v)):
            yield (loc,
                   f"lend_overhead must be finite and >= 0 s, got {v!r}")


def analyze_fleet(spec: FleetSpec,
                  config: Optional[RuleConfig] = None) -> List[Diagnostic]:
    """Run the F1xx pack against a :class:`FleetSpec`."""
    return run_pack("fleet", spec, config=config)
