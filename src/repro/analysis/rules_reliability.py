"""Reliability rules (Y1xx): failure models and traces are sane before
any goodput column is computed or any fault is injected.

``run_study`` runs these under its ``validate=`` gate whenever a
:class:`repro.core.study.StudySpec` carries a ``reliability``
:class:`~repro.reliability.FailureModel` (closed-form goodput columns)
or a lowered :class:`repro.fleet.FleetStudy`'s source
:class:`~repro.fleet.FleetSpec` carries an enabled ``failures``
:class:`~repro.reliability.FailureTrace` (fault injection); the
registry sweep CLI runs them over ``dse.reliability_study``.

======  ========  =====================================================
code    severity  invariant
======  ========  =====================================================
Y101    error     MTBF/MTTR/checkpoint-bw/restore-bw (and every swept
                  value) are positive and finite where required
Y102    error     a fixed checkpoint interval is > 0 and shorter than
                  the run it checkpoints
Y103    error     an enabled failure trace can actually produce events
Y104    error     explicit failure events name a real node group and a
                  blast radius within it
Y105    warning   a Poisson trace draws at least one failure over this
                  cluster and horizon (zero draws = the failure-aware
                  columns silently equal the failure-free ones)
======  ========  =====================================================
"""

from __future__ import annotations

import math
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.analysis.diagnostics import (Diagnostic, RuleConfig, rule,
                                        run_pack)
from repro.reliability.model import FailureModel
from repro.reliability.trace import FailureTrace

_REL_PREFIX = "reliability."
_FAIL_PREFIX = "fail."


def _model(spec: Any) -> Optional[FailureModel]:
    m = getattr(spec, "reliability", None)
    return m if isinstance(m, FailureModel) else None


def _trace(spec: Any) -> Optional[FailureTrace]:
    t = getattr(spec, "failures", None)
    return t if isinstance(t, FailureTrace) else None


def _swept(spec: Any, field: str) -> List[Any]:
    """Values any axis sweeps onto the failure model/trace field
    (``reliability.<field>`` on a StudySpec, ``fail.<field>`` on a
    FleetSpec)."""
    out: List[Any] = []
    for axis in getattr(spec, "axes", ()):
        path = getattr(axis, "path", None)
        if path in (_REL_PREFIX + field, _FAIL_PREFIX + field) \
                and getattr(axis, "mode", "set") == "set":
            out.extend(axis.values)
    return out


def _group_sizes(spec: Any) -> List[int]:
    cluster = getattr(spec, "cluster", None)
    if cluster is None:
        return []
    return [g.num_nodes for g in cluster.node_groups]


@rule("Y101", "reliability", "error",
      "failure-model rates and bandwidths are positive and finite")
def _check_rates(spec: Any,
                 ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    name = getattr(spec, "name", "?")
    model = _model(spec)
    if model is not None:
        loc = f"study {name!r} reliability"
        for v in [model.mtbf_hours] + _swept(spec, "mtbf_hours"):
            if not v > 0 or v != v:
                yield (loc, f"mtbf_hours must be > 0 (inf disables "
                            f"failures), got {v!r}")
        for v in [model.mttr_hours] + _swept(spec, "mttr_hours"):
            if not (v >= 0 and math.isfinite(v)):
                yield loc, f"mttr_hours must be finite and >= 0, got {v!r}"
        for v in [model.ckpt_bw] + _swept(spec, "ckpt_bw"):
            if not (v > 0 and math.isfinite(v)):
                yield (loc, f"ckpt_bw must be finite and > 0 bytes/s, got "
                            f"{v!r} — every checkpoint would stall forever")
        for v in [model.restore_bw] + _swept(spec, "restore_bw"):
            if not (v >= 0 and math.isfinite(v)):
                yield (loc, f"restore_bw must be finite and >= 0 "
                            f"(0 = ckpt_bw), got {v!r}")
    trace = _trace(spec)
    if trace is not None and trace.kind == "poisson":
        loc = f"fleet study {name!r} failures"
        for v in [trace.mtbf_hours] + _swept(spec, "mtbf_hours"):
            if not v > 0 or v != v:
                yield loc, f"mtbf_hours must be > 0, got {v!r}"
        for v in [trace.mttr_hours] + _swept(spec, "mttr_hours"):
            if not (v >= 0 and math.isfinite(v)):
                yield loc, f"mttr_hours must be finite and >= 0, got {v!r}"


@rule("Y102", "reliability", "error",
      "a fixed checkpoint interval is > 0 and shorter than the run")
def _check_interval(spec: Any,
                    ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    name = getattr(spec, "name", "?")
    model = _model(spec)
    if model is None:
        return
    loc = f"study {name!r} reliability"
    run_s = model.run_hours * 3600.0
    for v in [model.interval_s] + _swept(spec, "interval_s"):
        if not v >= 0 or v != v:
            yield (loc, f"interval_s must be >= 0 (0 = Young–Daly), "
                        f"got {v!r}")
        elif v >= run_s:
            yield (loc,
                   f"fixed checkpoint interval {v:g}s is not shorter than "
                   f"the {model.run_hours:g}h run ({run_s:g}s) — the run "
                   "would never commit a checkpoint")


@rule("Y103", "reliability", "error",
      "an enabled failure trace can produce events")
def _check_trace_events(spec: Any,
                        ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    name = getattr(spec, "name", "?")
    trace = _trace(spec)
    if trace is None or trace.kind == "none":
        return
    loc = f"fleet study {name!r} failures"
    if trace.kind == "explicit" and not trace.events:
        yield (loc, "explicit failure trace has no events — use "
                    "kind='none' to disable failures")
        return
    if trace.kind == "poisson" and not trace.horizon_hours > 0:
        yield (loc, f"poisson trace needs horizon_hours > 0, got "
                    f"{trace.horizon_hours!r}")


@rule("Y105", "reliability", "warning",
      "a Poisson failure trace draws at least one event over this "
      "cluster and horizon")
def _check_zero_draw(spec: Any,
                     ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    name = getattr(spec, "name", "?")
    trace = _trace(spec)
    if trace is None or trace.kind != "poisson" or not trace.enabled \
            or not trace.horizon_hours > 0:
        return
    sizes = _group_sizes(spec)
    if sizes and not trace.materialize(sizes):
        yield (f"fleet study {name!r} failures",
               f"poisson trace (mtbf={trace.mtbf_hours:g}h over "
               f"{sum(sizes)} nodes, horizon={trace.horizon_hours:g}h) "
               "drew zero failures — the failure-aware columns will "
               "equal the failure-free ones")


@rule("Y104", "reliability", "error",
      "explicit failure events name a real group and a blast radius "
      "within it")
def _check_blast(spec: Any,
                 ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    name = getattr(spec, "name", "?")
    trace = _trace(spec)
    if trace is None or trace.kind != "explicit" or not trace.events:
        return
    loc = f"fleet study {name!r} failures"
    sizes = _group_sizes(spec)
    for ev in trace.events:
        if sizes and ev.group >= len(sizes):
            yield (loc,
                   f"event at t={ev.time:g}s names group {ev.group} but "
                   f"the cluster has {len(sizes)} group(s)")
        elif sizes and ev.nodes > sizes[ev.group]:
            yield (loc,
                   f"event at t={ev.time:g}s downs {ev.nodes} nodes but "
                   f"group {ev.group} only has {sizes[ev.group]}")


def analyze_reliability(spec: Any,
                        config: Optional[RuleConfig] = None
                        ) -> List[Diagnostic]:
    """Run the Y1xx pack against a StudySpec carrying a ``reliability``
    FailureModel or a FleetSpec carrying a ``failures`` FailureTrace."""
    return run_pack("reliability", spec, config=config)
