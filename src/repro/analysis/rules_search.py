"""Search rules (R1xx): objective sets and Pareto annotations are sane.

The search layer (:mod:`repro.core.search`) ranks records by objective
columns and stamps ``pareto_rank`` / ``pareto_optimal`` annotations; a
degenerate objective set or a broken annotation silently turns a design
search into noise.  These rules run over a :class:`SearchTarget` — an
``(objectives, records)`` pair built by :func:`analyze_search` from a
:class:`repro.core.study.StudyResult`, a :class:`repro.core.search
.SearchResult` trace, or a bare record list.

======  ========  =====================================================
code    severity  invariant
======  ========  =====================================================
R101    error     objective set is non-empty with distinct columns that
                  at least one record carries
R102    warning   feasible records are finite on every objective
R103    error     ``pareto_optimal`` annotations are dominance-consistent
======  ========  =====================================================
"""

from __future__ import annotations

import dataclasses
import math
from typing import (Any, Dict, Iterator, List, Mapping, Optional, Sequence,
                    Tuple, Union)

from repro.analysis.diagnostics import (Diagnostic, RuleConfig, rule,
                                        run_pack)
from repro.core.search import (DEFAULT_OBJECTIVES, Objective, _participates,
                               _scores, dominates)


@dataclasses.dataclass(frozen=True)
class SearchTarget:
    """What the R1xx pack inspects: the objective set plus the (possibly
    Pareto-annotated) records it ranks."""

    objectives: Tuple[Objective, ...]
    records: Tuple[Mapping[str, Any], ...]
    name: str = "search"


@rule("R101", "search", "error",
      "objective set is non-empty, has distinct columns, and matches "
      "at least one record column")
def _check_objectives(target: SearchTarget,
                      ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    loc = f"search {target.name!r} objectives"
    if not target.objectives:
        yield loc, ("empty objective set — nothing to rank; pass at "
                    "least one Objective (e.g. Objective('total'))")
        return
    cols = [o.column for o in target.objectives]
    dupes = sorted({c for c in cols if cols.count(c) > 1})
    if dupes:
        yield loc, (f"duplicate objective column(s) {dupes} — each axis "
                    "of the trade space must be a distinct column")
    if target.records:
        missing = [c for c in cols
                   if not any(c in r for r in target.records)]
        if missing:
            yield loc, (f"objective column(s) {missing} appear in none "
                        f"of the {len(target.records)} record(s) — every "
                        "cell would score +inf on them")


@rule("R102", "search", "warning",
      "feasible records carry finite values on every objective column")
def _check_finite(target: SearchTarget,
                  ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    if not target.objectives:
        return
    for i, r in enumerate(target.records):
        if not r.get("feasible", True):
            continue
        for o in target.objectives:
            v = r.get(o.column)
            if isinstance(v, bool) or not isinstance(v, (int, float)) \
                    or not math.isfinite(float(v)):
                yield (f"search {target.name!r} record[{i}]",
                       f"feasible record has non-finite objective "
                       f"{o.column}={v!r} — it can never rank and is "
                       "silently excluded from the frontier")


@rule("R103", "search", "error",
      "pareto_optimal annotations are dominance-consistent")
def _check_frontier(target: SearchTarget,
                    ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    """Two-sided check over annotated records: no frontier member is
    dominated by any participating record, and every participating
    non-frontier record is dominated by some frontier member.  Records
    without a ``pareto_optimal`` annotation are skipped (the trace was
    never run through ``pareto_front``)."""
    if not target.objectives:
        return
    annotated = [(i, r) for i, r in enumerate(target.records)
                 if "pareto_optimal" in r]
    live = [(i, r, _scores(r, target.objectives)) for i, r in annotated
            if _participates(r, target.objectives)]
    front = [(i, s) for i, r, s in live if r.get("pareto_optimal")]
    rest = [(i, s) for i, r, s in live if not r.get("pareto_optimal")]
    name = f"search {target.name!r}"
    for i, si in front:
        for j, r, sj in live:
            if j != i and dominates(sj, si):
                yield (f"{name} record[{i}]",
                       f"marked pareto_optimal but dominated by "
                       f"record[{j}] on "
                       f"{[o.name for o in target.objectives]}")
                break
    for i, si in rest:
        if not any(dominates(sf, si) or sf == si for _, sf in front):
            yield (f"{name} record[{i}]",
                   "feasible, not marked pareto_optimal, yet no frontier "
                   "record dominates it — the frontier is incomplete")


def _as_target(obj: Union[SearchTarget, Sequence[Mapping[str, Any]], Any],
               objectives: Optional[Sequence[Objective]],
               name: str) -> SearchTarget:
    if isinstance(obj, SearchTarget):
        return obj
    records = getattr(obj, "records", obj)   # StudyResult / SearchResult
    obs = tuple(objectives if objectives is not None
                else getattr(obj, "objectives", DEFAULT_OBJECTIVES))
    return SearchTarget(objectives=obs, records=tuple(records), name=name)


def analyze_search(result: Union[SearchTarget, Sequence[Mapping[str, Any]],
                                 Any],
                   objectives: Optional[Sequence[Objective]] = None,
                   config: Optional[RuleConfig] = None,
                   name: str = "search") -> List[Diagnostic]:
    """Run the R1xx pack.  ``result`` may be a :class:`SearchTarget`, a
    ``StudyResult``/``SearchResult`` (its ``records``/``objectives`` are
    lifted), or a bare record sequence; ``objectives`` defaults to the
    result's own, else the (time, TCO, energy) triple."""
    return run_pack("search", _as_target(result, objectives, name),
                    config=config)
