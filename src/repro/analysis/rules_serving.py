"""Serving rules (V1xx): a ServingSpec is servable before any cell runs.

``run_study`` runs these (through the lowered
:class:`repro.serving.ServingStudy`) under its ``validate=`` gate; the
registry sweep CLI runs them over the default ``dse.serving_study``.

======  ========  =====================================================
code    severity  invariant
======  ========  =====================================================
V101    error     one KV slot + the weights fit *some* node group
V102    error     both SLO terms are positive
V103    error     the trace (and any swept rate) is non-empty, rate > 0
V104    error     a disaggregated placement keeps a decode group
======  ========  =====================================================
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.analysis.diagnostics import (Diagnostic, RuleConfig, rule,
                                        run_pack)
from repro.serving.placement import DisaggregatedPlacement
from repro.serving.spec import ServingSpec, is_serving_axis
from repro.serving.workload import ServingWorkload


def _swept(spec: ServingSpec, path: str) -> List[Any]:
    """Values an axis sweeps onto ``path`` (empty if not swept)."""
    out: List[Any] = []
    for axis in spec.axes:
        if is_serving_axis(axis) and axis.path == path \
                and axis.mode == "set":
            out.extend(axis.values)
    return out


def _placements(spec: ServingSpec) -> List[Tuple[str, Any]]:
    """The spec's placement plus every placement-axis value."""
    out: List[Tuple[str, Any]] = [("placement", spec.placement)]
    for axis in spec.axes:
        if axis.kind == "placement":
            out += [(f"axis {axis.name!r}", v) for v in axis.values]
    return out


@rule("V101", "serving", "error",
      "per-replica KV footprint (weights + one slot) fits some node group")
def _check_kv_fits(spec: ServingSpec,
                   ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    if spec.cluster is None:
        return
    wl = ServingWorkload(spec.model, spec.serving)
    groups = spec.cluster.node_groups
    if any(wl.fits(g.node) for g in groups):
        return
    caps = ", ".join(f"{g.node.name}={g.node.total_cap / 1e9:.0f}GB"
                     for g in groups)
    yield (f"serving study {spec.name!r}",
           f"weights ({wl.weight_bytes / 1e9:.1f}GB) + one KV slot "
           f"({wl.kv_slot_bytes / 1e9:.2f}GB) over "
           f"{spec.serving.nodes_per_replica} node(s) exceed every "
           f"pod's memory ({caps}) — no replica can serve")


@rule("V102", "serving", "error",
      "SLO terms (ttft, tpot) are positive")
def _check_slo(spec: ServingSpec,
               ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    for field in ("ttft", "tpot"):
        vals = [getattr(spec.slo, field)] + _swept(spec, f"slo.{field}")
        for v in vals:
            if not v > 0:
                yield (f"serving study {spec.name!r} slo.{field}",
                       f"SLO must be > 0 seconds, got {v!r} — every "
                       "request would miss and goodput is identically 0")


@rule("V103", "serving", "error",
      "traffic trace is non-empty with a positive arrival rate")
def _check_trace(spec: ServingSpec,
                 ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    loc = f"serving study {spec.name!r} trace"
    rates = [spec.trace.rate] + _swept(spec, "trace.rate")
    for r in rates:
        if not r > 0:
            yield loc, f"arrival rate must be > 0 requests/s, got {r!r}"
    counts = [spec.trace.num_requests] + _swept(spec, "trace.num_requests")
    for n in counts:
        if not n > 0:
            yield loc, f"trace needs num_requests > 0, got {n!r}"


@rule("V104", "serving", "error",
      "disaggregated placements keep at least one decode group")
def _check_decode_group(spec: ServingSpec,
                        ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    n_groups = len(spec.cluster.node_groups) \
        if spec.cluster is not None else None
    for where, value in _placements(spec):
        if not isinstance(value, DisaggregatedPlacement):
            continue
        loc = f"serving study {spec.name!r} {where}"
        if value.decode_groups is None:
            continue
        if len(value.decode_groups) == 0:
            yield (loc, "DisaggregatedPlacement with no decode group — "
                        "the fleet can never emit a token past the first")
        elif n_groups is not None:
            bad = sorted(g for g in value.decode_groups
                         if not 0 <= g < n_groups)
            if bad:
                yield (loc, f"decode_groups {bad} out of range for the "
                            f"cluster's {n_groups} node group(s)")


def analyze_serving(spec: ServingSpec,
                    config: Optional[RuleConfig] = None) -> List[Diagnostic]:
    """Run the V1xx pack against a :class:`ServingSpec`."""
    return run_pack("serving", spec, config=config)
