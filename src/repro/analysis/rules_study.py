"""Study rules (S1xx): a StudySpec is executable before any cell runs.

``run_study`` calls these (plus the K1xx pack on the base cluster) under
its ``validate=`` gate; the same checks run standalone via
:func:`analyze_study`.

======  ========  =====================================================
code    severity  invariant
======  ========  =====================================================
S101    error     dotted-path axes resolve on the base cluster schema
S102    error     metric names don't collide with engine/axis columns
S103    error     placement names (spec + placement axes) resolvable
S104    warning   the strategy space is non-empty on the base cluster
======  ========  =====================================================
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.analysis.diagnostics import Diagnostic, RuleConfig, rule, run_pack
from repro.core.placement import get_placement
from repro.core.study import (StudySpec, as_strategy_space, check_path,
                              is_reliability_axis)


@rule("S101", "study", "error",
      "dotted-path axes resolve against the base cluster's dataclass schema")
def _check_axis_paths(spec: StudySpec,
                      ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    if spec.cluster is None:
        return
    transformed = False
    for axis in spec.axes:
        if axis.kind != "cluster":
            continue
        if is_reliability_axis(axis):
            # resolves against the FailureModel, not the cluster —
            # already validated by StudySpec and the Y1xx pack
            continue
        if axis.apply is not None:
            # An apply axis may rewrite the cluster arbitrarily (even swap
            # its type), so later paths can't be checked statically.
            transformed = True
            continue
        if axis.path is None or transformed:
            continue
        try:
            check_path(spec.cluster, axis.path)
        except (AttributeError, TypeError) as exc:
            yield (f"study {spec.name!r} axis {axis.name!r}",
                   f"path {axis.path!r} does not resolve: {exc}")


@rule("S102", "study", "error",
      "metric names don't shadow engine record columns or axis names")
def _check_metric_names(spec: StudySpec,
                        ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    axis_names = {a.name for a in spec.axes}
    for name in spec.metrics:
        if name in StudySpec.RESERVED_COLUMNS:
            yield (f"study {spec.name!r} metric {name!r}",
                   "shadows an engine record column — the metric value "
                   "would silently overwrite it")
        elif name in axis_names:
            yield (f"study {spec.name!r} metric {name!r}",
                   "shadows an axis column of the same name")


@rule("S103", "study", "error",
      "placement names (spec and placement-axis values) resolvable")
def _check_placements(spec: StudySpec,
                      ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    try:
        get_placement(spec.placement)
    except (KeyError, TypeError, ValueError) as exc:
        yield f"study {spec.name!r} placement", str(exc)
    for axis in spec.axes:
        if axis.kind != "placement":
            continue
        for value in axis.values:
            try:
                get_placement(value)
            except (KeyError, TypeError, ValueError) as exc:
                yield (f"study {spec.name!r} axis {axis.name!r} "
                       f"value {value!r}", str(exc))


@rule("S104", "study", "warning",
      "the strategy space yields at least one strategy on the base cluster")
def _check_strategy_space(spec: StudySpec,
                          ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    space = as_strategy_space(spec.strategies)
    if space is None or spec.cluster is None:
        return
    num_nodes = spec.cluster.num_nodes
    if not space.specs(num_nodes):
        yield (f"study {spec.name!r}",
               f"{type(space).__name__} yields no strategies for the "
               f"{num_nodes}-node base cluster — every cell would be "
               "skipped")


def analyze_study(spec: StudySpec,
                  config: Optional[RuleConfig] = None) -> List[Diagnostic]:
    """Run the S1xx pack against one study spec."""
    return run_pack("study", spec, {}, config)
