"""Workload rules (W1xx): static invariants of a decomposed Workload.

These inspect the layer/op/event IR that :func:`repro.core.workload.decompose`
emits — the same structures both engines consume — without timing anything.

======  ========  =====================================================
code    severity  invariant
======  ========  =====================================================
W101    error     CommEvent scopes limited to the simulator's streams
W102    warning   every communicator has group size > 1
W103    error     FLOP / weight-byte totals conserved vs. a baseline
                  factorization (needs ``ctx["baseline"]``)
W104    error     stage ids dense in [0, pp); p2p only at boundaries
W105    error     bytes / FLOPs / dims nonnegative and finite
======  ========  =====================================================
"""

from __future__ import annotations

import math
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.analysis.diagnostics import Diagnostic, RuleConfig, rule, run_pack
from repro.core.compiled import SCOPES
from repro.core.gemm import ExplicitOp, Gemm
from repro.core.topology import _group_size
from repro.core.workload import LayerSpec, Workload

_REL_TOL = 1e-9


def _loc(wl: Workload, i: int, layer: LayerSpec, detail: str = "") -> str:
    base = f"workload {wl.name!r} layer[{i}] {layer.name!r}"
    return f"{base} {detail}" if detail else base


@rule("W101", "workload", "error",
      "CommEvent scopes limited to the simulator's network streams")
def _check_scopes(wl: Workload,
                  ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    for i, layer, phase, ev in wl.comm_events():
        if ev.scope not in SCOPES:
            yield (_loc(wl, i, layer, f"{phase} {ev.collective}"),
                   f"scope {ev.scope!r} is not one of {SCOPES}")


@rule("W102", "workload", "warning",
      "every communication event addresses a group of size > 1")
def _check_group_sizes(wl: Workload,
                       ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    sizes = {s: _group_size(s, wl.mp, wl.dp, wl.pp, wl.ep) for s in SCOPES}
    for i, layer, phase, ev in wl.comm_events():
        n = sizes.get(ev.scope)
        if n is not None and n <= 1:
            yield (_loc(wl, i, layer, f"{phase} {ev.collective}"),
                   f"scope {ev.scope!r} has group size {n} at "
                   f"(mp={wl.mp}, dp={wl.dp}, pp={wl.pp}, ep={wl.ep}) — "
                   "the collective is a no-op")


@rule("W103", "workload", "error",
      "FLOP and weight-byte totals conserved across factorizations")
def _check_conservation(wl: Workload,
                        ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    baseline: Optional[Workload] = ctx.get("baseline")
    if baseline is None or baseline is wl:
        return
    # The invariant only holds exactly for dense workloads: expert layers
    # shard weights over EP and reroute tokens, and sparse layers override
    # optimizer traffic (see tests/test_property.py, which pins the dynamic
    # form of this check).
    if any(layer.expert_bytes for layer in wl.layers) \
            or any(layer.expert_bytes for layer in baseline.layers):
        return
    if wl.mp != baseline.mp or wl.dp * wl.ep != baseline.dp * baseline.ep:
        return
    loc = f"workload {wl.name!r}"
    f_wl, f_base = wl.total_flops(), baseline.total_flops()
    if not math.isclose(f_wl, f_base, rel_tol=_REL_TOL):
        yield (loc,
               f"per-node FLOPs {f_wl:.6g} != baseline {f_base:.6g} at equal "
               f"(mp, dp*ep) — lost or duplicated work across "
               f"(pp={wl.pp}, ep={wl.ep}) vs "
               f"(pp={baseline.pp}, ep={baseline.ep})")
    w_wl, w_base = wl.total_weight_bytes(), baseline.total_weight_bytes()
    if not math.isclose(w_wl, w_base, rel_tol=_REL_TOL):
        yield (loc,
               f"replica weight bytes {w_wl:.6g} != baseline {w_base:.6g} "
               f"at equal mp — parameters lost or duplicated across stages")


@rule("W104", "workload", "error",
      "stage ids dense in [0, pp); p2p events only at stage boundaries")
def _check_stages(wl: Workload,
                  ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    pp = max(1, wl.pp)
    stages = [layer.stage for layer in wl.layers]
    bad_ids = sorted({s for s in stages if not 0 <= s < pp})
    if bad_ids:
        yield (f"workload {wl.name!r}",
               f"stage ids {bad_ids} outside [0, {pp})")
    missing = sorted(set(range(pp)) - set(stages))
    if missing:
        yield (f"workload {wl.name!r}",
               f"stages {missing} own no layers (ids must be dense)")
    if any(b < a for a, b in zip(stages, stages[1:])):
        yield (f"workload {wl.name!r}",
               "stage ids decrease along the layer list — layers must be "
               "grouped in pipeline order")
    # p2p activation hand-offs: comm_fwd on the last layer of stage s (to
    # s+1), comm_ig on the first layer of stage s (from s-1), nowhere else.
    first: Dict[int, int] = {}
    last: Dict[int, int] = {}
    for i, layer in enumerate(wl.layers):
        first.setdefault(layer.stage, i)
        last[layer.stage] = i
    for i, layer, phase, ev in wl.comm_events():
        if ev.scope != "pp":
            continue
        where = _loc(wl, i, layer, f"{phase} {ev.collective}")
        if pp <= 1:
            yield where, "pp-scope event in an unpipelined workload"
        elif phase == "fp":
            if i != last.get(layer.stage) or layer.stage >= pp - 1:
                yield (where,
                       "forward p2p must sit on the last layer of a "
                       f"non-final stage (layer stage {layer.stage})")
        elif phase == "ig":
            if i != first.get(layer.stage) or layer.stage == 0:
                yield (where,
                       "backward p2p must sit on the first layer of a "
                       f"non-initial stage (layer stage {layer.stage})")
        else:
            yield where, "p2p events may not appear in the WG phase"


def _bad_number(x: float) -> bool:
    return not math.isfinite(x) or x < 0


@rule("W105", "workload", "error",
      "bytes, FLOPs, and operand dims nonnegative and finite")
def _check_finite(wl: Workload,
                  ctx: Dict[str, Any]) -> Iterator[Tuple[str, str]]:
    for i, layer in enumerate(wl.layers):
        for field in ("weight_bytes", "act_out_bytes", "expert_bytes"):
            v = getattr(layer, field)
            if _bad_number(v):
                yield _loc(wl, i, layer), f"{field} = {v!r}"
        if layer.repeat < 1:
            yield _loc(wl, i, layer), f"repeat = {layer.repeat!r} (must be >= 1)"
        if layer.expert_bytes > layer.weight_bytes:
            yield (_loc(wl, i, layer),
                   f"expert_bytes {layer.expert_bytes} exceeds "
                   f"weight_bytes {layer.weight_bytes}")
        if layer.optim_bytes is not None and _bad_number(layer.optim_bytes):
            yield _loc(wl, i, layer), f"optim_bytes = {layer.optim_bytes!r}"
        for phase, ops in (("fp", layer.fwd), ("ig", layer.ig),
                           ("wg", layer.wg)):
            for op in ops:
                if isinstance(op, Gemm):
                    if min(op.m, op.k, op.n, op.batch) <= 0:
                        yield (_loc(wl, i, layer, phase),
                               f"degenerate GEMM dims (m={op.m}, k={op.k}, "
                               f"n={op.n}, batch={op.batch})")
                elif isinstance(op, ExplicitOp):
                    if _bad_number(op.flops) or _bad_number(op.bytes_moved):
                        yield (_loc(wl, i, layer, phase),
                               f"ExplicitOp flops={op.flops!r} "
                               f"bytes={op.bytes_moved!r}")
    for i, layer, phase, ev in wl.comm_events():
        if _bad_number(ev.size_bytes):
            yield (_loc(wl, i, layer, f"{phase} {ev.collective}"),
                   f"size_bytes = {ev.size_bytes!r}")


def analyze_workload(wl: Workload, baseline: Optional[Workload] = None,
                     config: Optional[RuleConfig] = None) -> List[Diagnostic]:
    """Run the W1xx pack. ``baseline`` (same model/shape/mp with
    ``baseline.dp * baseline.ep == wl.dp * wl.ep``) enables the W103
    conservation check; without one, W103 is vacuous."""
    return run_pack("workload", wl, {"baseline": baseline}, config)
