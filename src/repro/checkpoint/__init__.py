"""Atomic/async checkpointing with retention + elastic re-shard restore."""
from repro.checkpoint.checkpointer import Checkpointer, CheckpointManager  # noqa: F401
