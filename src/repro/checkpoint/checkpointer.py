"""Atomic, async-capable, resharding checkpointer.

Format: one directory per step —
    ckpt_dir/step_000123/
        meta.json                 (step, flat key list, dtypes, shapes)
        <flat-key>.npy            (one file per leaf)
    ckpt_dir/step_000123.done     (commit marker)

Writes go to ``step_X.tmp`` and are renamed after the commit marker is
fsynced — a crash mid-write never corrupts the latest checkpoint (restore
scans for the newest ``.done`` whose directory actually holds a
``meta.json``, falling back past stale markers left by an interrupted
re-save; orphaned ``step_X.tmp`` buffers are GC'd on construction).
``save_async`` runs the serialization on a worker thread so the train
loop only pays for the host transfer.

Elastic restore: leaves are stored unsharded; ``restore`` device_puts them
under whatever shardings the *current* mesh dictates, so restarting on a
different DP/TP degree re-shards transparently. (A production deployment
would write per-shard files + a global index; the commit protocol and the
re-shard path are the load-bearing parts and are identical.)
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

_SEP = "::"

# numpy can't serialize ml_dtypes (bf16, fp8) via np.save — store the raw
# bit pattern in a same-width integer view and record the logical dtype.
_EXOTIC_TO_STORAGE = {
    np.dtype(ml_dtypes.bfloat16): np.uint16,
    np.dtype(ml_dtypes.float8_e4m3fn): np.uint8,
    np.dtype(ml_dtypes.float8_e5m2): np.uint8,
}
_NAME_TO_EXOTIC = {str(d): d for d in _EXOTIC_TO_STORAGE}


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        # GC orphaned write buffers from a previous crashed save: a
        # step_X.tmp dir is by construction uncommitted and unreadable.
        for name in os.listdir(directory):
            if name.startswith("step_") and name.endswith(".tmp"):
                path = os.path.join(directory, name)
                if os.path.isdir(path):
                    shutil.rmtree(path)

    # ------------------------------------------------------------------ #
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> str:
        """Blocking atomic save."""
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(tree)
        meta = {"step": step, "keys": [], "extra": extra or {}}
        for key, leaf in flat:
            arr = np.asarray(jax.device_get(leaf))
            fname = key.replace("/", "_") + ".npy"
            logical = str(arr.dtype)
            storage = _EXOTIC_TO_STORAGE.get(arr.dtype)
            np.save(os.path.join(tmp, fname),
                    arr.view(storage) if storage else arr)
            meta["keys"].append(
                {"key": key, "file": fname, "dtype": logical,
                 "shape": list(arr.shape)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        done = final + ".done"
        if os.path.exists(final):
            # Re-save of an existing step: drop the commit marker before
            # touching the directory, so a crash inside the swap window
            # leaves no marker pointing at a missing/partial checkpoint.
            if os.path.exists(done):
                os.remove(done)
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(done, "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        return final

    def save_async(self, step: int, tree, extra: Optional[Dict] = None):
        """Non-blocking save: transfers to host now, writes on a thread."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self.wait()
        self._thread = threading.Thread(
            target=self.save, args=(step, host_tree, extra), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------ #
    def _committed_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and name.endswith(".done"):
                try:
                    steps.append(int(name[len("step_"):-len(".done")]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        """Newest step that is both committed (``.done``) and readable
        (``meta.json`` present).  A stale marker left by an interrupted
        re-save is skipped, falling back to the next-newest step."""
        for s in reversed(self._committed_steps()):
            if os.path.isfile(os.path.join(self._step_dir(s), "meta.json")):
                return s
        return None

    def restore(self, step: Optional[int] = None, target=None,
                shardings=None) -> Tuple[Any, Dict]:
        """Returns (tree, extra). ``target`` provides the tree structure;
        ``shardings`` (same structure) re-shards onto the current mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        by_key = {e["key"]: e for e in meta["keys"]}

        def _load(e):
            arr = np.load(os.path.join(d, e["file"]))
            exotic = _NAME_TO_EXOTIC.get(e["dtype"])
            return arr.view(exotic) if exotic is not None else arr

        if target is None:
            # reconstruct flat dict
            out = {e["key"]: _load(e) for e in meta["keys"]}
            return out, meta.get("extra", {})

        flat = _flatten(target)
        missing = sorted(k for k, _ in flat if k not in by_key)
        unexpected = sorted(set(by_key) - {k for k, _ in flat})
        if missing or unexpected:
            raise KeyError(
                f"checkpoint step {step} does not match the target tree: "
                f"missing from checkpoint: {missing or 'none'}; "
                f"unexpected in checkpoint: {unexpected or 'none'}")
        sh_flat = (_flatten(shardings) if shardings is not None
                   else [(k, None) for k, _ in flat])
        leaves = []
        for (key, _leaf), (_, sh) in zip(flat, sh_flat):
            e = by_key[key]
            arr = _load(e)
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(target)
        return (jax.tree_util.tree_unflatten(treedef, leaves),
                meta.get("extra", {}))


class CheckpointManager:
    """Retention + cadence policy around a Checkpointer."""

    def __init__(self, directory: str, interval: int = 100,
                 keep: int = 3, async_save: bool = True):
        self.ckpt = Checkpointer(directory)
        self.interval = interval
        self.keep = keep
        self.async_save = async_save

    def maybe_save(self, step: int, tree, extra=None, force=False) -> bool:
        if not force and (self.interval <= 0 or step % self.interval != 0):
            return False
        if force:
            # Drain any in-flight async save; skip if this step is already
            # committed (final flush after a cadence save of the same step).
            self.ckpt.wait()
            if self.latest_step() == step:
                return False
        if self.async_save and not force:
            self.ckpt.save_async(step, tree, extra)
        else:
            self.ckpt.save(step, tree, extra)
        self._gc()
        return True

    def _gc(self) -> None:
        steps = sorted(
            int(n[len("step_"):-len(".done")])
            for n in os.listdir(self.ckpt.directory) if n.endswith(".done"))
        for s in steps[:-self.keep] if self.keep > 0 else []:
            d = self.ckpt._step_dir(s)
            for path in (d, d + ".done"):
                if os.path.isdir(path):
                    shutil.rmtree(path)
                elif os.path.exists(path):
                    os.remove(path)

    def restore_latest(self, target=None, shardings=None):
        return self.ckpt.restore(None, target, shardings)

    def latest_step(self):
        return self.ckpt.latest_step()

    def wait(self):
        self.ckpt.wait()
