"""Architecture registry.

``get_config("internlm2-20b")`` -> full ModelConfig
``get_config("internlm2-20b", reduced=True)`` -> CPU smoke-test variant
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    SHAPES,
    EncDecConfig,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    VisionStubConfig,
    pad_vocab,
)

# arch-id -> module name under repro.configs
_ARCH_MODULES: Dict[str, str] = {
    "internlm2-20b": "internlm2_20b",
    "chatglm3-6b": "chatglm3_6b",
    "minitron-8b": "minitron_8b",
    "smollm-135m": "smollm_135m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "mamba2-780m": "mamba2_780m",
    "zamba2-2.7b": "zamba2_2p7b",
    "internvl2-76b": "internvl2_76b",
    # paper case-study models (analytical path; not dry-run archs)
    "transformer-1t": "transformer_1t",
}

ASSIGNED_ARCHS: List[str] = [a for a in _ARCH_MODULES if a != "transformer-1t"]


def list_configs() -> List[str]:
    """All registry arch ids (the sweep surface of ``python -m
    repro.analysis``)."""
    return sorted(_ARCH_MODULES)


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    if reduced:
        if hasattr(mod, "REDUCED"):
            return mod.REDUCED
        return mod.CONFIG.reduced()
    return mod.CONFIG


def get_dlrm_config(reduced: bool = False):
    from repro.configs import dlrm_1p2t
    return dlrm_1p2t.REDUCED if reduced else dlrm_1p2t.CONFIG


def all_cells() -> List[tuple]:
    """Every (arch_id, shape_name) cell, including documented skips.

    Returns (arch_id, shape_name, runnable: bool, skip_reason: str).
    """
    cells = []
    for arch_id in ASSIGNED_ARCHS:
        cfg = get_config(arch_id)
        runnable = set(cfg.applicable_shapes())
        for shape_name in SHAPES:
            if shape_name in runnable:
                cells.append((arch_id, shape_name, True, ""))
            else:
                cells.append((arch_id, shape_name, False,
                              "long_500k skipped: full quadratic attention at "
                              "512k context is mis-provisioned (DESIGN.md "
                              "§Arch-applicability)"))
    return cells
