"""Config dataclasses for model architectures and input shapes.

Every assigned architecture is expressed as a :class:`ModelConfig`. The same
object drives three independent consumers:

  * ``repro.models``      — builds the real JAX module (full or reduced),
  * ``repro.core.workload`` — builds the COMET analytical layer decomposition,
  * ``repro.launch.dryrun`` — builds ShapeDtypeStruct input specs and shardings.

Keeping one source of truth means the analytical COMET path and the compiled
dry-run path always describe the same model.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# Vocabulary is padded so each of the 16 model-parallel shards is a multiple
# of the 128-lane TPU register width: pad unit = 16 * 128 = 2048.
VOCAB_PAD_UNIT = 2048


def pad_vocab(vocab_size: int, unit: int = VOCAB_PAD_UNIT) -> int:
    return int(math.ceil(vocab_size / unit) * unit)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts block parameters."""

    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    moe_every: int = 1             # MoE block every k-th layer (others dense)
    shared_expert: bool = False    # Llama4-style always-on shared expert
    shared_expert_d_ff: int = 0    # 0 -> same as d_ff
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    # "gather": capacity-based top-C gather/scatter dispatch (EP-friendly).
    # "dense": run every expert on every token, weight by the combine matrix
    #          — no dispatch collectives; profitable for fine-grained experts
    #          under expert-TP where E*d_ff is small (granite: 40 x 512).
    dispatch: str = "gather"

    @property
    def shared_d_ff(self) -> int:
        return self.shared_expert_d_ff or self.d_ff


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD (state-space duality) block parameters."""

    state_dim: int                 # N: per-head SSM state size
    head_dim: int = 64             # P: channels per SSD head
    expand: int = 2                # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256          # SSD chunk length
    ngroups: int = 1               # B/C groups (GQA-like for SSM)


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder split (seamless-m4t style)."""

    encoder_layers: int
    decoder_layers: int
    # Ratio of encoder source length to decoder target length for a given
    # shape's seq_len budget (audio encoders see long frame sequences).
    source_frac: float = 0.5


@dataclasses.dataclass(frozen=True)
class VisionStubConfig:
    """Modality frontend stub: input_specs() supplies precomputed embeddings."""

    num_patches: int = 256         # vision prefix length (per image)
    patch_embed_dim: int = 0       # 0 -> d_model


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: SSM trunk + shared (reused) attention block."""

    attn_every: int = 6            # shared attention block applied every k layers
    attn_concat_embedding: bool = True  # block input = concat(h, initial_emb)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: (seq_len, global_batch, kind).

    ``num_microbatches`` is the pipeline-parallel microbatch count used
    when a strategy has pp > 1 (0 = auto: the decomposition defaults to
    4 * pp, capped at the per-replica batch)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"
    num_microbatches: int = 0

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description.

    ``family`` is one of: dense | moe | ssm | hybrid | encdec | vlm.
    Unused fields for a family are left at their defaults.
    """

    arch_id: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # Attention / positional details
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0     # chatglm3 "2d RoPE": rotary on half the head dim
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    activation: str = "swiglu"     # swiglu | gelu
    # When num_heads % tp != 0 the sharding rules replicate attention over
    # the model axis; this knob re-shards the attention BATCH over
    # ("data","model") instead, removing the 16x redundant compute+traffic
    # (§Perf hillclimb; needs an ambient mesh with those axes).
    attn_batch_shard: bool = False
    # Sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: Optional[EncDecConfig] = None
    vision: Optional[VisionStubConfig] = None
    hybrid: Optional[HybridConfig] = None
    # Bookkeeping
    source: str = ""               # provenance note ([arXiv/hf; tier])
    notes: str = ""

    # ------------------------------------------------------------------ #
    # Derived quantities
    # ------------------------------------------------------------------ #
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing -> long_500k is runnable."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.head_dim

    def applicable_shapes(self) -> Tuple[str, ...]:
        """Which of the four assigned shapes this arch runs (others are
        documented skips — see DESIGN.md §Arch-applicability)."""
        names = ["train_4k", "prefill_32k", "decode_32k"]
        if self.supports_long_context:
            names.append("long_500k")
        return tuple(names)

    # ------------------------------------------------------------------ #
    # Parameter counting (used for MODEL_FLOPS = 6*N*D and footprints)
    # ------------------------------------------------------------------ #
    def _attn_params(self) -> int:
        hd = self.resolved_head_dim
        q = self.d_model * self.num_heads * hd
        kv = 2 * self.d_model * self.num_kv_heads * hd
        o = self.num_heads * hd * self.d_model
        return q + kv + o

    def _dense_ffn_params(self, d_ff: int) -> int:
        mult = 3 if self.activation == "swiglu" else 2
        return mult * self.d_model * d_ff

    def _ssm_params(self) -> int:
        assert self.ssm is not None
        di, ng, n = self.d_inner, self.ssm.ngroups, self.ssm.state_dim
        nheads = self.ssm_heads
        in_proj = self.d_model * (2 * di + 2 * ng * n + nheads)
        conv = self.ssm.conv_width * (di + 2 * ng * n)
        out_proj = di * self.d_model
        head_extra = 2 * nheads  # A_log, D
        return in_proj + conv + out_proj + head_extra

    def _layer_params(self, layer_idx: int) -> int:
        """Parameter count of one trunk layer (by family)."""
        norms = 2 * self.d_model
        if self.family == "ssm":
            return self._ssm_params() + self.d_model  # single pre-norm
        if self.family == "hybrid":
            # SSM trunk layer; the shared attention block is counted once
            # globally in param_count().
            return self._ssm_params() + self.d_model
        attn = self._attn_params()
        if self.family == "moe":
            assert self.moe is not None
            if (layer_idx % self.moe.moe_every) == (self.moe.moe_every - 1):
                ffn = self.moe.num_experts * self._dense_ffn_params(self.moe.d_ff)
                ffn += self.d_model * self.moe.num_experts  # router
                if self.moe.shared_expert:
                    ffn += self._dense_ffn_params(self.moe.shared_d_ff)
            else:
                ffn = self._dense_ffn_params(self.d_ff)
            return attn + ffn + norms
        # dense / vlm backbone / encdec trunk layer
        return attn + self._dense_ffn_params(self.d_ff) + norms

    def _shared_attn_params(self) -> int:
        """Zamba2 shared attention block (input dim 2*d_model)."""
        assert self.hybrid is not None
        d_in = 2 * self.d_model if self.hybrid.attn_concat_embedding else self.d_model
        hd = self.resolved_head_dim
        q = d_in * self.num_heads * hd
        kv = 2 * d_in * self.num_kv_heads * hd
        o = self.num_heads * hd * self.d_model
        ffn = self._dense_ffn_params(self.d_ff) if self.d_ff else 0
        return q + kv + o + ffn + 2 * d_in

    def param_count(self) -> int:
        """Total parameters (with padded vocab)."""
        emb = self.padded_vocab * self.d_model
        head = 0 if self.tie_embeddings else self.padded_vocab * self.d_model
        total = emb + head + self.d_model  # final norm
        if self.family == "encdec":
            assert self.encdec is not None
            for i in range(self.encdec.encoder_layers):
                total += self._layer_params(i)
            for i in range(self.encdec.decoder_layers):
                total += self._layer_params(i) + self._attn_params() + self.d_model  # + cross-attn
        else:
            for i in range(self.num_layers):
                total += self._layer_params(i)
            if self.family == "hybrid":
                total += self._shared_attn_params()
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        assert self.moe is not None
        total = self.param_count()
        # Subtract inactive experts.
        n_moe_layers = sum(
            1 for i in range(self.num_layers)
            if (i % self.moe.moe_every) == (self.moe.moe_every - 1)
        )
        per_expert = self._dense_ffn_params(self.moe.d_ff)
        inactive = n_moe_layers * (self.moe.num_experts - self.moe.top_k) * per_expert
        return total - inactive

    # ------------------------------------------------------------------ #
    # Reduced config for CPU smoke tests
    # ------------------------------------------------------------------ #
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config: few layers, narrow width, small vocab."""
        kw: dict = dict(
            arch_id=self.arch_id + "-reduced",
            family=self.family,
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 2,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            rope_theta=self.rope_theta,
            rope_fraction=self.rope_fraction,
            tie_embeddings=self.tie_embeddings,
            activation=self.activation,
            source=self.source,
            notes="reduced smoke-test variant",
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2), d_ff=64,
                shared_expert_d_ff=64 if self.moe.shared_expert else 0)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=16, chunk_size=32)
        if self.encdec is not None:
            kw["encdec"] = dataclasses.replace(
                self.encdec, encoder_layers=2, decoder_layers=2)
        if self.vision is not None:
            kw["vision"] = dataclasses.replace(self.vision, num_patches=8)
        if self.hybrid is not None:
            kw["hybrid"] = dataclasses.replace(self.hybrid, attn_every=2)
        return ModelConfig(**kw)
