"""chatglm3-6b — dense GQA with 2d (partial) RoPE. [arXiv:2406.12793; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    rope_theta=10_000.0,
    rope_fraction=0.5,  # GLM "2d RoPE": rotary applied to half the head dim
    activation="swiglu",
    source="[arXiv:2406.12793; hf]",
    notes="kv=2 < TP=16 -> KV projections replicated across the model axis; "
          "vocab padded 65024 -> 65536.",
)

REDUCED = CONFIG.reduced()
