"""DLRM-1.2T — the paper's §V-C case-study model (Rashidi et al. [56] Table V).

DLRM does not fit :class:`ModelConfig`; it has its own dataclass consumed by
``repro.core.workload.decompose_dlrm`` (analytical path) and
``repro.models.dlrm`` (runnable reduced model).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    arch_id: str
    emb_dim: int
    num_tables: int
    rows_per_table: int            # uniform proxy for the published table mix
    lookups_per_table: int         # pooled multi-hot lookups per sample
    num_dense_features: int
    bottom_mlp: Tuple[int, ...]
    top_mlp: Tuple[int, ...]

    def embedding_params(self) -> int:
        return self.num_tables * self.rows_per_table * self.emb_dim

    def mlp_params(self) -> int:
        total = 0
        dims = (self.num_dense_features,) + self.bottom_mlp
        for a, b in zip(dims[:-1], dims[1:]):
            total += a * b + b
        # feature-interaction output feeds the top MLP
        n_feat = self.num_tables + 1
        interact = n_feat * (n_feat - 1) // 2 + self.bottom_mlp[-1]
        dims = (interact,) + self.top_mlp
        for a, b in zip(dims[:-1], dims[1:]):
            total += a * b + b
        return total

    def param_count(self) -> int:
        return self.embedding_params() + self.mlp_params()


# ~1.2T parameters: 64 tables x 146.5M rows x 128 dims = 1.2e12.
CONFIG = DLRMConfig(
    arch_id="dlrm-1.2t",
    emb_dim=128,
    num_tables=64,
    rows_per_table=146_484_375,
    lookups_per_table=32,
    num_dense_features=13,
    bottom_mlp=(512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
)

# Reduced, runnable variant for smoke tests / examples.
REDUCED = DLRMConfig(
    arch_id="dlrm-reduced",
    emb_dim=16,
    num_tables=4,
    rows_per_table=1000,
    lookups_per_table=32,
    num_dense_features=13,
    bottom_mlp=(32, 16),
    top_mlp=(32, 16, 1),
)
