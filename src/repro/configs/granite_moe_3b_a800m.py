"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,  # per-expert hidden dim (fine-grained experts)
    vocab_size=49155,
    rope_theta=10_000.0,
    tie_embeddings=True,
    activation="swiglu",
    moe=MoEConfig(
        num_experts=40,
        top_k=8,
        d_ff=512,
        moe_every=1,
        shared_expert=False,
        capacity_factor=1.5,
    ),
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]",
    notes="40 experts not divisible by 16 ranks -> expert-TP: every expert's "
          "d_ff=512 is sharded 16-way (32 cols/rank) instead of EP. "
          "vocab padded 49155 -> 51200.",
)

REDUCED = CONFIG.reduced()
