"""internlm2-20b — dense GQA decoder-only LM. [arXiv:2403.17297; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    rope_theta=1_000_000.0,
    activation="swiglu",
    source="[arXiv:2403.17297; hf]",
    notes="GQA kv=8; vocab padded 92544 -> 94208 for 16-way TP.",
)

REDUCED = CONFIG.reduced()
