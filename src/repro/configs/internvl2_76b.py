"""internvl2-76b — VLM: InternViT frontend (STUB) + LLaMA-3-70B-class backbone.
[arXiv:2404.16821; unverified]

Per the assignment the vision frontend is a stub: ``input_specs()`` provides
precomputed patch embeddings (batch, num_patches, d_model) which are prepended
to the token embeddings. Only the language backbone is modeled.
"""

from repro.configs.base import ModelConfig, VisionStubConfig

CONFIG = ModelConfig(
    arch_id="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    activation="swiglu",
    vision=VisionStubConfig(num_patches=256),
    source="[arXiv:2404.16821; unverified]",
    notes="Largest assigned dense model (~76B). vocab padded 128256 -> 129024.",
)

REDUCED = CONFIG.reduced()
