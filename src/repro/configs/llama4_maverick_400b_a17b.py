"""llama4-maverick-400b-a17b — interleaved MoE, 128 experts top-1, shared expert.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

The brief's header (48L d_model=5120 40H kv=8 d_ff=8192 vocab=202048, MoE 128e
top-1) with MoE in *every* layer yields ~775B parameters; the production
Maverick interleaves MoE every other layer (dense FFN between), which lands at
~400B total / ~17B active — matching the model name. We model ``moe_every=2``
with an always-on shared expert, and note the [unverified] tier.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,  # dense (non-MoE) interleaved layers use 2*expert d_ff
    vocab_size=202048,
    rope_theta=500_000.0,
    activation="swiglu",
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        d_ff=8192,
        moe_every=2,
        shared_expert=True,
        shared_expert_d_ff=8192,
        capacity_factor=1.25,
    ),
    source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
    notes="EP over the model axis: 128 experts / 16 ranks = 8 experts/rank. "
          "40 heads not divisible by 16 -> attention projections replicated "
          "over the model axis; vocab padded 202048 -> 202752.",
)

REDUCED = CONFIG.reduced()
