"""mamba2-780m — attention-free SSD (state-space duality). [arXiv:2405.21060; unverified]"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,      # attention-free
    num_kv_heads=0,
    d_ff=0,           # Mamba2 block has no separate FFN
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(
        state_dim=128,
        head_dim=64,   # d_inner = 2*1536 = 3072 -> 48 SSD heads
        expand=2,
        conv_width=4,
        chunk_size=256,
        ngroups=1,
    ),
    source="[arXiv:2405.21060; unverified]",
    notes="Sub-quadratic: runs long_500k. vocab padded 50280 -> 51200. "
          "Decode carries (conv_state, ssm_state) recurrent state, no KV cache.",
)

REDUCED = CONFIG.reduced()
