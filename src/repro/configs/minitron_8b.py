"""minitron-8b — width-pruned Nemotron-4, dense GQA. [arXiv:2407.14679; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    rope_theta=10_000.0,
    activation="gelu",  # Nemotron uses squared-ReLU-family; modeled as gelu (2-matrix FFN)
    source="[arXiv:2407.14679; hf]",
    notes="Large 256k vocab (already 2048-aligned); pruned-teacher arch.",
)

REDUCED = CONFIG.reduced()
