"""seamless-m4t-large-v2 — enc-dec multimodal (audio) backbone. [arXiv:2308.11596; hf]

The modality frontend (speech feature extractor / w2v-BERT conv stack) is a
STUB per the assignment: ``input_specs()`` provides precomputed frame
embeddings of shape (batch, src_len, d_model). Only the transformer
encoder-decoder backbone is modeled.
"""

from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-large-v2",
    family="encdec",
    num_layers=48,  # 24 encoder + 24 decoder (brief: 24L per stack)
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,  # MHA
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    rope_theta=10_000.0,
    activation="gelu",
    encdec=EncDecConfig(encoder_layers=24, decoder_layers=24, source_frac=0.5),
    source="[arXiv:2308.11596; hf]",
    notes="Audio frontend stubbed (precomputed frame embeddings). "
          "vocab padded 256206 -> 258048. Decode shapes run on the decoder "
          "with self-attn KV cache + precomputed cross-attn KV.",
)

REDUCED = CONFIG.reduced()
