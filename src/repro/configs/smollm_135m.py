"""smollm-135m — llama-arch small dense GQA. [hf:HuggingFaceTB/SmolLM-135M; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49152,
    rope_theta=10_000.0,
    tie_embeddings=True,
    activation="swiglu",
    source="[hf:HuggingFaceTB/SmolLM-135M; hf]",
    notes="9 heads not divisible by TP=16 -> attention replicated over the "
          "model axis (sharding rule falls back per-tensor); d_ff shards 16-way.",
)

REDUCED = CONFIG.reduced()
