"""Transformer-1T — the paper's §V-B case-study model (Megatron-LM 1T).

Megatron-LM's published 1T configuration: 128 layers, hidden 25600, 160 heads,
d_ff = 4*hidden, seq 2048 [arXiv:2104.04473 Table 1]. 12*L*h^2 ~= 1.007e12.
This config feeds the COMET *analytical* path (benchmarks reproducing
Fig. 6/8/9/10/11/12/15); it is not one of the ten dry-run architectures.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="transformer-1t",
    family="dense",
    num_layers=128,
    d_model=25600,
    num_heads=160,
    num_kv_heads=160,  # paper predates GQA: MHA
    head_dim=160,
    d_ff=102400,
    vocab_size=51200,
    activation="gelu",
    source="[arXiv:2104.04473; paper §V-B]",
    notes="COMET case-study workload; trained seq=2048, mini-batch per paper sweep.",
)

# Paper's training shape: Megatron-LM 1T uses sequence length 2048.
SEQ_LEN = 2048
MICRO_BATCH = 1
