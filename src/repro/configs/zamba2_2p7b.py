"""zamba2-2.7b — hybrid: Mamba2 trunk + shared attention block. [arXiv:2411.15242; hf]"""

from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,  # shared attention block is MHA
    head_dim=160,     # block operates on concat(h, emb) = 2*d_model = 5120
    d_ff=10240,       # shared block's FFN
    vocab_size=32000,
    tie_embeddings=True,
    ssm=SSMConfig(
        state_dim=64,
        head_dim=64,   # d_inner = 2*2560 = 5120 -> 80 SSD heads
        expand=2,
        conv_width=4,
        chunk_size=256,
        ngroups=1,
    ),
    hybrid=HybridConfig(attn_every=6, attn_concat_embedding=True),
    source="[arXiv:2411.15242; hf]",
    notes="One set of attention weights REUSED at layers 6,12,...,54 on "
          "concat(h, initial_emb); sub-quadratic trunk -> runs long_500k. "
          "vocab padded 32000 -> 32768.",
)

REDUCED = CONFIG.reduced()
