"""COMET methodology core: workload modeling, strategy sweeps, roofline +
memory-traffic + collective cost models, and the ASTRA-lite simulator.

This package is the paper's primary contribution, built as a reusable
library. Analytical frontend: configs -> workload.decompose ->
simulator.simulate_iteration. Measured frontend: launch.dryrun ->
hlo.terms_from_compiled -> the same roofline arithmetic.
"""

from repro.core.cluster import (  # noqa: F401
    ClusterConfig,
    ClusterSpec,
    CostModel,
    NodeConfig,
    PodSpec,
    get_cluster,
    list_clusters,
)
from repro.core.topology import (  # noqa: F401
    HierarchicalSwitch,
    SingleSwitch,
    Topology,
    Torus,
)
from repro.core.gemm import CommEvent, ExplicitOp, Gemm, PhaseCost  # noqa: F401
from repro.core.memory import (  # noqa: F401
    effective_memory_bw,
    hybrid_bandwidth,
    model_state_bytes,
    per_node_footprint,
)
from repro.core.placement import (  # noqa: F401
    EMAwarePlacement,
    ExplicitPlacement,
    JobSpec,
    PaperPlacement,
    Placement,
    Schedule,
    ScheduleModel,
    get_placement,
    list_placements,
)
from repro.core.roofline import attainable_perf, compute_delay  # noqa: F401
from repro.core.simulator import (  # noqa: F401
    IterationBreakdown,
    group_breakdowns,
    simulate_iteration,
)
from repro.core.strategy import best_strategy, sweep_strategies  # noqa: F401
from repro.core.study import (  # noqa: F401
    Axis,
    ExplicitSpace,
    FactorizationSpace,
    GridSpace,
    ParallelSpec,
    PowerOfTwoSpace,
    StrategySpace,
    StudyResult,
    StudySpec,
    get_by_path,
    placement_axis,
    run_study,
    set_by_path,
)
from repro.core.search import (  # noqa: F401
    DEFAULT_OBJECTIVES,
    Objective,
    SearchResult,
    evolutionary_search,
    pareto_front,
    successive_halving,
)
from repro.core.workload import Workload, decompose, decompose_dlrm  # noqa: F401
