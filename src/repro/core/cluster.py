"""COMET cluster descriptions: node resources + network topology + cost.

Faithful encodings of the paper's Table I (baseline DGX A100), Table III
(clusters A0..C2, Dojo, TPU v4), plus this repo's deployment target
(TPU v5e pods) used by the dry-run roofline analysis.

The cluster-description layer is composable (cluster-workload co-design,
paper §V-D; cost modeling follows MAD-Max, arXiv:2310.02784):

  * :class:`~repro.core.topology.Topology` — pluggable network protocol
    (families live in :mod:`repro.core.topology`, re-exported here);
  * :class:`PodSpec` — ``count`` pods of ``nodes_per_pod`` x one
    :class:`NodeConfig`, optionally with their own intra-pod ``fabric``;
  * :class:`ClusterSpec` — a tuple of pod groups + shared interconnect +
    an optional first-class :class:`CostModel`, so one cluster can mix
    node types and pod sizes (heterogeneous studies, ROADMAP);
  * :class:`ClusterConfig` — the seed homogeneous shim: same constructor
    signature as ever, now exposing the same ``node_groups`` interface the
    simulator consumes, so every legacy study runs bit-for-bit unchanged.
"""

from __future__ import annotations

import dataclasses
import difflib
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.core.topology import (  # noqa: F401  (re-exported legacy surface)
    HierarchicalSwitch,
    Hop,
    SingleSwitch,
    Topology,
    Torus,
)

GB = 1e9
TB = 1e12
MB = 1e6

HOURS_PER_YEAR = 8760.0


@dataclasses.dataclass(frozen=True)
class NodeConfig:
    """One compute unit (GPU / TPU / tray) — paper's 'node'."""

    name: str
    peak_flops: float              # peak fp16/bf16 FLOP/s
    local_cap: float               # local (HBM) capacity, bytes
    local_bw: float                # local memory bandwidth, bytes/s
    sram_bytes: float              # on-chip buffer S for the traffic model
    exp_cap: float = 0.0           # expanded-memory capacity, bytes
    exp_bw: float = 0.0            # expanded-memory bandwidth, bytes/s
    tdp_watts: float = 0.0         # board power draw, W (TCO energy term)

    @property
    def total_cap(self) -> float:
        return self.local_cap + self.exp_cap

    def with_expansion(self, cap: float, bw: float) -> "NodeConfig":
        return dataclasses.replace(self, exp_cap=cap, exp_bw=bw)

    def scaled_compute(self, factor: float) -> "NodeConfig":
        return dataclasses.replace(self, peak_flops=self.peak_flops * factor)


# --------------------------------------------------------------------- #
# Cost / TCO model (paper §V-D perf-per-dollar; MAD-Max-style knobs)
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class CostModel:
    """Capex + energy model attached to a cluster.

    Capex = per-node price + $/GB of local and expanded memory + $/link
    (links counted via ``Topology.links_per_node``).  Energy = per-node TDP
    x $/kWh over the amortization horizon.  All dollar figures flow into
    the ``cost_usd`` / ``tco`` / ``perf_per_dollar`` StudyResult columns
    and are sweepable as Axis knobs (``path="cost.usd_per_gb_em"``).
    """

    usd_per_node: float = 0.0      # accelerator + host share, excl. memory
    usd_per_gb_local: float = 0.0  # HBM $/GB
    usd_per_gb_em: float = 0.0     # expanded memory $/GB (CXL / HBM-pool)
    usd_per_link: float = 0.0      # per node-facing network link
    usd_per_kwh: float = 0.0
    amortization_years: float = 4.0

    def node_capex(self, node: NodeConfig) -> float:
        return (self.usd_per_node
                + self.usd_per_gb_local * node.local_cap / GB
                + self.usd_per_gb_em * node.exp_cap / GB)

    def capex(self, cluster: "ClusterLike") -> float:
        """Purchase cost of every node + its network links."""
        total = 0.0
        for g in cluster.node_groups:
            per_node = (self.node_capex(g.node)
                        + self.usd_per_link * g.topology.links_per_node)
            total += g.num_nodes * per_node
        return total

    def energy_usd(self, cluster: "ClusterLike") -> float:
        """Electricity over the amortization horizon at per-node TDP."""
        kwh = sum(g.num_nodes * g.node.tdp_watts / 1e3
                  for g in cluster.node_groups) \
            * HOURS_PER_YEAR * self.amortization_years
        return kwh * self.usd_per_kwh

    def tco(self, cluster: "ClusterLike") -> float:
        return self.capex(cluster) + self.energy_usd(cluster)


# --------------------------------------------------------------------- #
# Composable cluster specs
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class PodSpec:
    """``count`` pods of ``nodes_per_pod`` identical nodes.

    ``fabric``, when given, is the complete network as seen by this group
    (its intra-pod fabric plus the shared uplink — e.g. a
    ``HierarchicalSwitch`` with this group's pod size and NVLink intra
    bandwidth); when None the group communicates over the cluster's
    ``interconnect`` unchanged.
    """

    node: NodeConfig
    count: int = 1
    nodes_per_pod: int = 1
    fabric: Optional[Topology] = None

    @property
    def num_nodes(self) -> int:
        return self.count * self.nodes_per_pod

    def with_(self, **updates) -> "PodSpec":
        return dataclasses.replace(self, **updates)


@dataclasses.dataclass(frozen=True)
class NodeGroup:
    """One homogeneous slice of a cluster, as the simulator consumes it."""

    node: NodeConfig
    num_nodes: int
    topology: Topology


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """A composable cluster: pod groups x interconnect x cost model.

    The homogeneous case is a one-liner (:meth:`homogeneous`); the
    heterogeneous case mixes node types / pod sizes by listing several
    :class:`PodSpec` groups.  Synchronous-training semantics downstream:
    the slowest / least-capable group gates the iteration (see
    ``simulate_iteration``).
    """

    name: str
    pods: Tuple[PodSpec, ...]
    interconnect: Topology
    cost: Optional[CostModel] = None
    notes: str = ""

    def __post_init__(self):
        if not self.pods:
            raise ValueError(f"cluster {self.name!r} has no pods")

    # -- interface shared with ClusterConfig ---------------------------- #
    @property
    def num_nodes(self) -> int:
        return sum(p.num_nodes for p in self.pods)

    @property
    def topology(self) -> Topology:
        return self.interconnect

    @property
    def node(self) -> NodeConfig:
        """The single node type — raises on heterogeneous clusters."""
        nodes = {g.node for g in self.node_groups}
        if len(nodes) != 1:
            raise ValueError(
                f"cluster {self.name!r} is heterogeneous "
                f"({len(nodes)} node types); iterate node_groups instead")
        return next(iter(nodes))

    @property
    def node_groups(self) -> Tuple[NodeGroup, ...]:
        groups: Dict[Tuple[NodeConfig, Topology], int] = {}
        for p in self.pods:
            key = (p.node, p.fabric if p.fabric is not None
                   else self.interconnect)
            groups[key] = groups.get(key, 0) + p.num_nodes
        return tuple(NodeGroup(node, n, topo)
                     for (node, topo), n in groups.items())

    @property
    def is_heterogeneous(self) -> bool:
        return len(self.node_groups) > 1

    @property
    def min_node_cap(self) -> float:
        """Least-capable group's per-node capacity (bytes) — the
        synchronous-training feasibility bound under the default
        replicate-everywhere placement."""
        return min(g.node.total_cap for g in self.node_groups)

    # -- functional updates (ClusterConfig-shim parity) ------------------ #
    def with_node(self, node: NodeConfig) -> "ClusterSpec":
        """Replace every pod group's node (legacy axis-lambda parity)."""
        return self.map_nodes(lambda _: node)

    def with_topology(self, topo: Topology) -> "ClusterSpec":
        """Replace the shared interconnect (per-pod fabrics are kept)."""
        return dataclasses.replace(self, interconnect=topo)

    def with_cost(self, cost: CostModel) -> "ClusterSpec":
        return dataclasses.replace(self, cost=cost)

    def with_pods(self, pods: Tuple[PodSpec, ...]) -> "ClusterSpec":
        return dataclasses.replace(self, pods=tuple(pods))

    def map_nodes(self, fn: Callable[[NodeConfig], NodeConfig]) -> "ClusterSpec":
        """Apply ``fn`` to every pod group's node (e.g. add EM everywhere)."""
        return self.with_pods(tuple(p.with_(node=fn(p.node))
                                    for p in self.pods))

    # -- construction ---------------------------------------------------- #
    @classmethod
    def homogeneous(cls, name: str, node: NodeConfig, num_nodes: int,
                    topology: Topology, cost: Optional[CostModel] = None,
                    notes: str = "") -> "ClusterSpec":
        """The seed ``ClusterConfig`` shape as one pod group."""
        return cls(name=name,
                   pods=(PodSpec(node=node, count=1,
                                 nodes_per_pod=num_nodes),),
                   interconnect=topology, cost=cost, notes=notes)


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Homogeneous shim: the seed constructor signature, same semantics.

    Exposes the ``node_groups`` interface of :class:`ClusterSpec`, so the
    simulator / cost model treat both uniformly; ``to_spec()`` lifts it
    into the composable form.
    """

    name: str
    node: NodeConfig
    num_nodes: int
    topology: Topology
    notes: str = ""
    cost: Optional[CostModel] = None

    def with_node(self, node: NodeConfig) -> "ClusterConfig":
        return dataclasses.replace(self, node=node)

    def with_topology(self, topo) -> "ClusterConfig":
        return dataclasses.replace(self, topology=topo)

    def with_cost(self, cost: CostModel) -> "ClusterConfig":
        return dataclasses.replace(self, cost=cost)

    @property
    def node_groups(self) -> Tuple[NodeGroup, ...]:
        return (NodeGroup(self.node, self.num_nodes, self.topology),)

    @property
    def is_heterogeneous(self) -> bool:
        return False

    @property
    def min_node_cap(self) -> float:
        return self.node.total_cap

    @property
    def pods(self) -> Tuple[PodSpec, ...]:
        per_pod = min(self.topology.pod_size, self.num_nodes)
        count, rem = divmod(self.num_nodes, per_pod)
        out = (PodSpec(self.node, count=count, nodes_per_pod=per_pod),)
        if rem:
            out += (PodSpec(self.node, count=1, nodes_per_pod=rem),)
        return out

    def to_spec(self) -> ClusterSpec:
        return ClusterSpec(name=self.name, pods=self.pods,
                           interconnect=self.topology, cost=self.cost,
                           notes=self.notes)


ClusterLike = Union[ClusterConfig, ClusterSpec]


# --------------------------------------------------------------------- #
# Paper Table I: baseline 1024-GPU DGX A100 cluster (8-GPU pods)
# --------------------------------------------------------------------- #

A100_NODE = NodeConfig(
    name="A100",
    peak_flops=624e12,            # fp16 TC peak, Table I
    local_cap=80 * GB,
    local_bw=2039 * GB,
    sram_bytes=40 * MB,
    tdp_watts=400,
)

# Illustrative list-price defaults (sweep them — they are knobs, not data):
# node $ excludes memory, which is priced per GB so EM axes move capex.
_A100_COST = CostModel(usd_per_node=15_000, usd_per_gb_local=24,
                       usd_per_link=400, usd_per_kwh=0.12)

BASELINE_DGX_A100 = ClusterConfig(
    name="dgx-a100-1k",
    node=A100_NODE,
    num_nodes=1024,
    topology=HierarchicalSwitch(pod_size=8, intra_bw=300 * GB, inter_bw=31.25 * GB),
    notes="Paper Table I: 128 pods x 8 GPUs, NVLink3 intra / IB inter.",
    cost=_A100_COST,
)


# --------------------------------------------------------------------- #
# Paper Table III: clusters A/B/C (x memory systems 0/1/2), Dojo, TPU v4
# §V-D: GPU clusters organized in 16-GPU pods.
# --------------------------------------------------------------------- #

_V100 = NodeConfig("V100", 125e12, 80 * GB, 900 * GB, 36 * MB, tdp_watts=300)
_A100 = NodeConfig("A100", 625e12, 80 * GB, 2039 * GB, 40 * MB, tdp_watts=400)
_H100 = NodeConfig("H100", 1979e12, 80 * GB, 3350 * GB, 50 * MB, tdp_watts=700)

_MEMSYS = {
    0: (0.0, 0.0),
    1: (480 * GB, 500 * GB),       # CXL/DDR-class pool: cheap, slower
    2: (201 * GB, 1000 * GB),      # HBM-class pool: pricey, fast
}

_MEMSYS_USD_PER_GB = {0: 0.0, 1: 8.0, 2: 20.0}

_NET = {
    "A": HierarchicalSwitch(16, 150 * GB, 6.25 * GB),
    "B": HierarchicalSwitch(16, 300 * GB, 31.25 * GB),
    "C": HierarchicalSwitch(16, 450 * GB, 62.5 * GB),
}

_BASE = {"A": _V100, "B": _A100, "C": _H100}

_GEN_COST = {
    "A": CostModel(usd_per_node=8_000, usd_per_gb_local=20,
                   usd_per_link=300, usd_per_kwh=0.12),
    "B": CostModel(usd_per_node=15_000, usd_per_gb_local=24,
                   usd_per_link=400, usd_per_kwh=0.12),
    "C": CostModel(usd_per_node=30_000, usd_per_gb_local=40,
                   usd_per_link=600, usd_per_kwh=0.12),
}


def _gpu_variant(letter: str, mem: int) -> ClusterConfig:
    cap, bw = _MEMSYS[mem]
    cost = dataclasses.replace(_GEN_COST[letter],
                               usd_per_gb_em=_MEMSYS_USD_PER_GB[mem])
    return ClusterConfig(
        name=f"{letter}{mem}",
        node=_BASE[letter].with_expansion(cap, bw),
        num_nodes=1024,
        topology=_NET[letter],
        notes=f"Table III {letter}{mem}: {_BASE[letter].name} x1024, 16-GPU pods.",
        cost=cost,
    )


DOJO = ClusterConfig(
    name="dojo",
    node=NodeConfig("DojoTray", 54_300e12, 640 * GB, 16 * TB, 66 * GB,
                    tdp_watts=15_000),
    num_nodes=64,
    topology=SingleSwitch(bw=20 * 50 * GB),
    notes="Table III: 64 trays, one-level switch, 20x50GB/s per direction.",
    cost=CostModel(usd_per_node=180_000, usd_per_gb_local=30,
                   usd_per_link=2_000, usd_per_kwh=0.12),
)

TPU_V4 = ClusterConfig(
    name="tpu-v4",
    node=NodeConfig("TPUv4", 275e12, 32 * GB, 1200 * GB, 32 * MB,
                    exp_cap=39 * GB, exp_bw=1200 * GB, tdp_watts=270),
    num_nodes=4096,
    topology=Torus(dims=(16, 16, 16), link_bw=48 * GB),
    notes="Table III: 4096 chips, 3D torus, 6x48GB/s per direction.",
    cost=CostModel(usd_per_node=9_000, usd_per_gb_local=24,
                   usd_per_gb_em=24, usd_per_link=200, usd_per_kwh=0.12),
)

TABLE_III_CLUSTERS = {
    **{f"{tier}{m}": _gpu_variant(tier, m) for tier in "ABC" for m in (0, 1, 2)},
    "dojo": DOJO,
    "tpu-v4": TPU_V4,
}


# --------------------------------------------------------------------- #
# Heterogeneous example: B-class pods, half with the mem1 expansion
# (paper §V-D perf-per-dollar discussion over a mixed fleet).
# --------------------------------------------------------------------- #

B_HYBRID_EM = ClusterSpec(
    name="b-hybrid-em",
    pods=(PodSpec(_A100, count=32, nodes_per_pod=16),
          PodSpec(_A100.with_expansion(*_MEMSYS[1]), count=32,
                  nodes_per_pod=16)),
    interconnect=_NET["B"],
    cost=dataclasses.replace(_GEN_COST["B"],
                             usd_per_gb_em=_MEMSYS_USD_PER_GB[1]),
    notes="Hetero demo: 32 plain B0 pods + 32 memory-expanded B1 pods.",
)


# --------------------------------------------------------------------- #
# Deployment target: TPU v5e (this repo's dry-run hardware constants)
# --------------------------------------------------------------------- #

V5E_PEAK_FLOPS = 197e12            # bf16 per chip
V5E_HBM_BW = 819e9                 # bytes/s
V5E_HBM_CAP = 16 * GB
V5E_LINK_BW = 50e9                 # per ICI link per direction
V5E_VMEM = 128 * MB

V5E_NODE = NodeConfig(
    name="TPUv5e",
    peak_flops=V5E_PEAK_FLOPS,
    local_cap=V5E_HBM_CAP,
    local_bw=V5E_HBM_BW,
    sram_bytes=V5E_VMEM,
    tdp_watts=200,
)

_V5E_COST = CostModel(usd_per_node=5_000, usd_per_gb_local=24,
                      usd_per_link=150, usd_per_kwh=0.12)

TPU_V5E_POD = ClusterConfig(
    name="tpu-v5e-pod",
    node=V5E_NODE,
    num_nodes=256,
    topology=Torus(dims=(16, 16), link_bw=V5E_LINK_BW),
    notes="Production single-pod mesh: 16x16 ICI torus.",
    cost=_V5E_COST,
)

TPU_V5E_MULTIPOD = ClusterConfig(
    name="tpu-v5e-2pod",
    node=V5E_NODE,
    num_nodes=512,
    topology=Torus(dims=(16, 16), link_bw=V5E_LINK_BW, dcn_bw=25e9),
    notes="Production multi-pod mesh: 2 pods x (16x16 ICI), DCN inter-pod.",
    cost=_V5E_COST,
)


def _registry() -> Dict[str, ClusterLike]:
    return {
        "dgx-a100-1k": BASELINE_DGX_A100,
        "tpu-v5e-pod": TPU_V5E_POD,
        "tpu-v5e-2pod": TPU_V5E_MULTIPOD,
        "b-hybrid-em": B_HYBRID_EM,
        **TABLE_III_CLUSTERS,
    }


def list_clusters() -> List[str]:
    """Sorted names accepted by :func:`get_cluster`."""
    return sorted(_registry())


def get_cluster(name: str) -> ClusterLike:
    registry = _registry()
    if name not in registry:
        hints = difflib.get_close_matches(name, registry, n=3, cutoff=0.4)
        suggest = f"; did you mean {' / '.join(hints)}?" if hints else ""
        raise KeyError(f"unknown cluster {name!r}{suggest} "
                       f"(available: {sorted(registry)})")
    return registry[name]
