"""COMET cluster descriptions: node resources + network topology.

Faithful encodings of the paper's Table I (baseline DGX A100), Table III
(clusters A0..C2, Dojo, TPU v4), plus this repo's deployment target
(TPU v5e pods) used by the dry-run roofline analysis.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

GB = 1e9
TB = 1e12
MB = 1e6


@dataclasses.dataclass(frozen=True)
class NodeConfig:
    """One compute unit (GPU / TPU / tray) — paper's 'node'."""

    name: str
    peak_flops: float              # peak fp16/bf16 FLOP/s
    local_cap: float               # local (HBM) capacity, bytes
    local_bw: float                # local memory bandwidth, bytes/s
    sram_bytes: float              # on-chip buffer S for the traffic model
    exp_cap: float = 0.0           # expanded-memory capacity, bytes
    exp_bw: float = 0.0            # expanded-memory bandwidth, bytes/s

    @property
    def total_cap(self) -> float:
        return self.local_cap + self.exp_cap

    def with_expansion(self, cap: float, bw: float) -> "NodeConfig":
        return dataclasses.replace(self, exp_cap=cap, exp_bw=bw)

    def scaled_compute(self, factor: float) -> "NodeConfig":
        return dataclasses.replace(self, peak_flops=self.peak_flops * factor)


# --------------------------------------------------------------------- #
# Topologies
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class HierarchicalSwitch:
    """Two-level switch: fast intra-pod + slower inter-pod (Fig. 7)."""

    pod_size: int
    intra_bw: float                # per-node per-direction, bytes/s
    inter_bw: float
    intra_latency: float = 1e-6
    inter_latency: float = 5e-6

    def scaled(self, intra: float = 1.0, inter: float = 1.0) -> "HierarchicalSwitch":
        return dataclasses.replace(
            self, intra_bw=self.intra_bw * intra, inter_bw=self.inter_bw * inter)


@dataclasses.dataclass(frozen=True)
class Torus:
    """k-dimensional torus (TPU): per-direction link bandwidth per dim."""

    dims: Tuple[int, ...]
    link_bw: float
    latency: float = 1e-6
    # Optional DCN uplink for multi-pod torus clusters (v5e pods over DCN).
    dcn_bw: float = 0.0
    dcn_latency: float = 10e-6

    @property
    def pod_size(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n


@dataclasses.dataclass(frozen=True)
class SingleSwitch:
    """One logical switch delivering ``bw`` per node (Dojo model)."""

    bw: float
    latency: float = 1e-6

    @property
    def pod_size(self) -> int:  # flat network: one "pod"
        return 1 << 30


Topology = object  # union of the three classes above


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    name: str
    node: NodeConfig
    num_nodes: int
    topology: Topology
    notes: str = ""

    def with_node(self, node: NodeConfig) -> "ClusterConfig":
        return dataclasses.replace(self, node=node)

    def with_topology(self, topo) -> "ClusterConfig":
        return dataclasses.replace(self, topology=topo)


# --------------------------------------------------------------------- #
# Paper Table I: baseline 1024-GPU DGX A100 cluster (8-GPU pods)
# --------------------------------------------------------------------- #

A100_NODE = NodeConfig(
    name="A100",
    peak_flops=624e12,            # fp16 TC peak, Table I
    local_cap=80 * GB,
    local_bw=2039 * GB,
    sram_bytes=40 * MB,
)

BASELINE_DGX_A100 = ClusterConfig(
    name="dgx-a100-1k",
    node=A100_NODE,
    num_nodes=1024,
    topology=HierarchicalSwitch(pod_size=8, intra_bw=300 * GB, inter_bw=31.25 * GB),
    notes="Paper Table I: 128 pods x 8 GPUs, NVLink3 intra / IB inter.",
)


# --------------------------------------------------------------------- #
# Paper Table III: clusters A/B/C (x memory systems 0/1/2), Dojo, TPU v4
# §V-D: GPU clusters organized in 16-GPU pods.
# --------------------------------------------------------------------- #

_V100 = NodeConfig("V100", 125e12, 80 * GB, 900 * GB, 36 * MB)
_A100 = NodeConfig("A100", 625e12, 80 * GB, 2039 * GB, 40 * MB)
_H100 = NodeConfig("H100", 1979e12, 80 * GB, 3350 * GB, 50 * MB)

_MEMSYS = {
    0: (0.0, 0.0),
    1: (480 * GB, 500 * GB),
    2: (201 * GB, 1000 * GB),
}

_NET = {
    "A": HierarchicalSwitch(16, 150 * GB, 6.25 * GB),
    "B": HierarchicalSwitch(16, 300 * GB, 31.25 * GB),
    "C": HierarchicalSwitch(16, 450 * GB, 62.5 * GB),
}

_BASE = {"A": _V100, "B": _A100, "C": _H100}


def _gpu_variant(letter: str, mem: int) -> ClusterConfig:
    cap, bw = _MEMSYS[mem]
    return ClusterConfig(
        name=f"{letter}{mem}",
        node=_BASE[letter].with_expansion(cap, bw),
        num_nodes=1024,
        topology=_NET[letter],
        notes=f"Table III {letter}{mem}: {_BASE[letter].name} x1024, 16-GPU pods.",
    )


DOJO = ClusterConfig(
    name="dojo",
    node=NodeConfig("DojoTray", 54_300e12, 640 * GB, 16 * TB, 66 * GB),
    num_nodes=64,
    topology=SingleSwitch(bw=20 * 50 * GB),
    notes="Table III: 64 trays, one-level switch, 20x50GB/s per direction.",
)

TPU_V4 = ClusterConfig(
    name="tpu-v4",
    node=NodeConfig("TPUv4", 275e12, 32 * GB, 1200 * GB, 32 * MB,
                    exp_cap=39 * GB, exp_bw=1200 * GB),
    num_nodes=4096,
    topology=Torus(dims=(16, 16, 16), link_bw=48 * GB),
    notes="Table III: 4096 chips, 3D torus, 6x48GB/s per direction.",
)

TABLE_III_CLUSTERS = {
    **{f"{l}{m}": _gpu_variant(l, m) for l in "ABC" for m in (0, 1, 2)},
    "dojo": DOJO,
    "tpu-v4": TPU_V4,
}


# --------------------------------------------------------------------- #
# Deployment target: TPU v5e (this repo's dry-run hardware constants)
# --------------------------------------------------------------------- #

V5E_PEAK_FLOPS = 197e12            # bf16 per chip
V5E_HBM_BW = 819e9                 # bytes/s
V5E_HBM_CAP = 16 * GB
V5E_LINK_BW = 50e9                 # per ICI link per direction
V5E_VMEM = 128 * MB

V5E_NODE = NodeConfig(
    name="TPUv5e",
    peak_flops=V5E_PEAK_FLOPS,
    local_cap=V5E_HBM_CAP,
    local_bw=V5E_HBM_BW,
    sram_bytes=V5E_VMEM,
)

TPU_V5E_POD = ClusterConfig(
    name="tpu-v5e-pod",
    node=V5E_NODE,
    num_nodes=256,
    topology=Torus(dims=(16, 16), link_bw=V5E_LINK_BW),
    notes="Production single-pod mesh: 16x16 ICI torus.",
)

TPU_V5E_MULTIPOD = ClusterConfig(
    name="tpu-v5e-2pod",
    node=V5E_NODE,
    num_nodes=512,
    topology=Torus(dims=(16, 16), link_bw=V5E_LINK_BW, dcn_bw=25e9),
    notes="Production multi-pod mesh: 2 pods x (16x16 ICI), DCN inter-pod.",
)


def get_cluster(name: str) -> ClusterConfig:
    registry = {
        "dgx-a100-1k": BASELINE_DGX_A100,
        "tpu-v5e-pod": TPU_V5E_POD,
        "tpu-v5e-2pod": TPU_V5E_MULTIPOD,
        **TABLE_III_CLUSTERS,
    }
    if name not in registry:
        raise KeyError(f"unknown cluster {name!r}; available: {sorted(registry)}")
    return registry[name]
