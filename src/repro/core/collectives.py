"""COMET §III-C3: collective-communication cost models over cluster topologies.

The paper uses ASTRA-SIM's analytical network backend with hierarchical
(bandwidth-aware) collectives [10], [58]: reduce-scatter within the pod,
all-reduce across pods on the shrunken shard, all-gather back.  The
analytical models themselves live on the topology families in
:mod:`repro.core.topology` — each implements
``Topology.collective_time(collective, size, scope, mp, dp, pp=1, ep=1)``
— and this module's :class:`CollectiveModel` consumes that protocol, so
adding a topology family never touches this file.

Rank placement (shared by every family, re-exported here) follows the
four-axis mesh order: MP groups fill consecutive ranks (pods first), then
EP, then DP (striding by the inner axes), with PP stages outermost — the
stage-boundary ``"p2p"`` transfers hop ``mp * ep * dp`` ranks.  All
functions return seconds for one collective of ``size`` bytes issued by
every member of the group (the usual symmetric-collective convention).
"""

from __future__ import annotations

from repro.core.cluster import ClusterLike
from repro.core.topology import _group_size  # live: four-axis group sizing
from repro.core.topology import (  # noqa: F401  (legacy import surface)
    GroupPlacement,
    Topology,
    all_to_all,
    flat_time,
    placement,
    ring_allgather,
    ring_allreduce,
)


class CollectiveModel:
    """Collective timing for one cluster (or bare topology) + one
    (MP, DP, PP, EP) strategy.  Dispatches through the :class:`Topology`
    protocol; group sizing covers the four-axis product (scope ``"ep"``
    with ep == 1 keeps the legacy mapping onto the MP group, ``"dp"`` spans
    the DP x EP data group, ``"edp"`` the expert-gradient DP group, and
    ``"pp"`` carries the stage-boundary ``"p2p"`` transfers)."""

    def __init__(self, cluster: "ClusterLike | Topology", mp: int, dp: int,
                 pp: int = 1, ep: int = 1, placement=None):
        self.cluster = cluster
        # Optional repro.core.placement.Placement overriding the paper rank
        # order for hop resolution; None keeps the fixed MP→EP→DP→PP order.
        self.placement = placement
        # Use the node groups' topology (agreeing with the simulator when a
        # per-pod fabric overrides the interconnect); mixed fabrics need one
        # model per group, so refuse to pick one silently.
        topos = {g.topology for g in getattr(cluster, "node_groups", ())}
        if len(topos) > 1:
            raise ValueError(
                "cluster mixes per-pod fabrics; build one CollectiveModel "
                "per NodeGroup.topology (as the simulator does) instead of "
                "timing over the shared interconnect only")
        self.topo = topos.pop() if topos \
            else getattr(cluster, "topology", cluster)
        self.mp = max(1, mp)
        self.dp = max(1, dp)
        self.pp = max(1, pp)
        self.ep = max(1, ep)

    def time(self, collective: str, size: float, scope: str) -> float:
        group = _group_size(scope, self.mp, self.dp, self.pp, self.ep)
        if group <= 1 or size <= 0:
            return 0.0
        time_fn = getattr(self.topo, "collective_time", None)
        if time_fn is None:
            raise TypeError(
                f"{type(self.topo).__name__} does not implement the "
                "Topology protocol (missing collective_time)")
        if self.placement is None:
            # Keep the PR-2 protocol signature working for downstream
            # Topology implementations that predate the placement kwarg.
            return time_fn(collective, size, scope, self.mp, self.dp,
                           pp=self.pp, ep=self.ep)
        return time_fn(collective, size, scope, self.mp, self.dp,
                       pp=self.pp, ep=self.ep, placement=self.placement)

    def time_batch(self, collectives, sizes, scopes) -> "np.ndarray":
        """Times for a whole event table at once (compiled study engine).

        ``collectives`` / ``sizes`` / ``scopes`` are parallel sequences —
        one entry per communication event.  Events are grouped by
        (collective, scope) and dispatched to the topology's
        ``collective_time_batch`` (one vectorized call per group); a
        downstream family without the batched method falls back to
        per-event :meth:`time` calls, so correctness never depends on it.
        """
        import numpy as np
        out = np.zeros(len(sizes))
        if not len(sizes):
            return out
        sizes = np.asarray(sizes, dtype=float)
        groups: "dict[tuple, list]" = {}
        for i, (c, s) in enumerate(zip(collectives, scopes)):
            groups.setdefault((c, s), []).append(i)
        batch_fn = getattr(self.topo, "collective_time_batch", None)
        for (c, scope), idx in groups.items():
            if _group_size(scope, self.mp, self.dp, self.pp, self.ep) <= 1:
                continue                       # stays 0.0, as in time()
            if batch_fn is not None:
                out[idx] = batch_fn(c, sizes[idx], scope, self.mp, self.dp,
                                    pp=self.pp, ep=self.ep,
                                    placement=self.placement)
            else:
                out[idx] = [self.time(c, float(s), scope)
                            for s in sizes[idx]]
        return out
