"""COMET §III-C3: collective-communication cost models over cluster topologies.

The paper uses ASTRA-SIM's analytical network backend with hierarchical
(bandwidth-aware) collectives [10], [58]: reduce-scatter within the pod,
all-reduce across pods on the shrunken shard, all-gather back.  This module
reimplements that analytical model for the three topology families in
``core.cluster`` and for the rank-placement rule used throughout the paper:
MP groups fill consecutive ranks (pods first), DP groups stride by MP.

All functions return seconds for one collective of ``size`` bytes issued by
every member of the group (the usual symmetric-collective convention).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

from repro.core.cluster import (
    ClusterConfig,
    HierarchicalSwitch,
    SingleSwitch,
    Torus,
)


def _ring_ar(size: float, n: int, bw: float, lat: float) -> float:
    """Logical-ring all-reduce: 2(n-1)/n * size / bw + 2(n-1) hops."""
    if n <= 1 or size <= 0:
        return 0.0
    return 2 * (n - 1) / n * size / bw + 2 * (n - 1) * lat


def _ring_ag(size: float, n: int, bw: float, lat: float) -> float:
    """All-gather / reduce-scatter: (n-1)/n * size / bw (one ring pass)."""
    if n <= 1 or size <= 0:
        return 0.0
    return (n - 1) / n * size / bw + (n - 1) * lat


def _a2a(size: float, n: int, bw: float, lat: float) -> float:
    """All-to-all: each node sends size*(n-1)/n bytes through its link."""
    if n <= 1 or size <= 0:
        return 0.0
    return (n - 1) / n * size / bw + lat


@dataclasses.dataclass(frozen=True)
class GroupPlacement:
    """How a communication group maps onto pods.

    intra: members co-located per pod; inter: number of pods spanned.
    group size = intra * inter.
    """

    intra: int
    inter: int


def placement(scope: str, mp: int, dp: int, pod_size: int) -> GroupPlacement:
    """Paper's placement: MP consecutive (fills pods first), DP strided."""
    if scope in ("mp", "ep"):
        if mp <= pod_size:
            return GroupPlacement(intra=mp, inter=1)
        return GroupPlacement(intra=pod_size, inter=mp // pod_size)
    # dp: peers stride by mp
    if mp >= pod_size:
        return GroupPlacement(intra=1, inter=dp)
    per_pod = max(1, pod_size // mp)
    per_pod = min(per_pod, dp)
    return GroupPlacement(intra=per_pod, inter=max(1, dp // per_pod))


class CollectiveModel:
    """Collective timing for one cluster + one (MP, DP) strategy."""

    def __init__(self, cluster: ClusterConfig, mp: int, dp: int):
        self.cluster = cluster
        self.topo = cluster.topology
        self.mp = max(1, mp)
        self.dp = max(1, dp)

    # ------------------------------------------------------------------ #
    def time(self, collective: str, size: float, scope: str) -> float:
        group = self.mp if scope in ("mp", "ep") else self.dp
        if group <= 1 or size <= 0:
            return 0.0
        topo = self.topo
        if isinstance(topo, HierarchicalSwitch):
            return self._hier(collective, size, scope, topo)
        if isinstance(topo, Torus):
            return self._torus(collective, size, scope, topo, group)
        if isinstance(topo, SingleSwitch):
            return self._flat(collective, size, group, topo.bw, topo.latency)
        raise TypeError(f"unknown topology {type(topo)!r}")

    # ------------------------------------------------------------------ #
    @staticmethod
    def _flat(collective: str, size: float, n: int, bw: float, lat: float) -> float:
        if collective == "all-reduce":
            return _ring_ar(size, n, bw, lat)
        if collective in ("all-gather", "reduce-scatter"):
            return _ring_ag(size, n, bw, lat)
        if collective == "all-to-all":
            return _a2a(size, n, bw, lat)
        raise ValueError(f"unknown collective {collective!r}")

    # ------------------------------------------------------------------ #
    def _hier(self, collective: str, size: float, scope: str,
              topo: HierarchicalSwitch) -> float:
        pl = placement(scope, self.mp, self.dp, topo.pod_size)
        p, q = pl.intra, pl.inter
        if q <= 1:  # fully intra-pod
            return self._flat(collective, size, p, topo.intra_bw, topo.intra_latency)
        if p <= 1:  # fully inter-pod
            return self._flat(collective, size, q, topo.inter_bw, topo.inter_latency)
        # Hierarchical collective [10],[58]: intra RS -> inter stage on
        # size/p -> intra AG.
        if collective == "all-reduce":
            t_intra = 2 * _ring_ag(size, p, topo.intra_bw, topo.intra_latency)
            t_inter = _ring_ar(size / p, q, topo.inter_bw, topo.inter_latency)
            return t_intra + t_inter
        if collective in ("all-gather", "reduce-scatter"):
            t_intra = _ring_ag(size, p, topo.intra_bw, topo.intra_latency)
            t_inter = _ring_ag(size / p, q, topo.inter_bw, topo.inter_latency)
            return t_intra + t_inter
        if collective == "all-to-all":
            # Traffic share crossing pod boundaries vs. staying local.
            n = p * q
            inter_frac = (n - p) / n
            intra_frac = (p - 1) / n
            t_inter = inter_frac * size / topo.inter_bw + topo.inter_latency
            t_intra = intra_frac * size / topo.intra_bw + topo.intra_latency
            return max(t_inter, t_intra)
        raise ValueError(f"unknown collective {collective!r}")

    # ------------------------------------------------------------------ #
    def _torus(self, collective: str, size: float, scope: str,
               topo: Torus, group: int) -> float:
        """Multi-dimensional bucket algorithm: per-dimension ring stages.

        Bidirectional links -> ring uses both directions (2x link bw).
        Groups smaller than the full torus use as many dims as needed
        (mesh-axis-major placement)."""
        pod = topo.pod_size
        bw = 2 * topo.link_bw
        if topo.dcn_bw and group > pod:
            # group spans pods over DCN: hierarchical (torus intra + DCN flat)
            q = math.ceil(group / pod)
            if collective == "all-reduce":
                t_in = self._torus("reduce-scatter", size, scope, topo, pod) \
                     + self._torus("all-gather", size, scope, topo, pod)
                t_out = _ring_ar(size / pod, q, topo.dcn_bw, topo.dcn_latency)
                return t_in + t_out
            t_in = self._torus(collective, size, scope, topo, pod)
            t_out = self._flat(collective, size / pod, q, topo.dcn_bw,
                               topo.dcn_latency)
            return t_in + t_out
        # Decompose the group across torus dims (row-major).
        dims = []
        rem = min(group, pod)
        for d in topo.dims:
            if rem <= 1:
                break
            use = math.gcd(rem, d) if rem % d else d
            use = min(d, rem)
            dims.append(use)
            rem = max(1, rem // use)
        if not dims:
            return 0.0
        if collective == "all-reduce":
            t, s = 0.0, size
            for d in dims:  # reduce-scatter sweep
                t += _ring_ag(s, d, bw, topo.latency)
                s /= d
            for d in reversed(dims):  # all-gather sweep
                s *= d
                t += _ring_ag(s, d, bw, topo.latency)
            return t
        if collective in ("all-gather", "reduce-scatter"):
            t, s = 0.0, size
            for d in dims:
                t += _ring_ag(s, d, bw, topo.latency)
                s /= d
            return t
        if collective == "all-to-all":
            n = 1
            for d in dims:
                n *= d
            return _a2a(size, n, bw * len(dims), topo.latency)
        raise ValueError(f"unknown collective {collective!r}")
