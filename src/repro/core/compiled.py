"""Lower a decomposed :class:`~repro.core.workload.Workload` to flat arrays.

Phase 1 of the two-phase compiled study engine (ROADMAP: fork-pool scaling
past 1.25x).  A COMET study cell's cost splits cleanly into a
strategy-dependent part (the decomposition, plus the event layout compiled
here) and a cluster-dependent part (roofline and collective *scalars*), the
same split ASTRA-sim-style analytical backends and Calculon-class
closed-form estimators exploit.  :func:`compile_workload` walks the layer
list exactly once per strategy and emits, per pipeline stage:

  * **delay classes** — distinct (op-list) rows: per-class FLOP totals,
    streaming-op base traffic, and every GEMM's operand sizes
    ``(u, v, w, batch)`` with a segment map back to its class row.  The
    repeated transformer blocks ``decompose`` stamps out share their op
    lists, so a 514-layer stack collapses to a dozen classes and the
    §III-C2 tiling traffic for *any* on-chip buffer size is a handful of
    array ops;
  * **deduplicated communication events** — one row per distinct
    (collective, bytes, scope) triple, which is all a duration depends on;
  * the two execution-ordered event streams (forward pass; interleaved
    IG/WG backward pass) with layer repeats unrolled, referencing class
    and event rows — everything the ASTRA-lite timeline needs, with no
    per-cell Python op walk left;
  * the optimizer-update byte totals (dense / expert / sparse).

Phase 2 is :func:`repro.core.simulator.time_compiled`, which times one
``CompiledWorkload`` against a whole batch of (node, topology)
environments in vectorized NumPy;
``repro.core.study.run_study(engine="compiled")`` drives it strategy-major.
The compiled path reproduces the reference event-loop within 1e-9 relative
(tests/test_compiled.py); bit-for-bit behavior stays with
``engine="reference"``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.gemm import ExplicitOp, Gemm
from repro.core.workload import LayerSpec, Workload

PHASES = ("fp", "ig", "wg")

# Scope codes shared with the simulator's per-scope network streams
# (mirrors repro.core.simulator._SCOPES; tests assert they agree).
SCOPES = ("mp", "dp", "ep", "pp", "edp")
_SCOPE_CODE = {s: i for i, s in enumerate(SCOPES)}


@dataclasses.dataclass
class CompiledPass:
    """One timeline pass (forward, or interleaved IG/WG backward) in
    execution order, repeats unrolled.

    ``seq`` lists delay-class rows in the order their compute runs; each
    communication event fires after ``ev_pos`` of those compute steps have
    executed (several events may share a position)."""

    seq: np.ndarray          # int64 (nseq,) rows into the delay matrix
    ev_pos: np.ndarray       # int64 (nev,) compute steps preceding the event
    ev_comm: np.ndarray      # int64 (nev,) rows into the stage comm table
    ev_blocking: np.ndarray  # bool  (nev,)
    ev_scope: np.ndarray     # int64 (nev,) index into SCOPES
    ev_phase: np.ndarray     # int64 (nev,) 0=fp 1=ig 2=wg


@dataclasses.dataclass
class CompiledStage:
    """Flat arrays for one pipeline stage's layer list.

    Rows are *delay classes*: one per distinct (layer, phase) op list
    (clones stamped out by ``decompose`` share op-list identity and
    collapse into one row)."""

    n_classes: int
    flops: np.ndarray          # (ncls,) op-FLOP totals (cell-independent)
    base_traffic: np.ndarray   # (ncls,) streaming-op bytes (sram-independent)
    counts: np.ndarray         # (3, ncls) repeat-weighted phase occurrences
    # GEMM table, ordered by class row (contiguous segments):
    gemm_u: np.ndarray         # (nops,) A-operand bytes  (m * k * bpe)
    gemm_v: np.ndarray         # (nops,) B-operand bytes  (k * n * bpe)
    gemm_w: np.ndarray         # (nops,) output bytes     (m * n * bpe)
    gemm_batch: np.ndarray     # (nops,)
    gemm_starts: np.ndarray    # (nseg,) first op index of each nonempty class
    gemm_cls: np.ndarray       # (nseg,) that segment's class row
    # Distinct communication events — one row per (kind, bytes, scope):
    comm_kinds: Tuple[str, ...]
    comm_scopes: Tuple[str, ...]
    comm_sizes: np.ndarray     # (ncomm,) bytes
    fwd: CompiledPass
    bwd: CompiledPass
    # Optimizer-update byte totals (repro.core.simulator._optimizer_time):
    dense_w: float             # dense fp16 weight bytes (excl. experts)
    expert_w: float            # EP-sharded expert weight bytes
    sparse: float              # optim_bytes overrides (embedding bags)


@dataclasses.dataclass
class CompiledWorkload:
    """A lowered workload: one :class:`CompiledStage` per pipeline stage
    (exactly one when ``pp == 1``), plus the source workload for the
    footprint / schedule metadata the simulator still reads."""

    workload: Workload
    stages: List[CompiledStage]

    @property
    def pp(self) -> int:
        return len(self.stages)


def _pass_arrays(seq, ev) -> CompiledPass:
    if ev:
        pos, comm, blocking, scope, phase = zip(*ev)
    else:
        pos = comm = blocking = scope = phase = ()
    return CompiledPass(
        seq=np.asarray(seq, dtype=np.int64),
        ev_pos=np.asarray(pos, dtype=np.int64),
        ev_comm=np.asarray(comm, dtype=np.int64),
        ev_blocking=np.asarray(blocking, dtype=bool),
        ev_scope=np.asarray(scope, dtype=np.int64),
        ev_phase=np.asarray(phase, dtype=np.int64),
    )


def _compile_stage(layers: List[LayerSpec]) -> CompiledStage:
    flops: List[float] = []
    base: List[float] = []
    cls_of: Dict[int, int] = {}        # id(op list) -> class row
    g_u: List[float] = []
    g_v: List[float] = []
    g_w: List[float] = []
    g_b: List[float] = []
    g_cls: List[int] = []
    comm_kinds: List[str] = []
    comm_scopes: List[str] = []
    comm_sizes: List[float] = []
    comm_of: Dict[tuple, int] = {}     # (kind, bytes, scope) -> comm row
    # Per layer: 3 class rows + per-phase compiled event triples.
    layer_cls: List[Tuple[int, int, int]] = []
    layer_ev: List[Tuple[list, list, list]] = []

    def classify(ops: list) -> int:
        c = cls_of.get(id(ops))
        if c is None:
            c = cls_of[id(ops)] = len(flops)
            f = 0.0
            b = 0.0
            for op in ops:
                if isinstance(op, Gemm):
                    bpe = op.bytes_per_element
                    g_u.append(op.m * op.k * bpe)
                    g_v.append(op.k * op.n * bpe)
                    g_w.append(op.m * op.n * bpe)
                    g_b.append(op.batch)
                    g_cls.append(c)
                    f += op.flops()
                elif isinstance(op, ExplicitOp):
                    b += op.bytes_moved
                    f += op.flops
                else:
                    raise TypeError(f"unknown op type {type(op)!r}")
            flops.append(f)
            base.append(b)
        return c

    def events(comm: list) -> list:
        out = []
        for e in comm:
            key = (e.collective, e.size_bytes, e.scope)
            row = comm_of.get(key)
            if row is None:
                row = comm_of[key] = len(comm_kinds)
                comm_kinds.append(e.collective)
                comm_scopes.append(e.scope)
                comm_sizes.append(e.size_bytes)
            out.append((row, e.blocking, _SCOPE_CODE[e.scope]))
        return out

    for layer in layers:
        layer_cls.append((classify(layer.fwd), classify(layer.ig),
                          classify(layer.wg)))
        layer_ev.append((events(layer.comm_fwd), events(layer.comm_ig),
                         events(layer.comm_wg)))

    ncls = len(flops)
    counts = np.zeros((3, ncls))
    for layer, (cf, ci, cw) in zip(layers, layer_cls):
        counts[0, cf] += layer.repeat
        counts[1, ci] += layer.repeat
        counts[2, cw] += layer.repeat

    fwd_seq: List[int] = []
    fwd_ev: List[tuple] = []
    for layer, (cf, _, _), (ef, _, _) in zip(layers, layer_cls, layer_ev):
        for _ in range(layer.repeat):
            fwd_seq.append(cf)
            for row, blocking, scope in ef:
                fwd_ev.append((len(fwd_seq), row, blocking, scope, 0))
    bwd_seq: List[int] = []
    bwd_ev: List[tuple] = []
    for layer, (_, ci, cw), (_, ei, ew) in zip(reversed(layers),
                                               reversed(layer_cls),
                                               reversed(layer_ev)):
        for _ in range(layer.repeat):
            bwd_seq.append(ci)
            for row, blocking, scope in ei:
                bwd_ev.append((len(bwd_seq), row, blocking, scope, 1))
            bwd_seq.append(cw)
            for row, blocking, scope in ew:
                bwd_ev.append((len(bwd_seq), row, blocking, scope, 2))

    g_cls_arr = np.asarray(g_cls, dtype=np.int64)
    if g_cls_arr.size:
        starts = np.flatnonzero(np.diff(g_cls_arr, prepend=-1))
        seg_cls = g_cls_arr[starts]
    else:
        starts = np.zeros(0, dtype=np.int64)
        seg_cls = np.zeros(0, dtype=np.int64)
    # Optimizer-update totals (mirrors simulator._optimizer_time's sums).
    dense_w = sum((ly.weight_bytes - ly.expert_bytes) * ly.repeat
                  for ly in layers if ly.optim_bytes is None)
    expert_w = sum(ly.expert_bytes * ly.repeat for ly in layers
                   if ly.optim_bytes is None)
    sparse = sum(ly.optim_bytes * ly.repeat for ly in layers
                 if ly.optim_bytes is not None)
    return CompiledStage(
        n_classes=ncls,
        flops=np.asarray(flops),
        base_traffic=np.asarray(base),
        counts=counts,
        gemm_u=np.asarray(g_u, dtype=float),
        gemm_v=np.asarray(g_v, dtype=float),
        gemm_w=np.asarray(g_w, dtype=float),
        gemm_batch=np.asarray(g_b, dtype=float),
        gemm_starts=starts,
        gemm_cls=seg_cls,
        comm_kinds=tuple(comm_kinds),
        comm_scopes=tuple(comm_scopes),
        comm_sizes=np.asarray(comm_sizes, dtype=float),
        fwd=_pass_arrays(fwd_seq, fwd_ev),
        bwd=_pass_arrays(bwd_seq, bwd_ev),
        dense_w=float(dense_w),
        expert_w=float(expert_w),
        sparse=float(sparse),
    )


def compile_workload(workload: Workload) -> CompiledWorkload:
    """Lower ``workload`` into flat arrays, one stage per pipeline stage.

    This is the strategy-dependent half of a study cell's cost: call it
    once per (strategy, workload_deps) key and reuse the result against
    every cluster cell (``Workload.compiled()`` memoizes exactly that)."""
    return CompiledWorkload(
        workload=workload,
        stages=[_compile_stage(layers) for layers in workload.stage_layers()],
    )


def pass_event_totals(stage: CompiledStage
                      ) -> Dict[Tuple[str, str], Tuple[int, float]]:
    """Occurrence counts and total bytes per (collective, scope) across a
    stage's two execution streams — what the timeline will actually issue
    per microbatch, with the (kind, bytes, scope) dedup expanded back out.
    The static analyzer (C102/C103) compares this against the source
    layer list."""
    totals: Dict[Tuple[str, str], List[float]] = {}
    for p in (stage.fwd, stage.bwd):
        for row in p.ev_comm.tolist():
            key = (stage.comm_kinds[row], stage.comm_scopes[row])
            cell = totals.setdefault(key, [0, 0.0])
            cell[0] += 1
            cell[1] += float(stage.comm_sizes[row])
    return {k: (int(c), b) for k, (c, b) in totals.items()}


def stage_traffic(stage: CompiledStage, sram: np.ndarray) -> np.ndarray:
    """Per-delay-class memory traffic for a batch of on-chip buffer sizes:
    ``(ncls, nenv)`` bytes.  The §III-C2 tiling estimate
    (min{Psi1, Psi2} + W, see :func:`repro.core.gemm.gemm_traffic_bytes`)
    vectorized over every GEMM and environment at once."""
    nenv = sram.shape[0]
    traffic = np.repeat(stage.base_traffic[:, None], nenv, axis=1)
    if stage.gemm_u.size:
        u = stage.gemm_u[:, None]
        v = stage.gemm_v[:, None]
        w = stage.gemm_w[:, None]
        s = sram[None, :]
        psi1 = np.ceil(u / s) * v + u
        psi2 = np.ceil(v / s) * u + v
        per = np.minimum(psi1, psi2) + w
        degenerate = (u == 0) | (v == 0)
        if degenerate.any():
            per = np.where(degenerate, u + v + w, per)
        contrib = stage.gemm_batch[:, None] * per
        traffic[stage.gemm_cls] += np.add.reduceat(contrib, stage.gemm_starts,
                                                   axis=0)
    return traffic
