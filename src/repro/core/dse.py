"""COMET §V case studies as declarative :mod:`repro.core.study` specs.

Each paper figure is now a ``<fig>_study(...) -> StudySpec`` builder (a few
lines of axes x strategies over one engine) plus a thin wrapper keeping the
seed function signature and return shape for existing callers/tests. New
scenario axes are added by composing :class:`Axis`/:class:`StrategySpace` —
not by writing another bespoke sweep loop.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.cluster import (
    ClusterConfig,
    ClusterLike,
    ClusterSpec,
    HierarchicalSwitch,
    NodeConfig,
    PodSpec,
    TABLE_III_CLUSTERS,
)
from repro.core.placement import JobSpec
from repro.core.strategy import StrategyResult
from repro.core.study import (
    Axis,
    GridSpace,
    ParallelSpec,
    PowerOfTwoSpace,
    StudyResult,
    StudySpec,
    as_strategy_space,
    placement_axis,
    run_study,
)
from repro.core.workload import decompose_dlrm

GB = 1e9


def _expand_axis(values_gbs: Sequence[float]) -> Axis:
    """EM-bandwidth axis: infinite expanded capacity at the swept bandwidth
    (capacity is sized to whatever the strategy needs — paper Fig. 9)."""
    return Axis("bw_em_gbs", tuple(values_gbs),
                apply=lambda cl, bw: cl.with_node(
                    cl.node.with_expansion(cap=1e15, bw=bw * GB)))


# --------------------------------------------------------------------- #
# §V-B1 / Fig. 8: MP-DP sweep at fixed memory bandwidth
# --------------------------------------------------------------------- #

def mpdp_study(cfg: ModelConfig, shape: ShapeConfig, cluster: ClusterConfig,
               assume_infinite_capacity: bool = True,
               min_mp: int = 1) -> StudySpec:
    return StudySpec(
        name="fig8-mpdp-sweep", model=cfg, shape=shape, cluster=cluster,
        strategies=PowerOfTwoSpace(min_mp=min_mp),
        mem_bw_override="local" if assume_infinite_capacity else None)


def mpdp_sweep(cfg: ModelConfig, shape: ShapeConfig, cluster: ClusterConfig,
               assume_infinite_capacity: bool = True,
               min_mp: int = 1) -> List[StrategyResult]:
    """Training-time breakdown for each (MP, DP); §V-B1 assumes infinite
    per-node capacity at baseline bandwidth."""
    res = run_study(mpdp_study(cfg, shape, cluster,
                               assume_infinite_capacity, min_mp))
    return [StrategyResult(c.strategy.mp, c.strategy.dp, c.breakdown,
                           c.footprint.total) for c in res]


# --------------------------------------------------------------------- #
# §V-B2 / Fig. 9: expanded-memory bandwidth heatmap
# --------------------------------------------------------------------- #

def memory_expansion_study(
    cfg: ModelConfig, shape: ShapeConfig, cluster: ClusterConfig,
    em_bandwidths_gbs: Sequence[float] = (100, 250, 500, 750, 1000, 1500, 2000),
    strategies: Optional[Sequence] = None,
) -> StudySpec:
    return StudySpec(
        name="fig9-memory-expansion", model=cfg, shape=shape, cluster=cluster,
        strategies=as_strategy_space(strategies) or PowerOfTwoSpace(),
        axes=[_expand_axis(em_bandwidths_gbs)])


def memory_expansion_heatmap(
    cfg: ModelConfig,
    shape: ShapeConfig,
    cluster: ClusterConfig,
    em_bandwidths_gbs: Sequence[float] = (100, 250, 500, 750, 1000, 1500, 2000),
    strategies: Optional[Sequence[tuple]] = None,
) -> Dict[str, Dict[float, float]]:
    """runtime[strategy_label][bw_EM_GBs], normalized by the caller."""
    res = run_study(memory_expansion_study(cfg, shape, cluster,
                                           em_bandwidths_gbs, strategies))
    return res.pivot(index="strategy", columns="bw_em_gbs")


# --------------------------------------------------------------------- #
# §V-B3 / Fig. 10: per-node compute-capability scaling
# --------------------------------------------------------------------- #

def compute_scaling_study(
    cfg: ModelConfig, shape: ShapeConfig, cluster: ClusterConfig,
    mp: int, dp: int,
    compute_factors: Sequence[float] = (0.5, 1.0, 2.0, 4.0, 8.0),
    em_bandwidths_gbs: Sequence[float] = (500, 1000, 2000),
) -> StudySpec:
    return StudySpec(
        name="fig10-compute-scaling", model=cfg, shape=shape, cluster=cluster,
        strategies=ParallelSpec(mp=mp, dp=dp),
        axes=[Axis("compute_x", tuple(compute_factors),
                   path="node.peak_flops", mode="scale"),
              _expand_axis(em_bandwidths_gbs)])


def compute_scaling(
    cfg: ModelConfig,
    shape: ShapeConfig,
    cluster: ClusterConfig,
    mp: int,
    dp: int,
    compute_factors: Sequence[float] = (0.5, 1.0, 2.0, 4.0, 8.0),
    em_bandwidths_gbs: Sequence[float] = (500, 1000, 2000),
) -> Dict[float, Dict[float, float]]:
    """runtime[compute_factor][bw_EM_GBs] for a fixed strategy."""
    res = run_study(compute_scaling_study(cfg, shape, cluster, mp, dp,
                                          compute_factors, em_bandwidths_gbs))
    return res.pivot(index="compute_x", columns="bw_em_gbs")


# --------------------------------------------------------------------- #
# §V-B4 / Fig. 11: intra-/inter-pod bandwidth scaling
# --------------------------------------------------------------------- #

def network_scaling_study(
    cfg: ModelConfig, shape: ShapeConfig, cluster: ClusterConfig,
    mp: int, dp: int,
    intra_factors: Sequence[float] = (0.5, 1.0, 2.0, 4.0),
    inter_factors: Sequence[float] = (0.5, 1.0, 2.0, 4.0),
) -> StudySpec:
    assert isinstance(cluster.topology, HierarchicalSwitch)
    return StudySpec(
        name="fig11-network-scaling", model=cfg, shape=shape, cluster=cluster,
        strategies=ParallelSpec(mp=mp, dp=dp), mem_bw_override="local",
        axes=[Axis("intra_x", tuple(intra_factors),
                   path="topology.intra_bw", mode="scale"),
              Axis("inter_x", tuple(inter_factors),
                   path="topology.inter_bw", mode="scale")])


def network_scaling(
    cfg: ModelConfig,
    shape: ShapeConfig,
    cluster: ClusterConfig,
    mp: int,
    dp: int,
    intra_factors: Sequence[float] = (0.5, 1.0, 2.0, 4.0),
    inter_factors: Sequence[float] = (0.5, 1.0, 2.0, 4.0),
) -> Dict[tuple, float]:
    """runtime[(intra_factor, inter_factor)] at baseline compute/memory."""
    res = run_study(network_scaling_study(cfg, shape, cluster, mp, dp,
                                          intra_factors, inter_factors))
    return {(c.point["intra_x"], c.point["inter_x"]): c.breakdown.total
            for c in res}


# --------------------------------------------------------------------- #
# §V-B4 / Fig. 12: fixed-aggregate bandwidth re-balancing
# --------------------------------------------------------------------- #

def bandwidth_rebalance_study(
    cfg: ModelConfig, shape: ShapeConfig, cluster: ClusterConfig,
    mp: int, dp: int,
    ratios: Sequence[float] = (1, 2, 3, 4, 5, 6, 7, 8, 9.6, 12, 16),
) -> StudySpec:
    assert isinstance(cluster.topology, HierarchicalSwitch)
    agg = cluster.topology.intra_bw + cluster.topology.inter_bw

    def rebalance(cl: ClusterConfig, r: float) -> ClusterConfig:
        inter = agg / (1 + r)
        return cl.with_topology(dataclasses.replace(
            cl.topology, intra_bw=agg - inter, inter_bw=inter))

    return StudySpec(
        name="fig12-bandwidth-rebalance", model=cfg, shape=shape,
        cluster=cluster, strategies=ParallelSpec(mp=mp, dp=dp),
        mem_bw_override="local",
        axes=[Axis("ratio", tuple(ratios), apply=rebalance)])


def bandwidth_rebalance(
    cfg: ModelConfig,
    shape: ShapeConfig,
    cluster: ClusterConfig,
    mp: int,
    dp: int,
    ratios: Sequence[float] = (1, 2, 3, 4, 5, 6, 7, 8, 9.6, 12, 16),
) -> Dict[float, float]:
    """runtime[inter:intra ratio 1:r] with intra+inter = aggregate constant.

    Baseline DGX: 300 + 31.25 = 331.25 GB/s aggregate; ratio 1:9.6."""
    res = run_study(bandwidth_rebalance_study(cfg, shape, cluster, mp, dp,
                                              ratios))
    return {c.point["ratio"]: c.breakdown.total for c in res}


# --------------------------------------------------------------------- #
# §V-C / Fig. 13: DLRM cluster-size sweep + memory-expansion study
# --------------------------------------------------------------------- #

def dlrm_cluster_size_study(dlrm_cfg, cluster: ClusterConfig,
                            global_batch: int = 4096,
                            node_counts: Sequence[int] = (64, 32, 16, 8),
                            ) -> StudySpec:
    from repro.core.memory import per_node_footprint
    base = cluster
    return StudySpec(
        name="fig13a-dlrm-cluster-size", cluster=cluster,
        axes=[Axis("nodes", tuple(node_counts),
                   apply=lambda cl, n: dataclasses.replace(cl, num_nodes=n)
                   .with_node(base.node.with_expansion(
                       cap=1e15, bw=base.node.local_bw)))],
        workload=lambda ctx: decompose_dlrm(dlrm_cfg, global_batch,
                                            ctx.point["nodes"]),
        workload_deps=("nodes",),
        metrics={"footprint_gb":
                 lambda ctx: per_node_footprint(ctx.workload,
                                                base.node).total / GB})


def dlrm_cluster_size_sweep(
    dlrm_cfg,
    cluster: ClusterConfig,
    global_batch: int = 4096,
    node_counts: Sequence[int] = (64, 32, 16, 8),
) -> Dict[int, dict]:
    """Single-instance DLRM training breakdown vs cluster size (Fig. 13a)."""
    res = run_study(dlrm_cluster_size_study(dlrm_cfg, cluster, global_batch,
                                            node_counts))
    return {c.point["nodes"]: {**c.breakdown.as_dict(),
                               "footprint_gb": c.record["footprint_gb"]}
            for c in res}


def dlrm_memory_expansion_study(
    dlrm_cfg, cluster: ClusterConfig, global_batch: int = 4096,
    total_nodes: int = 64, num_instances: int = 8,
    em_bandwidths_gbs: Sequence[float] = (250, 500, 800, 1000, 1500, 2000),
    nodes_per_instance_opts: Sequence[int] = (64, 32, 16, 8),
) -> StudySpec:
    """N concurrent DLRM instances on a ``total_nodes`` fleet: the waves /
    turnaround bookkeeping is the study-native :class:`JobSpec` layer (the
    engine schedules instances over the fleet's node groups and writes the
    ``turnaround``/``waves`` columns the legacy lambdas used to compute)."""
    fleet = dataclasses.replace(cluster, num_nodes=total_nodes)
    return StudySpec(
        name="fig13b-dlrm-memory-expansion", cluster=fleet,
        axes=[Axis("nodes_per_inst", tuple(nodes_per_instance_opts)),
              _expand_axis(em_bandwidths_gbs)],
        workload=lambda ctx: decompose_dlrm(dlrm_cfg, global_batch,
                                            ctx.point["nodes_per_inst"]),
        workload_deps=("nodes_per_inst",),
        job=lambda ctx: JobSpec(
            instances=num_instances,
            nodes_per_instance=ctx.point["nodes_per_inst"]))


def dlrm_memory_expansion(
    dlrm_cfg,
    cluster: ClusterConfig,
    global_batch: int = 4096,
    total_nodes: int = 64,
    num_instances: int = 8,
    em_bandwidths_gbs: Sequence[float] = (250, 500, 800, 1000, 1500, 2000),
    nodes_per_instance_opts: Sequence[int] = (64, 32, 16, 8),
) -> Dict[int, Dict[float, float]]:
    """Fig. 13b: turnaround of ``num_instances`` DLRMs on 64 nodes.

    Using fewer nodes per instance needs expanded memory but runs
    ceil(64/n) instances concurrently: turnaround = iter_time * n_waves."""
    res = run_study(dlrm_memory_expansion_study(
        dlrm_cfg, cluster, global_batch, total_nodes, num_instances,
        em_bandwidths_gbs, nodes_per_instance_opts))
    return res.pivot(index="nodes_per_inst", columns="bw_em_gbs",
                     values="turnaround")


# --------------------------------------------------------------------- #
# Beyond Fig. 13: heterogeneous pod mix ranked by perf-per-dollar
# (paper §V-D discusses perf/$ qualitatively; MAD-Max carries the cost
# model explicitly — this study does both over a mixed A100+EM fleet).
# --------------------------------------------------------------------- #

def _em_pod_mix(plain: str = "B0", expanded: str = "B1"):
    """``apply(cluster, frac) -> ClusterSpec`` mixing the ``plain``
    cluster's pods with the ``expanded`` cluster's memory-expanded pods
    (same interconnect / pod size / fleet size), priced by the expanded
    cluster's cost model so the EM pods carry their $/GB premium."""
    base, em = TABLE_III_CLUSTERS[plain], TABLE_III_CLUSTERS[expanded]
    pod = base.topology.pod_size
    num_pods = base.num_nodes // pod

    def mix(_, frac: float) -> ClusterSpec:
        if not 0.0 <= frac <= 1.0:
            raise ValueError(f"em_pod_frac must be in [0, 1], got {frac}")
        n_em = int(round(frac * num_pods))
        pods = tuple(
            p for p in (PodSpec(base.node, count=num_pods - n_em,
                                nodes_per_pod=pod),
                        PodSpec(em.node, count=n_em, nodes_per_pod=pod))
            if p.count > 0)
        return ClusterSpec(
            name=f"{plain}+{expanded}-em{n_em}of{num_pods}",
            pods=pods, interconnect=base.topology, cost=em.cost,
            notes=f"{num_pods - n_em} plain + {n_em} memory-expanded pods.")

    return mix

def hetero_cost_study(
    cfg: ModelConfig, shape: ShapeConfig,
    em_pod_fractions: Sequence[float] = (0.0, 0.25, 0.5, 1.0),
    plain: str = "B0", expanded: str = "B1",
    strategies=None,
) -> StudySpec:
    """Fig.-8-style sweep over clusters mixing plain and memory-expanded
    pods, with ``cost_usd``/``tco``/``perf_per_dollar`` columns.

    Each ``em_pod_frac`` value builds a :class:`ClusterSpec` whose pods mix
    the ``plain`` cluster's node with the ``expanded`` cluster's node (same
    interconnect and pod size).  Synchronous-training semantics apply: a
    strategy is feasible only if its shard fits the *plain* pods too, so
    the ranking quantifies when partial EM deployment is money wasted and
    when full EM wins perf-per-dollar (Fig. 15's B0-vs-B1 story)."""
    mix = _em_pod_mix(plain, expanded)
    return StudySpec(
        name="hetero-em-tco", model=cfg, shape=shape,
        strategies=as_strategy_space(strategies) or PowerOfTwoSpace(min_mp=8),
        axes=[Axis("em_pod_frac", tuple(em_pod_fractions), apply=mix)])


def hetero_cost_ranking(cfg: ModelConfig, shape: ShapeConfig,
                        processes: Optional[int] = None,
                        engine: str = "compiled",
                        **kwargs) -> List[Dict[str, float]]:
    """Feasible (em_pod_frac, strategy) cells, best perf-per-dollar first."""
    res: StudyResult = run_study(hetero_cost_study(cfg, shape, **kwargs),
                                 processes=processes, engine=engine)
    feasible = [c.record for c in res if c.record["feasible"]]
    return sorted(feasible, key=lambda r: r["perf_per_dollar"], reverse=True)


def pareto_frontier(cfg: Optional[ModelConfig] = None,
                    shape: Optional[ShapeConfig] = None,
                    objectives=None,
                    processes: Optional[int] = None,
                    engine: str = "compiled",
                    **kwargs) -> List[Dict[str, float]]:
    """Demo search study: the (time, TCO, energy) Pareto frontier of the
    mixed plain/EM fleet design space (``hetero_cost_study``).

    A single perf-per-dollar scalar hides the trade surface; the frontier
    keeps every fleet fraction x strategy cell no other cell beats on all
    three axes at once — typically the all-plain fleet (cheap, slow), the
    all-EM fleet (fast, expensive) and the EM-aware mixes between them.
    Every record is annotated with ``pareto_rank`` / ``pareto_optimal``
    (:mod:`repro.core.search`); returns the frontier records, fastest
    first."""
    from repro.core.search import DEFAULT_OBJECTIVES, pareto_front
    cfg = cfg or _default_transformer()
    shape = shape or ShapeConfig("pareto", 2048, 1024, "train")
    res = run_study(hetero_cost_study(cfg, shape, **kwargs),
                    processes=processes, engine=engine)
    front = pareto_front(res, objectives if objectives is not None
                         else DEFAULT_OBJECTIVES)
    return sorted((c.record for c in front),
                  key=lambda r: r["total"])


# --------------------------------------------------------------------- #
# Beyond Fig. 8: the full MP x DP x PP x EP joint sweep (ISSUE 3 tentpole)
# Megatron-LM-style pipeline stages + GSPMD-style expert sharding now run
# through the default analytical workload builder, so the four-axis design
# space the paper's §V methodology implies is swept directly.
# --------------------------------------------------------------------- #

def pp_ep_study(
    cfg: Optional[ModelConfig] = None,
    shape: Optional[ShapeConfig] = None,
    clusters: Sequence[str] = ("A0", "B1"),
    mp: Sequence[int] = (4, 8, 16, 32, 64),
    dp: Sequence[int] = (1, 2, 4, 8, 16, 32, 64, 128, 256),
    pp: Sequence[int] = (1, 2, 4),
    ep: Sequence[int] = (1, 2),
    num_microbatches: Sequence[int] = (0,),
) -> StudySpec:
    """MoE transformer over the four-axis MP x DP x PP x EP product on the
    registry clusters (default: bandwidth-starved A0 vs memory-expanded B1).

    Every cell runs the default workload builder — PP stages with their
    p2p boundary transfers and microbatch bubble, EP expert sharding with
    all-to-all dispatch/combine — so the ranking shows where pipeline or
    expert degrees beat the paper's pure MP x DP slice."""
    from repro.configs import get_config
    from repro.core.cluster import get_cluster

    cfg = cfg or get_config("llama4-maverick-400b-a17b")
    shape = shape or ShapeConfig("pp_ep", 4096, 256, "train")
    names = tuple(clusters)
    return StudySpec(
        name="pp-ep-four-axis", model=cfg, shape=shape,
        axes=[Axis("cluster", names,
                   apply=lambda _, name: get_cluster(name))],
        strategies=GridSpace(mp=tuple(mp), dp=tuple(dp), pp=tuple(pp),
                             ep=tuple(ep),
                             num_microbatches=tuple(num_microbatches)))


def pp_ep_ranking(processes: Optional[int] = None,
                  engine: str = "compiled",
                  **kwargs) -> List[Dict[str, float]]:
    """Feasible four-axis cells, fastest first (per-cluster ranking is a
    ``select(cluster=...)`` away)."""
    res = run_study(pp_ep_study(**kwargs), processes=processes,
                    engine=engine)
    feasible = [c.record for c in res if c.record["feasible"]]
    return sorted(feasible, key=lambda r: r["total"])


# --------------------------------------------------------------------- #
# §V-D / Fig. 15: comparative training across 11 clusters
# --------------------------------------------------------------------- #

def _dlrm_group_nodes_per_instance(node: NodeConfig, fleet_nodes: int) -> int:
    """Paper §V-D placement rule for one node type:
    mem0 -> 64, mem1 -> 16, mem2 -> 8."""
    if node.exp_cap > 0.75 * node.local_cap:
        return 16 if node.exp_bw <= 500 * GB else 8
    return min(64, fleet_nodes)


def _dlrm_nodes_per_instance(cl: ClusterLike) -> int:
    """§V-D rule routed through ``node_groups`` so heterogeneous
    ``ClusterSpec`` inputs work (``cl.node`` raises on >1 node types):
    the largest group's node type sizes the instance."""
    g = max(cl.node_groups, key=lambda g: g.num_nodes)
    return _dlrm_group_nodes_per_instance(g.node, cl.num_nodes)


def cluster_comparison_studies(
    transformer_cfg: ModelConfig, transformer_shape: ShapeConfig,
    dlrm_cfg, dlrm_batch: int = 4096,
    clusters: Optional[Dict[str, ClusterLike]] = None,
):
    """(transformer study, dlrm study) over a cluster-valued axis."""
    clusters = clusters or TABLE_III_CLUSTERS
    # Workload depends only on the strategy, so decompositions are shared
    # across same-size clusters (workload_deps stays empty).
    transformer = StudySpec(
        name="fig15-transformer", model=transformer_cfg,
        shape=transformer_shape,
        axes=[Axis("cluster", tuple(clusters),
                   apply=lambda _, name: clusters[name])],
        strategies=PowerOfTwoSpace())

    # 8 DLRM instances on (at most) 64 fleet nodes: the waves/turnaround
    # bookkeeping is the study-native JobSpec layer now.
    dlrm = StudySpec(
        name="fig15-dlrm",
        axes=[Axis("cluster", tuple(clusters),
                   apply=lambda _, name: clusters[name])],
        workload=lambda ctx: decompose_dlrm(
            dlrm_cfg, dlrm_batch,
            _dlrm_nodes_per_instance(clusters[ctx.point["cluster"]])),
        workload_deps=("cluster",),
        job=lambda ctx: JobSpec(
            instances=8, max_nodes=64,
            nodes_per_instance=_dlrm_nodes_per_instance(ctx.cluster)))
    return transformer, dlrm


def cluster_comparison(
    transformer_cfg: ModelConfig,
    transformer_shape: ShapeConfig,
    dlrm_cfg,
    dlrm_batch: int = 4096,
    clusters: Optional[Dict[str, ClusterLike]] = None,
    processes: Optional[int] = None,
    engine: str = "compiled",
) -> Dict[str, Dict[str, float]]:
    """runtime[cluster][workload] for Transformer-1T + 8 DLRM instances.

    Transformer: best feasible (MP, DP) per cluster (capacity-constrained;
    heterogeneous specs gate on the least-capable group).
    DLRM: nodes-per-instance per the paper (mem0: 64, mem1: 16, mem2: 8).
    ``processes`` fans study cells over a fork pool (§V-E); ``engine``
    selects the evaluator (``"compiled"`` for the vectorized fast path)."""
    clusters = clusters or TABLE_III_CLUSTERS
    t_study, d_study = cluster_comparison_studies(
        transformer_cfg, transformer_shape, dlrm_cfg, dlrm_batch, clusters)
    t_res = run_study(t_study, processes=processes, engine=engine)
    d_res = run_study(d_study, processes=processes, engine=engine)
    out: Dict[str, Dict[str, float]] = {}
    for name, cl in clusters.items():
        per = t_res.select(cluster=name)
        fit = [c for c in per
               if c.record["footprint_bytes"] <= cl.min_node_cap
               and c.breakdown.feasible]
        out[name] = {
            "transformer-1t": (min(c.record["total"] for c in fit) if fit
                               else float("inf")),
            "dlrm": d_res.select(cluster=name).cells[0].record["turnaround"],
        }
    return out


# --------------------------------------------------------------------- #
# ISSUE 4 tentpole: placement as a swept study axis.
# (a) placement_study — EM-aware stage placement on a partial-EM fleet
#     (ROADMAP: "a placement model that puts memory-hungry shards on the
#     EM pods would let mixed fleets actually win");
# (b) multi_tenant_study — the Fig. 13b waves metric generalized to a
#     heterogeneous fleet through the JobSpec/ScheduleModel layer.
# --------------------------------------------------------------------- #

PLACEMENT_SHAPE = ShapeConfig("placement", 4096, 2048, "train")


def placement_study(
    cfg: Optional[ModelConfig] = None,
    shape: Optional[ShapeConfig] = None,
    em_pod_fractions: Sequence[float] = (0.0, 0.25, 0.5, 1.0),
    plain: str = "B0", expanded: str = "B1",
    strategies=None,
    placements: Sequence[str] = ("paper", "em-aware"),
) -> StudySpec:
    """Transformer-1T pipeline-stage placement over (EM-pod fraction) x
    (placement) x pipeline strategies.

    The placement lever exists only for ``pp > 1`` — a flat job has one
    stage and nothing to place (``hetero_cost_study`` covers that slice:
    all-or-nothing EM) — so the default strategy grid sweeps the pipeline
    cells.  Under the default ``PaperPlacement`` every pod group must
    hold every stage, so a partial-EM fleet is gated by its plain pods
    and the EM money is wasted (the PR-2 result).  ``EMAwarePlacement``
    assigns the memory-hungry stages to the EM pods (1F1B stashes
    ``pp - s`` microbatches at stage ``s``, so early stages are the fat
    ones): a half-EM fleet then runs ZeRO-heavy low-MP pipelines the
    plain fleet cannot fit at nearly the all-EM iteration time but well
    below the all-EM TCO — and tops ``perf_per_dollar`` over both
    all-plain and all-EM (see ``placement_ranking`` and the
    ``--only placement`` bench row)."""
    cfg = cfg or _default_transformer()
    shape = shape or PLACEMENT_SHAPE
    strategies = as_strategy_space(strategies) or GridSpace(
        mp=(4, 8, 16, 32), dp=(4, 8, 16, 32, 64, 128), pp=(2, 4, 8))
    return StudySpec(
        name="placement-em-aware", model=cfg, shape=shape,
        strategies=strategies,
        axes=[Axis("em_pod_frac", tuple(em_pod_fractions),
                   apply=_em_pod_mix(plain, expanded)),
              placement_axis(tuple(placements))])


def placement_ranking(processes: Optional[int] = None,
                      engine: str = "compiled",
                      **kwargs) -> List[Dict[str, float]]:
    """Feasible (em_pod_frac, placement, strategy) cells, best
    perf-per-dollar first."""
    res = run_study(placement_study(**kwargs), processes=processes,
                    engine=engine)
    feasible = [c.record for c in res if c.record["feasible"]]
    return sorted(feasible, key=lambda r: r["perf_per_dollar"],
                  reverse=True)


def _default_transformer() -> ModelConfig:
    from repro.configs import get_config
    return get_config("transformer-1t")


def mixed_dlrm_fleet(plain: str = "B0", expanded: str = "B1",
                     pods_each: int = 2) -> ClusterSpec:
    """A small two-type fleet for multi-tenant studies: ``pods_each``
    plain pods + ``pods_each`` memory-expanded pods (16-node Table III
    pods; the default is the Fig. 13b 64-node fleet, half-expanded)."""
    base, em = TABLE_III_CLUSTERS[plain], TABLE_III_CLUSTERS[expanded]
    pod = base.topology.pod_size
    return ClusterSpec(
        name=f"{plain}+{expanded}-fleet",
        pods=(PodSpec(base.node, count=pods_each, nodes_per_pod=pod),
              PodSpec(em.node, count=pods_each, nodes_per_pod=pod)),
        interconnect=base.topology, cost=em.cost,
        notes=f"{pods_each} plain + {pods_each} EM pods x {pod} nodes.")


def multi_tenant_study(
    dlrm_cfg=None,
    fleet: Optional[ClusterLike] = None,
    global_batch: int = 4096,
    num_instances: int = 8,
    nodes_per_instance_opts: Sequence[int] = (64, 32, 16, 8),
    placements: Sequence[str] = ("paper", "em-aware"),
) -> StudySpec:
    """Fig. 13b generalized: N DLRM instances on a (possibly mixed) fleet.

    Each cell sweeps the per-instance node count and the placement; the
    engine's JobSpec/ScheduleModel layer places the instances over the
    fleet's pod groups and emits native ``concurrent_instances`` /
    ``waves`` / ``turnaround`` / ``makespan`` columns.  On the default
    half-EM fleet, small (memory-hungry) instances only fit the EM pods:
    ``EMAwarePlacement`` schedules them there (more waves, but feasible),
    while the paper placement spreads them fleet-wide and reports the
    cell infeasible — the §V-C turnaround story, now placement-aware."""
    if dlrm_cfg is None:
        from repro.configs import get_dlrm_config
        dlrm_cfg = get_dlrm_config()
    fleet = fleet if fleet is not None else mixed_dlrm_fleet()
    return StudySpec(
        name="multi-tenant-dlrm", cluster=fleet,
        axes=[Axis("nodes_per_inst", tuple(nodes_per_instance_opts)),
              placement_axis(tuple(placements))],
        workload=lambda ctx: decompose_dlrm(dlrm_cfg, global_batch,
                                            ctx.point["nodes_per_inst"]),
        workload_deps=("nodes_per_inst",),
        job=lambda ctx: JobSpec(
            instances=num_instances,
            nodes_per_instance=ctx.point["nodes_per_inst"]))


def multi_tenant_ranking(processes: Optional[int] = None,
                         engine: str = "compiled",
                         **kwargs) -> List[Dict[str, float]]:
    """Feasible (nodes_per_inst, placement) cells, best turnaround first."""
    res = run_study(multi_tenant_study(**kwargs), processes=processes,
                    engine=engine)
    feasible = [c.record for c in res if c.record["feasible"]]
    return sorted(feasible, key=lambda r: r["turnaround"])


# --------------------------------------------------------------------- #
# Beyond the paper's training studies: serving-fleet DSE (ISSUE 7).
# Prefill/decode rooflines + an SLO-gated traffic simulation decide when
# disaggregating the two phases onto separate pods beats colocated
# replicas on goodput-per-dollar.
# --------------------------------------------------------------------- #

def _serving_pod_mix(plain: str = "B0", expanded: str = "B1",
                     num_pods: int = 4):
    """``apply(cluster, frac) -> ClusterSpec`` building a small serving
    fleet: ``num_pods`` Table III pods, ``frac`` of them memory-expanded
    (same interconnect; priced by the expanded cluster's cost model)."""
    base, em = TABLE_III_CLUSTERS[plain], TABLE_III_CLUSTERS[expanded]
    pod = base.topology.pod_size

    def mix(_, frac: float) -> ClusterSpec:
        if not 0.0 <= frac <= 1.0:
            raise ValueError(f"em_pod_frac must be in [0, 1], got {frac}")
        n_em = int(round(frac * num_pods))
        pods = tuple(
            p for p in (PodSpec(base.node, count=num_pods - n_em,
                                nodes_per_pod=pod),
                        PodSpec(em.node, count=n_em, nodes_per_pod=pod))
            if p.count > 0)
        return ClusterSpec(
            name=f"serve-{plain}+{expanded}-em{n_em}of{num_pods}",
            pods=pods, interconnect=base.topology, cost=em.cost,
            notes=f"serving fleet: {num_pods - n_em} plain + {n_em} EM "
                  f"pods x {pod} nodes.")

    return mix


def serving_study(
    cfg: Optional[ModelConfig] = None,
    em_pod_fractions: Sequence[float] = (0.0, 0.25, 0.5),
    rates: Sequence[float] = (120.0, 280.0, 440.0),
    placements: Sequence[str] = ("colocated", "disaggregated"),
    num_requests: int = 3000,
    plain: str = "B0", expanded: str = "B1", num_pods: int = 4,
):
    """Serving DSE over an ``em_pod_frac x rate x placement`` grid.

    Each cell builds a mixed plain/EM fleet, prices one replica's
    prefill and decode phases on the roofline, then pushes a Poisson
    trace through the fleet queue to get SLO-gated ``goodput`` (and
    ``goodput_per_dollar`` via the fleet's TCO).  Colocated replicas
    stall their whole batch for every admission's prefill (the
    ``repro.serve.engine`` semantics), so past a traffic knee their
    TPOT blows through the SLO; disaggregated fleets keep decode pods
    at pure-decode cadence at the price of dedicating pods (and a KV
    hand-off per request) to prefill.  Returns a
    :class:`repro.serving.ServingSpec` — pass it straight to
    :func:`run_study`."""
    from repro.configs import get_config
    from repro.serving import (ServingModel, ServingSpec, SLOSpec,
                               TrafficTrace, serving_placement_axis)
    cfg = cfg or get_config("internlm2-20b")
    mix = _serving_pod_mix(plain, expanded, num_pods)
    return ServingSpec(
        name="serving-disagg-dse", model=cfg,
        serving=ServingModel(max_batch=32, max_seq=8192,
                             prompt_len=1024, max_new_tokens=64),
        trace=TrafficTrace(kind="poisson", rate=float(rates[0]),
                           num_requests=num_requests),
        slo=SLOSpec(ttft=1.0, tpot=0.035),
        axes=[Axis("em_pod_frac", tuple(em_pod_fractions), apply=mix),
              Axis("rate", tuple(float(r) for r in rates),
                   path="trace.rate"),
              serving_placement_axis(tuple(placements))])


def serving_ranking(processes: Optional[int] = None,
                    **kwargs) -> List[Dict[str, float]]:
    """Feasible (em_pod_frac, rate, placement) cells, best
    goodput-per-dollar first."""
    res: StudyResult = run_study(serving_study(**kwargs),
                                 processes=processes)
    feasible = [c.record for c in res if c.record["feasible"]]
    return sorted(feasible, key=lambda r: r["goodput_per_dollar"],
                  reverse=True)


# --------------------------------------------------------------------- #
# Beyond the paper's static allocation: elastic-fleet DSE (ISSUE 9).
# A discrete-time timeline over the mixed EM/plain fleet decides when
# priority preemption + elastic DP resize + burst parallelism beat the
# static ScheduleModel allocation on turnaround and perf-per-dollar.
# --------------------------------------------------------------------- #

def _fleet_job_mix(num_iters_scale: float = 1.0):
    """The mixed-tenant template tuple ``fleet_study`` stamps arrivals
    onto: a DLRM batch job pinned (by memory) to the EM pods, elastic
    chat fine-tunes, a wide tenant, and a high-priority burst job."""
    from repro.fleet import FleetJobSpec

    def n(iters: int) -> int:
        return max(1, int(round(iters * num_iters_scale)))

    return (
        FleetJobSpec(name="dlrm-batch", model="dlrm", global_batch=4096,
                     nodes_per_instance=16, widths=(16, 32),
                     iterations=n(120_000), priority=0),
        FleetJobSpec(name="chat-ft", model="chatglm3-6b", mp=2,
                     global_batch=256, nodes_per_instance=8,
                     widths=(8, 16, 32), iterations=n(60), priority=0),
        FleetJobSpec(name="tenant", model="internlm2-20b", mp=4,
                     global_batch=512, nodes_per_instance=16,
                     widths=(16, 32), iterations=n(12), priority=1),
        FleetJobSpec(name="burst", model="internlm2-20b", mp=4,
                     global_batch=256, nodes_per_instance=8,
                     widths=(8, 32), iterations=n(24), burst_iters=n(20),
                     priority=2, preemptible=False),
    )


def fleet_study(
    fleet: Optional[ClusterLike] = None,
    policies: Sequence[str] = ("static", "elastic", "elastic+burst"),
    rate: float = 1 / 600.0,
    num_jobs: int = 12,
    seed: int = 0,
    num_iters_scale: float = 1.0,
    placement: str = "em-aware",
):
    """Elastic-fleet DSE: a mixed job trace replayed under each fleet
    policy on the half-EM Fig. 13b fleet.

    Each cell materializes a Poisson arrival trace over the
    ``_fleet_job_mix`` templates, prices every (job, width) with the
    compiled study engine, and replays the timeline under the cell's
    ``fleet.policy``.  Static cells hold the PR-4 ``ScheduleModel``
    allocation for a job's whole life; elastic cells grow/shrink DP
    width (priced as checkpoint + reshard via ``remesh_delay``) and
    preempt by priority; ``elastic+burst`` additionally lends the fleet
    to the high-priority burst job for its bounded window.  Returns a
    :class:`repro.fleet.FleetSpec` — pass it straight to
    :func:`run_study`."""
    from repro.fleet import FleetSpec, FleetTrace
    return FleetSpec(
        name="fleet-elastic-dse",
        jobs=_fleet_job_mix(num_iters_scale),
        cluster=fleet if fleet is not None else mixed_dlrm_fleet(),
        ftrace=FleetTrace(kind="poisson", rate=rate, num_jobs=num_jobs,
                          seed=seed),
        placement=placement,
        axes=[Axis("policy", tuple(policies), path="fleet.policy")])


def fleet_ranking(processes: Optional[int] = None,
                  **kwargs) -> List[Dict[str, float]]:
    """Feasible policy cells, best turnaround-p99 first.  The headline
    claim — elastic+burst beats the static ScheduleModel allocation by
    >= 1.3x on turnaround-p99 or perf-per-dollar — reads straight off
    this table (see ``fleet_headline``)."""
    res: StudyResult = run_study(fleet_study(**kwargs),
                                 processes=processes)
    feasible = [c.record for c in res if c.record["feasible"]]
    return sorted(feasible, key=lambda r: r["turnaround_p99"])


def fleet_headline(records: Sequence[Dict[str, float]]
                   ) -> Dict[str, float]:
    """The elastic+burst-vs-static win ratios from a ``fleet_ranking``
    table: ``{"turnaround_p99_ratio", "perf_per_dollar_ratio"}``
    (both >1 means the timeline policies beat the static allocation)."""
    by_policy = {r["policy"]: r for r in records}
    static, eb = by_policy["static"], by_policy["elastic+burst"]
    return {
        "turnaround_p99_ratio":
            static["turnaround_p99"] / eb["turnaround_p99"],
        "perf_per_dollar_ratio":
            eb["perf_per_dollar"] / static["perf_per_dollar"],
    }


# --------------------------------------------------------------------- #
# ISSUE 10 tentpole: failure-aware DSE headline studies.
# (a) reliability_study — closed-form Young–Daly goodput columns over a
#     cluster-shape axis engineered so the §V-D perf-per-dollar ranking
#     flips once failures are priced in (goodput_per_dollar);
# (b) reliability_fleet_study — fault injection in the fleet timeline:
#     wait-for-repair vs shrink-to-survive under an explicit failure.
# --------------------------------------------------------------------- #

def _reliability_clusters() -> Dict[str, ClusterConfig]:
    """Two same-aggregate-compute cluster shapes: many cheap half-speed
    nodes vs a quarter as many double-speed ones.  Failure-free, the
    many-weak shape wins perf-per-dollar (cheaper capex per FLOP); at
    finite MTBF its 4x node count quadruples the job-level failure rate
    and the few-strong shape wins goodput-per-dollar — the ranking-flip
    headline."""
    from repro.core.cluster import BASELINE_DGX_A100
    base = BASELINE_DGX_A100
    assert base.cost is not None
    weak = base.node.scaled_compute(0.5).with_expansion(
        cap=1e15, bw=1000 * GB)
    strong = base.node.scaled_compute(2.0).with_expansion(
        cap=1e15, bw=1000 * GB)
    many = dataclasses.replace(
        base, name="many-weak", num_nodes=2048, node=weak,
        cost=dataclasses.replace(base.cost, usd_per_node=7_500))
    few = dataclasses.replace(
        base, name="few-strong", num_nodes=512, node=strong,
        cost=dataclasses.replace(base.cost, usd_per_node=29_000))
    return {"many-weak": many, "few-strong": few}


RELIABILITY_SHAPE = ShapeConfig("reliability", 2048, 1024, "train")


def reliability_study(
    cfg: Optional[ModelConfig] = None,
    shape: Optional[ShapeConfig] = None,
    clusters: Optional[Dict[str, ClusterLike]] = None,
    mtbf_hours: Sequence[float] = (float("inf"), 10_000.0),
    intervals: Sequence[float] = (0.0, 120.0),
    mttr_hours: float = 2.0,
    ckpt_bw: float = 400e9,
    run_hours: float = 168.0,
) -> StudySpec:
    """Transformer-1T failure-aware cluster DSE (closed form).

    Sweeps (cluster shape) x (per-node MTBF, inf = failure-free) x
    (checkpoint cadence: 0 = the Young–Daly optimum, else a naive fixed
    interval) with each shape's fill-the-cluster strategy, and attaches
    the ``ckpt_interval_s / ckpt_overhead_frac / expected_restarts /
    goodput_frac / goodput_per_dollar`` columns through
    ``StudySpec.reliability``.  ``reliability_headline`` reads the two
    ISSUE-10 claims off the result: the Daly interval beats the naive
    cadence on goodput, and the perf-per-dollar ranking flips once
    failures are priced in."""
    from repro.reliability import FailureModel
    cfg = cfg or _default_transformer()
    shape = shape or RELIABILITY_SHAPE
    cl = dict(clusters) if clusters is not None else _reliability_clusters()
    return StudySpec(
        name="reliability-goodput-dse", model=cfg, shape=shape,
        strategies=GridSpace(mp=(8,), dp=(64, 256)),
        axes=[Axis("cluster", tuple(cl), apply=lambda _, n: cl[n]),
              Axis("mtbf_hours", tuple(mtbf_hours),
                   path="reliability.mtbf_hours"),
              Axis("ckpt_interval", tuple(intervals),
                   path="reliability.interval_s")],
        reliability=FailureModel(mtbf_hours=50_000.0,
                                 mttr_hours=mttr_hours, ckpt_bw=ckpt_bw,
                                 run_hours=run_hours))


def reliability_ranking(processes: Optional[int] = None,
                        engine: str = "compiled",
                        **kwargs) -> List[Dict[str, float]]:
    """Feasible (cluster, mtbf, cadence) cells, best failure-aware
    goodput-per-dollar first."""
    res = run_study(reliability_study(**kwargs), processes=processes,
                    engine=engine)
    feasible = [c.record for c in res if c.record["feasible"]]
    return sorted(feasible, key=lambda r: r["goodput_per_dollar"],
                  reverse=True)


def reliability_headline(records: Sequence[Dict[str, float]]
                         ) -> Dict[str, object]:
    """The two closed-form ISSUE-10 claims from a
    ``reliability_ranking`` table: ``daly_vs_naive`` (>= 1: the
    Young–Daly cadence never loses goodput to the naive fixed one) and
    ``ranking_flips`` (the failure-free perf-per-dollar winner is not
    the failure-aware goodput-per-dollar winner)."""
    import math
    fin = [r for r in records if math.isfinite(r["mtbf_hours"])]
    free = [r for r in records if math.isinf(r["mtbf_hours"])]
    best_aware = max(fin, key=lambda r: r["goodput_per_dollar"])
    best_free = max(free, key=lambda r: r["perf_per_dollar"])
    same = [r for r in fin if r["cluster"] == best_aware["cluster"]]
    daly = max(r["goodput_frac"] for r in same if r["ckpt_interval"] == 0.0)
    naive = max(r["goodput_frac"] for r in same if r["ckpt_interval"] > 0.0)
    return {
        "daly_goodput": daly,
        "naive_goodput": naive,
        "daly_vs_naive": daly / naive,
        "best_failure_free": best_free["cluster"],
        "best_failure_aware": best_aware["cluster"],
        "ranking_flips": best_free["cluster"] != best_aware["cluster"],
    }


def _reliability_pod(kind: str = "B1") -> ClusterSpec:
    """A single 16-node Table III pod: with only one group, a killed
    wide instance cannot relocate — wait-for-repair genuinely waits."""
    base = TABLE_III_CLUSTERS[kind]
    pod = base.topology.pod_size
    return ClusterSpec(
        name=f"{kind}-pod",
        pods=(PodSpec(base.node, count=1, nodes_per_pod=pod),),
        interconnect=base.topology, cost=base.cost,
        notes=f"One {kind} pod x {pod} nodes for fault-injection studies.")


def _reliability_fleet_mix(num_iters_scale: float = 1.0):
    """Two elastic trainers whose width menu reaches below the base
    width — the lever shrink-to-survive pulls when a failure leaves
    fewer than base-width nodes up."""
    from repro.fleet import FleetJobSpec

    def n(iters: int) -> int:
        return max(1, int(round(iters * num_iters_scale)))

    return (
        FleetJobSpec(name="pretrain", model="chatglm3-6b", mp=2,
                     global_batch=256, nodes_per_instance=8,
                     widths=(2, 8), iterations=n(40), priority=0),
        FleetJobSpec(name="finetune", model="chatglm3-6b", mp=2,
                     global_batch=256, nodes_per_instance=8,
                     widths=(2, 8), iterations=n(40), arrival=10.0,
                     priority=0),
    )


def reliability_fleet_study(
    fleet: Optional[ClusterLike] = None,
    policies: Sequence[str] = ("wait", "shrink"),
    fail_time: float = 300.0,
    fail_nodes: int = 12,
    repair_s: float = 30_000.0,
    ckpt_interval_s: float = 120.0,
    num_iters_scale: float = 1.0,
    placement: str = "em-aware",
):
    """Fault injection in the fleet timeline: an explicit failure downs
    ``fail_nodes`` of a single 16-node pod mid-run with a long repair,
    and the ``fleet.degradation`` axis replays the same timeline under
    wait-for-repair vs shrink-to-survive.  With one group there is
    nowhere to relocate: the wait cells stall until the repair; the
    shrink cells restart narrow on what is left —
    ``reliability_fleet_headline`` reads the turnaround-p99 win off the
    table.  Returns a :class:`repro.fleet.FleetSpec`."""
    from repro.fleet import FleetModel, FleetSpec, FleetTrace
    from repro.reliability import FailureEvent, FailureTrace
    return FleetSpec(
        name="fleet-reliability-dse",
        jobs=_reliability_fleet_mix(num_iters_scale),
        cluster=fleet if fleet is not None else _reliability_pod(),
        fleet=FleetModel(policy="elastic",
                         ckpt_interval_s=ckpt_interval_s),
        ftrace=FleetTrace(kind="static"),
        failures=FailureTrace(
            kind="explicit",
            events=(FailureEvent(time=fail_time, group=0,
                                 nodes=fail_nodes, repair_s=repair_s),)),
        placement=placement,
        axes=[Axis("degradation", tuple(policies),
                   path="fleet.degradation")])


def reliability_fleet_ranking(processes: Optional[int] = None,
                              **kwargs) -> List[Dict[str, float]]:
    """Feasible degradation-policy cells, best turnaround-p99 first."""
    res: StudyResult = run_study(reliability_fleet_study(**kwargs),
                                 processes=processes)
    feasible = [c.record for c in res if c.record["feasible"]]
    return sorted(feasible, key=lambda r: r["turnaround_p99"])


def reliability_fleet_headline(records: Sequence[Dict[str, float]]
                               ) -> Dict[str, float]:
    """The fault-injection ISSUE-10 claim from a
    ``reliability_fleet_ranking`` table: shrink-to-survive beats
    wait-for-repair on turnaround-p99 (``p99_ratio`` > 1)."""
    by_policy = {r["degradation"]: r for r in records}
    wait, shrink = by_policy["wait"], by_policy["shrink"]
    return {
        "wait_p99": wait["turnaround_p99"],
        "shrink_p99": shrink["turnaround_p99"],
        "p99_ratio": wait["turnaround_p99"] / shrink["turnaround_p99"],
        "wait_goodput": wait["goodput"],
        "shrink_goodput": shrink["goodput"],
    }


# --------------------------------------------------------------------- #
# Figure-study registry
# --------------------------------------------------------------------- #

def figure_studies(cfg: Optional[ModelConfig] = None,
                   shape: Optional[ShapeConfig] = None,
                   dlrm_cfg=None,
                   cluster: Optional[ClusterConfig] = None,
                   ) -> Dict[str, StudySpec]:
    """The seven paper-figure studies as StudySpecs with their defaults,
    keyed ``fig8`` .. ``fig13b``.

    This is the declarative surface the static analyzer sweeps
    (``python -m repro.analysis``) and the validate-equivalence tests
    iterate; the ``*_sweep`` / runner functions above stay the execution
    entry points."""
    from repro.core.cluster import BASELINE_DGX_A100
    cfg = cfg if cfg is not None else _default_transformer()
    shape = shape if shape is not None else ShapeConfig(
        "paper", seq_len=2048, global_batch=1024, kind="train")
    if dlrm_cfg is None:
        from repro.configs import get_dlrm_config
        dlrm_cfg = get_dlrm_config()
    cluster = cluster if cluster is not None else BASELINE_DGX_A100
    return {
        "fig8": mpdp_study(cfg, shape, cluster),
        "fig9": memory_expansion_study(cfg, shape, cluster),
        "fig10": compute_scaling_study(cfg, shape, cluster, mp=8, dp=128),
        "fig11": network_scaling_study(cfg, shape, cluster, mp=64, dp=16),
        "fig12": bandwidth_rebalance_study(cfg, shape, cluster, mp=64, dp=16),
        "fig13a": dlrm_cluster_size_study(dlrm_cfg, cluster),
        "fig13b": dlrm_memory_expansion_study(dlrm_cfg, cluster),
    }
