"""COMET §III-A / §III-C2: GEMM workload primitives and the memory-traffic model.

Every model layer is expressed either as a GEMM between input activations
(M x K) and weights (K x N) producing (M x N), or as an explicit op with
stated FLOPs and bytes moved (embedding lookups, element-wise ops).

The memory-traffic model (§III-C2) is the paper's linear tiling estimate for
a compute node with an on-chip buffer of S bytes:

    traffic = min(Psi_1, Psi_2) + W
    Psi_1   = ceil(U / S) * V + U        # tile operand U, stream V
    Psi_2   = ceil(V / S) * U + V        # tile operand V, stream U

where U, V are the input operand sizes in bytes and W the output size.
"""

from __future__ import annotations

import dataclasses
import math

def gemm_traffic_bytes(u: int, v: int, w: int, sram_bytes: int) -> int:
    """Paper Eqn (traffic): min{Psi1, Psi2} + W for on-chip buffer S."""
    if u == 0 or v == 0:
        return u + v + w
    s = max(int(sram_bytes), 1)
    psi1 = math.ceil(u / s) * v + u
    psi2 = math.ceil(v / s) * u + v
    return min(psi1, psi2) + w


@dataclasses.dataclass(frozen=True)
class Gemm:
    """One (M x K) @ (K x N) GEMM; ``batch`` repeats it (e.g. per-head)."""

    m: int
    k: int
    n: int
    batch: int = 1
    bytes_per_element: int = 2  # bf16/fp16 compute

    def flops(self) -> int:
        return 2 * self.batch * self.m * self.k * self.n

    @property
    def a_bytes(self) -> int:
        return self.batch * self.m * self.k * self.bytes_per_element

    @property
    def b_bytes(self) -> int:
        return self.batch * self.k * self.n * self.bytes_per_element

    @property
    def out_bytes(self) -> int:
        return self.batch * self.m * self.n * self.bytes_per_element

    def traffic(self, sram_bytes: int) -> int:
        # Each batch instance is tiled independently (per-head working sets).
        per = gemm_traffic_bytes(
            self.m * self.k * self.bytes_per_element,
            self.k * self.n * self.bytes_per_element,
            self.m * self.n * self.bytes_per_element,
            sram_bytes,
        )
        return self.batch * per

    def transposed_for_ig(self) -> "Gemm":
        """Input-gradient GEMM: dX = dY @ W^T -> (M x N) @ (N x K)."""
        return Gemm(self.m, self.n, self.k, self.batch, self.bytes_per_element)

    def transposed_for_wg(self) -> "Gemm":
        """Weight-gradient GEMM: dW = X^T @ dY -> (K x M) @ (M x N)."""
        return Gemm(self.k, self.m, self.n, self.batch, self.bytes_per_element)


@dataclasses.dataclass(frozen=True)
class ExplicitOp:
    """Non-GEMM op: embedding lookup, element-wise, softmax, conv, ...

    Encoded per §III-A by its FLOPs and the bytes moved between memory and
    the compute unit (no tiling model — these ops are streaming).
    """

    flops: int
    bytes_moved: int

    def traffic(self, sram_bytes: int) -> int:  # noqa: ARG002 (streaming)
        return self.bytes_moved


@dataclasses.dataclass(frozen=True)
class PhaseCost:
    """Aggregate FLOPs + traffic of one layer in one training phase."""

    flops: int = 0
    traffic: int = 0

    def __add__(self, other: "PhaseCost") -> "PhaseCost":
        return PhaseCost(self.flops + other.flops, self.traffic + other.traffic)

    @property
    def operational_intensity(self) -> float:
        """OI (FLOPs/byte), paper Eqn (1)."""
        if self.traffic == 0:
            return float("inf")
        return self.flops / self.traffic


def phase_cost(op, sram_bytes: int) -> PhaseCost:
    """PhaseCost of a single Gemm/ExplicitOp on a node with buffer S."""
    if isinstance(op, Gemm):
        return PhaseCost(op.flops(), op.traffic(sram_bytes))
    if isinstance(op, ExplicitOp):
        return PhaseCost(op.flops, op.bytes_moved)
    raise TypeError(f"unknown op type {type(op)!r}")


@dataclasses.dataclass(frozen=True)
class CommEvent:
    """One collective issued by a layer in a phase.

    scope: which mesh dimension the collective spans —
      "mp" (model-parallel group),
      "dp" (data-parallel group; spans DP x EP when an EP axis exists),
      "ep" (expert-parallel group; with ep == 1 it maps onto the mp group),
      "pp" (pipeline axis: the stage-boundary "p2p" transfers),
      "edp" (expert-gradient group: DP only, experts being EP-sharded).
    blocking: True -> on the critical path (FP/IG MP collectives);
              False -> overlappable with compute (WG DP collectives).
    """

    collective: str  # all-reduce | all-gather | reduce-scatter | all-to-all | p2p
    size_bytes: int
    scope: str
    blocking: bool

    def scaled(self, factor: float) -> "CommEvent":
        return dataclasses.replace(self, size_bytes=int(self.size_bytes * factor))
