"""Measured-frontend COMET: roofline terms from compiled XLA artifacts.

The paper estimates FLOPs/bytes analytically; the dry-run path measures them
from the compiled executable instead and feeds them into the *same* roofline
arithmetic:

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``cost_analysis()`` provides HLO_FLOPs and HLO_bytes; collective bytes are
parsed out of the (post-SPMD-partitioning) HLO text by summing operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.core.cluster import V5E_HBM_BW, V5E_LINK_BW, V5E_PEAK_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g. "bf16[256,4096,128]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# HLO instruction line: "  %name = TYPE[SHAPE] opcode(...)" or
# "  name.123 = (tuple...) all-reduce(...)"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(",
)


def shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes in a (possibly tuple) HLO type."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes per collective opcode over the HLO module.

    ``-done`` halves of async pairs are skipped (the ``-start`` already
    carries the transferred shape)."""
    totals: Dict[str, int] = {op: 0 for op in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_str, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue
        totals[op] += shape_bytes(shape_str)
    return totals


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """Per-device roofline terms (seconds) for one compiled step."""

    flops: float                   # total HLO FLOPs (all devices)
    hbm_bytes: float               # total HLO bytes accessed
    coll_bytes: float              # total collective bytes
    chips: int
    peak_flops: float = V5E_PEAK_FLOPS
    hbm_bw: float = V5E_HBM_BW
    link_bw: float = V5E_LINK_BW
    coll_breakdown: Optional[Dict[str, int]] = None

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * self.peak_flops)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * self.hbm_bw)

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / (self.chips * self.link_bw)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """Fraction of the step bound spent in useful compute: how close the
        dominant term sits to the pure-compute roofline."""
        if self.bound_s == 0:
            return 0.0
        return self.compute_s / self.bound_s

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "roofline_fraction": self.roofline_fraction(),
        }


def terms_from_compiled(compiled, hlo_text: str, chips: int,
                        **hw_overrides) -> RooflineTerms:
    """Build RooflineTerms from a jax Compiled object + its HLO text.

    ``cost_analysis()`` reports per-module totals; on SPMD-partitioned
    modules these are per-device numbers, so multiply by chip count."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0)) * chips
    hbm = float(cost.get("bytes accessed", 0.0)) * chips
    coll = collective_bytes(hlo_text)
    coll_total = float(sum(coll.values())) * chips
    return RooflineTerms(flops=flops, hbm_bytes=hbm, coll_bytes=coll_total,
                         chips=chips, coll_breakdown=coll, **hw_overrides)


def model_flops_util(model_flops: float, terms: RooflineTerms) -> float:
    """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful
    (catches remat/redundancy waste)."""
    if terms.flops == 0:
        return 0.0
    return model_flops / terms.flops
