"""Trip-count-weighted HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop body
ONCE, so any scan-over-layers model under-reports FLOPs, HBM bytes, and —
critically for the collective roofline term — per-layer collective bytes by
a factor of num_layers. This module walks the compiled HLO text, multiplies
computation costs by ``known_trip_count`` at each ``while`` site, and
accounts fusion boundaries as the HBM traffic unit (fusion internals stay
on-chip — a closer model of real memory traffic than XLA's per-op sum).

Costs are per-device (post-SPMD shapes); callers multiply by chip count.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

# "  %name = TYPE opcode(OPERANDS), attrs..."  /  "  ROOT %name = ..."
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\(")

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{")


def _shape_info(type_str: str) -> Tuple[int, List[int]]:
    """(total bytes, dims of first array shape)."""
    total = 0
    first_dims: List[int] = []
    for i, (dtype, dims) in enumerate(_SHAPE_RE.findall(type_str)):
        if dtype not in _DTYPE_BYTES:
            continue
        sizes = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in sizes:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
        if i == 0:
            first_dims = sizes
    return total, first_dims


def _elems(type_str: str) -> int:
    n_total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_total += n
    return n_total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    line: str


# Ops a TPU compiler fuses into their consumer (elementwise + layout +
# windowed reads). XLA:CPU wraps each in its own kLoop "fusion", so counting
# every boundary massively overstates TPU HBM traffic; instead an op in this
# set with exactly one user is treated as unmaterialized.
_FUSIBLE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "log-plus-one", "exponential-minus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "clamp", "select",
    "compare", "and", "or", "not", "xor", "convert", "copy", "erf",
    "broadcast", "iota", "reshape", "transpose", "bitcast", "slice",
    "dynamic-slice", "pad", "reduce-precision", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "rem", "atan2",
    "is-finite", "partition-id", "replica-id", "cosine", "sine",
}


def _fusion_kind_elementwise(comp: List["Instr"]) -> bool:
    """A called fusion computation containing only fusible ops behaves like
    a single elementwise op."""
    for i in comp:
        if i.opcode in ("parameter", "constant", "tuple",
                        "get-tuple-element"):
            continue
        if i.opcode not in _FUSIBLE:
            return False
    return True


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[Dict[str, float]] = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {}

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self._parse(text)
        self._memo: Dict[str, Cost] = {}
        self.entry = self._find_entry(text)

    # ------------------------------------------------------------------ #
    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        for line in text.splitlines():
            stripped = line.strip()
            if current is None:
                # computation header: "%name (args...) -> type {" (args may
                # contain nested parens, so match tokens, not a regex group)
                if stripped.endswith("{") and "->" in stripped:
                    tok = stripped.split()[0]
                    if tok == "ENTRY":
                        tok = stripped.split()[1]
                    current = tok.split("(")[0].lstrip("%")
                    self.computations[current] = []
                continue
            if stripped == "}" or stripped.startswith("}"):
                current = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                name, type_str, opcode = m.group(1), m.group(2), m.group(3)
                after = line[m.end():]
                depth = 1
                end = 0
                for i, ch in enumerate(after):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            end = i
                            break
                operand_str = after[:end]
                operands = re.findall(r"%([\w.\-]+)", operand_str)
                self.computations[current].append(
                    Instr(name, type_str, opcode, operands, line))

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        if m:
            return m.group(1)
        return next(iter(self.computations))

    # ------------------------------------------------------------------ #
    def _instr_cost(self, instr: Instr, defs: Dict[str, Instr],
                    mat: Dict[str, bool]) -> Cost:
        op = instr.opcode
        line = instr.line
        c = Cost()
        out_full, out_dims = _shape_info(instr.type_str)
        out_bytes = out_full if mat.get(instr.name, True) else 0.0

        def operand_bytes() -> float:
            # unmaterialized producers fuse into this op: no HBM read
            total = 0.0
            for o in instr.operands:
                d = defs.get(o)
                if d is not None and mat.get(o, True):
                    total += _shape_info(d.type_str)[0]
            return total

        # --- called computations -------------------------------------- #
        if op == "fusion":
            called = re.search(r"calls=%?([\w.\-]+)", line)
            comp_name = called.group(1) if called else None
            if comp_name in self.computations:
                inner = self.comp_cost(comp_name)
                c.flops += inner.flops
                for k, v in inner.coll.items():
                    c.coll[k] = c.coll.get(k, 0.0) + v
            # Fusion boundary bytes, slice-aware per operand: a parameter
            # consumed only by slice/gather ops inside the fusion reads the
            # window, not the whole array (stacked scan params!); a buffer
            # updated in place by dynamic-update-slice moves only the
            # updated window (scan ys / grad accumulators).
            comp = self.computations.get(comp_name, [])
            comp_defs = {i.name: i for i in comp}
            params_by_idx: Dict[int, Instr] = {}
            for i in comp:
                if i.opcode == "parameter":
                    m = re.search(r"parameter\((\d+)\)", i.line)
                    if m:
                        params_by_idx[int(m.group(1))] = i
            dus_update_bytes = 0.0
            for u in comp:
                if u.opcode == "dynamic-update-slice" and len(u.operands) > 1:
                    upd = comp_defs.get(u.operands[1])
                    if upd is not None:
                        dus_update_bytes += _shape_info(upd.type_str)[0]
                    else:
                        dus_update_bytes += out_full
            for idx, oname in enumerate(instr.operands):
                d = defs.get(oname)
                if d is not None and not mat.get(oname, True):
                    continue  # fused producer, no HBM read
                full = _shape_info(d.type_str)[0] if d is not None else 0
                p = params_by_idx.get(idx)
                if p is not None:
                    users = [u for u in comp if p.name in u.operands]
                    if users and all(u.opcode in ("slice", "dynamic-slice",
                                                  "gather")
                                     for u in users):
                        c.bytes += sum(_shape_info(u.type_str)[0]
                                       for u in users)
                        continue
                    if users and all(
                            u.opcode in ("dynamic-update-slice", "bitcast",
                                         "copy")
                            for u in users) and any(
                            u.opcode == "dynamic-update-slice"
                            and u.operands and u.operands[0] == p.name
                            for u in users):
                        c.bytes += dus_update_bytes  # RMW window read
                        continue
                c.bytes += full
            if dus_update_bytes > 0:
                c.bytes += dus_update_bytes  # in-place write, not full buffer
            else:
                c.bytes += out_bytes
            return c
        if op == "while":
            trip = 1
            m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
            if m:
                trip = int(m.group(1))
            body = re.search(r"body=%?([\w.\-]+)", line)
            cond = re.search(r"condition=%?([\w.\-]+)", line)
            if body and body.group(1) in self.computations:
                c.add(self.comp_cost(body.group(1)), trip)
            if cond and cond.group(1) in self.computations:
                c.add(self.comp_cost(cond.group(1)), trip)
            return c
        if op in ("call", "custom-call"):
            # A call executes its target once; the callee's own cost model
            # (incl. slice-aware fusion reads of stacked scan params) is the
            # traffic — charging the call's operands here would re-charge the
            # full stacked tensors the callee only windows into.
            called = re.search(r"to_apply=%?([\w.\-]+)", line)
            if called and called.group(1) in self.computations:
                c.add(self.comp_cost(called.group(1)))
                return c
            c.bytes += operand_bytes() + out_bytes
            return c
        if op in ("reduce", "reduce-window", "scatter", "sort", "map",
                  "select-and-scatter"):
            called = re.search(r"to_apply=%?([\w.\-]+)", line)
            if called and called.group(1) in self.computations:
                # applied per output element (reduce/scatter/map)
                inner = self.comp_cost(called.group(1))
                c.add(inner, max(_elems(instr.type_str), 1))
            c.bytes += operand_bytes() + out_bytes
            if op == "reduce":
                c.flops += max(operand_bytes() / 4.0, 0)  # ~1 flop/elem
            return c
        if op == "conditional":
            branches = re.findall(
                r"(?:true_computation|false_computation|branch_computations)"
                r"=\{?%?([\w.\-,% ]+)\}?", line)
            names: List[str] = []
            for b in branches:
                names += re.findall(r"([\w.\-]+)", b.replace("%", ""))
            costs = [self.comp_cost(n) for n in names
                     if n in self.computations]
            if costs:
                worst = max(costs, key=lambda cc: cc.flops + cc.bytes)
                c.add(worst)
            c.bytes += operand_bytes() + out_bytes
            return c

        # --- collectives ----------------------------------------------- #
        for coll in COLLECTIVES:
            if op == coll or op == coll + "-start":
                c.coll[coll] = c.coll.get(coll, 0.0) + out_bytes
                c.bytes += operand_bytes() + out_bytes
                return c
            if op == coll + "-done":
                return c

        # --- compute ops ------------------------------------------------ #
        if op in ("dot", "dot-general"):
            k = 1
            m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
            lhs = defs.get(instr.operands[0]) if instr.operands else None
            if m and lhs is not None:
                _, lhs_dims = _shape_info(lhs.type_str)
                for d in m.group(1).split(","):
                    if d and int(d) < len(lhs_dims):
                        k *= lhs_dims[int(d)]
            out_elems = _elems(instr.type_str)
            c.flops += 2.0 * out_elems * k
            c.bytes += operand_bytes() + out_bytes
            return c
        if op == "convolution":
            m = re.search(r"window=\{size=([0-9x]+)", line)
            kelems = 1
            if m:
                for d in m.group(1).split("x"):
                    kelems *= int(d)
            c.flops += 2.0 * _elems(instr.type_str) * kelems
            c.bytes += operand_bytes() + out_bytes
            return c
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all"):
            return c
        # Slicing/gather ops move only the selected window, not the full
        # operand (a dynamic-slice of stacked scan params reads one layer).
        if op in ("slice", "dynamic-slice", "gather"):
            c.bytes += 2.0 * out_bytes
            return c
        if op in ("dynamic-update-slice", "scatter"):
            upd = 0.0
            if len(instr.operands) >= 2:
                d = defs.get(instr.operands[1])
                if d is not None:
                    upd = _shape_info(d.type_str)[0]
            c.bytes += 2.0 * max(upd, out_bytes * 0.0)
            if upd == 0.0:
                c.bytes += out_bytes  # fallback when update operand unknown
            return c
        if op in ("broadcast", "iota", "reshape", "transpose", "copy",
                  "convert", "reverse", "pad", "concatenate"):
            c.bytes += operand_bytes() + out_bytes
            return c
        # generic elementwise
        c.flops += _elems(instr.type_str)
        c.bytes += operand_bytes() + out_bytes
        return c

    # ------------------------------------------------------------------ #
    def _is_fusible_node(self, instr: Instr) -> bool:
        if instr.opcode in _FUSIBLE:
            return True
        if instr.opcode == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", instr.line)
            if m and m.group(1) in self.computations:
                return _fusion_kind_elementwise(self.computations[m.group(1)])
        return False

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # cycle guard
        instrs = self.computations.get(name, [])
        defs = {i.name: i for i in instrs}
        # Materialization: a fusible op with exactly one user fuses into its
        # consumer (roots have zero users -> materialized).
        user_count: Dict[str, int] = {}
        for i in instrs:
            for o in i.operands:
                user_count[o] = user_count.get(o, 0) + 1
        mat: Dict[str, bool] = {}
        for i in instrs:
            mat[i.name] = (not self._is_fusible_node(i)
                           or user_count.get(i.name, 0) != 1)
        total = Cost()
        for instr in instrs:
            total.add(self._instr_cost(instr, defs, mat))
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        return self.comp_cost(self.entry)


def analyze_hlo(text: str) -> Cost:
    """Per-device (flops, hbm bytes, collective bytes by opcode)."""
    return HloModule(text).entry_cost()
