"""JAX backend for the compiled study engine: jit + vmap over environments.

Phase 2b of the two-phase engine (ROADMAP: "JAX-native mega-scale
search").  :mod:`repro.core.compiled` lowers each strategy to flat arrays
once; :func:`repro.core.simulator.time_compiled` times them against
environment batches.  This module re-expresses that hot path — the
delay-class roofline matrix (§III-C2 tiling traffic + Eqns (1)/(2)), the
per-family ``collective_time_batch`` formulas (hierarchical switch /
torus / single switch), and the ASTRA-lite timeline (a closed form when
no scope interleaves non-blocking and blocking events, a ``lax.scan``
walk otherwise) — as pure jittable functions of those flat arrays:

* :func:`stage_compute_exposed` is the drop-in kernel the simulator
  dispatches to under ``backend="jax"``: one ``jax.jit`` call per
  (stage, environment-batch), with the per-environment timeline
  ``vmap``-ed over the batch axis, so a whole (strategy x cluster-env)
  cross-product is one device dispatch per stage.  Shapes are the jit
  cache key: strategies stamped from the same model share event-stream
  shapes, so a sweep typically compiles once and replays.
* :func:`comm_matrix` vectorizes collective pricing over *environments*
  too: distinct topologies sharing a structural key (family + pod/dims
  layout) differ only in bandwidth/latency scalars, so one vectorized
  evaluation per (collective, scope, structural-key) prices every
  environment column at once.  (These formulas run in NumPy: they sit
  *outside* the jit and feed it as an input, where per-op dispatch
  overhead would dominate their tiny arithmetic.)  Topology families
  outside the three built-ins fall back to their own
  ``collective_time_batch`` / scalar ``collective_time`` (NumPy), so
  correctness never depends on this fast path.

Everything runs in float64 under ``jax.experimental.enable_x64`` —
scoped, so the f32 training/kernel stack elsewhere in the repo is
untouched — and matches the NumPy compiled engine (and therefore the
reference event loop) within 1e-9 relative (tests/test_jax_engine.py).
When JAX is not importable, ``HAVE_JAX`` is False and the simulator
falls back to the NumPy path with a one-time warning.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.topology import (
    HierarchicalSwitch,
    SingleSwitch,
    Torus,
    _group_size,
    _PAPER_ORDER,
)

try:
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    HAVE_JAX = True
except Exception:  # pragma: no cover - exercised on jax-less installs
    jax = None          # type: ignore[assignment]
    jnp = None          # type: ignore[assignment]
    enable_x64 = None   # type: ignore[assignment]
    HAVE_JAX = False


# --------------------------------------------------------------------- #
# Collective formulas over environment-parameter arrays
# --------------------------------------------------------------------- #
# Mirrors repro.core.topology's *_batch helpers term for term, with the
# bandwidth / latency scalars promoted to arrays over the environment
# group: ``sizes`` is (nev, 1), parameters are (k,), results broadcast to
# (nev, k).  Group sizes / pod layout / placement stay Python ints — they
# are part of the structural key that formed the group.

def _ring_allreduce(sizes, n: int, bw, lat):
    if n <= 1:
        return np.zeros(np.broadcast_shapes(np.shape(sizes),
                                              np.shape(bw)))
    t = 2 * (n - 1) / n * sizes / bw + 2 * (n - 1) * lat
    return np.where(sizes > 0, t, 0.0)


def _ring_allgather(sizes, n: int, bw, lat):
    if n <= 1:
        return np.zeros(np.broadcast_shapes(np.shape(sizes),
                                              np.shape(bw)))
    t = (n - 1) / n * sizes / bw + (n - 1) * lat
    return np.where(sizes > 0, t, 0.0)


def _all_to_all(sizes, n: int, bw, lat):
    if n <= 1:
        return np.zeros(np.broadcast_shapes(np.shape(sizes),
                                              np.shape(bw)))
    t = (n - 1) / n * sizes / bw + lat
    return np.where(sizes > 0, t, 0.0)


def _flat_time(collective: str, sizes, n: int, bw, lat):
    if collective == "all-reduce":
        return _ring_allreduce(sizes, n, bw, lat)
    if collective in ("all-gather", "reduce-scatter"):
        return _ring_allgather(sizes, n, bw, lat)
    if collective == "all-to-all":
        return _all_to_all(sizes, n, bw, lat)
    if collective == "p2p":
        return np.where(sizes > 0, sizes / bw + lat, 0.0)
    raise ValueError(f"unknown collective {collective!r}")


def _hier_time(collective: str, sizes, scope: str, mp: int, dp: int,
               pp: int, ep: int, order, pod_size: int,
               intra_bw, inter_bw, intra_lat, inter_lat):
    """HierarchicalSwitch.collective_time_batch over a parameter array."""
    if collective == "p2p":
        if not order.p2p_crosses_pod(mp, dp, pod_size, pp, ep):
            return np.where(sizes > 0, sizes / intra_bw + intra_lat, 0.0)
        return np.where(sizes > 0, sizes / inter_bw + inter_lat, 0.0)
    pl = order.group_placement(scope, mp, dp, pod_size, pp, ep)
    p, q = pl.intra, pl.inter
    if q <= 1:
        return _flat_time(collective, sizes, p, intra_bw, intra_lat)
    if p <= 1:
        return _flat_time(collective, sizes, q, inter_bw, inter_lat)
    if collective == "all-reduce":
        return 2 * _ring_allgather(sizes, p, intra_bw, intra_lat) \
            + _ring_allreduce(sizes / p, q, inter_bw, inter_lat)
    if collective in ("all-gather", "reduce-scatter"):
        return _ring_allgather(sizes, p, intra_bw, intra_lat) \
            + _ring_allgather(sizes / p, q, inter_bw, inter_lat)
    if collective == "all-to-all":
        n = p * q
        inter_frac = (n - p) / n
        intra_frac = (p - 1) / n
        t_inter = inter_frac * sizes / inter_bw + inter_lat
        t_intra = intra_frac * sizes / intra_bw + intra_lat
        return np.where(sizes > 0, np.maximum(t_inter, t_intra), 0.0)
    raise ValueError(f"unknown collective {collective!r}")


def _torus_sweep(collective: str, sizes, group: int,
                 dims_spec: Tuple[int, ...], pod: int, has_dcn: bool,
                 link_bw, lat, dcn_bw, dcn_lat):
    """Torus._time_batch over a parameter array (per-dim ring sweeps plus
    the DCN spill level)."""
    bw = 2 * link_bw
    if has_dcn and group > pod:
        q = math.ceil(group / pod)
        if collective == "all-reduce":
            t_in = _torus_sweep("reduce-scatter", sizes, pod, dims_spec,
                                pod, has_dcn, link_bw, lat, dcn_bw, dcn_lat) \
                + _torus_sweep("all-gather", sizes, pod, dims_spec, pod,
                               has_dcn, link_bw, lat, dcn_bw, dcn_lat)
            return t_in + _ring_allreduce(sizes / pod, q, dcn_bw, dcn_lat)
        t_in = _torus_sweep(collective, sizes, pod, dims_spec, pod,
                            has_dcn, link_bw, lat, dcn_bw, dcn_lat)
        return t_in + _flat_time(collective, sizes / pod, q, dcn_bw, dcn_lat)
    dims: List[int] = []
    rem = min(group, pod)
    for d in dims_spec:
        if rem <= 1:
            break
        use = min(d, rem)
        dims.append(use)
        rem = max(1, rem // use)
    if not dims:
        return np.zeros(np.broadcast_shapes(np.shape(sizes),
                                              np.shape(link_bw)))
    if collective == "all-reduce":
        t, s = 0.0, sizes
        for d in dims:
            t = t + _ring_allgather(s, d, bw, lat)
            s = s / d
        for d in reversed(dims):
            s = s * d
            t = t + _ring_allgather(s, d, bw, lat)
        return t
    if collective in ("all-gather", "reduce-scatter"):
        t, s = 0.0, sizes
        for d in dims:
            t = t + _ring_allgather(s, d, bw, lat)
            s = s / d
        return t
    if collective == "all-to-all":
        n = 1
        for d in dims:
            n *= d
        return _all_to_all(sizes, n, bw * len(dims), lat)
    raise ValueError(f"unknown collective {collective!r}")


def _torus_time(collective: str, sizes, scope: str, mp: int, dp: int,
                pp: int, ep: int, order, dims_spec: Tuple[int, ...],
                pod: int, has_dcn: bool, link_bw, lat, dcn_bw, dcn_lat):
    group = _group_size(scope, mp, dp, pp, ep)
    if collective == "p2p":
        if has_dcn and order.p2p_crosses_pod(mp, dp, pod, pp, ep):
            t = sizes / dcn_bw + dcn_lat
        else:
            t = sizes / link_bw + lat
        return np.where(sizes > 0, t, 0.0)
    return _torus_sweep(collective, sizes, group, dims_spec, pod, has_dcn,
                        link_bw, lat, dcn_bw, dcn_lat)


def _structural_key(topo) -> Optional[tuple]:
    """Environments whose topologies share a key differ only in bandwidth
    and latency scalars, so one vectorized formula prices them all."""
    if isinstance(topo, HierarchicalSwitch):
        return ("hier", topo.pod_size)
    if isinstance(topo, Torus):
        return ("torus", topo.dims, bool(topo.dcn_bw))
    if isinstance(topo, SingleSwitch):
        return ("switch",)
    return None


def comm_matrix(stage, envs, mp: int, dp: int, pp: int, ep: int,
                placement) -> np.ndarray:
    """Collective durations ``(ncomm, nenv)`` with the environment axis
    vectorized per structural topology family.

    Same semantics as the per-topology
    ``CollectiveModel.time_batch`` loop in
    :func:`repro.core.simulator._compiled_comm` — rows group by
    (collective, scope), zero when the scope's group size is <= 1 — but
    evaluated once per (row-group, structural key) over every matching
    environment column instead of once per distinct topology."""
    nenv = len(envs)
    out = np.zeros((len(stage.comm_kinds), nenv))
    if not stage.comm_kinds:
        return out
    order = placement if placement is not None else _PAPER_ORDER
    sizes_all = np.asarray(stage.comm_sizes, dtype=float)

    # Distinct topologies -> their environment columns (dict identity via
    # the frozen dataclasses' value hash, like _compiled_comm).
    topo_cols: Dict[object, List[int]] = {}
    for e, (_, topo) in enumerate(envs):
        topo_cols.setdefault(topo, []).append(e)
    families: Dict[tuple, List[object]] = {}
    fallback: List[object] = []
    for topo in topo_cols:
        key = _structural_key(topo)
        if key is None:
            fallback.append(topo)
        else:
            families.setdefault(key, []).append(topo)

    row_groups: Dict[Tuple[str, str], List[int]] = {}
    for i, (c, s) in enumerate(zip(stage.comm_kinds, stage.comm_scopes)):
        row_groups.setdefault((c, s), []).append(i)

    for key, topos in families.items():
        cols = [topo_cols[t] for t in topos]
        if key[0] == "hier":
            params = tuple(
                np.asarray([getattr(t, f) for t in topos])
                for f in ("intra_bw", "inter_bw", "intra_latency",
                          "inter_latency"))
        elif key[0] == "torus":
            params = tuple(
                np.asarray([getattr(t, f) for t in topos])
                for f in ("link_bw", "latency", "dcn_bw", "dcn_latency"))
        else:
            params = tuple(np.asarray([getattr(t, f) for t in topos])
                           for f in ("bw", "latency"))
        for (c, scope), rows in row_groups.items():
            if _group_size(scope, mp, dp, pp, ep) <= 1:
                continue
            sizes = np.asarray(sizes_all[rows])[:, None]   # (nrow, 1)
            if key[0] == "hier":
                t = _hier_time(c, sizes, scope, mp, dp, pp, ep, order,
                               key[1], *params)
            elif key[0] == "torus":
                t = _torus_time(c, sizes, scope, mp, dp, pp, ep, order,
                                key[1], int(np.prod(key[1])), key[2],
                                *params)
            else:
                group = _group_size(scope, mp, dp, pp, ep)
                t = _flat_time(c, sizes, group, *params)
            t = np.asarray(t)                                # (nrow, k)
            for j, tcols in enumerate(cols):
                out[np.ix_(rows, tcols)] = t[:, j:j + 1]

    if fallback:
        from repro.core.collectives import CollectiveModel
        for topo in fallback:
            coll = CollectiveModel(topo, mp, dp, pp=pp, ep=ep,
                                   placement=placement)
            col = coll.time_batch(stage.comm_kinds, stage.comm_sizes,
                                  stage.comm_scopes)
            for e in topo_cols[topo]:
                out[:, e] = col
    return out


# --------------------------------------------------------------------- #
# The jitted stage kernel: roofline delays + batched timeline
# --------------------------------------------------------------------- #

_SCOPE_COUNT = 5    # simulator._SCOPES: (mp, dp, ep, pp, edp)


def _prep_pass(p, ncomm: int, nseq: int, ncls: int) -> Dict[str, np.ndarray]:
    """Static per-pass arrays with the tail-compute sentinel appended.

    The reference walk adds the compute remaining after the last event
    (``csum[-1] - csum[prev]``) once the event loop ends; a final
    zero-duration non-blocking event at position ``nseq`` charges exactly
    that (scope 0's stream time becomes ``max(tc, tn[0])``, which never
    changes the exposed residue ``max(0, max(tn) - tc)``).

    All the cumulative structure is folded into *static count matrices*
    so that nothing sequential survives into the kernel:

    * ``dcounts`` (``(nev+1, ncls)``, scan path) — ops of each delay
      class between consecutive events, making every per-environment
      compute delta one matrix product;
    * ``exp_cnt`` (fast path) — blocking exposure per (phase, comm
      kind), so exposure is one small static-matrix product (XLA's CPU
      ``cumsum``/``cummax`` lowerings are O(n log n) with large
      constants — the count matrices sidestep them entirely);
    * ``nb`` (fast path) — per scope with non-blocking events: one
      static matrix ``R`` whose product with the stacked
      ``[delays; comm_pad]`` gives each event's *residual margin* — the
      scope's final stream time minus the pass's final compute clock, as
      seen from that event.  The counts are integers, so the
      chain-vs-compute subtraction happens exactly at prep time and the
      kernel evaluates one short well-conditioned dot product per row
      instead of differencing two large totals (which would amplify
      rounding on near-zero residues).  Within a repeated layer run the
      count rows advance by a constant increment, so the margin is
      affine in the event index and its max sits at a run endpoint —
      interior rows are pruned statically (514 chain events in the
      transformer stack collapse to a handful of rows).

    ``mixed`` flags a pass where some scope sees a non-blocking event
    *before* a later blocking one — the only shape the closed form
    cannot price (the blocking event would have to wait on the pending
    transfer), so it drops to the ``lax.scan`` walk."""
    pos = np.append(p.ev_pos, nseq).astype(np.int64)
    prev = np.concatenate([[0], pos[:-1]]).astype(np.int64)
    comm = np.append(p.ev_comm, ncomm).astype(np.int64)  # -> padded zero row
    block = np.append(p.ev_blocking, False).astype(float)
    scope = np.append(p.ev_scope, 0).astype(np.int64)
    phase = np.append(p.ev_phase, 0).astype(np.int64)
    seq = p.seq.astype(np.int64)
    onehot = np.zeros((nseq + 1, ncls))
    onehot[np.arange(nseq) + 1, seq] = 1.0
    prefix = np.cumsum(onehot, axis=0)           # (nseq+1, ncls)
    comm_oh = np.eye(ncomm + 1)[comm]            # (nev+1, ncomm+1)
    phase_oh = np.eye(3)[phase] * block[:, None]
    # Cumulative blocking-duration counts per comm kind at each event.
    bcc = np.cumsum(comm_oh * block[:, None], axis=0)
    nb: Dict[str, Dict[str, np.ndarray]] = {}
    mixed = False
    for s in range(_SCOPE_COUNT):
        on = np.asarray(p.ev_scope) == s
        nb_idx = np.flatnonzero(on & ~np.asarray(p.ev_blocking))
        blk_idx = np.flatnonzero(on & np.asarray(p.ev_blocking))
        if nb_idx.size:
            oh = comm_oh[nb_idx]
            dafter = np.cumsum(oh[::-1], axis=0)[::-1]   # incl. own dur
            # Residual margin at event k: the chain's durations from k on
            # minus the ops (and blocking durations) still ahead of it.
            R = np.concatenate(
                [prefix[pos[nb_idx]] - prefix[nseq],
                 dafter + bcc[nb_idx] - bcc[-1]], axis=1)
            if R.shape[0] > 2:
                d = np.diff(R, axis=0)
                interior = np.all(d[1:] == d[:-1], axis=1)
                R = R[np.concatenate([[True], ~interior, [True]])]
            nb[str(s)] = R
            if blk_idx.size and nb_idx.min() < blk_idx.max():
                mixed = True
    return {
        "dcounts": prefix[pos] - prefix[prev],   # (nev+1, ncls)
        "comm": comm,
        "block": block,
        "scope_oh": np.eye(_SCOPE_COUNT)[scope],
        # Exposure lands on the event's phase row only when it blocks.
        "phase_oh": phase_oh,
        "exp_cnt": phase_oh.T @ comm_oh,         # (3, ncomm+1)
        "nb": nb,
        "mixed": mixed,
    }


def _prep(stage) -> Tuple[dict, bool]:
    """The stage's flat arrays in kernel form plus the closed-form
    eligibility flag, cached on the stage (one lowering per strategy,
    reused for every environment batch)."""
    cached = getattr(stage, "_jax_prep", None)
    if cached is not None:
        return cached
    ncomm = len(stage.comm_kinds)
    ncls = stage.flops.shape[0]
    P: dict = {
        "flops": np.asarray(stage.flops, dtype=float),
        "base": np.asarray(stage.base_traffic, dtype=float),
        "counts": np.asarray(stage.counts, dtype=float),
        "fwd": _prep_pass(stage.fwd, ncomm, stage.fwd.seq.size, ncls),
        "bwd": _prep_pass(stage.bwd, ncomm, stage.bwd.seq.size, ncls),
    }
    if stage.gemm_u.size:
        nops = stage.gemm_u.size
        lengths = np.diff(np.append(stage.gemm_starts, nops))
        P["g_u"] = np.asarray(stage.gemm_u, dtype=float)
        P["g_v"] = np.asarray(stage.gemm_v, dtype=float)
        P["g_w"] = np.asarray(stage.gemm_w, dtype=float)
        P["g_b"] = np.asarray(stage.gemm_batch, dtype=float)
        P["op_cls"] = np.repeat(stage.gemm_cls, lengths).astype(np.int64)
    fast = not (P["fwd"].pop("mixed") or P["bwd"].pop("mixed"))
    # Keep only the arrays the selected kernel reads: stray leaves would
    # widen the jit cache key (and the fast/scan paths share none).
    drop = (("dcounts", "comm", "block", "scope_oh", "phase_oh") if fast
            else ("exp_cnt", "nb"))
    for p in (P["fwd"], P["bwd"]):
        for k in drop:
            p.pop(k)
    stage._jax_prep = (P, fast)
    return P, fast


def _delays_jnp(P: dict, sram, peak, mem_bw):
    """:func:`repro.core.compiled.stage_traffic` +
    :func:`repro.core.simulator._compiled_delays` in one fused jnp
    expression: ``(ncls, nenv)`` roofline delays."""
    traffic = P["base"][:, None] + jnp.zeros((1, sram.shape[0]))
    if "g_u" in P:
        u = P["g_u"][:, None]
        v = P["g_v"][:, None]
        w = P["g_w"][:, None]
        s = sram[None, :]
        psi1 = jnp.ceil(u / s) * v + u
        psi2 = jnp.ceil(v / s) * u + v
        per = jnp.minimum(psi1, psi2) + w
        per = jnp.where((u == 0) | (v == 0), u + v + w, per)
        contrib = P["g_b"][:, None] * per
        traffic = traffic + jax.ops.segment_sum(
            contrib, P["op_cls"], num_segments=P["flops"].shape[0])
    flops = P["flops"][:, None]
    oi = flops / traffic                        # inf when traffic == 0
    perf = jnp.minimum(peak[None, :], oi * mem_bw[None, :])
    delays = flops / perf
    # Pure data movement (zero-FLOP rows): memory-bound transfer.
    mem_t = jnp.where(traffic > 0, traffic / mem_bw[None, :], 0.0)
    return jnp.where((P["flops"] == 0)[:, None], mem_t, delays)


def _pass_fast(pP: dict, comm_pad, stacked):
    """Closed-form timeline for a scope-disjoint pass, whole batch at once.

    With no non-blocking transfer pending when a blocking event fires
    (the ``mixed`` pre-check), every blocking event starts exactly at the
    compute clock — its exposure *is* its duration, one static-count
    matrix product.  Each scope's non-blocking stream unrolls
    ``tn = max(tc, tn) + dur`` into a max over per-event residual
    margins (``R @ [delays; comm_pad]``, rows statically pruned to run
    endpoints), since only the final stream time past the final compute
    clock feeds the exposed residue.  Returns
    ``(exposed (3, nenv), residual margin (nenv) or None)``."""
    exp = pP["exp_cnt"] @ comm_pad                       # (3, nenv)
    resid = None
    for s in sorted(pP["nb"]):
        m = jnp.max(pP["nb"][s] @ stacked, axis=0)       # (nenv,)
        resid = m if resid is None else jnp.maximum(resid, m)
    return exp, resid


def _stage_fn_fast(P: dict, sram, peak, mem_bw, comm):
    """The pure stage kernel, closed form: flat arrays in,
    (compute, exposed) out — jitted once per shape set, every step a
    whole-batch matrix product or reduction (no scan, no vmap, no
    cumulatives)."""
    delays = _delays_jnp(P, sram, peak, mem_bw)          # (ncls, nenv)
    compute = P["counts"] @ delays                        # (3, nenv)
    comm_pad = jnp.concatenate(
        [comm, jnp.zeros((1, comm.shape[1]))], axis=0)
    stacked = jnp.concatenate([delays, comm_pad], axis=0)
    exp_f, _ = _pass_fast(P["fwd"], comm_pad, stacked)
    exp_b, resid_b = _pass_fast(P["bwd"], comm_pad, stacked)
    exposed = exp_f + exp_b
    if resid_b is not None:
        # Non-blocking residue past the end of backward compute.
        resid = jnp.maximum(0.0, resid_b)
        exposed = exposed + jnp.array([0.0, 0.0, 1.0])[:, None] * resid
    return compute, exposed


def _scan_pass(pass_P: dict, deltas_col, durs_col, exposed):
    """One timeline pass for one environment: the event walk as a
    ``lax.scan`` over (delta, duration, blocking, scope, phase) rows —
    the general-shape fallback when a pass is not scope-disjoint."""

    def step(carry, x):
        tc, tn, exp = carry
        delta, dur, blk, sc_oh, ph_oh = x
        tc = tc + delta
        start = jnp.maximum(tc, jnp.sum(tn * sc_oh))
        end = start + dur
        exp = exp + ph_oh * (end - tc)          # ph_oh pre-masked by blk
        tc = jnp.where(blk > 0, end, tc)
        tn = tn * (1.0 - sc_oh) + sc_oh * end
        return (tc, tn, exp), None

    init = (jnp.zeros(()), jnp.zeros(_SCOPE_COUNT), exposed)
    (tc, tn, exposed), _ = jax.lax.scan(
        step, init, (deltas_col, durs_col, pass_P["block"],
                     pass_P["scope_oh"], pass_P["phase_oh"]))
    return tc, tn, exposed


def _stage_fn_scan(P: dict, sram, peak, mem_bw, comm):
    """The general stage kernel: per-event ``lax.scan`` vmapped over the
    environment batch.  Only reached when a pass interleaves non-blocking
    and blocking events on one scope."""
    delays = _delays_jnp(P, sram, peak, mem_bw)          # (ncls, nenv)
    compute = P["counts"] @ delays                        # (3, nenv)
    comm_pad = jnp.concatenate(
        [comm, jnp.zeros((1, comm.shape[1]))], axis=0)
    df = P["fwd"]["dcounts"] @ delays
    db = P["bwd"]["dcounts"] @ delays
    uf = comm_pad[P["fwd"]["comm"]]
    ub = comm_pad[P["bwd"]["comm"]]

    def one_env(df_c, uf_c, db_c, ub_c):
        _, _, exp = _scan_pass(P["fwd"], df_c, uf_c, jnp.zeros(3))
        tc, tn, exp = _scan_pass(P["bwd"], db_c, ub_c, exp)
        # Non-blocking residue past the end of backward compute.
        resid = jnp.maximum(0.0, jnp.max(tn) - tc)
        return exp + jnp.array([0.0, 0.0, 1.0]) * resid

    exposed = jax.vmap(one_env, in_axes=(1, 1, 1, 1), out_axes=1)(
        df, uf, db, ub)
    return compute, exposed


_jit_fns: dict = {}


def _stage_jit(fast: bool):
    fn = _jit_fns.get(fast)
    if fn is None:
        fn = jax.jit(_stage_fn_fast if fast else _stage_fn_scan)
        _jit_fns[fast] = fn
    return fn


def stage_compute_exposed(stage, envs, nodes, mem_bw, mp: int, dp: int,
                          pp: int, ep: int, placement
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """The ``backend="jax"`` twin of the simulator's NumPy kernel
    (:func:`repro.core.simulator._stage_compute_exposed`): one jitted
    device call per (stage, environment batch), returning NumPy
    ``(compute, exposed)`` arrays, each ``(3, nenv)``."""
    if not HAVE_JAX:   # pragma: no cover - callers gate on HAVE_JAX
        raise RuntimeError("jax backend requested but jax is unavailable")
    with enable_x64():
        comm = comm_matrix(stage, envs, mp, dp, pp, ep, placement)
        sram = np.array([max(int(n.sram_bytes), 1) for n in nodes],
                        dtype=float)
        peak = np.array([n.peak_flops for n in nodes], dtype=float)
        P, fast = _prep(stage)
        compute, exposed = _stage_jit(fast)(
            P, jnp.asarray(sram), jnp.asarray(peak),
            jnp.asarray(np.asarray(mem_bw, dtype=float)),
            jnp.asarray(comm))
        return np.asarray(compute), np.asarray(exposed)
