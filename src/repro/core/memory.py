"""COMET §III-B / §IV-B: per-node memory footprint + hybrid-memory model.

Model-state footprint follows ZeRO's accounting (fp16 weights/grads, fp32
Adam states): 16 bytes/param baseline, staged down by ZeRO-1/2/3 across the
DP dimension.  Residual state is activation working memory (intermediates
between two consecutive activation checkpoints) — checkpoints themselves are
assumed host-offloaded, as in the paper.

The hybrid local+expanded memory bandwidth is the paper's Eqn (3):

    bw_hybrid = total / (data_LM / bw_LM + data_EM / bw_EM)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.cluster import NodeConfig
from repro.core.workload import Workload

# bytes per parameter
FP16 = 2
GRAD = 2
OPTIM = 12  # fp32 master + momentum + variance (ZeRO's K=12)


def model_state_bytes(params: float, dp: int, zero_stage: int) -> float:
    """Per-node model-state bytes for ``params`` parameters held on this
    node's MP shard, under ZeRO stage 0..3 across ``dp`` replicas."""
    dp = max(1, dp)
    if zero_stage == 0:
        return (FP16 + GRAD + OPTIM) * params
    if zero_stage == 1:  # optimizer states sharded
        return (FP16 + GRAD) * params + OPTIM * params / dp
    if zero_stage == 2:  # + gradients sharded
        return FP16 * params + (GRAD + OPTIM) * params / dp
    if zero_stage == 3:  # + parameters sharded
        return (FP16 + GRAD + OPTIM) * params / dp
    raise ValueError(f"zero_stage must be 0..3, got {zero_stage}")


@dataclasses.dataclass(frozen=True)
class FootprintReport:
    model_states: float
    activation_working: float
    total: float
    fits_local: bool
    fits_total: bool


def worst_report(reps) -> FootprintReport:
    """Gating report over several footprints (pipeline stages, node
    groups): the largest total, with the fits flags ANDed — feasible only
    if every report fits."""
    return dataclasses.replace(
        max(reps, key=lambda r: r.total),
        fits_local=all(r.fits_local for r in reps),
        fits_total=all(r.fits_total for r in reps))


def _data_ways(workload: Workload) -> int:
    """ZeRO shards dense weights across the full data group: DP x EP (EP
    ranks replicate the dense weights, so they join the sharding group;
    pre-EP workloads have ep == 1 and this is exactly dp)."""
    return max(1, workload.dp * getattr(workload, "ep", 1))


def _layer_states(layers, dense_ways: int, expert_ways: int,
                  zero_stage: int) -> float:
    """Model-state bytes for a layer list: dense params replicate (and ZeRO-
    shard) across DP x EP, expert params are EP-sharded already and only
    replicate across DP — mirroring the "dp" vs "edp" gradient scopes."""
    dense = sum((ly.weight_bytes - ly.expert_bytes) * ly.repeat
                for ly in layers) / FP16
    expert = sum(ly.expert_bytes * ly.repeat for ly in layers) / FP16
    states = model_state_bytes(dense, dense_ways, zero_stage)
    if expert:
        states += model_state_bytes(expert, expert_ways, zero_stage)
    return states


def stage_footprints(
    workload: Workload,
    node: Optional[NodeConfig] = None,
    zero_stage: int = 2,
    nodes: Optional[list] = None,
) -> list:
    """Per-pipeline-stage footprint reports (one entry when pp == 1).

    Each stage holds its own layers' model states.  Activation working
    memory is per-microbatch (1/m of the full-batch intermediates) times
    the schedule's stash depth: GPipe stashes all ``m`` in-flight
    microbatches; 1F1B at stage ``s`` stashes at most ``pp - s``
    (Megatron-LM §2.2), so early stages pay more; the interleaved
    schedule pays the 1F1B stash scaled by ``1 + (pp-1)/(pp*v)``
    (Megatron-LM §2.2.2: ``v`` in-flight virtual-stage chunks).

    ``nodes`` (one :class:`NodeConfig` per stage) gates each stage
    against *its own* node — the EM-aware heterogeneous placement path;
    ``node`` gates every stage against the same node (the paper's
    replicate-everywhere semantics)."""
    m = max(1, getattr(workload, "num_microbatches", 1))
    schedule = getattr(workload, "schedule", "1f1b")
    v = max(1, getattr(workload, "virtual_stages", 1))
    pp = max(1, getattr(workload, "pp", 1))
    if nodes is not None and len(nodes) != pp:
        raise ValueError(f"nodes must have one entry per stage "
                         f"({pp}), got {len(nodes)}")
    dways = _data_ways(workload)
    reps = []
    for s, layers in enumerate(workload.stage_layers()):
        states = _layer_states(layers, dways, max(1, workload.dp),
                               zero_stage)
        max_act = max((ly.act_out_bytes for ly in layers), default=0)
        if schedule == "gpipe":
            stash = m
        else:
            stash = min(m, pp - s)
            if schedule == "interleaved":
                stash *= 1 + (pp - 1) / (pp * v)
        awm = max_act / m * stash
        total = states + awm
        gate = nodes[s] if nodes is not None else node
        fits_local = fits_total = True
        if gate is not None:
            fits_local = total <= gate.local_cap
            fits_total = total <= gate.total_cap
        reps.append(FootprintReport(states, awm, total, fits_local,
                                    fits_total))
    return reps


def per_node_footprint(
    workload: Workload,
    node: Optional[NodeConfig] = None,
    zero_stage: int = 2,
) -> FootprintReport:
    """Per-node footprint of a decomposed workload (paper defaults: ZeRO-2,
    fp16 activations, checkpoint activations host-offloaded).

    For pipeline workloads (pp > 1) this reports the *worst* stage's bytes,
    with the fits flags ANDed over every stage (feasibility = each stage
    fits its nodes)."""
    if getattr(workload, "pp", 1) > 1:
        return worst_report(stage_footprints(workload, node, zero_stage))
    states = _layer_states(workload.layers, _data_ways(workload),
                           max(1, workload.dp), zero_stage)
    awm = workload.activation_working_bytes()
    total = states + awm
    fits_local = fits_total = True
    if node is not None:
        fits_local = total <= node.local_cap
        fits_total = total <= node.total_cap
    return FootprintReport(states, awm, total, fits_local, fits_total)


def cluster_footprint(workload: Workload, cluster,
                      zero_stage: int = 2) -> FootprintReport:
    """Per-node footprint across a (possibly heterogeneous) cluster.

    The byte totals are node-independent (same shard everywhere under
    synchronous training); the fits flags AND across every node group, so
    a mixed cluster only 'fits' if its least-capable group does."""
    return worst_report([per_node_footprint(workload, g.node, zero_stage)
                         for g in cluster.node_groups])


def hybrid_bandwidth(total_bytes: float, data_lm: float,
                     bw_lm: float, bw_em: float) -> float:
    """Paper Eqn (3). ``data_lm`` = bytes served from local memory."""
    data_em = max(0.0, total_bytes - data_lm)
    if total_bytes <= 0:
        return bw_lm
    if data_em <= 0 or bw_em <= 0:
        return bw_lm
    return total_bytes / (data_lm / bw_lm + data_em / bw_em)


def effective_memory_bw(node: NodeConfig, footprint_bytes: float) -> float:
    """Roofline slope for a node given the working set it must hold:
    if the footprint spills past local capacity, accesses split between
    LM and EM proportionally to residency (paper §III-C2)."""
    if footprint_bytes <= node.local_cap or node.exp_cap <= 0:
        return node.local_bw
    frac_lm = node.local_cap / footprint_bytes
    # Accesses hit LM with probability = residency fraction.
    return hybrid_bandwidth(1.0, frac_lm, node.local_bw, node.exp_bw)
