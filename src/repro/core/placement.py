"""First-class placement & scheduling: how jobs map onto a cluster.

COMET hard-codes two mapping decisions that §V-C/§V-D actually *study*:

  * the rank order — MP groups fill consecutive ranks (pods first), then
    EP, then DP, with PP stages outermost — lives in
    :func:`repro.core.topology.placement`;
  * the job→fleet mapping — how many training instances run concurrently
    on a fleet, and which pods host the memory-hungry shards — lived as
    ad-hoc ``waves()`` lambdas copied across ``repro.core.dse``.

This module makes both pluggable:

  * :class:`Placement` — protocol for mesh-axis → node-group assignment:
    per-rank-group hop resolution (``group_placement``/``p2p_crosses_pod``,
    consumed by the :class:`~repro.core.topology.Topology` families), plus
    pipeline-stage → node-group assignment on heterogeneous clusters
    (``assign_stages``, consumed by ``simulate_iteration``) and
    instance → group eligibility (``instance_groups``, consumed by the
    :class:`ScheduleModel`);
  * :class:`PaperPlacement` — bit-for-bit the paper's fixed mapping
    (default everywhere): MP→EP→DP→PP rank order, synchronous
    replicate-everywhere gating (every group must fit the shard);
  * :class:`EMAwarePlacement` — same rank order, but memory-hungry
    pipeline stages / instances go to the pod groups with the most
    (expanded) memory, so a *partial*-EM fleet can win (ROADMAP;
    cf. arXiv:1802.02326 — heterogeneous fleets pay off only when
    placement is memory-aware);
  * :class:`ExplicitPlacement` — a pinned stage → group mapping for
    what-if studies;
  * :class:`JobSpec` / :class:`ScheduleModel` / :class:`Schedule` — the
    multi-tenant layer: N identical instances × per-group capacities →
    concurrent placement, waves, turnaround/makespan (the Fig. 13b and
    Fig. 15 metrics, now study-native columns).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, Sequence, Tuple, Union, runtime_checkable

from repro.core.topology import _PAPER_ORDER, GroupPlacement


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# --------------------------------------------------------------------- #
# The protocol
# --------------------------------------------------------------------- #

@runtime_checkable
class Placement(Protocol):
    """How a job's mesh axes and instances map onto a cluster.

    ``group_placement``/``p2p_crosses_pod`` resolve which network hops a
    communication group crosses (the topology families dispatch through
    them); ``assign_stages`` maps pipeline stages to heterogeneous node
    groups (``None`` = the paper's replicate-everywhere gating);
    ``instance_groups`` filters which groups may host a training instance
    in a multi-tenant schedule.
    """

    @property
    def label(self) -> str: ...

    def group_placement(self, scope: str, mp: int, dp: int, pod_size: int,
                        pp: int = 1, ep: int = 1) -> GroupPlacement: ...

    def p2p_crosses_pod(self, mp: int, dp: int, pod_size: int,
                        pp: int = 1, ep: int = 1) -> bool: ...

    def assign_stages(self, stage_bytes: Sequence[float], groups: Sequence,
                      nodes_per_stage: int) -> Optional[Tuple[int, ...]]: ...

    def instance_groups(self, fits: Sequence[bool]) -> Tuple[int, ...]: ...


class _PaperOrderMixin:
    """The paper's MP→EP→DP→PP rank order (hop resolution shared by every
    concrete placement; only the *group assignment* policies differ).
    Delegates to the single topology-side implementation so the rule
    cannot drift between the placement-passed and placement=None paths."""

    def group_placement(self, scope: str, mp: int, dp: int, pod_size: int,
                        pp: int = 1, ep: int = 1) -> GroupPlacement:
        return _PAPER_ORDER.group_placement(scope, mp, dp, pod_size, pp, ep)

    def p2p_crosses_pod(self, mp: int, dp: int, pod_size: int,
                        pp: int = 1, ep: int = 1) -> bool:
        return _PAPER_ORDER.p2p_crosses_pod(mp, dp, pod_size, pp, ep)


@dataclasses.dataclass(frozen=True)
class PaperPlacement(_PaperOrderMixin):
    """COMET's fixed mapping, bit-for-bit (the default everywhere).

    Stages are not assigned to groups: a heterogeneous cluster simulates
    every group and the slowest / least-capable one gates the iteration
    (synchronous training, PR-2 semantics).  Instances schedule onto any
    group regardless of fit — infeasibility surfaces as ``feasible=False``
    exactly as the legacy waves lambdas did.
    """

    @property
    def label(self) -> str:
        return "paper"

    def assign_stages(self, stage_bytes: Sequence[float], groups: Sequence,
                      nodes_per_stage: int) -> Optional[Tuple[int, ...]]:
        return None

    def instance_groups(self, fits: Sequence[bool]) -> Tuple[int, ...]:
        return tuple(range(len(fits)))


@dataclasses.dataclass(frozen=True)
class EMAwarePlacement(_PaperOrderMixin):
    """Memory-aware assignment: hungry shards go where the memory is.

    Same rank order as the paper (collective costs stay comparable), but
    on a heterogeneous cluster the memory-hungriest pipeline stages are
    assigned to the node groups with the largest per-node capacity (the
    EM pods), each stage gated by *its* group only — so a partial-EM
    fleet is feasible whenever the EM pods can hold the hungry stages,
    instead of being gated by the plain pods.  Multi-tenant instances
    only schedule onto groups they fit.
    """

    @property
    def label(self) -> str:
        return "em-aware"

    def assign_stages(self, stage_bytes: Sequence[float], groups: Sequence,
                      nodes_per_stage: int) -> Optional[Tuple[int, ...]]:
        pp = len(stage_bytes)
        if pp <= 1 or len(groups) <= 1 or nodes_per_stage < 1:
            return None
        caps = [g.num_nodes // nodes_per_stage for g in groups]
        if sum(caps) < pp:
            return None              # fleet can't hold the pipeline: gate
        # Biggest stages to the roomiest groups, greedily.
        group_order = sorted(range(len(groups)),
                             key=lambda i: (groups[i].node.total_cap,
                                            groups[i].num_nodes),
                             reverse=True)
        assign = [0] * pp
        gi = 0
        for s in sorted(range(pp), key=lambda s: stage_bytes[s],
                        reverse=True):
            while caps[group_order[gi]] == 0:
                gi += 1
            assign[s] = group_order[gi]
            caps[group_order[gi]] -= 1
        return tuple(assign)

    def instance_groups(self, fits: Sequence[bool]) -> Tuple[int, ...]:
        ok = tuple(i for i, f in enumerate(fits) if f)
        # Nothing fits anywhere: fall back to every group so the schedule
        # is still computed (and reported infeasible) rather than empty.
        return ok or tuple(range(len(fits)))


@dataclasses.dataclass(frozen=True)
class ExplicitPlacement(_PaperOrderMixin):
    """A pinned stage → node-group mapping (what-if studies).

    ``stage_groups[s]`` is the node-group index hosting pipeline stage
    ``s``; length must equal the workload's ``pp``.  Hop resolution and
    instance scheduling follow the paper defaults.
    """

    stage_groups: Tuple[int, ...] = ()

    @property
    def label(self) -> str:
        return "explicit[" + ",".join(map(str, self.stage_groups)) + "]"

    def assign_stages(self, stage_bytes: Sequence[float], groups: Sequence,
                      nodes_per_stage: int) -> Optional[Tuple[int, ...]]:
        if not self.stage_groups:
            return None
        if len(self.stage_groups) != len(stage_bytes):
            raise ValueError(
                f"ExplicitPlacement maps {len(self.stage_groups)} stages "
                f"but the workload has {len(stage_bytes)}")
        bad = [g for g in self.stage_groups if not 0 <= g < len(groups)]
        if bad:
            raise ValueError(
                f"ExplicitPlacement names node groups {sorted(set(bad))} "
                f"but the cluster has {len(groups)}")
        for i, g in enumerate(groups):
            need = self.stage_groups.count(i) * nodes_per_stage
            if need > g.num_nodes:
                raise ValueError(
                    f"ExplicitPlacement puts {self.stage_groups.count(i)} "
                    f"stages x {nodes_per_stage} nodes on group {i} "
                    f"({g.num_nodes} nodes)")
        return tuple(self.stage_groups)

    def instance_groups(self, fits: Sequence[bool]) -> Tuple[int, ...]:
        return tuple(range(len(fits)))


PAPER_PLACEMENT = PaperPlacement()
EM_AWARE_PLACEMENT = EMAwarePlacement()

_REGISTRY = {
    "paper": PAPER_PLACEMENT,
    "em-aware": EM_AWARE_PLACEMENT,
}

PlacementLike = Union[Placement, str, None]


def list_placements() -> Tuple[str, ...]:
    """Names accepted by :func:`get_placement` (and placement axes)."""
    return tuple(sorted(_REGISTRY))


def get_placement(obj: PlacementLike) -> Optional[Placement]:
    """Coerce a placement name / instance / None to a Placement."""
    if obj is None or isinstance(obj, Placement):
        return obj
    if isinstance(obj, str):
        if obj not in _REGISTRY:
            raise KeyError(f"unknown placement {obj!r} "
                           f"(available: {list(list_placements())})")
        return _REGISTRY[obj]
    raise TypeError(f"expected a Placement, its name, or None; "
                    f"got {type(obj).__name__}")


# --------------------------------------------------------------------- #
# Multi-tenant scheduling: N instances onto per-group capacities
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class JobSpec:
    """``instances`` identical training instances, each occupying
    ``nodes_per_instance`` nodes (0 = the strategy's node count).
    ``max_nodes`` caps how many fleet nodes the job may use (0 = all) —
    the Fig. 15 "64-node DLRM fleet" constraint."""

    instances: int = 1
    nodes_per_instance: int = 0
    max_nodes: int = 0
    name: str = "job"

    def __post_init__(self):
        if self.instances < 1:
            raise ValueError(f"instances must be >= 1, got {self.instances}")
        for f in ("nodes_per_instance", "max_nodes"):
            if getattr(self, f) < 0:
                raise ValueError(f"{f} must be >= 0, got {getattr(self, f)}")


@dataclasses.dataclass(frozen=True)
class GroupSchedule:
    """One node group's share of a schedule."""

    group: int           # node-group index
    concurrent: int      # instances running side by side on this group
    instances: int       # instances assigned to this group in total
    iter_time: float     # one instance-iteration on this group, seconds

    @property
    def waves(self) -> int:
        return _ceil_div(self.instances, max(1, self.concurrent))

    @property
    def finish_time(self) -> float:
        return self.waves * self.iter_time


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A concrete multi-tenant placement of a :class:`JobSpec`.

    ``turnaround`` is the makespan — when the last instance finishes —
    which on a homogeneous fleet reduces to the paper's
    ``waves * iteration_time`` (Fig. 13b / Fig. 15).
    """

    job: JobSpec
    groups: Tuple[GroupSchedule, ...]
    feasible: bool

    @property
    def concurrent(self) -> int:
        return sum(g.concurrent for g in self.groups)

    @property
    def waves(self) -> int:
        return max((g.waves for g in self.groups if g.instances), default=0)

    @property
    def makespan(self) -> float:
        return max((g.finish_time for g in self.groups if g.instances),
                   default=0.0)

    @property
    def turnaround(self) -> float:
        return self.makespan


@dataclasses.dataclass(frozen=True)
class ScheduleModel:
    """Greedy earliest-finish scheduling of identical instances.

    Per-group concurrency = usable nodes // nodes-per-instance (usable is
    capped by ``JobSpec.max_nodes`` across groups, in group order); each
    instance then goes to the eligible group — ``placement.instance_groups``
    decides eligibility from the per-group fit flags — whose finish time
    grows least.  If no group can hold even one instance, the largest
    group runs them one at a time (the legacy ``max(1, fleet // n)``
    convention, so oversubscribed what-ifs still produce a number).
    """

    def schedule(self, job: JobSpec, groups: Sequence,
                 iter_times: Sequence[float],
                 fits: Optional[Sequence[bool]] = None,
                 nodes_per_instance: Optional[Sequence[int]] = None,
                 placement: Optional[Placement] = None) -> Schedule:
        if len(groups) != len(iter_times):
            raise ValueError("one iteration time per node group required")
        fits = list(fits) if fits is not None else [True] * len(groups)
        npis = (list(nodes_per_instance) if nodes_per_instance is not None
                else [job.nodes_per_instance] * len(groups))
        if any(n < 1 for n in npis):
            raise ValueError("nodes_per_instance must be >= 1 per group "
                             "(set JobSpec.nodes_per_instance or pass "
                             "per-group values)")
        placement = placement or PAPER_PLACEMENT

        def concurrency(idxs) -> list:
            """Per-group concurrency with the ``max_nodes`` budget handed
            out (in group order) only to the groups in ``idxs`` — an
            ineligible group must not eat the fleet cap, and neither must
            a group too small to hold even one instance (its ``usable``
            share would starve later groups that could have hosted
            instances within the cap)."""
            remaining = job.max_nodes or sum(g.num_nodes for g in groups)
            out = [0] * len(groups)
            for i in idxs:
                usable = min(groups[i].num_nodes, remaining)
                if usable // npis[i] == 0:
                    continue
                remaining -= usable
                out[i] = usable // npis[i]
            return out

        chosen = placement.instance_groups(fits)
        conc = concurrency(chosen)
        eligible = [i for i in chosen if conc[i] > 0]
        forced = not eligible
        if forced and len(chosen) < len(groups):
            # No eligible group can hold an instance: fall back to the
            # whole fleet (reported infeasible via the fits check below).
            conc = concurrency(range(len(groups)))
            eligible = [i for i in range(len(groups)) if conc[i] > 0]
        if not eligible:
            # Oversubscribed: run one at a time on the largest group (the
            # legacy ``max(1, fleet // n)`` convention keeps a number
            # flowing, but an instance wider than every group — or than
            # the ``max_nodes`` cap — cannot actually be placed, so the
            # schedule is marked infeasible below).
            big = max(range(len(groups)), key=lambda i: groups[i].num_nodes)
            conc = [0] * len(groups)
            conc[big] = 1
            eligible = [big]
        counts = [0] * len(groups)
        for _ in range(job.instances):
            best = min(eligible,
                       key=lambda i: (_ceil_div(counts[i] + 1, conc[i])
                                      * iter_times[i], i))
            counts[best] += 1
        assigned = tuple(GroupSchedule(i, conc[i], counts[i], iter_times[i])
                         for i in range(len(groups)) if counts[i])
        feasible = all(fits[g.group] for g in assigned)
        for g in assigned:
            cap = min(groups[g.group].num_nodes,
                      job.max_nodes or groups[g.group].num_nodes)
            feasible = feasible and npis[g.group] <= cap
        return Schedule(job=job, groups=assigned, feasible=feasible)
