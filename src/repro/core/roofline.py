"""COMET §III-C1: roofline compute-delay model.

    OI        = FLOPs / memory_traffic                      (Eqn 1)
    perf_max  = min(perf_peak, OI * BW_mem)
    delay     = FLOPs / perf_max                            (Eqn 2)

The same roofline arithmetic is reused by the dry-run analysis (core/hlo.py)
with measured HLO FLOPs/bytes instead of analytical ones.
"""

from __future__ import annotations

import dataclasses

from repro.core.cluster import NodeConfig
from repro.core.gemm import PhaseCost


@dataclasses.dataclass(frozen=True)
class RooflinePoint:
    flops: int
    traffic: int
    oi: float
    perf_max: float
    delay: float
    bound: str  # "compute" | "memory"


def attainable_perf(oi: float, peak_flops: float, mem_bw: float) -> float:
    """min{perf_peak, OI * BW_mem}."""
    if oi == float("inf"):
        return peak_flops
    return min(peak_flops, oi * mem_bw)


def compute_delay(cost: PhaseCost, node: NodeConfig,
                  mem_bw: float | None = None) -> RooflinePoint:
    """Roofline delay for one phase cost on one node.

    ``mem_bw`` overrides the node's local bandwidth (hybrid-memory studies
    pass ``effective_memory_bw`` here)."""
    bw = node.local_bw if mem_bw is None else mem_bw
    if cost.flops == 0:
        # Pure data movement (e.g. embedding lookup): memory-bound transfer.
        delay = cost.traffic / bw if cost.traffic else 0.0
        return RooflinePoint(0, cost.traffic, 0.0, bw, delay, "memory")
    oi = cost.operational_intensity
    perf = attainable_perf(oi, node.peak_flops, bw)
    bound = "compute" if perf >= node.peak_flops else "memory"
    return RooflinePoint(cost.flops, cost.traffic, oi, perf,
                         cost.flops / perf, bound)


def ridge_point(node: NodeConfig, mem_bw: float | None = None) -> float:
    """OI at which the node transitions memory- -> compute-bound."""
    bw = node.local_bw if mem_bw is None else mem_bw
    return node.peak_flops / bw
