"""Design-space *search* over studies: Pareto fronts and real optimizers.

The grid engines (:func:`repro.core.study.run_study`) price every cell of
an axis product; this module spends evaluations where they matter — the
promotion the ROADMAP asks for now that the compiled/JAX engines make a
single evaluation effectively free (grown out of the
``experiments/hillclimb_run.py`` variant driver):

* :func:`pareto_front` — non-dominated enumeration over any objective
  columns, default the paper triple (time, TCO, energy).  Every record is
  annotated with ``pareto_rank`` (0 = frontier, NSGA-style peeled fronts)
  and ``pareto_optimal``; the returned :class:`StudyResult` keeps only
  the frontier cells.
* :func:`successive_halving` — rung-by-rung fidelity scaling (the
  shape's ``global_batch``); each rung keeps the best ``1/eta`` cells,
  the last rung runs survivors at full fidelity.
* :func:`evolutionary_search` — a seeded mutation/tournament loop over
  the *joint* (strategy x cluster-axis) genome, batch-evaluating each
  generation through the study engines so the compiled/JAX fast paths
  apply.

Both optimizers return a :class:`SearchResult` whose ``trace`` and
``final`` are ordinary :class:`StudyResult` objects — every evaluated
cell carries ``search_round`` / ``search_fidelity`` / ``search_score``
columns (reserved in :class:`StudySpec`), so ``select``/``pivot``/
``to_csv`` and the R1xx analysis rules (:mod:`repro.analysis
.rules_search`) work on search output unchanged.  Scores are
minimization-normalized: ``Objective.score`` negates ``maximize``
columns, so "lower is better" uniformly.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.study import (
    CellResult,
    StudyResult,
    StudySpec,
    _cells,
    _run_cells,
    as_strategy_space,
)

__all__ = [
    "DEFAULT_OBJECTIVES",
    "Objective",
    "SearchResult",
    "dominates",
    "evolutionary_search",
    "pareto_front",
    "pareto_rank",
    "successive_halving",
]


# ===================================================================== #
# Objectives
# ===================================================================== #

@dataclasses.dataclass(frozen=True)
class Objective:
    """One ranking column.  ``score`` is minimization-normalized (the
    negation of a ``maximize`` column), so every consumer — dominance,
    halving, evolution — uniformly treats lower as better.  Missing or
    non-numeric values score ``+inf`` (never selected, never dominant)."""

    column: str
    maximize: bool = False
    label: Optional[str] = None

    @property
    def name(self) -> str:
        return self.label or self.column

    def score(self, record: Mapping[str, Any]) -> float:
        v = record.get(self.column)
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            return math.inf
        v = float(v)
        if math.isnan(v):
            return math.inf
        return -v if self.maximize else v


#: The paper triple: iteration time, total cost of ownership, energy
#: dollars (all engine-written record columns, all minimized).
DEFAULT_OBJECTIVES: Tuple[Objective, ...] = (
    Objective("total", label="time"),
    Objective("tco"),
    Objective("energy_usd", label="energy"),
)


def _scores(record: Mapping[str, Any],
            objectives: Sequence[Objective]) -> Tuple[float, ...]:
    return tuple(o.score(record) for o in objectives)


def _participates(record: Mapping[str, Any],
                  objectives: Sequence[Objective]) -> bool:
    """Feasible and finite on every objective — the cells dominance is
    defined over.  Everything else gets ``pareto_rank=None``."""
    if not record.get("feasible", True):
        return False
    return all(math.isfinite(s) for s in _scores(record, objectives))


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """Pareto dominance on minimization-normalized score vectors:
    ``a`` no worse everywhere and strictly better somewhere."""
    return all(x <= y for x, y in zip(a, b)) \
        and any(x < y for x, y in zip(a, b))


def pareto_rank(records: Sequence[Mapping[str, Any]],
                objectives: Sequence[Objective] = DEFAULT_OBJECTIVES,
                ) -> List[Optional[int]]:
    """Non-dominated sorting: rank 0 is the frontier, rank 1 the frontier
    after removing rank 0, and so on (NSGA-style peeling).  Infeasible
    records and records non-finite on any objective get ``None``."""
    scores = [_scores(r, objectives) for r in records]
    alive = [i for i, r in enumerate(records)
             if _participates(r, objectives)]
    ranks: List[Optional[int]] = [None] * len(records)
    depth = 0
    while alive:
        front = [i for i in alive
                 if not any(dominates(scores[j], scores[i])
                            for j in alive if j != i)]
        for i in front:
            ranks[i] = depth
        alive = [i for i in alive if i not in set(front)]
        depth += 1
    return ranks


def pareto_front(result: StudyResult,
                 objectives: Sequence[Objective] = DEFAULT_OBJECTIVES,
                 ) -> StudyResult:
    """Annotate every record of ``result`` with ``pareto_rank`` /
    ``pareto_optimal`` (in place, like ``normalize``) and return the
    frontier cells as a new :class:`StudyResult` on the same spec."""
    objectives = tuple(objectives)
    if not objectives:
        raise ValueError("pareto_front needs at least one objective")
    ranks = pareto_rank(result.records, objectives)
    for cell, rank in zip(result.cells, ranks):
        cell.record["pareto_rank"] = rank
        cell.record["pareto_optimal"] = rank == 0
    kept = [c for c, r in zip(result.cells, ranks) if r == 0]
    return StudyResult(spec=result.spec, cells=kept)


# ===================================================================== #
# Search results
# ===================================================================== #

@dataclasses.dataclass
class SearchResult:
    """Optimizer output: the full evaluation ``trace`` plus the ``final``
    round/rung, both plain :class:`StudyResult` objects (records carry
    ``search_round`` / ``search_fidelity`` / ``search_score``)."""

    spec: StudySpec
    objectives: Tuple[Objective, ...]
    trace: StudyResult
    final: StudyResult
    evaluations: int

    @property
    def records(self) -> List[Dict[str, Any]]:
        return self.trace.records

    def best(self) -> CellResult:
        """Feasible cell with the lowest (minimization-normalized)
        ``search_score`` among *full-fidelity* evaluations — scores from
        reduced-batch halving rungs are not comparable to final ones."""
        pool = [c for c in self.trace.cells
                if c.record.get("feasible", True)
                and c.record.get("search_fidelity", 1.0) == 1.0
                and math.isfinite(c.record.get("search_score", math.inf))]
        if not pool:
            raise ValueError("search produced no feasible full-fidelity "
                             "evaluation")
        return min(pool, key=lambda c: c.record["search_score"])


def _annotate(cells: Sequence[CellResult], rnd: int, fidelity: float,
              objective: Objective) -> None:
    for c in cells:
        c.record["search_round"] = rnd
        c.record["search_fidelity"] = fidelity
        c.record["search_score"] = objective.score(c.record)


# ===================================================================== #
# Successive halving
# ===================================================================== #

def _fidelity_schedule(rungs: int, min_fidelity: float) -> List[float]:
    if rungs < 1:
        raise ValueError(f"rungs must be >= 1, got {rungs}")
    if not 0.0 < min_fidelity <= 1.0:
        raise ValueError(f"min_fidelity must be in (0, 1], "
                         f"got {min_fidelity}")
    if rungs == 1:
        return [1.0]
    return [min_fidelity ** (1.0 - r / (rungs - 1)) for r in range(rungs)]


def _at_fidelity(spec: StudySpec, fidelity: float) -> StudySpec:
    if fidelity == 1.0:
        return spec
    shape = spec.shape
    gb = max(1, int(round(shape.global_batch * fidelity)))
    return dataclasses.replace(
        spec, shape=dataclasses.replace(shape, global_batch=gb))


def successive_halving(spec: StudySpec,
                       objective: Objective = Objective("total"),
                       eta: int = 3,
                       rungs: int = 3,
                       min_fidelity: float = 0.25,
                       engine: str = "compiled") -> SearchResult:
    """Rung-by-rung elimination over the spec's full cell product.

    Rung ``r`` evaluates the surviving cells at fidelity ``f_r`` (a
    geometric ramp from ``min_fidelity`` to 1.0 applied to
    ``shape.global_batch``) and keeps the best ``ceil(n / eta)`` by
    ``objective``; the last rung always runs at full fidelity, so the
    ``final`` result is authoritative.  Cells infeasible at a rung rank
    last (standard SHA behavior: they are culled, not retried).

    Requires the default workload builder (``spec.model`` +
    ``spec.shape``): the batch is the fidelity lever.  Keep
    ``min_fidelity`` a power-of-two fraction when strategies carry large
    DP degrees, so scaled batches stay divisible."""
    if spec.model is None or spec.shape is None or spec.workload is not None:
        raise ValueError(
            "successive_halving scales shape.global_batch, so the study "
            "must use the default workload builder (model + shape set, "
            "no custom workload)")
    if eta < 2:
        raise ValueError(f"eta must be >= 2, got {eta}")
    cells = _cells(spec)
    if not cells:
        raise ValueError(f"study {spec.name!r} has no cells to search")
    trace: List[CellResult] = []
    final: List[CellResult] = []
    alive = list(range(len(cells)))
    evals = 0
    for rnd, fidelity in enumerate(_fidelity_schedule(rungs, min_fidelity)):
        rung_spec = _at_fidelity(spec, fidelity)
        results = _run_cells(rung_spec, [cells[i] for i in alive], engine)
        evals += len(results)
        _annotate(results, rnd, fidelity, objective)
        trace.extend(results)
        order = sorted(range(len(alive)),
                       key=lambda k: results[k].record["search_score"])
        if rnd == rungs - 1:
            final = [results[k] for k in order]
        else:
            keep = max(1, math.ceil(len(alive) / eta))
            alive = [alive[k] for k in order[:keep]]
    return SearchResult(spec=spec, objectives=(objective,),
                        trace=StudyResult(spec=spec, cells=trace),
                        final=StudyResult(spec=spec, cells=final),
                        evaluations=evals)


# ===================================================================== #
# Evolutionary search
# ===================================================================== #

# A genome is one integer per cluster/placement axis (an index into the
# axis's value tuple) plus one strategy gene (an index into the strategy
# space resolved against the genome's own overridden cluster — the list
# length varies per cluster, so the gene is taken modulo it).
_Genome = Tuple[Tuple[int, ...], int]


def _genome_cell(spec: StudySpec, genome: _Genome) -> tuple:
    from repro.core.study import get_placement
    axis_idx, strat_idx = genome
    space = as_strategy_space(spec.strategies)
    cluster = spec.cluster
    pl = get_placement(spec.placement)
    point: Dict[str, Any] = {}
    for axis, vi in zip(spec.axes, axis_idx):
        value = axis.values[vi]
        if axis.kind == "placement":
            pl = get_placement(value)
            point[axis.name] = pl.label if pl is not None else None
        else:
            point[axis.name] = value
            cluster = axis.override(cluster, value)
    if space is None:
        return (None, point, cluster, pl)
    strategies = space.specs(cluster.num_nodes if cluster is not None else 0)
    if not strategies:
        return None
    return (strategies[strat_idx % len(strategies)], point, cluster, pl)


def _cell_key(cell: tuple) -> tuple:
    """Canonical identity of a resolved cell: distinct genomes whose
    strategy genes agree modulo the strategy-list length (or whose axis
    values coincide) are the *same* evaluation and must share one
    simulation."""
    strategy, point, _, placement = cell
    return (str(strategy), tuple(sorted(point.items())),
            placement.label if placement is not None else None)


def _mutate(rng: np.random.Generator, genome: _Genome, spec: StudySpec,
            rate: float) -> _Genome:
    axis_idx, strat_idx = genome
    out = list(axis_idx)
    for k, axis in enumerate(spec.axes):
        n = len(axis.values)
        if n > 1 and rng.random() < rate:
            step = 1 if rng.random() < 0.5 else -1
            out[k] = int((out[k] + step) % n)
    if rng.random() < rate:
        # Strategy lists are cluster-dependent, so the gene mutates in a
        # fixed large index space and resolves modulo the actual length.
        strat_idx = int(rng.integers(0, 1 << 16))
    return (tuple(out), strat_idx)


def evolutionary_search(spec: StudySpec,
                        objective: Objective = Objective("total"),
                        population: int = 16,
                        generations: int = 8,
                        mutation_rate: float = 0.35,
                        elite_frac: float = 0.25,
                        seed: int = 0,
                        engine: str = "compiled") -> SearchResult:
    """Seeded (mu + lambda)-style loop over the joint strategy x cluster
    axes.  Each generation batch-evaluates its unseen genomes through
    ``_run_cells`` (one compiled/JAX batch per generation), keeps the
    ``elite_frac`` best, and refills by mutating tournament-selected
    parents.  Deterministic for a fixed ``seed``.  The trace holds every
    *evaluation*: genomes are memoized by their resolved cell (strategy,
    axis point, placement), so no cell is ever simulated twice — even
    when distinct raw genes alias the same strategy modulo the
    cluster-dependent list length."""
    if population < 2:
        raise ValueError(f"population must be >= 2, got {population}")
    if generations < 1:
        raise ValueError(f"generations must be >= 1, got {generations}")
    rng = np.random.default_rng(seed)
    dims = [len(a.values) for a in spec.axes]
    if spec.cluster is None and not any(a.kind != "placement"
                                        for a in spec.axes):
        raise ValueError(
            "evolutionary_search needs a cluster (StudySpec.cluster or a "
            "cluster-valued axis) to resolve strategies against")

    def random_genome() -> _Genome:
        return (tuple(int(rng.integers(0, d)) for d in dims),
                int(rng.integers(0, 1 << 16)))

    seen: Dict[tuple, CellResult] = {}
    keys: Dict[_Genome, Optional[tuple]] = {}
    trace: List[CellResult] = []
    evals = 0
    pop = [random_genome() for _ in range(population)]
    fitness: Dict[_Genome, float] = {}
    last_gen: List[CellResult] = []
    for gen in range(generations):
        batch: List[Tuple[tuple, tuple]] = []   # (key, cell) to simulate
        for g in dict.fromkeys(pop):
            if g in keys:
                continue
            cell = _genome_cell(spec, g)
            if cell is None:     # empty strategy list for this cluster
                keys[g] = None
                fitness[g] = math.inf
                continue
            key = _cell_key(cell)
            keys[g] = key
            if key not in seen and all(k != key for k, _ in batch):
                batch.append((key, cell))
        if batch:
            results = _run_cells(spec, [c for _, c in batch], engine)
            evals += len(results)
            _annotate(results, gen, 1.0, objective)
            trace.extend(results)
            for (key, _), res in zip(batch, results):
                seen[key] = res
        for g in pop:
            if g not in fitness and keys[g] is not None:
                r = seen[keys[g]].record
                fitness[g] = (r["search_score"]
                              if r.get("feasible", True) else math.inf)
        ranked = sorted(dict.fromkeys(pop), key=lambda g: fitness[g])
        done = set()
        last_gen = []
        for g in ranked:
            key = keys[g]
            if key is not None and key not in done:
                done.add(key)
                last_gen.append(seen[key])
        if gen == generations - 1:
            break
        elites = ranked[:max(1, int(round(elite_frac * population)))]
        nxt = list(elites)
        while len(nxt) < population:
            a, b = (ranked[int(rng.integers(0, len(ranked)))]
                    for _ in range(2))
            parent = a if fitness[a] <= fitness[b] else b
            nxt.append(_mutate(rng, parent, spec, mutation_rate))
        pop = nxt
    return SearchResult(spec=spec, objectives=(objective,),
                        trace=StudyResult(spec=spec, cells=trace),
                        final=StudyResult(spec=spec, cells=last_gen),
                        evaluations=evals)
