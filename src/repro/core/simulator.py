"""COMET §III-C3/4: ASTRA-lite — analytical, overlap-aware iteration timeline.

Replaces the paper's ASTRA-SIM discrete-event backend with the same inputs
(per-layer compute delays + collective type/size per phase) and the same
semantics:

  * FP and IG blocking MP collectives serialize with compute on the
    critical path;
  * WG DP collectives are non-blocking: they run on the network stream and
    overlap subsequent backward compute — only the residue past the end of
    compute is exposed;
  * MP and DP collectives travel disjoint link sets under the paper's
    placement (MP fills pods, DP strides), so they get independent network
    streams (documented simplification of ASTRA-SIM's link-level model);
  * heterogeneous clusters (ClusterSpec with several pod groups) follow
    synchronous-training semantics: every group holds the same shard, the
    slowest / least-capable group gates the iteration, and the cluster is
    feasible only if the shard fits every group's nodes.

Outputs the per-phase compute/exposed-communication breakdown of Fig. 8a.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.cluster import ClusterLike, NodeConfig
from repro.core.collectives import CollectiveModel
from repro.core.memory import (
    FootprintReport,
    effective_memory_bw,
    per_node_footprint,
)
from repro.core.roofline import compute_delay
from repro.core.topology import Topology
from repro.core.workload import Workload

OPTIM_BYTES_PER_PARAM = 28  # grad read + fp32 m/v/master read+write


@dataclasses.dataclass
class PhaseBreakdown:
    compute: float = 0.0
    exposed_comm: float = 0.0

    @property
    def total(self) -> float:
        return self.compute + self.exposed_comm


@dataclasses.dataclass
class IterationBreakdown:
    fp: PhaseBreakdown
    ig: PhaseBreakdown
    wg: PhaseBreakdown
    optimizer: float
    footprint: FootprintReport
    mem_bw: float
    feasible: bool

    @property
    def total(self) -> float:
        return (self.fp.total + self.ig.total + self.wg.total + self.optimizer)

    def as_dict(self) -> Dict[str, float]:
        return {
            "fp_compute": self.fp.compute,
            "fp_exposed_comm": self.fp.exposed_comm,
            "ig_compute": self.ig.compute,
            "ig_exposed_comm": self.ig.exposed_comm,
            "wg_compute": self.wg.compute,
            "wg_exposed_comm": self.wg.exposed_comm,
            "optimizer": self.optimizer,
            "total": self.total,
        }


def simulate_iteration(
    workload: Workload,
    cluster: ClusterLike,
    zero_stage: int = 2,
    mem_bw_override: "Optional[float | str]" = None,
    require_fit: bool = False,
) -> IterationBreakdown:
    """One training iteration of ``workload`` on ``cluster``.

    Accepts the homogeneous ``ClusterConfig`` shim or a composable
    ``ClusterSpec``; a heterogeneous spec simulates each node group and is
    gated by the slowest one (synchronous training), with feasibility
    requiring the shard to fit every group.  ``mem_bw_override`` may be a
    float or the string ``"local"``, which resolves to each group's own
    ``node.local_bw`` (§V-B1's infinite-capacity assumption)."""
    groups = cluster.node_groups
    if len(groups) == 1:
        g = groups[0]
        return _simulate_group(workload, g.node, g.topology, zero_stage,
                               mem_bw_override, require_fit)
    per = [_simulate_group(workload, g.node, g.topology, zero_stage,
                           mem_bw_override, require_fit) for g in groups]
    reps = [b.footprint for b in per]
    # Footprint totals are node-independent; only the fits flags differ.
    worst_rep = dataclasses.replace(
        max(reps, key=lambda r: r.total),
        fits_local=all(r.fits_local for r in reps),
        fits_total=all(r.fits_total for r in reps))
    feasible = all(b.feasible for b in per)
    if require_fit and not feasible:
        return IterationBreakdown(PhaseBreakdown(), PhaseBreakdown(),
                                  PhaseBreakdown(), 0.0, worst_rep,
                                  min(b.mem_bw for b in per), False)
    worst = max(per, key=lambda b: b.total)
    return IterationBreakdown(worst.fp, worst.ig, worst.wg, worst.optimizer,
                              worst_rep, worst.mem_bw, feasible)


def _simulate_group(
    workload: Workload,
    node: NodeConfig,
    topology: Topology,
    zero_stage: int,
    mem_bw_override: "Optional[float | str]",
    require_fit: bool,
) -> IterationBreakdown:
    """The ASTRA-lite timeline for one homogeneous node group."""
    if mem_bw_override == "local":
        mem_bw_override = node.local_bw
    fp_rep = per_node_footprint(workload, node, zero_stage)
    mem_bw = (mem_bw_override if mem_bw_override is not None
              else effective_memory_bw(node, fp_rep.total))
    feasible = fp_rep.fits_total
    if require_fit and not feasible:
        return IterationBreakdown(PhaseBreakdown(), PhaseBreakdown(),
                                  PhaseBreakdown(), 0.0, fp_rep, mem_bw, False)
    coll = CollectiveModel(topology, workload.mp, workload.dp)
    sram = node.sram_bytes

    # Precompute per-unique-layer delays.
    delays = []  # (layer, {phase: compute_delay}, {phase: [(dur, blocking, scope)]})
    for layer in workload.layers:
        d = {p: compute_delay(layer.phase_cost(p, sram), node, mem_bw).delay
             for p in ("fp", "ig", "wg")}
        c = {p: [(coll.time(e.collective, e.size_bytes, e.scope),
                  e.blocking, e.scope) for e in layer.comm(p)]
             for p in ("fp", "ig", "wg")}
        delays.append((layer, d, c))

    fp = PhaseBreakdown()
    ig = PhaseBreakdown()
    wg = PhaseBreakdown()

    # ---------------- forward pass ----------------
    tc = 0.0
    tn: Dict[str, float] = {"mp": 0.0, "dp": 0.0, "ep": 0.0}
    for layer, d, c in delays:
        for _ in range(layer.repeat):
            tc += d["fp"]
            fp.compute += d["fp"]
            for dur, blocking, scope in c["fp"]:
                if blocking:
                    start = max(tc, tn[scope])
                    end = start + dur
                    fp.exposed_comm += end - tc
                    tc = end
                    tn[scope] = end
                else:
                    start = max(tc, tn[scope])
                    tn[scope] = start + dur

    # ---------------- backward (IG + WG interleaved, reverse order) ------
    tc = 0.0
    tn = {"mp": 0.0, "dp": 0.0, "ep": 0.0}
    for layer, d, c in reversed(delays):
        for _ in range(layer.repeat):
            tc += d["ig"]
            ig.compute += d["ig"]
            for dur, blocking, scope in c["ig"]:
                if blocking:
                    start = max(tc, tn[scope])
                    end = start + dur
                    ig.exposed_comm += end - tc
                    tc = end
                    tn[scope] = end
                else:
                    start = max(tc, tn[scope])
                    tn[scope] = start + dur
            tc += d["wg"]
            wg.compute += d["wg"]
            for dur, blocking, scope in c["wg"]:
                if blocking:
                    start = max(tc, tn[scope])
                    end = start + dur
                    wg.exposed_comm += end - tc
                    tc = end
                    tn[scope] = end
                else:
                    start = max(tc, tn[scope])
                    tn[scope] = start + dur
    # Non-blocking residue past the end of backward compute is exposed.
    wg.exposed_comm += max(0.0, max(tn.values()) - tc)

    # ---------------- optimizer update ----------------
    dense_w = sum(l.weight_bytes * l.repeat for l in workload.layers
                  if l.optim_bytes is None)
    sparse = sum(l.optim_bytes * l.repeat for l in workload.layers
                 if l.optim_bytes is not None)
    params = dense_w / 2
    shard = params / max(1, workload.dp) if zero_stage >= 1 else params
    optim = (shard * OPTIM_BYTES_PER_PARAM + sparse) / mem_bw

    return IterationBreakdown(fp, ig, wg, optim, fp_rep, mem_bw, feasible)
