"""COMET §III-C3/4: ASTRA-lite — analytical, overlap-aware iteration timeline.

Replaces the paper's ASTRA-SIM discrete-event backend with the same inputs
(per-layer compute delays + collective type/size per phase) and the same
semantics:

  * FP and IG blocking MP collectives serialize with compute on the
    critical path;
  * WG DP collectives are non-blocking: they run on the network stream and
    overlap subsequent backward compute — only the residue past the end of
    compute is exposed;
  * MP and DP collectives travel disjoint link sets under the paper's
    placement (MP fills pods, DP strides), so they get independent network
    streams (documented simplification of ASTRA-SIM's link-level model);
  * heterogeneous clusters (ClusterSpec with several pod groups) follow
    the active :class:`~repro.core.placement.Placement`: the default
    ``PaperPlacement`` keeps synchronous replicate-everywhere semantics —
    every group holds the same shard, the slowest / least-capable group
    gates the iteration, and the cluster is feasible only if the shard
    fits every group's nodes; ``EMAwarePlacement`` instead *assigns*
    pipeline stages to node groups (hungry stages to EM pods), each stage
    simulated on and gated by its own group;
  * pipeline workloads (``Workload.pp > 1``) run a microbatch schedule
    model: each stage's full-batch time ``T_s`` (compute + blocking comm +
    exposed residue, including the stage-boundary p2p transfers) is split
    into ``m = num_microbatches`` microbatches, and the iteration is gated
    by the slowest stage with the standard bubble term

        T_pipe = (m + pp - 1) / m * max_s T_s

    i.e. bubble fraction (pp - 1) / (m + pp - 1) — identical for GPipe and
    1F1B (they differ in activation stashing, handled by
    ``repro.core.memory.stage_footprints``).  Megatron-LM's interleaved
    schedule (``schedule="interleaved"``, ``v`` virtual stages per node)
    shrinks the bubble to (pp - 1) / (v*m + pp - 1) at v-fold p2p volume
    (charged by ``decompose``).  Feasibility requires every stage to fit
    its nodes.

Outputs the per-phase compute/exposed-communication breakdown of Fig. 8a.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.cluster import ClusterLike, NodeConfig
from repro.core.collectives import CollectiveModel
from repro.core.memory import (
    FootprintReport,
    effective_memory_bw,
    per_node_footprint,
    stage_footprints,
    worst_report,
)
from repro.core.roofline import compute_delay
from repro.core.topology import Topology
from repro.core.workload import LayerSpec, Workload

OPTIM_BYTES_PER_PARAM = 28  # grad read + fp32 m/v/master read+write

_SCOPES = ("mp", "dp", "ep", "pp", "edp")


@dataclasses.dataclass
class PhaseBreakdown:
    compute: float = 0.0
    exposed_comm: float = 0.0

    @property
    def total(self) -> float:
        return self.compute + self.exposed_comm

    def scaled(self, factor: float) -> "PhaseBreakdown":
        return PhaseBreakdown(self.compute * factor,
                              self.exposed_comm * factor)


@dataclasses.dataclass
class IterationBreakdown:
    fp: PhaseBreakdown
    ig: PhaseBreakdown
    wg: PhaseBreakdown
    optimizer: float
    footprint: FootprintReport
    mem_bw: float
    feasible: bool
    # Pipeline-schedule idle fraction (pp - 1) / (m + pp - 1); 0.0 when the
    # workload has no pipeline dimension.  Kept out of as_dict() so the
    # time components still sum to ``total``.
    bubble_fraction: float = 0.0

    @property
    def total(self) -> float:
        return (self.fp.total + self.ig.total + self.wg.total + self.optimizer)

    def as_dict(self) -> Dict[str, float]:
        return {
            "fp_compute": self.fp.compute,
            "fp_exposed_comm": self.fp.exposed_comm,
            "ig_compute": self.ig.compute,
            "ig_exposed_comm": self.ig.exposed_comm,
            "wg_compute": self.wg.compute,
            "wg_exposed_comm": self.wg.exposed_comm,
            "optimizer": self.optimizer,
            "total": self.total,
        }


def _infeasible(rep: FootprintReport, mem_bw: float,
                bubble_fraction: float = 0.0) -> IterationBreakdown:
    return IterationBreakdown(PhaseBreakdown(), PhaseBreakdown(),
                              PhaseBreakdown(), 0.0, rep, mem_bw, False,
                              bubble_fraction=bubble_fraction)


def simulate_iteration(
    workload: Workload,
    cluster: ClusterLike,
    zero_stage: int = 2,
    mem_bw_override: "Optional[float | str]" = None,
    require_fit: bool = False,
    placement=None,
) -> IterationBreakdown:
    """One training iteration of ``workload`` on ``cluster``.

    Accepts the homogeneous ``ClusterConfig`` shim or a composable
    ``ClusterSpec``.  ``placement`` (a
    :class:`repro.core.placement.Placement`; None = ``PaperPlacement``)
    decides how the workload maps onto a heterogeneous spec: the paper
    default simulates each node group and is gated by the slowest one
    (synchronous training, feasibility = the shard fits every group);
    a placement that *assigns* pipeline stages to groups (EM-aware,
    explicit) simulates each stage on its own group and gates it there.
    ``mem_bw_override`` may be a float or the string ``"local"``, which
    resolves to each group's own ``node.local_bw`` (§V-B1's
    infinite-capacity assumption)."""
    groups = cluster.node_groups
    if len(groups) == 1:
        g = groups[0]
        return _simulate_group(workload, g.node, g.topology, zero_stage,
                               mem_bw_override, require_fit, placement)
    if placement is not None and getattr(workload, "pp", 1) > 1:
        stage_bytes = [r.total for r in
                       stage_footprints(workload, None, zero_stage)]
        nodes_per_stage = workload.mp * workload.dp * workload.ep
        assign = placement.assign_stages(stage_bytes, groups,
                                         nodes_per_stage)
        if assign is not None:
            envs = [(groups[i].node, groups[i].topology) for i in assign]
            return _simulate_pipeline(workload, envs, zero_stage,
                                      mem_bw_override, require_fit,
                                      placement)
    per = [_simulate_group(workload, g.node, g.topology, zero_stage,
                           mem_bw_override, require_fit, placement)
           for g in groups]
    # Footprint totals are node-independent; only the fits flags differ.
    worst_rep = worst_report([b.footprint for b in per])
    feasible = all(b.feasible for b in per)
    if require_fit and not feasible:
        return _infeasible(worst_rep, min(b.mem_bw for b in per),
                           bubble_fraction=max(b.bubble_fraction
                                               for b in per))
    worst = max(per, key=lambda b: b.total)
    return IterationBreakdown(worst.fp, worst.ig, worst.wg, worst.optimizer,
                              worst_rep, worst.mem_bw, feasible,
                              bubble_fraction=worst.bubble_fraction)


def group_breakdowns(
    workload: Workload,
    cluster: ClusterLike,
    zero_stage: int = 2,
    mem_bw_override: "Optional[float | str]" = None,
    placement=None,
) -> List[IterationBreakdown]:
    """One breakdown per node group, in ``cluster.node_groups`` order —
    how one *instance* of ``workload`` runs on each group alone.  The
    multi-tenant :class:`~repro.core.placement.ScheduleModel` consumes
    this to place concurrent instances on a mixed fleet."""
    return [_simulate_group(workload, g.node, g.topology, zero_stage,
                            mem_bw_override, False, placement)
            for g in cluster.node_groups]


# --------------------------------------------------------------------- #
# Shared timeline machinery
# --------------------------------------------------------------------- #

# (layer, {phase: compute delay}, {phase: [(dur, blocking, scope)]})
_Delays = List[Tuple[LayerSpec, Dict[str, float], Dict[str, list]]]


def _layer_delays(layers: List[LayerSpec], node: NodeConfig, mem_bw: float,
                  coll: CollectiveModel, sram: float) -> _Delays:
    out = []
    for layer in layers:
        d = {p: compute_delay(layer.phase_cost(p, sram), node, mem_bw).delay
             for p in ("fp", "ig", "wg")}
        c = {p: [(coll.time(e.collective, e.size_bytes, e.scope),
                  e.blocking, e.scope) for e in layer.comm(p)]
             for p in ("fp", "ig", "wg")}
        out.append((layer, d, c))
    return out


def _run_timeline(delays: _Delays) -> Tuple[PhaseBreakdown, PhaseBreakdown,
                                            PhaseBreakdown]:
    """FP pass then interleaved IG/WG backward pass over one layer list,
    with blocking collectives on the critical path and non-blocking ones on
    independent per-scope network streams (residue exposed at the end)."""
    fp = PhaseBreakdown()
    ig = PhaseBreakdown()
    wg = PhaseBreakdown()

    # ---------------- forward pass ----------------
    tc = 0.0
    tn: Dict[str, float] = {s: 0.0 for s in _SCOPES}
    for layer, d, c in delays:
        for _ in range(layer.repeat):
            tc += d["fp"]
            fp.compute += d["fp"]
            for dur, blocking, scope in c["fp"]:
                if blocking:
                    start = max(tc, tn[scope])
                    end = start + dur
                    fp.exposed_comm += end - tc
                    tc = end
                    tn[scope] = end
                else:
                    start = max(tc, tn[scope])
                    tn[scope] = start + dur

    # ---------------- backward (IG + WG interleaved, reverse order) ------
    tc = 0.0
    tn = {s: 0.0 for s in _SCOPES}
    for layer, d, c in reversed(delays):
        for _ in range(layer.repeat):
            tc += d["ig"]
            ig.compute += d["ig"]
            for dur, blocking, scope in c["ig"]:
                if blocking:
                    start = max(tc, tn[scope])
                    end = start + dur
                    ig.exposed_comm += end - tc
                    tc = end
                    tn[scope] = end
                else:
                    start = max(tc, tn[scope])
                    tn[scope] = start + dur
            tc += d["wg"]
            wg.compute += d["wg"]
            for dur, blocking, scope in c["wg"]:
                if blocking:
                    start = max(tc, tn[scope])
                    end = start + dur
                    wg.exposed_comm += end - tc
                    tc = end
                    tn[scope] = end
                else:
                    start = max(tc, tn[scope])
                    tn[scope] = start + dur
    # Non-blocking residue past the end of backward compute is exposed.
    wg.exposed_comm += max(0.0, max(tn.values()) - tc)
    return fp, ig, wg


def _optimizer_time(layers: List[LayerSpec], dense_ways: int,
                    expert_ways: int, zero_stage: int,
                    mem_bw: float) -> float:
    """Optimizer-update memory time.  Dense params ZeRO-shard across the
    DP x EP data group; expert params are EP-sharded already and shard
    across DP only (matching ``memory._layer_states``)."""
    dense_w = sum((ly.weight_bytes - ly.expert_bytes) * ly.repeat
                  for ly in layers if ly.optim_bytes is None)
    expert_w = sum(ly.expert_bytes * ly.repeat for ly in layers
                   if ly.optim_bytes is None)
    sparse = sum(ly.optim_bytes * ly.repeat for ly in layers
                 if ly.optim_bytes is not None)
    return _optimizer_numer(dense_w, expert_w, sparse, dense_ways,
                            expert_ways, zero_stage) / mem_bw


def _schedule_factors(schedule: str, pp: int, m: int,
                      v: int) -> Tuple[float, float]:
    """(iteration scale over the gating stage, bubble fraction) for a
    pipeline schedule.  GPipe / 1F1B: (m + pp - 1)/m; Megatron-LM
    interleaved 1F1B with ``v`` virtual stages per node: the bubble
    shrinks v-fold to (pp - 1)/(v*m + pp - 1)."""
    slots = v * m if schedule == "interleaved" else m
    return (slots + pp - 1) / slots, (pp - 1) / (slots + pp - 1)


def _optimizer_numer(dense_w: float, expert_w: float, sparse: float,
                     dense_ways: int, expert_ways: int,
                     zero_stage: int) -> float:
    """Optimizer-update bytes before the ``/ mem_bw`` division — the
    environment-independent half of :func:`_optimizer_time`, shared with
    the compiled path so the two cannot drift."""
    params = dense_w / 2
    shard = params / max(1, dense_ways) if zero_stage >= 1 else params
    if expert_w:
        ep_params = expert_w / 2
        shard += (ep_params / max(1, expert_ways) if zero_stage >= 1
                  else ep_params)
    return shard * OPTIM_BYTES_PER_PARAM + sparse


def _simulate_group(
    workload: Workload,
    node: NodeConfig,
    topology: Topology,
    zero_stage: int,
    mem_bw_override: "Optional[float | str]",
    require_fit: bool,
    placement=None,
) -> IterationBreakdown:
    """The ASTRA-lite timeline for one homogeneous node group."""
    if getattr(workload, "pp", 1) > 1:
        return _simulate_pipeline(workload, [(node, topology)] * workload.pp,
                                  zero_stage, mem_bw_override, require_fit,
                                  placement)
    if mem_bw_override == "local":
        mem_bw_override = node.local_bw
    ep = getattr(workload, "ep", 1)
    fp_rep = per_node_footprint(workload, node, zero_stage)
    mem_bw = (mem_bw_override if mem_bw_override is not None
              else effective_memory_bw(node, fp_rep.total))
    feasible = fp_rep.fits_total
    if require_fit and not feasible:
        return _infeasible(fp_rep, mem_bw)
    coll = CollectiveModel(topology, workload.mp, workload.dp, ep=ep,
                           placement=placement)
    delays = _layer_delays(workload.layers, node, mem_bw, coll,
                           node.sram_bytes)
    fp, ig, wg = _run_timeline(delays)
    optim = _optimizer_time(workload.layers, workload.dp * ep, workload.dp,
                            zero_stage, mem_bw)
    return IterationBreakdown(fp, ig, wg, optim, fp_rep, mem_bw, feasible)


def _simulate_pipeline(
    workload: Workload,
    stage_envs: "List[Tuple[NodeConfig, Topology]]",
    zero_stage: int,
    mem_bw_override: "Optional[float | str]",
    require_fit: bool,
    placement=None,
) -> IterationBreakdown:
    """Microbatch pipeline schedule over the slowest stage.

    ``stage_envs`` holds the (node, topology) hosting each stage — all
    identical on a homogeneous group, per-assignment under an EM-aware /
    explicit placement on a mixed fleet.  Per-stage full-batch times come
    from the same timeline machinery as the flat path (boundary p2p
    transfers are blocking events on the boundary layers); the reported
    phase breakdown is the gating stage's, scaled by the schedule factor
    — (m + pp - 1)/m for GPipe/1F1B, (v*m + pp - 1)/(v*m) interleaved —
    so ``total`` is the pipeline iteration time.  Each stage's footprint
    gates against *its* node; the optimizer step runs concurrently on
    every stage, so its time is the max over stages."""
    pp = workload.pp
    m = max(1, workload.num_microbatches)
    v = max(1, getattr(workload, "virtual_stages", 1))
    stages = workload.stage_layers()
    nodes = [node for node, _ in stage_envs]
    reps = stage_footprints(workload, None, zero_stage, nodes=nodes)
    worst_rep = worst_report(reps)
    mem_bws = [node.local_bw if mem_bw_override == "local"
               else mem_bw_override if mem_bw_override is not None
               else effective_memory_bw(node, r.total)
               for node, r in zip(nodes, reps)]
    feasible = worst_rep.fits_total
    scale, bubble = _schedule_factors(workload.schedule, pp, m, v)
    if require_fit and not feasible:
        return _infeasible(worst_rep, min(mem_bws), bubble_fraction=bubble)
    colls = {}
    for _, topo in stage_envs:
        if id(topo) not in colls:
            colls[id(topo)] = CollectiveModel(
                topo, workload.mp, workload.dp, pp=pp, ep=workload.ep,
                placement=placement)
    data_ways = workload.dp * workload.ep
    per_stage = []
    for layers, (node, topo), bw in zip(stages, stage_envs, mem_bws):
        delays = _layer_delays(layers, node, bw, colls[id(topo)],
                               node.sram_bytes)
        fp, ig, wg = _run_timeline(delays)
        per_stage.append((fp, ig, wg, fp.total + ig.total + wg.total))
    k = max(range(pp), key=lambda s: per_stage[s][3])
    fp, ig, wg, _ = per_stage[k]
    optim = max(_optimizer_time(layers, data_ways, workload.dp, zero_stage,
                                bw)
                for layers, bw in zip(stages, mem_bws))
    return IterationBreakdown(fp.scaled(scale), ig.scaled(scale),
                              wg.scaled(scale), optim, worst_rep,
                              mem_bws[k], feasible,
                              bubble_fraction=bubble)


# --------------------------------------------------------------------- #
# Compiled (vectorized) evaluation — phase 2 of the two-phase engine
# --------------------------------------------------------------------- #
# Phase 1 (repro.core.compiled) lowers a decomposed Workload into flat
# arrays once per strategy; the functions below time that CompiledWorkload
# against a whole batch of (node, topology) environments in NumPy array
# ops, reproducing _simulate_group / simulate_iteration within float
# round-off (<= 1e-9 relative, tests/test_compiled.py).  The event-loop
# path above stays untouched as the bit-for-bit reference engine.
#
# ``backend`` selects the array library for the per-stage hot path (the
# roofline delay matrix, the batched collective table and the timeline
# scan): ``"numpy"`` is the PR-5 vectorized engine; ``"jax"`` routes
# through :mod:`repro.core.jax_engine` — one jitted/vmapped device call
# per (stage, environment-batch) — and silently falls back to NumPy when
# JAX is not importable (a one-time warning).

def _compiled_delays(stage, nodes, mem_bw) -> "np.ndarray":
    """Roofline compute delays, ``(n_lp, nenv)``: Eqns (1)/(2) over every
    (layer, phase) row and environment at once."""
    import numpy as np

    from repro.core.compiled import stage_traffic
    sram = np.array([max(int(n.sram_bytes), 1) for n in nodes], dtype=float)
    peak = np.array([n.peak_flops for n in nodes], dtype=float)
    traffic = stage_traffic(stage, sram)
    flops = stage.flops[:, None]
    with np.errstate(divide="ignore", invalid="ignore"):
        oi = flops / traffic                       # inf when traffic == 0
        perf = np.minimum(peak[None, :], oi * mem_bw[None, :])
        delays = flops / perf
    zero_flop = stage.flops == 0
    if zero_flop.any():
        # Pure data movement (embedding lookups): memory-bound transfer.
        t = traffic[zero_flop]
        delays[zero_flop] = np.where(t > 0, t / mem_bw[None, :], 0.0)
    return delays


def _compiled_comm(stage, envs, mp: int, dp: int, pp: int, ep: int,
                   placement) -> "np.ndarray":
    """Collective durations, ``(ncomm, nenv)``: one batched
    CollectiveModel.time_batch call per distinct topology in the batch."""
    import numpy as np
    nenv = len(envs)
    durations = np.zeros((len(stage.comm_kinds), nenv))
    if not stage.comm_kinds:
        return durations
    columns = {}
    for e, (_, topo) in enumerate(envs):
        if topo not in columns:
            coll = CollectiveModel(topo, mp, dp, pp=pp, ep=ep,
                                   placement=placement)
            columns[topo] = coll.time_batch(stage.comm_kinds,
                                            stage.comm_sizes,
                                            stage.comm_scopes)
        durations[:, e] = columns[topo]
    return durations


def _compiled_scan(stage, delays, comm):
    """The ASTRA-lite timeline (:func:`_run_timeline`) vectorized across
    environments: compute totals are a counts x delays product; exposure
    comes from walking the communication events once, with the compute
    runs between events collapsed to cumulative-sum differences.

    Returns ``(compute, exposed)``, each ``(3, nenv)`` (fp/ig/wg rows)."""
    import numpy as np
    nenv = delays.shape[1]
    compute = stage.counts @ delays
    exposed = np.zeros((3, nenv))
    for is_bwd, p in ((False, stage.fwd), (True, stage.bwd)):
        dseq = delays[p.seq]
        csum = np.zeros((dseq.shape[0] + 1, nenv))
        np.cumsum(dseq, axis=0, out=csum[1:])
        tc = np.zeros(nenv)
        tn = np.zeros((len(_SCOPES), nenv))
        prev = 0
        for j in range(p.ev_comm.size):
            pos = p.ev_pos[j]
            if pos != prev:
                tc = tc + (csum[pos] - csum[prev])
                prev = pos
            dur = comm[p.ev_comm[j]]
            sc = p.ev_scope[j]
            start = np.maximum(tc, tn[sc])
            if p.ev_blocking[j]:
                end = start + dur
                exposed[p.ev_phase[j]] += end - tc
                tc = end
                tn[sc] = end
            else:
                tn[sc] = start + dur
        tc = tc + (csum[-1] - csum[prev])
        if is_bwd:
            # Non-blocking residue past the end of backward compute.
            exposed[2] += np.maximum(0.0, tn.max(axis=0) - tc)
    return compute, exposed


_warned_no_jax = False


def _stage_compute_exposed(stage, envs, nodes, mem_bw, mp, dp, pp, ep,
                           placement, backend: str = "numpy"):
    """One stage's ``(compute, exposed)`` — each ``(3, nenv)`` — through
    the selected array backend.  The NumPy path is the PR-5 pipeline
    (:func:`_compiled_delays` / :func:`_compiled_comm` /
    :func:`_compiled_scan`); ``backend="jax"`` hands the same flat arrays
    to :func:`repro.core.jax_engine.stage_compute_exposed` (jit + vmap
    over the environment axis) and degrades to NumPy when JAX is absent."""
    if backend == "jax":
        from repro.core import jax_engine
        if jax_engine.HAVE_JAX:
            return jax_engine.stage_compute_exposed(
                stage, envs, nodes, mem_bw, mp, dp, pp, ep, placement)
        global _warned_no_jax
        if not _warned_no_jax:
            _warned_no_jax = True
            import warnings
            warnings.warn("backend='jax' requested but jax is not "
                          "importable; falling back to the NumPy compiled "
                          "engine (identical results, no device dispatch)",
                          RuntimeWarning, stacklevel=3)
    delays = _compiled_delays(stage, nodes, mem_bw)
    comm = _compiled_comm(stage, envs, mp, dp, pp, ep, placement)
    return _compiled_scan(stage, delays, comm)


def _compiled_mem_bws(nodes, total: float, mem_bw_override) -> "np.ndarray":
    import numpy as np
    return np.array([n.local_bw if mem_bw_override == "local"
                     else mem_bw_override if mem_bw_override is not None
                     else effective_memory_bw(n, total) for n in nodes])


def _time_compiled_flat(cw, envs, zero_stage, mem_bw_override, require_fit,
                        placement,
                        backend: str = "numpy") -> List[IterationBreakdown]:
    wl = cw.workload
    stage = cw.stages[0]
    nodes = [n for n, _ in envs]
    rep0 = per_node_footprint(wl, None, zero_stage)
    total = rep0.total
    reps = [dataclasses.replace(rep0,
                                fits_local=total <= n.local_cap,
                                fits_total=total <= n.total_cap)
            for n in nodes]
    mem_bw = _compiled_mem_bws(nodes, total, mem_bw_override)
    ep = getattr(wl, "ep", 1)
    compute, exposed = _stage_compute_exposed(stage, envs, nodes, mem_bw,
                                              wl.mp, wl.dp, 1, ep, placement,
                                              backend)
    numer = _optimizer_numer(stage.dense_w, stage.expert_w, stage.sparse,
                             wl.dp * ep, wl.dp, zero_stage)
    out = []
    for e in range(len(nodes)):
        if require_fit and not reps[e].fits_total:
            out.append(_infeasible(reps[e], float(mem_bw[e])))
            continue
        out.append(IterationBreakdown(
            PhaseBreakdown(float(compute[0, e]), float(exposed[0, e])),
            PhaseBreakdown(float(compute[1, e]), float(exposed[1, e])),
            PhaseBreakdown(float(compute[2, e]), float(exposed[2, e])),
            numer / float(mem_bw[e]), reps[e], float(mem_bw[e]),
            reps[e].fits_total))
    return out


def _time_compiled_pipeline(cw, envs, zero_stage, mem_bw_override,
                            require_fit, placement,
                            backend: str = "numpy"
                            ) -> List[IterationBreakdown]:
    import numpy as np
    wl = cw.workload
    pp = wl.pp
    m = max(1, wl.num_microbatches)
    v = max(1, getattr(wl, "virtual_stages", 1))
    nodes = [n for n, _ in envs]
    nenv = len(envs)
    reps0 = stage_footprints(wl, None, zero_stage)
    # worst_report picks the first maximal total; totals are
    # environment-independent, so the gating report row is too.
    k0 = max(range(pp), key=lambda s: reps0[s].total)
    fits_local = [all(r.total <= n.local_cap for r in reps0) for n in nodes]
    fits_total = [all(r.total <= n.total_cap for r in reps0) for n in nodes]
    mem_bws = np.stack([_compiled_mem_bws(nodes, r.total, mem_bw_override)
                        for r in reps0])                      # (pp, nenv)
    scale, bubble = _schedule_factors(wl.schedule, pp, m, v)
    data_ways = wl.dp * wl.ep
    computes, exposeds = [], []
    totals = np.zeros((pp, nenv))
    numers = np.zeros(pp)
    for s, stage in enumerate(cw.stages):
        compute, exposed = _stage_compute_exposed(stage, envs, nodes,
                                                  mem_bws[s], wl.mp, wl.dp,
                                                  pp, wl.ep, placement,
                                                  backend)
        computes.append(compute)
        exposeds.append(exposed)
        totals[s] = compute.sum(axis=0) + exposed.sum(axis=0)
        numers[s] = _optimizer_numer(stage.dense_w, stage.expert_w,
                                     stage.sparse, data_ways, wl.dp,
                                     zero_stage)
    gating = np.argmax(totals, axis=0)           # first max, like max(key=)
    optim = np.max(numers[:, None] / mem_bws, axis=0)
    out = []
    for e in range(nenv):
        rep = dataclasses.replace(reps0[k0], fits_local=fits_local[e],
                                  fits_total=fits_total[e])
        if require_fit and not fits_total[e]:
            out.append(_infeasible(rep, float(mem_bws[:, e].min()),
                                   bubble_fraction=bubble))
            continue
        k = int(gating[e])
        fp = PhaseBreakdown(float(computes[k][0, e]),
                            float(exposeds[k][0, e])).scaled(scale)
        ig = PhaseBreakdown(float(computes[k][1, e]),
                            float(exposeds[k][1, e])).scaled(scale)
        wg = PhaseBreakdown(float(computes[k][2, e]),
                            float(exposeds[k][2, e])).scaled(scale)
        out.append(IterationBreakdown(fp, ig, wg, float(optim[e]), rep,
                                      float(mem_bws[k, e]), fits_total[e],
                                      bubble_fraction=bubble))
    return out


def _time_compiled_assigned(
    cw,
    stage_envs: "List[Tuple[NodeConfig, Topology]]",
    zero_stage: int,
    mem_bw_override: "Optional[float | str]",
    require_fit: bool,
    placement=None,
) -> IterationBreakdown:
    """:func:`_simulate_pipeline` over a pre-lowered workload: the
    placement-assigned pipeline path (mixed fleet + ``pp > 1`` + a
    placement whose ``assign_stages`` maps stages to node groups), with
    each stage timed on *its own* (node, topology) environment through
    the compiled per-stage kernels instead of the reference event loop.

    Mirrors ``_simulate_pipeline`` clause for clause — per-stage
    footprints gated against the assigned node, per-stage memory
    bandwidths, gating stage ``k``, concurrent optimizer as a max over
    stages, schedule scaling — so the two agree within 1e-9 relative
    (tests/test_compiled.py)."""
    wl = cw.workload
    pp = wl.pp
    m = max(1, wl.num_microbatches)
    v = max(1, getattr(wl, "virtual_stages", 1))
    nodes = [node for node, _ in stage_envs]
    reps = stage_footprints(wl, None, zero_stage, nodes=nodes)
    worst_rep = worst_report(reps)
    mem_bws = [node.local_bw if mem_bw_override == "local"
               else mem_bw_override if mem_bw_override is not None
               else effective_memory_bw(node, r.total)
               for node, r in zip(nodes, reps)]
    feasible = worst_rep.fits_total
    scale, bubble = _schedule_factors(wl.schedule, pp, m, v)
    if require_fit and not feasible:
        return _infeasible(worst_rep, min(mem_bws), bubble_fraction=bubble)
    import numpy as np
    data_ways = wl.dp * wl.ep
    per_stage = []
    for stage, env, bw in zip(cw.stages, stage_envs, mem_bws):
        compute, exposed = _stage_compute_exposed(
            stage, [env], [env[0]], np.array([bw], dtype=float),
            wl.mp, wl.dp, pp, wl.ep, placement)
        fp = PhaseBreakdown(float(compute[0, 0]), float(exposed[0, 0]))
        ig = PhaseBreakdown(float(compute[1, 0]), float(exposed[1, 0]))
        wg = PhaseBreakdown(float(compute[2, 0]), float(exposed[2, 0]))
        per_stage.append((fp, ig, wg, fp.total + ig.total + wg.total))
    k = max(range(pp), key=lambda s: per_stage[s][3])
    fp, ig, wg, _ = per_stage[k]
    optim = max(_optimizer_numer(stage.dense_w, stage.expert_w, stage.sparse,
                                 data_ways, wl.dp, zero_stage) / bw
                for stage, bw in zip(cw.stages, mem_bws))
    return IterationBreakdown(fp.scaled(scale), ig.scaled(scale),
                              wg.scaled(scale), optim, worst_rep,
                              mem_bws[k], feasible,
                              bubble_fraction=bubble)


def time_compiled(
    cw,
    envs: "List[Tuple[NodeConfig, Topology]]",
    zero_stage: int = 2,
    mem_bw_override: "Optional[float | str]" = None,
    require_fit: bool = False,
    placement=None,
    backend: str = "numpy",
) -> List[IterationBreakdown]:
    """Time one :class:`~repro.core.compiled.CompiledWorkload` on a batch
    of (node, topology) environments at once.

    Semantically one :func:`_simulate_group` call per environment — same
    roofline, collective, timeline, optimizer and footprint models — but
    the per-environment work is NumPy array ops over the pre-lowered
    arrays, so a batch costs barely more than a single cell.  Results
    match the reference event loop within 1e-9 relative.
    ``backend="jax"`` runs the per-stage hot path as one jitted/vmapped
    device call (:mod:`repro.core.jax_engine`), NumPy-fallback when JAX
    is absent."""
    if not envs:
        return []
    if getattr(cw.workload, "pp", 1) > 1:
        return _time_compiled_pipeline(cw, envs, zero_stage, mem_bw_override,
                                       require_fit, placement, backend)
    return _time_compiled_flat(cw, envs, zero_stage, mem_bw_override,
                               require_fit, placement, backend)


def _env_breakdowns(cw, envs, zero_stage, mem_bw_override, require_fit,
                    placement, env_cache,
                    backend: str = "numpy") -> List[IterationBreakdown]:
    """Per-environment breakdowns through the optional cross-cell cache
    (key: placement x environment x require_fit; the study engine prefills
    it with one big batch per strategy group)."""
    if env_cache is None:
        return time_compiled(cw, envs, zero_stage, mem_bw_override,
                             require_fit, placement, backend)
    missing = [env for env in dict.fromkeys(envs)
               if (placement, env, require_fit) not in env_cache]
    if missing:
        for env, br in zip(missing,
                           time_compiled(cw, missing, zero_stage,
                                         mem_bw_override, require_fit,
                                         placement, backend)):
            env_cache[(placement, env, require_fit)] = br
    return [env_cache[(placement, env, require_fit)] for env in envs]


def compiled_stage_assignment(workload: Workload, cluster: ClusterLike,
                              placement, zero_stage: int = 2):
    """The per-stage (node, topology) environments a placement assigns,
    or None when replicate-everywhere semantics apply (single group, no
    placement, ``pp == 1``, or the placement declines the fleet).

    Mirrors the dispatch at the top of :func:`simulate_iteration`;
    shared with :func:`simulate_iteration_compiled` and the study
    engine's batch prefetch so the three cannot drift.  (Until PR 8 this
    path — mixed fleet + ``pp > 1`` + explicit placement — *delegated*
    to the reference event loop; it now runs compiled via
    :func:`_time_compiled_assigned`.)"""
    groups = cluster.node_groups
    if len(groups) <= 1 or placement is None \
            or getattr(workload, "pp", 1) <= 1:
        return None
    stage_bytes = [r.total for r in
                   stage_footprints(workload, None, zero_stage)]
    nodes_per_stage = workload.mp * workload.dp * workload.ep
    assign = placement.assign_stages(stage_bytes, groups, nodes_per_stage)
    if assign is None:
        return None
    return [(groups[i].node, groups[i].topology) for i in assign]


def simulate_iteration_compiled(
    cw,
    cluster: ClusterLike,
    zero_stage: int = 2,
    mem_bw_override: "Optional[float | str]" = None,
    require_fit: bool = False,
    placement=None,
    env_cache: "Optional[dict]" = None,
    backend: str = "numpy",
) -> IterationBreakdown:
    """:func:`simulate_iteration` over a pre-lowered workload.

    Single-group clusters and heterogeneous flat / replicate-everywhere
    cells run vectorized over the group environments; the
    placement-assigned pipeline path
    (:func:`compiled_stage_assignment` not None) runs each stage on its
    assigned environment through :func:`_time_compiled_assigned` — every
    cell is compiled, none delegates to the reference loop."""
    groups = cluster.node_groups
    wl = cw.workload
    stage_envs = compiled_stage_assignment(wl, cluster, placement,
                                           zero_stage)
    if stage_envs is not None:
        return _time_compiled_assigned(cw, stage_envs, zero_stage,
                                       mem_bw_override, require_fit,
                                       placement)
    per = _env_breakdowns(cw, [(g.node, g.topology) for g in groups],
                          zero_stage, mem_bw_override, require_fit,
                          placement, env_cache, backend)
    if len(per) == 1:
        return per[0]
    worst_rep = worst_report([b.footprint for b in per])
    feasible = all(b.feasible for b in per)
    if require_fit and not feasible:
        return _infeasible(worst_rep, min(b.mem_bw for b in per),
                           bubble_fraction=max(b.bubble_fraction
                                               for b in per))
    worst = max(per, key=lambda b: b.total)
    return IterationBreakdown(worst.fp, worst.ig, worst.wg, worst.optimizer,
                              worst_rep, worst.mem_bw, feasible,
                              bubble_fraction=worst.bubble_fraction)


def group_breakdowns_compiled(
    cw,
    cluster: ClusterLike,
    zero_stage: int = 2,
    mem_bw_override: "Optional[float | str]" = None,
    placement=None,
    env_cache: "Optional[dict]" = None,
    backend: str = "numpy",
) -> List[IterationBreakdown]:
    """:func:`group_breakdowns` over a pre-lowered workload (the
    multi-tenant ScheduleModel's per-group instance timings)."""
    return _env_breakdowns(cw, [(g.node, g.topology)
                                for g in cluster.node_groups],
                           zero_stage, mem_bw_override, False, placement,
                           env_cache, backend)
