"""COMET §III-B: parallelization-strategy sweeps (legacy surface).

The sweep engine now lives in :mod:`repro.core.study` — strategies are
:class:`~repro.core.study.ParallelSpec` points enumerated by pluggable
:class:`~repro.core.study.StrategySpace` implementations, and every sweep is
a :class:`~repro.core.study.StudySpec` run through
:func:`~repro.core.study.run_study`. This module keeps the seed API
(``power_of_two_strategies``, ``sweep_strategies``, ``best_strategy``,
``footprint_table``) as thin wrappers so existing callers and the paper's
Fig. 6/8 benchmarks keep working unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.cluster import ClusterLike
from repro.core.simulator import IterationBreakdown
from repro.core.study import (
    PowerOfTwoSpace,
    StudySpec,
    run_study,
)
from repro.core.workload import Workload, decompose


def power_of_two_strategies(num_nodes: int) -> List[Tuple[int, int]]:
    """All (MP, DP) with MP*DP = N, MP a power of two (paper sweep).

    Legacy tuple form of ``PowerOfTwoSpace().specs(num_nodes)``."""
    return [(s.mp, s.dp) for s in PowerOfTwoSpace().specs(num_nodes)]


@dataclasses.dataclass
class StrategyResult:
    mp: int
    dp: int
    breakdown: IterationBreakdown
    footprint_bytes: float

    @property
    def label(self) -> str:
        return f"MP{self.mp}_DP{self.dp}"

    @property
    def total(self) -> float:
        return self.breakdown.total


def sweep_strategies(
    cfg: ModelConfig,
    shape: ShapeConfig,
    cluster: ClusterLike,
    zero_stage: int = 2,
    mem_bw_override: Optional[float] = None,
    min_mp: int = 1,
    max_mp: Optional[int] = None,
    workload_fn: Optional[Callable[..., Workload]] = None,
) -> List[StrategyResult]:
    """Fig. 8 engine: simulate every (MP, DP) combination on the cluster.

    ``mem_bw_override`` reproduces §V-B1's 'infinite capacity at baseline
    bandwidth' assumption when set to the node's local bandwidth."""
    decomp = workload_fn or decompose
    spec = StudySpec(
        name="strategy-sweep", model=cfg, shape=shape, cluster=cluster,
        strategies=PowerOfTwoSpace(zero_stage=zero_stage, min_mp=min_mp,
                                   max_mp=max_mp),
        workload=lambda ctx: decomp(cfg, shape, mp=ctx.strategy.mp,
                                    dp=ctx.strategy.dp),
        mem_bw_override=mem_bw_override,
    )
    return [StrategyResult(c.strategy.mp, c.strategy.dp, c.breakdown,
                           c.footprint.total)
            for c in run_study(spec)]


def best_strategy(results: List[StrategyResult],
                  require_fit_bytes: Optional[float] = None) -> StrategyResult:
    """Fastest strategy; optionally restricted to those fitting a capacity."""
    pool = results
    if require_fit_bytes is not None:
        pool = [r for r in results if r.footprint_bytes <= require_fit_bytes]
        if not pool:
            raise ValueError("no strategy fits the given capacity")
    return min(pool, key=lambda r: r.total)


def footprint_table(
    cfg: ModelConfig,
    shape: ShapeConfig,
    num_nodes: int,
    zero_stages=(0, 1, 2, 3),
) -> Dict[str, Dict[int, float]]:
    """Fig. 6 engine: per-node model-state footprint vs MP degree x ZeRO."""
    from repro.core.memory import model_state_bytes

    table: Dict[str, Dict[int, float]] = {}
    for mp, dp in power_of_two_strategies(num_nodes):
        wl = decompose(cfg, shape, mp=mp, dp=dp)
        params = wl.total_weight_bytes() / 2
        table[f"MP{mp}_DP{dp}"] = {
            z: model_state_bytes(params, dp, z) for z in zero_stages}
    return table
