"""COMET §III-B: parallelization-strategy sweeps.

For a cluster of N nodes, sweep all power-of-two (MP, DP) with MP*DP = N,
decompose the workload per combination, and simulate (§III-C).  This is the
paper's Fig. 8 experiment engine; higher-level studies build on it (dse.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.cluster import ClusterConfig
from repro.core.memory import per_node_footprint
from repro.core.simulator import IterationBreakdown, simulate_iteration
from repro.core.workload import Workload, decompose


def power_of_two_strategies(num_nodes: int) -> List[tuple]:
    """All (MP, DP) with MP*DP = N, both powers of two (paper sweep)."""
    out = []
    mp = num_nodes
    while mp >= 1:
        out.append((mp, num_nodes // mp))
        mp //= 2
    return out


@dataclasses.dataclass
class StrategyResult:
    mp: int
    dp: int
    breakdown: IterationBreakdown
    footprint_bytes: float

    @property
    def label(self) -> str:
        return f"MP{self.mp}_DP{self.dp}"

    @property
    def total(self) -> float:
        return self.breakdown.total


def sweep_strategies(
    cfg: ModelConfig,
    shape: ShapeConfig,
    cluster: ClusterConfig,
    zero_stage: int = 2,
    mem_bw_override: Optional[float] = None,
    min_mp: int = 1,
    max_mp: Optional[int] = None,
    workload_fn: Optional[Callable[..., Workload]] = None,
) -> List[StrategyResult]:
    """Fig. 8 engine: simulate every (MP, DP) combination on the cluster.

    ``mem_bw_override`` reproduces §V-B1's 'infinite capacity at baseline
    bandwidth' assumption when set to the node's local bandwidth."""
    decomp = workload_fn or decompose
    results = []
    for mp, dp in power_of_two_strategies(cluster.num_nodes):
        if mp < min_mp or (max_mp is not None and mp > max_mp):
            continue
        wl = decomp(cfg, shape, mp=mp, dp=dp)
        br = simulate_iteration(wl, cluster, zero_stage=zero_stage,
                                mem_bw_override=mem_bw_override)
        fp = per_node_footprint(wl, cluster.node, zero_stage)
        results.append(StrategyResult(mp, dp, br, fp.total))
    return results


def best_strategy(results: List[StrategyResult],
                  require_fit_bytes: Optional[float] = None) -> StrategyResult:
    """Fastest strategy; optionally restricted to those fitting a capacity."""
    pool = results
    if require_fit_bytes is not None:
        pool = [r for r in results if r.footprint_bytes <= require_fit_bytes]
        if not pool:
            raise ValueError("no strategy fits the given capacity")
    return min(pool, key=lambda r: r.total)


def footprint_table(
    cfg: ModelConfig,
    shape: ShapeConfig,
    num_nodes: int,
    zero_stages=(0, 1, 2, 3),
) -> Dict[str, Dict[int, float]]:
    """Fig. 6 engine: per-node model-state footprint vs MP degree x ZeRO."""
    from repro.core.memory import model_state_bytes

    table: Dict[str, Dict[int, float]] = {}
    for mp, dp in power_of_two_strategies(num_nodes):
        wl = decompose(cfg, shape, mp=mp, dp=dp)
        params = wl.total_weight_bytes() / 2
        table[f"MP{mp}_DP{dp}"] = {
            z: model_state_bytes(params, dp, z) for z in zero_stages}
    return table
