"""Declarative Study API: one engine for every COMET case study.

COMET's methodology (§V) is a joint sweep over *parallelization strategies*
and *cluster resource knobs*; this module turns that into data instead of
per-figure functions:

  * :class:`ParallelSpec` — a strategy point generalizing the paper's
    (MP, DP) pairs to (MP, DP, PP, EP, ZeRO stage, microbatch count), all
    modeled natively by the default analytical workload builder;
  * :class:`StrategySpace` — pluggable strategy enumerators
    (:class:`PowerOfTwoSpace` reproduces the paper sweep,
    :class:`FactorizationSpace` adds non-power-of-two factorizations,
    :class:`GridSpace` takes the cartesian product over all five axes,
    :class:`ExplicitSpace` pins a hand-picked list);
  * :class:`Axis` — one swept cluster knob, addressed by a dotted path into
    the frozen config tree (``"node.exp_bw"``, ``"topology.intra_bw"``,
    ``"num_nodes"``) or by an arbitrary ``apply(cluster, value)`` transform;
  * :class:`StudySpec` — the study: base cluster + axes x strategies, an
    optional custom workload builder and derived metrics;
  * :func:`run_study` — the engine: enumerates cells, memoizes workload
    decompositions and :func:`simulate_iteration` calls, optionally fans
    cells out over processes, and returns a :class:`StudyResult` of tidy
    records with ``normalize``/``pivot``/``to_csv``/``to_json``.

``repro.core.dse`` expresses the paper's Fig. 8-13/15 case studies as
StudySpecs over this engine; see ``docs/study_api.md`` for a custom study.
"""

from __future__ import annotations

import csv
import dataclasses
import io
import itertools
import json
import math
import os
import warnings
from typing import (
    Any,
    Callable,
    Dict,
    Iterable,
    List,
    Optional,
    Sequence,
    Tuple,
    Union,
)

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.cluster import ClusterLike
from repro.core.memory import FootprintReport
from repro.core.placement import (
    JobSpec,
    Placement,
    PlacementLike,
    Schedule,
    ScheduleModel,
    get_placement,
)
from repro.core.simulator import (
    IterationBreakdown,
    PhaseBreakdown,
    group_breakdowns,
    simulate_iteration,
)
from repro.core.workload import InfeasibleStrategyError, Workload, decompose

GB = 1e9

DEFAULT_ZERO_STAGE = 2  # paper default (§IV-B): ZeRO-2 (os + g sharded)


# ===================================================================== #
# Strategy points and strategy spaces
# ===================================================================== #

@dataclasses.dataclass(frozen=True, order=True)
class ParallelSpec:
    """One parallelization-strategy point.

    Generalizes the paper's (MP, DP) pairs to the four-axis product
    (MP, DP, PP, EP) plus the ZeRO stage — all modeled natively by the
    default analytical ``decompose``.  ``num_microbatches`` sets the
    pipeline microbatch count (0 = auto: the shape's knob, else ``4 * pp``).
    """

    mp: int = 1
    dp: int = 1
    pp: int = 1
    ep: int = 1
    zero_stage: int = DEFAULT_ZERO_STAGE
    num_microbatches: int = 0          # 0 = auto (shape knob or 4 * pp)
    schedule: str = "1f1b"             # "gpipe" | "1f1b" | "interleaved"
    virtual_stages: int = 0            # 0 = auto (2 when interleaved)

    def __post_init__(self):
        for f in ("mp", "dp", "pp", "ep"):
            if getattr(self, f) < 1:
                raise ValueError(f"{f} must be >= 1, got {getattr(self, f)}")
        if not 0 <= self.zero_stage <= 3:
            raise ValueError(f"zero_stage must be 0..3, got {self.zero_stage}")
        if self.num_microbatches < 0:
            raise ValueError(
                f"num_microbatches must be >= 0, got {self.num_microbatches}")
        if self.schedule not in ("gpipe", "1f1b", "interleaved"):
            raise ValueError(f"schedule must be 'gpipe', '1f1b' or "
                             f"'interleaved', got {self.schedule!r}")
        if self.virtual_stages < 0:
            raise ValueError(
                f"virtual_stages must be >= 0, got {self.virtual_stages}")
        # Pipeline-only knobs normalize away off the pipeline so distinct
        # specs mean distinct physics (labels, memo keys, grid dedupe):
        # microbatches/schedule do nothing at pp == 1, virtual stages do
        # nothing off the interleaved schedule.
        if self.pp == 1:
            object.__setattr__(self, "num_microbatches", 0)
            object.__setattr__(self, "schedule", "1f1b")
        if self.schedule != "interleaved" and self.virtual_stages:
            object.__setattr__(self, "virtual_stages", 0)

    @property
    def num_nodes(self) -> int:
        return self.mp * self.dp * self.pp * self.ep

    @property
    def label(self) -> str:
        parts = [f"MP{self.mp}", f"DP{self.dp}"]
        if self.pp > 1:
            parts.append(f"PP{self.pp}")
        if self.ep > 1:
            parts.append(f"EP{self.ep}")
        if self.zero_stage != DEFAULT_ZERO_STAGE:
            parts.append(f"Z{self.zero_stage}")
        if self.num_microbatches:
            parts.append(f"MB{self.num_microbatches}")
        if self.schedule == "gpipe":
            parts.append("GPIPE")
        elif self.schedule == "interleaved":
            parts.append(f"INT{self.virtual_stages or 2}")
        return "_".join(parts)


class StrategySpace:
    """Enumerates the :class:`ParallelSpec` points to evaluate on a cluster."""

    def specs(self, num_nodes: int) -> List[ParallelSpec]:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PowerOfTwoSpace(StrategySpace):
    """The paper's sweep: all (MP, DP) with MP * DP = N, MP a power of two,
    MP descending (Fig. 8 ordering).

    ``pp`` / ``ep`` extend the sweep to the four-axis product: for every
    (pp, ep) pair dividing the cluster, MP powers of two enumerate over the
    remaining N / (pp * ep) nodes.  Defaults reproduce the paper sweep."""

    zero_stage: int = DEFAULT_ZERO_STAGE
    min_mp: int = 1
    max_mp: Optional[int] = None
    pp: Sequence[int] = (1,)
    ep: Sequence[int] = (1,)
    num_microbatches: int = 0

    def specs(self, num_nodes: int) -> List[ParallelSpec]:
        out = []
        for pp, ep in itertools.product(self.pp, self.ep):
            if num_nodes % (pp * ep):
                continue
            rem = num_nodes // (pp * ep)
            mp = rem
            while mp >= 1:
                if mp >= self.min_mp and (self.max_mp is None
                                          or mp <= self.max_mp):
                    out.append(ParallelSpec(
                        mp=mp, dp=rem // mp, pp=pp, ep=ep,
                        zero_stage=self.zero_stage,
                        num_microbatches=self.num_microbatches))
                mp //= 2
        return out


@dataclasses.dataclass(frozen=True)
class FactorizationSpace(StrategySpace):
    """All exact factorizations MP * DP = N (non-power-of-two included),
    MP descending — e.g. 12 nodes yields MP in (12, 6, 4, 3, 2, 1)."""

    zero_stage: int = DEFAULT_ZERO_STAGE
    min_mp: int = 1
    max_mp: Optional[int] = None

    def specs(self, num_nodes: int) -> List[ParallelSpec]:
        out = []
        for mp in range(num_nodes, 0, -1):
            if num_nodes % mp:
                continue
            if mp < self.min_mp or (self.max_mp is not None
                                    and mp > self.max_mp):
                continue
            out.append(ParallelSpec(mp=mp, dp=num_nodes // mp,
                                    zero_stage=self.zero_stage))
        return out


@dataclasses.dataclass(frozen=True)
class GridSpace(StrategySpace):
    """Cartesian product over (mp, dp, pp, ep, zero_stage, microbatch)
    value sets.

    With ``fill_cluster`` (default) only points whose total degree equals
    the cluster size survive — the paper's "use every node" constraint;
    switch it off to study partial-cluster placements."""

    mp: Sequence[int] = (1,)
    dp: Sequence[int] = (1,)
    pp: Sequence[int] = (1,)
    ep: Sequence[int] = (1,)
    zero_stages: Sequence[int] = (DEFAULT_ZERO_STAGE,)
    num_microbatches: Sequence[int] = (0,)
    schedules: Sequence[str] = ("1f1b",)
    virtual_stages: Sequence[int] = (0,)
    fill_cluster: bool = True

    def specs(self, num_nodes: int) -> List[ParallelSpec]:
        out = []
        seen = set()
        for mp, dp, pp, ep, z, mb, sched, v in itertools.product(
                self.mp, self.dp, self.pp, self.ep, self.zero_stages,
                self.num_microbatches, self.schedules, self.virtual_stages):
            s = ParallelSpec(mp=mp, dp=dp, pp=pp, ep=ep, zero_stage=z,
                             num_microbatches=mb, schedule=sched,
                             virtual_stages=v)
            if self.fill_cluster and s.num_nodes != num_nodes:
                continue
            if s in seen:   # pp=1 normalizes the pipeline knobs away
                continue
            seen.add(s)
            out.append(s)
        return out


@dataclasses.dataclass(frozen=True)
class ExplicitSpace(StrategySpace):
    """A fixed, ordered list of strategies (cluster size is not checked, so
    partial-cluster what-ifs are allowed)."""

    strategies: Tuple[ParallelSpec, ...]

    def specs(self, num_nodes: int) -> List[ParallelSpec]:
        return list(self.strategies)


StrategiesLike = Union[StrategySpace, ParallelSpec, Iterable, None]


def as_strategy_space(obj: StrategiesLike) -> Optional[StrategySpace]:
    """Coerce user input to a StrategySpace: a space passes through, a
    ParallelSpec or (mp, dp) tuple becomes a one-point ExplicitSpace, an
    iterable of either becomes an ExplicitSpace, None stays None."""
    if obj is None or isinstance(obj, StrategySpace):
        return obj
    if isinstance(obj, ParallelSpec):
        return ExplicitSpace((obj,))
    if isinstance(obj, tuple) and len(obj) == 2 \
            and all(isinstance(x, int) for x in obj):
        return ExplicitSpace((ParallelSpec(mp=obj[0], dp=obj[1]),))
    specs = []
    for item in obj:
        if isinstance(item, ParallelSpec):
            specs.append(item)
        else:
            mp, dp = item
            specs.append(ParallelSpec(mp=mp, dp=dp))
    return ExplicitSpace(tuple(specs))


# ===================================================================== #
# Dotted-path overrides over the frozen config tree
# ===================================================================== #

def get_by_path(obj: Any, path: str) -> Any:
    """Read ``obj.a.b.c`` given ``"a.b.c"``."""
    for part in path.split("."):
        obj = getattr(obj, part)
    return obj


def _check_field(obj: Any, head: str, path: str) -> None:
    """The field check ``set_by_path`` applies at each path segment."""
    if not dataclasses.is_dataclass(obj):
        raise TypeError(f"cannot override {path!r} on non-dataclass "
                        f"{type(obj).__name__}")
    if head not in {f.name for f in dataclasses.fields(obj)}:
        raise AttributeError(
            f"{type(obj).__name__} has no field {head!r} "
            f"(available: {sorted(f.name for f in dataclasses.fields(obj))})")


def check_path(obj: Any, path: str) -> None:
    """Walk a dotted path through nested dataclasses without mutating
    anything, raising exactly what :func:`set_by_path` would raise on a
    typo'd segment — lets StudySpec (and the S101 analysis rule) reject a
    bad ``Axis.path`` at construction instead of mid-run in a worker."""
    head, _, rest = path.partition(".")
    _check_field(obj, head, path)
    if rest:
        check_path(getattr(obj, head), rest)


def set_by_path(obj: Any, path: str, value: Any, scale: bool = False) -> Any:
    """Functionally update a nested frozen-dataclass field by dotted path.

    ``set_by_path(cluster, "node.exp_bw", 1e12)`` returns a new cluster;
    with ``scale=True`` the leaf is multiplied by ``value`` instead of
    replaced (the paper's "2x intra-pod bandwidth" style knob)."""
    head, _, rest = path.partition(".")
    _check_field(obj, head, path)
    if rest:
        new_child = set_by_path(getattr(obj, head), rest, value, scale)
        return dataclasses.replace(obj, **{head: new_child})
    leaf = getattr(obj, head) * value if scale else value
    return dataclasses.replace(obj, **{head: leaf})


@dataclasses.dataclass(frozen=True)
class Axis:
    """One swept knob: a name, its values, and how a value rewrites the
    cluster — a dotted ``path`` (optionally ``mode="scale"``) or a custom
    ``apply(cluster, value) -> cluster``. An axis with neither is a pure
    label axis (it only parameterizes the workload builder or metrics).

    ``kind="placement"`` sweeps the cell's
    :class:`~repro.core.placement.Placement` instead of the cluster: the
    values are placement names (``"paper"``, ``"em-aware"``) or Placement
    instances, and the record column holds the placement label.  The
    helper :func:`placement_axis` builds one."""

    name: str
    values: Sequence[Any]
    path: Optional[str] = None
    mode: str = "set"                                  # "set" | "scale"
    apply: Optional[Callable[[ClusterLike, Any], ClusterLike]] = None
    kind: str = "cluster"                              # "cluster" | "placement"

    def __post_init__(self):
        if self.mode not in ("set", "scale"):
            raise ValueError(f"mode must be 'set' or 'scale', got {self.mode!r}")
        if self.kind not in ("cluster", "placement"):
            raise ValueError(
                f"kind must be 'cluster' or 'placement', got {self.kind!r}")
        if self.path is not None and self.apply is not None:
            raise ValueError("give either path or apply, not both")
        if self.kind == "placement" and (self.path or self.apply):
            raise ValueError("a placement axis takes neither path nor apply")

    def override(self, cluster: ClusterLike, value: Any) -> ClusterLike:
        if self.kind == "placement" or self.apply is None and self.path is None:
            return cluster
        if self.apply is not None:
            return self.apply(cluster, value)
        return set_by_path(cluster, self.path, value,
                           scale=(self.mode == "scale"))


def placement_axis(values: Sequence[PlacementLike] = ("paper", "em-aware"),
                   name: str = "placement") -> Axis:
    """A sweepable placement axis; values are names from
    :func:`repro.core.placement.list_placements` or Placement instances."""
    return Axis(name, tuple(values), kind="placement")


_RELIABILITY_PREFIX = "reliability."


def is_reliability_axis(axis: Axis) -> bool:
    """True when the axis path rewrites the spec's FailureModel instead
    of the cluster (``reliability.*`` — mirrors the fleet's ``fleet.*``
    convention)."""
    return (axis.kind == "cluster" and axis.path is not None
            and axis.path.startswith(_RELIABILITY_PREFIX))


# ===================================================================== #
# Study specification
# ===================================================================== #

@dataclasses.dataclass
class StudyContext:
    """Everything a workload builder / metric / evaluator can see for one
    cell. ``workload``/``breakdown``/``footprint`` are populated as the
    engine progresses through the cell."""

    spec: "StudySpec"
    strategy: Optional[ParallelSpec]
    point: Dict[str, Any]                      # axis name -> swept value
    cluster: Optional[ClusterLike]             # None only in evaluate studies
    placement: Optional[Placement] = None
    workload: Optional[Workload] = None
    breakdown: Optional[IterationBreakdown] = None
    footprint: Optional[FootprintReport] = None
    schedule: Optional[Schedule] = None        # set when the spec has a job


@dataclasses.dataclass
class StudySpec:
    """A declarative COMET study: strategies x axes on a base cluster.

    ``workload`` (default: ``decompose(model, shape, mp, dp, pp, ep)`` —
    the full four-axis analytical decomposition) may read
    anything on the context; list the axis names it depends on in
    ``workload_deps`` so the engine's memoizer keys decompositions
    correctly. ``metrics`` adds derived record columns. ``evaluate``
    replaces the simulator entirely (for studies over measured frontends —
    see experiments/hillclimb_run.py).

    ``placement`` (a :class:`~repro.core.placement.Placement` or its
    registry name) fixes how cells map onto the cluster; a
    ``kind="placement"`` axis sweeps it per cell instead.  ``job`` (a
    :class:`~repro.core.placement.JobSpec`, or ``ctx -> JobSpec`` when it
    depends on the swept point) turns every cell multi-tenant: the engine
    schedules ``job.instances`` concurrent instances over the cluster's
    node groups through ``schedule_model`` (default
    :class:`~repro.core.placement.ScheduleModel`) and writes native
    ``concurrent_instances`` / ``waves`` / ``turnaround`` / ``makespan``
    record columns (the Fig. 13b / Fig. 15 metrics)."""

    name: str
    cluster: Optional[ClusterLike] = None
    model: Optional[ModelConfig] = None
    shape: Optional[ShapeConfig] = None
    axes: Sequence[Axis] = ()
    strategies: StrategiesLike = None
    workload: Optional[Callable[[StudyContext], Workload]] = None
    workload_deps: Sequence[str] = ()
    mem_bw_override: Union[float, str, None] = None    # float | "local" | None
    require_fit: bool = False
    placement: PlacementLike = None
    job: Union[JobSpec, Callable[[StudyContext], JobSpec], None] = None
    schedule_model: Optional[ScheduleModel] = None
    metrics: Dict[str, Callable[[StudyContext], Any]] = \
        dataclasses.field(default_factory=dict)
    evaluate: Optional[Callable[[StudyContext], Dict[str, Any]]] = None
    # A repro.reliability.FailureModel: every simulated cell then grows
    # the closed-form Young–Daly columns (ckpt_interval_s /
    # ckpt_overhead_frac / expected_restarts / goodput_frac and, with a
    # cost model, goodput_per_dollar).  ``reliability.*`` dotted-path
    # axes rewrite it per cell.  None (default) adds nothing — records
    # are bit-for-bit the pre-reliability output.
    reliability: Optional[Any] = None

    # Record columns the engine itself writes; an axis shadowing one would
    # silently corrupt select()/pivot()/best().  (A kind="placement" axis
    # *owns* the "placement" column, so it is exempt from the check.)
    RESERVED_COLUMNS = frozenset({
        "study", "strategy", "mp", "dp", "pp", "ep", "zero_stage",
        "num_microbatches", "schedule", "virtual_stages", "placement",
        "bubble_fraction", "infeasible_reason",
        "fp_compute", "fp_exposed_comm", "ig_compute", "ig_exposed_comm",
        "wg_compute", "wg_exposed_comm", "optimizer", "total",
        "feasible", "footprint_bytes", "mem_bw",
        "cost_usd", "energy_usd", "tco", "perf_per_dollar",
        "pareto_rank", "pareto_optimal",
        "search_round", "search_fidelity", "search_score",
        "concurrent_instances", "waves", "turnaround", "makespan",
        "ttft_p50", "ttft_p99", "tpot", "goodput", "goodput_per_dollar",
        "fleet_util", "turnaround_p50", "turnaround_p99", "preemptions",
        "resize_events", "burst_events", "jobs_completed", "n_events",
        "ckpt_interval_s", "ckpt_overhead_frac", "expected_restarts",
        "goodput_frac", "failures", "lost_work_frac",
    })

    def __post_init__(self):
        axis_names = [a.name for a in self.axes]
        if len(set(axis_names)) != len(axis_names):
            raise ValueError(f"duplicate axis names: {axis_names}")
        reserved = {a.name for a in self.axes
                    if not (a.kind == "placement" and a.name == "placement")} \
            & self.RESERVED_COLUMNS
        if reserved:
            raise ValueError(
                f"axis names shadow engine record columns: {sorted(reserved)}")
        unknown = set(self.workload_deps) - set(axis_names)
        if unknown:
            raise ValueError(f"workload_deps name unknown axes: {unknown}")
        if isinstance(self.mem_bw_override, str) \
                and self.mem_bw_override != "local":
            raise ValueError("mem_bw_override must be a float, None, "
                             "or the string 'local'")
        get_placement(self.placement)   # fail fast on unknown names
        # Fail fast on typo'd dotted paths too: resolve every path axis
        # against the base cluster's schema now, instead of erroring on the
        # first cell inside an imap_unordered worker.  An apply axis may
        # rewrite the cluster arbitrarily (even change its type), so paths
        # behind one can only be checked at run time.
        for axis in self.axes:
            if is_reliability_axis(axis):
                if self.reliability is None:
                    raise ValueError(
                        f"axis {axis.name!r} sweeps {axis.path!r} but the "
                        "study has no FailureModel — set "
                        "StudySpec.reliability")
                check_path(self.reliability,
                           (axis.path or "")[len(_RELIABILITY_PREFIX):])
        if self.cluster is not None:
            transformed = False
            for axis in self.axes:
                if axis.kind != "cluster" or is_reliability_axis(axis):
                    continue
                if axis.apply is not None:
                    transformed = True
                elif axis.path is not None and not transformed:
                    check_path(self.cluster, axis.path)


@dataclasses.dataclass
class CellResult:
    """One evaluated cell: its identity plus the raw model objects (for
    programmatic consumers) and the flat ``record`` (for tidy output)."""

    strategy: Optional[ParallelSpec]
    point: Dict[str, Any]
    cluster: Optional[ClusterLike]
    breakdown: Optional[IterationBreakdown]
    footprint: Optional[FootprintReport]
    record: Dict[str, Any]


# ===================================================================== #
# Engine
# ===================================================================== #

def _cells(spec: StudySpec) -> List[Tuple[Optional[ParallelSpec],
                                          Dict[str, Any], ClusterLike,
                                          Optional[Placement]]]:
    """Axis-product-major enumeration; strategies are resolved against each
    cell's *overridden* cluster so a cluster-valued axis (Fig. 15) gets the
    right per-cluster strategy list.  A ``kind="placement"`` axis rewrites
    the cell's placement instead of the cluster (the point keeps the
    placement's label so records stay tidy)."""
    space = as_strategy_space(spec.strategies)
    names = [a.name for a in spec.axes]
    out = []
    for combo in itertools.product(*(a.values for a in spec.axes)):
        point = dict(zip(names, combo))
        cluster = spec.cluster
        pl = get_placement(spec.placement)
        for axis, value in zip(spec.axes, combo):
            if axis.kind == "placement":
                pl = get_placement(value)
                point[axis.name] = pl.label if pl is not None else None
            elif is_reliability_axis(axis):
                pass   # folded into the FailureModel per cell (_eval_cell)
            else:
                cluster = axis.override(cluster, value)
        if cluster is None and spec.evaluate is None:
            raise ValueError(
                f"study {spec.name!r}: no cluster — set StudySpec.cluster "
                "or provide it via an axis apply() (only evaluate-based "
                "studies may run clusterless)")
        if space is None:
            out.append((None, point, cluster, pl))
        else:
            n = cluster.num_nodes if cluster is not None else 0
            for strategy in space.specs(n):
                out.append((strategy, point, cluster, pl))
    return out


def _default_workload(ctx: StudyContext) -> Workload:
    s = ctx.strategy or ParallelSpec()
    if ctx.spec.model is None or ctx.spec.shape is None:
        raise ValueError(f"study {ctx.spec.name!r}: set model+shape or "
                         "provide a workload builder")
    return decompose(ctx.spec.model, ctx.spec.shape, mp=s.mp, dp=s.dp,
                     pp=s.pp, ep=s.ep,
                     num_microbatches=s.num_microbatches or None,
                     schedule=s.schedule,
                     virtual_stages=s.virtual_stages or None)


def _workload_key(spec: StudySpec, strategy: Optional[ParallelSpec],
                  point: Dict[str, Any]) -> tuple:
    return (strategy,
            tuple((n, point[n]) for n in spec.workload_deps))


def _cost_columns(record: Dict[str, Any], cluster: ClusterLike) -> None:
    """Attach cost_usd / tco / perf_per_dollar when the cluster carries a
    CostModel.  perf_per_dollar is iterations-per-second per TCO dollar:
    1 / (iteration_time * tco) — the paper §V-D ranking metric.  Infeasible
    cells get 0.0 so ``best("perf_per_dollar", maximize=True)`` never
    recommends a strategy that does not fit in memory."""
    cost = getattr(cluster, "cost", None)
    if cost is None:
        return
    capex = cost.capex(cluster)
    record["cost_usd"] = capex
    energy = cost.energy_usd(cluster)
    record["energy_usd"] = energy
    tco = capex + energy
    record["tco"] = tco
    total = record.get("total")
    if record.get("feasible", True) and isinstance(total, (int, float)) \
            and total > 0 and tco > 0:
        record["perf_per_dollar"] = 1.0 / (total * tco)
    else:
        record["perf_per_dollar"] = 0.0


def _reliability_columns(spec: StudySpec, ctx: StudyContext,
                         record: Dict[str, Any]) -> None:
    """Attach the closed-form Young–Daly columns when the spec carries a
    FailureModel.  ``reliability.*`` axes fold into the model here (the
    cluster never sees them).  Infeasible cells get zeroed columns so
    ``best("goodput_per_dollar", maximize=True)`` never recommends a
    strategy that does not fit."""
    model = spec.reliability
    if model is None:
        return
    from repro.fleet.resize import instance_state_bytes
    from repro.reliability.model import reliability_columns
    for axis in spec.axes:
        if is_reliability_axis(axis):
            model = set_by_path(model,
                                (axis.path or "")[len(_RELIABILITY_PREFIX):],
                                ctx.point[axis.name],
                                scale=(axis.mode == "scale"))
    if not record.get("feasible", True) or ctx.workload is None:
        record.update(ckpt_interval_s=0.0, ckpt_overhead_frac=0.0,
                      expected_restarts=0.0, goodput_frac=0.0)
        if "perf_per_dollar" in record:
            record["goodput_per_dollar"] = 0.0
        return
    num_nodes = (ctx.strategy.num_nodes if ctx.strategy is not None
                 else ctx.cluster.num_nodes if ctx.cluster is not None
                 else 0)
    record.update(reliability_columns(
        model, instance_state_bytes(ctx.workload), num_nodes))
    if "perf_per_dollar" in record:
        # iterations of *useful* work per second per TCO dollar — the
        # failure-aware §V-D ranking metric.
        record["goodput_per_dollar"] = \
            record["goodput_frac"] * record["perf_per_dollar"]


_DEFAULT_SCHEDULER = ScheduleModel()


def _job_columns(spec: StudySpec, ctx: StudyContext,
                 record: Dict[str, Any], sim_memo: dict,
                 skey: tuple, group_sim=None) -> None:
    """Schedule ``spec.job``'s instances over the cell's node groups and
    attach the multi-tenant columns (Fig. 13b / Fig. 15 metrics).  The
    per-group breakdowns are memoized alongside the simulator calls (the
    same physics repeats across placement/job-only axis values).
    ``group_sim`` is the per-group evaluator — :func:`group_breakdowns`
    for the reference engine, its compiled twin otherwise."""
    job = spec.job(ctx) if callable(spec.job) else spec.job
    if job.nodes_per_instance == 0:
        if ctx.strategy is None:
            raise ValueError(
                f"study {spec.name!r}: JobSpec.nodes_per_instance is 0 and "
                "the study has no strategy to derive it from")
        job = dataclasses.replace(job,
                                  nodes_per_instance=ctx.strategy.num_nodes)
    if group_sim is None:
        group_sim = group_breakdowns
    gkey = ("groups",) + skey
    if gkey not in sim_memo:
        sim_memo[gkey] = group_sim(
            ctx.workload, ctx.cluster,
            zero_stage=(ctx.strategy.zero_stage
                        if ctx.strategy is not None else DEFAULT_ZERO_STAGE),
            mem_bw_override=spec.mem_bw_override,
            placement=ctx.placement)
    per = sim_memo[gkey]
    sched = (spec.schedule_model or _DEFAULT_SCHEDULER).schedule(
        job, ctx.cluster.node_groups, [b.total for b in per],
        fits=[b.feasible for b in per], placement=ctx.placement)
    ctx.schedule = sched
    record.update(concurrent_instances=sched.concurrent, waves=sched.waves,
                  turnaround=sched.turnaround, makespan=sched.makespan)
    # Multi-tenant semantics supersede the synchronous single-job gate:
    # the cell is feasible iff every *hosting* group fits its instances
    # (identical on a homogeneous fleet; on a mixed fleet an EM-aware
    # schedule confined to the EM pods is feasible even though the
    # replicate-everywhere gate is not).
    record["feasible"] = sched.feasible


def _eval_cell(spec: StudySpec, strategy: Optional[ParallelSpec],
               point: Dict[str, Any], cluster: ClusterLike,
               placement: Optional[Placement],
               wl_memo: dict, sim_memo: dict,
               simulate=None, group_sim=None) -> CellResult:
    # None -> the module-level reference evaluators, resolved at call time
    # so tests patching study.simulate_iteration keep intercepting them.
    if simulate is None:
        simulate = simulate_iteration
    if group_sim is None:
        group_sim = group_breakdowns
    ctx = StudyContext(spec=spec, strategy=strategy, point=dict(point),
                       cluster=cluster, placement=placement)
    base: Dict[str, Any] = {"study": spec.name}
    if strategy is not None:
        base.update(strategy=strategy.label, mp=strategy.mp, dp=strategy.dp,
                    pp=strategy.pp, ep=strategy.ep,
                    zero_stage=strategy.zero_stage,
                    num_microbatches=strategy.num_microbatches)
    if placement is not None and "placement" not in point:
        base["placement"] = placement.label
    base.update(point)

    if spec.evaluate is not None:
        record = {**base, **spec.evaluate(ctx)}
        if cluster is not None:
            _cost_columns(record, cluster)
        for mname, fn in spec.metrics.items():
            record[mname] = fn(ctx)
        return CellResult(strategy, ctx.point, cluster, None, None, record)

    wkey = _workload_key(spec, strategy, point)
    if wkey not in wl_memo:
        try:
            wl_memo[wkey] = (spec.workload or _default_workload)(ctx)
        except InfeasibleStrategyError as err:
            wl_memo[wkey] = err
    wl = wl_memo[wkey]
    if isinstance(wl, InfeasibleStrategyError):
        # A swept degree this model cannot realize (ep not dividing the
        # experts, pp past the layer count): an infeasible record, not an
        # aborted sweep.  Derives the standard column set from a zeroed
        # IterationBreakdown (one schema for both record shapes) plus every
        # custom metric column (NaN when the metric needs the absent
        # workload) so pivot()/normalize()/best() keep working on mixed
        # results.
        zeroed = IterationBreakdown(
            PhaseBreakdown(), PhaseBreakdown(), PhaseBreakdown(),
            0.0, None, 0.0, False).as_dict()
        record = {**base, **zeroed, "total": float("inf"),
                  "feasible": False, "footprint_bytes": float("inf"),
                  "mem_bw": 0.0, "bubble_fraction": 0.0,
                  "infeasible_reason": str(wl)}
        if spec.job is not None:
            record.update(concurrent_instances=0, waves=0,
                          turnaround=float("inf"), makespan=float("inf"))
        if cluster is not None:
            _cost_columns(record, cluster)
        _reliability_columns(spec, ctx, record)
        for mname, fn in spec.metrics.items():
            try:
                record[mname] = fn(ctx)
            except Exception:
                record[mname] = float("nan")
        return CellResult(strategy, ctx.point, cluster, None, None, record)
    ctx.workload = wl
    if strategy is not None and hasattr(ctx.workload, "num_microbatches"):
        # Surface the workload's *resolved* pipeline knobs (the strategy
        # may have asked for 0 = auto; pp == 1 resolves to 1).
        base["num_microbatches"] = ctx.workload.num_microbatches
        base["schedule"] = getattr(ctx.workload, "schedule",
                                   strategy.schedule)
        base["virtual_stages"] = getattr(ctx.workload, "virtual_stages",
                                         strategy.virtual_stages)

    # "local" resolves per node group inside the simulator, so it works on
    # heterogeneous ClusterSpecs too (each group's own node.local_bw).
    override = spec.mem_bw_override
    zero = strategy.zero_stage if strategy is not None else DEFAULT_ZERO_STAGE
    # The simulator never reads the CostModel, so strip it from the memo
    # key: a pure cost-axis sweep (path="cost.usd_per_gb_em") simulates
    # each physical configuration once, not once per price point.
    sim_cluster = cluster
    if dataclasses.is_dataclass(cluster) \
            and getattr(cluster, "cost", None) is not None:
        sim_cluster = dataclasses.replace(cluster, cost=None)
    skey = (wkey, sim_cluster, zero, override, spec.require_fit, placement)
    if skey not in sim_memo:
        sim_memo[skey] = simulate(
            ctx.workload, cluster, zero_stage=zero,
            mem_bw_override=override, require_fit=spec.require_fit,
            placement=placement)
    br = sim_memo[skey]
    ctx.breakdown = br
    ctx.footprint = br.footprint

    record = {**base, **br.as_dict(),
              "feasible": br.feasible,
              "footprint_bytes": br.footprint.total,
              "mem_bw": br.mem_bw,
              "bubble_fraction": br.bubble_fraction}
    if spec.job is not None:
        _job_columns(spec, ctx, record, sim_memo, skey, group_sim=group_sim)
    _cost_columns(record, cluster)
    _reliability_columns(spec, ctx, record)
    for mname, fn in spec.metrics.items():
        record[mname] = fn(ctx)
    return CellResult(strategy, ctx.point, cluster, br, br.footprint, record)


# --- engines ----------------------------------------------------------- #

ENGINES = ("reference", "compiled", "jax")


def _run_cells(spec: StudySpec, cells: List[tuple],
               engine: str) -> List[CellResult]:
    """Evaluate ``cells`` in order with fresh memo dicts.

    The memos live here — never in module globals — so an exception
    anywhere (a raising metric fn, an infeasible builder) cannot leave
    state behind that poisons a later run (serial or forked)."""
    wl_memo: dict = {}
    sim_memo: dict = {}
    if engine in ("compiled", "jax"):
        backend = "jax" if engine == "jax" else "numpy"
        return _run_cells_compiled(spec, cells, wl_memo, sim_memo,
                                   backend=backend)
    return [_eval_cell(spec, s, p, cl, pl, wl_memo, sim_memo)
            for s, p, cl, pl in cells]


def _run_cells_compiled(spec: StudySpec, cells: List[tuple],
                        wl_memo: dict, sim_memo: dict,
                        backend: str = "numpy") -> List[CellResult]:
    """Strategy-major compiled evaluation.

    Cells are grouped by workload key; each group resolves and lowers its
    decomposition exactly once (``Workload.compiled()``), prefetches every
    (placement, environment) this group's cells will need through *one*
    vectorized :func:`repro.core.simulator.time_compiled` batch, then
    assembles records through the same :func:`_eval_cell` path as the
    reference engine — only the simulate callables differ, so the record
    schema and every non-timing column are identical by construction."""
    from repro.core.simulator import (
        compiled_stage_assignment,
        group_breakdowns_compiled,
        simulate_iteration_compiled,
        time_compiled,
    )
    results: List[Optional[CellResult]] = [None] * len(cells)
    groups: Dict[tuple, List[int]] = {}
    for i, (s, p, _, _) in enumerate(cells):
        groups.setdefault(_workload_key(spec, s, p), []).append(i)
    for wkey, idxs in groups.items():
        s0, p0, cl0, pl0 = cells[idxs[0]]
        simulate = group_sim = None          # reference fallbacks
        if spec.evaluate is None:
            if wkey not in wl_memo:
                ctx0 = StudyContext(spec=spec, strategy=s0,
                                    point=dict(p0), cluster=cl0,
                                    placement=pl0)
                try:
                    wl_memo[wkey] = (spec.workload
                                     or _default_workload)(ctx0)
                except InfeasibleStrategyError as err:
                    wl_memo[wkey] = err
            wl = wl_memo[wkey]
            if not isinstance(wl, InfeasibleStrategyError):
                cw = wl.compiled()
                zero = (s0.zero_stage if s0 is not None
                        else DEFAULT_ZERO_STAGE)
                env_cache: dict = {}
                # Prefetch: one batched evaluation per (placement,
                # require_fit) over every environment the group's cells
                # touch.  Cells on the assigned-pipeline path (mixed
                # fleet + pp>1 + a placement that stages the fleet) skip
                # the prefetch: simulate_iteration_compiled times those
                # per-stage (_time_compiled_assigned), not per-group, so
                # they never read the env cache.
                want: Dict[tuple, List[tuple]] = {}
                for i in idxs:
                    _, _, cl, pl = cells[i]
                    if cl is None:
                        continue
                    if compiled_stage_assignment(wl, cl, pl,
                                                 zero) is not None:
                        continue
                    for g in cl.node_groups:
                        env = (g.node, g.topology)
                        want.setdefault((pl, spec.require_fit),
                                        []).append(env)
                        if spec.job is not None and spec.require_fit:
                            want.setdefault((pl, False), []).append(env)
                for (pl, rf), envs in want.items():
                    batch = [env for env in dict.fromkeys(envs)
                             if (pl, env, rf) not in env_cache]
                    for env, br in zip(batch,
                                       time_compiled(cw, batch, zero,
                                                     spec.mem_bw_override,
                                                     rf, pl, backend)):
                        env_cache[(pl, env, rf)] = br

                def simulate(workload, cluster, zero_stage=2,
                             mem_bw_override=None, require_fit=False,
                             placement=None, _cw=cw, _cache=env_cache):
                    return simulate_iteration_compiled(
                        _cw, cluster, zero_stage, mem_bw_override,
                        require_fit, placement, env_cache=_cache,
                        backend=backend)

                def group_sim(workload, cluster, zero_stage=2,
                              mem_bw_override=None, placement=None,
                              _cw=cw, _cache=env_cache):
                    return group_breakdowns_compiled(
                        _cw, cluster, zero_stage, mem_bw_override,
                        placement, env_cache=_cache, backend=backend)
        for i in idxs:
            s, p, cl, pl = cells[i]
            results[i] = _eval_cell(spec, s, p, cl, pl, wl_memo, sim_memo,
                                    simulate=simulate, group_sim=group_sim)
    return results


# --- optional process-parallel execution ------------------------------- #
# Cells are embarrassingly parallel (§V-E). Closures in specs don't pickle,
# so the spec travels to fork()ed workers via one module global and only
# chunk indices cross the pipe.  Dispatch is strategy-major: one chunk per
# workload key, so every strategy is decomposed (and compiled) exactly once
# process-wide — pool.map's default interleaving used to hand the same
# strategy to several workers and capped fig15 fork scaling at ~1.25x.
# Worker memos are plain locals inside _run_cells (nothing to poison if a
# chunk raises); _FORK_STATE is reset in a finally.
_FORK_STATE: Optional[tuple] = None     # (spec, cells, chunks, engine)


def _strategy_chunks(spec: StudySpec, cells: List[tuple],
                     processes: int) -> List[List[int]]:
    """Cell indices grouped by workload key.  When there are fewer groups
    than workers, the biggest groups split in half (each sub-chunk then
    re-decomposes once — still never per cell) until every worker has
    something to do."""
    groups: Dict[tuple, List[int]] = {}
    for i, (s, p, _, _) in enumerate(cells):
        groups.setdefault(_workload_key(spec, s, p), []).append(i)
    chunks = list(groups.values())
    while chunks and len(chunks) < processes:
        big = max(range(len(chunks)), key=lambda c: len(chunks[c]))
        if len(chunks[big]) <= 1:
            break
        mid = len(chunks[big]) // 2
        chunks.append(chunks[big][mid:])
        chunks[big] = chunks[big][:mid]
    return chunks


def _eval_chunk(ci: int) -> "Tuple[List[int], List[CellResult]]":
    spec, cells, chunks, engine = _FORK_STATE
    idxs = chunks[ci]
    return idxs, _run_cells(spec, [cells[i] for i in idxs], engine)


VALIDATE_MODES = ("off", "warn", "error")


def _validate_spec(spec: StudySpec, mode: str) -> None:
    """Static pre-flight (``repro.analysis``): S1xx rules on the spec plus
    K1xx rules on the base cluster.  Pure inspection — it never touches
    the cells or records, so results are identical across modes."""
    from repro.analysis import (AnalysisError, analyze_cluster,
                                analyze_study, format_report, has_errors)
    diags = analyze_study(spec)
    if spec.cluster is not None:
        diags += analyze_cluster(spec.cluster)
    if getattr(spec, "serving", None) is not None:
        from repro.analysis import analyze_serving
        diags += analyze_serving(spec.serving)
    if getattr(spec, "fleet", None) is not None:
        from repro.analysis import analyze_fleet
        diags += analyze_fleet(spec.fleet)
    fleet_failures = getattr(getattr(spec, "fleet", None), "failures", None)
    if getattr(spec, "reliability", None) is not None:
        from repro.analysis import analyze_reliability
        diags += analyze_reliability(spec)
    elif fleet_failures is not None and fleet_failures.enabled:
        from repro.analysis import analyze_reliability
        diags += analyze_reliability(spec.fleet)
    # Advisory (info) findings don't warrant interrupting a run; they stay
    # visible through the CLI and analyze_* helpers.
    diags = [d for d in diags if d.severity != "info"]
    if not diags:
        return
    if mode == "error" and has_errors(diags):
        raise AnalysisError(diags)
    warnings.warn(f"study {spec.name!r} pre-flight:\n{format_report(diags)}",
                  stacklevel=3)


def run_study(spec: StudySpec, processes: Optional[int] = None,
              engine: str = "compiled",
              validate: str = "warn") -> "StudyResult":
    """Evaluate every cell of ``spec``; memoizes workload decompositions
    (keyed by strategy + ``workload_deps``) and simulator calls (keyed by
    workload + overridden cluster + ZeRO stage + bandwidth override).

    ``engine`` selects the evaluator:

    * ``"compiled"`` (default) — each decomposition is lowered once to
      flat NumPy arrays (:mod:`repro.core.compiled`) and timed against
      whole batches of cluster cells in array ops
      (:func:`repro.core.simulator.time_compiled`).  Records match the
      reference within 1e-9 relative (tests/test_compiled.py) at a
      multiple of the throughput — see docs/perf.md.
    * ``"jax"`` — the compiled arrays are dispatched through the
      jit+vmap kernel in :mod:`repro.core.jax_engine` (scoped float64);
      identical records within 1e-9, fastest on large cross-products.
      Falls back to the NumPy compiled engine (with a one-time
      RuntimeWarning) when ``jax`` is not importable.
    * ``"reference"`` — the event-loop simulator, bit-for-bit the
      historical behavior; the escape hatch if a compiled record is ever
      in doubt.

    ``processes > 1`` fans cells out over a fork()-based process pool
    (POSIX only; falls back to serial elsewhere).  Dispatch is
    strategy-major: one chunk per workload key via ``imap_unordered``,
    results reassembled into cell order, so parallel and serial runs
    return identical records.

    ``validate`` gates a static pre-flight over the spec (S1xx rules) and
    its base cluster (K1xx rules) from :mod:`repro.analysis`: ``"warn"``
    (default) reports findings as a warning, ``"error"`` raises
    :class:`repro.analysis.AnalysisError` on error-severity findings,
    ``"off"`` skips the pass.  Validation only inspects — records are
    identical across all three modes.

    ``spec`` may also be anything with a ``to_study()`` lowering — a
    :class:`repro.serving.ServingSpec` runs here directly, with the V1xx
    serving rules joining the pre-flight."""
    if not isinstance(spec, StudySpec):
        to_study = getattr(spec, "to_study", None)
        if to_study is None:
            raise TypeError(
                f"run_study wants a StudySpec or an object with "
                f"to_study(); got {type(spec).__name__}")
        spec = to_study()
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    if validate not in VALIDATE_MODES:
        raise ValueError(f"validate must be one of {VALIDATE_MODES}, "
                         f"got {validate!r}")
    if validate != "off":
        _validate_spec(spec, validate)
    global _FORK_STATE
    cells = _cells(spec)
    if processes and processes > 1 and hasattr(os, "fork") \
            and _FORK_STATE is None:
        # The global makes the fork path non-reentrant; a nested or
        # concurrent parallel run_study falls back to serial instead of
        # clobbering the in-flight study's state.
        import multiprocessing
        chunks = _strategy_chunks(spec, cells, processes)
        # Workers beyond the chunk count or the core count only add fork
        # and scheduling overhead to a CPU-bound pool, so cap at both.
        workers = min(processes, len(chunks) or 1,
                      multiprocessing.cpu_count())
        _FORK_STATE = (spec, cells, chunks, engine)
        try:
            ctx = multiprocessing.get_context("fork")
            with ctx.Pool(processes=max(1, workers)) as pool:
                results: List[Optional[CellResult]] = [None] * len(cells)
                for idxs, rs in pool.imap_unordered(_eval_chunk,
                                                    range(len(chunks))):
                    for i, r in zip(idxs, rs):
                        results[i] = r
            return StudyResult(spec=spec, cells=results)
        finally:
            _FORK_STATE = None
    return StudyResult(spec=spec, cells=_run_cells(spec, cells, engine))


# ===================================================================== #
# Results
# ===================================================================== #

@dataclasses.dataclass
class StudyResult:
    """Tidy study output: one record per evaluated cell."""

    spec: StudySpec
    cells: List[CellResult]

    # -- container protocol -------------------------------------------- #
    def __len__(self) -> int:
        return len(self.cells)

    def __iter__(self):
        return iter(self.cells)

    @property
    def records(self) -> List[Dict[str, Any]]:
        return [c.record for c in self.cells]

    # -- selection / reduction ----------------------------------------- #
    def select(self, **where: Any) -> "StudyResult":
        """Cells whose record matches every ``column=value`` filter."""
        kept = [c for c in self.cells
                if all(c.record.get(k) == v for k, v in where.items())]
        return StudyResult(spec=self.spec, cells=kept)

    def column(self, name: str) -> List[Any]:
        return [c.record.get(name) for c in self.cells]

    def best(self, metric: str = "total",
             require_fit_bytes: Optional[float] = None,
             maximize: bool = False) -> CellResult:
        """Cell minimizing ``metric`` (or maximizing it, e.g. for
        ``perf_per_dollar``), optionally capacity-constrained.  Cells whose
        metric is missing or NaN (infeasible-strategy records) are
        skipped."""
        pool = [c for c in self.cells
                if not (c.record.get(metric) is None
                        or (isinstance(c.record.get(metric), float)
                            and math.isnan(c.record[metric])))]
        if require_fit_bytes is not None:
            pool = [c for c in pool
                    if c.record.get("footprint_bytes", 0) <= require_fit_bytes]
        if not pool:
            raise ValueError("no cell satisfies the constraint")
        pick = max if maximize else min
        return pick(pool, key=lambda c: c.record[metric])

    # -- derived columns ------------------------------------------------ #
    def normalize(self, metric: str = "total",
                  value: Optional[float] = None,
                  **where: Any) -> "StudyResult":
        """Add ``<metric>_norm`` = metric / baseline to every record.

        The baseline is ``value`` if given, else the ``metric`` of the
        single cell selected by the ``where`` filters."""
        if value is None:
            base_cells = self.select(**where).cells
            if len(base_cells) != 1:
                raise ValueError(
                    f"normalize baseline filter matched "
                    f"{len(base_cells)} cells, need exactly 1")
            value = base_cells[0].record[metric]
        for c in self.cells:
            c.record[f"{metric}_norm"] = c.record[metric] / value
        return self

    def pareto_front(self, objectives=None) -> "StudyResult":
        """Frontier cells over ``objectives`` (default: the paper's
        time/TCO/energy triple).  Annotates every record with
        ``pareto_rank`` / ``pareto_optimal`` in place — a thin delegate
        to :func:`repro.core.search.pareto_front`."""
        from repro.core import search
        return search.pareto_front(
            self, objectives if objectives is not None
            else search.DEFAULT_OBJECTIVES)

    # -- reshaping / export --------------------------------------------- #
    def pivot(self, index: str, columns: str,
              values: str = "total") -> Dict[Any, Dict[Any, Any]]:
        """records -> nested dict ``out[record[index]][record[columns]]``.

        Raises if (index, columns) does not uniquely identify a cell —
        ``select()`` the result down to a unique slice first."""
        out: Dict[Any, Dict[Any, Any]] = {}
        for c in self.cells:
            r = c.record
            row = out.setdefault(r[index], {})
            if r[columns] in row:
                raise ValueError(
                    f"pivot({index!r}, {columns!r}) is ambiguous: multiple "
                    f"cells at ({r[index]!r}, {r[columns]!r}) — select() a "
                    "unique slice before pivoting")
            row[r[columns]] = r[values]
        return out

    def _columns(self) -> List[str]:
        cols: List[str] = []
        for c in self.cells:
            for k in c.record:
                if k not in cols:
                    cols.append(k)
        return cols

    def to_csv(self, path: Optional[str] = None) -> str:
        buf = io.StringIO()
        cols = self._columns()
        w = csv.DictWriter(buf, fieldnames=cols)
        w.writeheader()
        for c in self.cells:
            w.writerow({k: c.record.get(k, "") for k in cols})
        text = buf.getvalue()
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text

    def to_json(self, path: Optional[str] = None) -> str:
        # inf/nan (infeasible-strategy records) are not valid JSON tokens;
        # serialize them as null so strict RFC 8259 parsers accept the file.
        records = [{k: (None if isinstance(v, float) and not math.isfinite(v)
                        else v) for k, v in r.items()}
                   for r in self.records]
        text = json.dumps({"study": self.spec.name, "records": records},
                          indent=1, default=str)
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text
