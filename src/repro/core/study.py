"""Declarative Study API: one engine for every COMET case study.

COMET's methodology (§V) is a joint sweep over *parallelization strategies*
and *cluster resource knobs*; this module turns that into data instead of
per-figure functions:

  * :class:`ParallelSpec` — a strategy point generalizing the paper's
    (MP, DP) pairs to (MP, DP, PP, EP, ZeRO stage, microbatch count), all
    modeled natively by the default analytical workload builder;
  * :class:`StrategySpace` — pluggable strategy enumerators
    (:class:`PowerOfTwoSpace` reproduces the paper sweep,
    :class:`FactorizationSpace` adds non-power-of-two factorizations,
    :class:`GridSpace` takes the cartesian product over all five axes,
    :class:`ExplicitSpace` pins a hand-picked list);
  * :class:`Axis` — one swept cluster knob, addressed by a dotted path into
    the frozen config tree (``"node.exp_bw"``, ``"topology.intra_bw"``,
    ``"num_nodes"``) or by an arbitrary ``apply(cluster, value)`` transform;
  * :class:`StudySpec` — the study: base cluster + axes x strategies, an
    optional custom workload builder and derived metrics;
  * :func:`run_study` — the engine: enumerates cells, memoizes workload
    decompositions and :func:`simulate_iteration` calls, optionally fans
    cells out over processes, and returns a :class:`StudyResult` of tidy
    records with ``normalize``/``pivot``/``to_csv``/``to_json``.

``repro.core.dse`` expresses the paper's Fig. 8-13/15 case studies as
StudySpecs over this engine; see ``docs/study_api.md`` for a custom study.
"""

from __future__ import annotations

import csv
import dataclasses
import io
import itertools
import json
import math
import os
from typing import (
    Any,
    Callable,
    Dict,
    Iterable,
    List,
    Optional,
    Sequence,
    Tuple,
    Union,
)

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.cluster import ClusterConfig, ClusterLike
from repro.core.memory import FootprintReport
from repro.core.placement import (
    JobSpec,
    Placement,
    PlacementLike,
    Schedule,
    ScheduleModel,
    get_placement,
)
from repro.core.simulator import (
    IterationBreakdown,
    PhaseBreakdown,
    group_breakdowns,
    simulate_iteration,
)
from repro.core.workload import InfeasibleStrategyError, Workload, decompose

GB = 1e9

DEFAULT_ZERO_STAGE = 2  # paper default (§IV-B): ZeRO-2 (os + g sharded)


# ===================================================================== #
# Strategy points and strategy spaces
# ===================================================================== #

@dataclasses.dataclass(frozen=True, order=True)
class ParallelSpec:
    """One parallelization-strategy point.

    Generalizes the paper's (MP, DP) pairs to the four-axis product
    (MP, DP, PP, EP) plus the ZeRO stage — all modeled natively by the
    default analytical ``decompose``.  ``num_microbatches`` sets the
    pipeline microbatch count (0 = auto: the shape's knob, else ``4 * pp``).
    """

    mp: int = 1
    dp: int = 1
    pp: int = 1
    ep: int = 1
    zero_stage: int = DEFAULT_ZERO_STAGE
    num_microbatches: int = 0          # 0 = auto (shape knob or 4 * pp)
    schedule: str = "1f1b"             # "gpipe" | "1f1b" | "interleaved"
    virtual_stages: int = 0            # 0 = auto (2 when interleaved)

    def __post_init__(self):
        for f in ("mp", "dp", "pp", "ep"):
            if getattr(self, f) < 1:
                raise ValueError(f"{f} must be >= 1, got {getattr(self, f)}")
        if not 0 <= self.zero_stage <= 3:
            raise ValueError(f"zero_stage must be 0..3, got {self.zero_stage}")
        if self.num_microbatches < 0:
            raise ValueError(
                f"num_microbatches must be >= 0, got {self.num_microbatches}")
        if self.schedule not in ("gpipe", "1f1b", "interleaved"):
            raise ValueError(f"schedule must be 'gpipe', '1f1b' or "
                             f"'interleaved', got {self.schedule!r}")
        if self.virtual_stages < 0:
            raise ValueError(
                f"virtual_stages must be >= 0, got {self.virtual_stages}")
        # Pipeline-only knobs normalize away off the pipeline so distinct
        # specs mean distinct physics (labels, memo keys, grid dedupe):
        # microbatches/schedule do nothing at pp == 1, virtual stages do
        # nothing off the interleaved schedule.
        if self.pp == 1:
            object.__setattr__(self, "num_microbatches", 0)
            object.__setattr__(self, "schedule", "1f1b")
        if self.schedule != "interleaved" and self.virtual_stages:
            object.__setattr__(self, "virtual_stages", 0)

    @property
    def num_nodes(self) -> int:
        return self.mp * self.dp * self.pp * self.ep

    @property
    def label(self) -> str:
        parts = [f"MP{self.mp}", f"DP{self.dp}"]
        if self.pp > 1:
            parts.append(f"PP{self.pp}")
        if self.ep > 1:
            parts.append(f"EP{self.ep}")
        if self.zero_stage != DEFAULT_ZERO_STAGE:
            parts.append(f"Z{self.zero_stage}")
        if self.num_microbatches:
            parts.append(f"MB{self.num_microbatches}")
        if self.schedule == "gpipe":
            parts.append("GPIPE")
        elif self.schedule == "interleaved":
            parts.append(f"INT{self.virtual_stages or 2}")
        return "_".join(parts)


class StrategySpace:
    """Enumerates the :class:`ParallelSpec` points to evaluate on a cluster."""

    def specs(self, num_nodes: int) -> List[ParallelSpec]:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PowerOfTwoSpace(StrategySpace):
    """The paper's sweep: all (MP, DP) with MP * DP = N, MP a power of two,
    MP descending (Fig. 8 ordering).

    ``pp`` / ``ep`` extend the sweep to the four-axis product: for every
    (pp, ep) pair dividing the cluster, MP powers of two enumerate over the
    remaining N / (pp * ep) nodes.  Defaults reproduce the paper sweep."""

    zero_stage: int = DEFAULT_ZERO_STAGE
    min_mp: int = 1
    max_mp: Optional[int] = None
    pp: Sequence[int] = (1,)
    ep: Sequence[int] = (1,)
    num_microbatches: int = 0

    def specs(self, num_nodes: int) -> List[ParallelSpec]:
        out = []
        for pp, ep in itertools.product(self.pp, self.ep):
            if num_nodes % (pp * ep):
                continue
            rem = num_nodes // (pp * ep)
            mp = rem
            while mp >= 1:
                if mp >= self.min_mp and (self.max_mp is None
                                          or mp <= self.max_mp):
                    out.append(ParallelSpec(
                        mp=mp, dp=rem // mp, pp=pp, ep=ep,
                        zero_stage=self.zero_stage,
                        num_microbatches=self.num_microbatches))
                mp //= 2
        return out


@dataclasses.dataclass(frozen=True)
class FactorizationSpace(StrategySpace):
    """All exact factorizations MP * DP = N (non-power-of-two included),
    MP descending — e.g. 12 nodes yields MP in (12, 6, 4, 3, 2, 1)."""

    zero_stage: int = DEFAULT_ZERO_STAGE
    min_mp: int = 1
    max_mp: Optional[int] = None

    def specs(self, num_nodes: int) -> List[ParallelSpec]:
        out = []
        for mp in range(num_nodes, 0, -1):
            if num_nodes % mp:
                continue
            if mp < self.min_mp or (self.max_mp is not None
                                    and mp > self.max_mp):
                continue
            out.append(ParallelSpec(mp=mp, dp=num_nodes // mp,
                                    zero_stage=self.zero_stage))
        return out


@dataclasses.dataclass(frozen=True)
class GridSpace(StrategySpace):
    """Cartesian product over (mp, dp, pp, ep, zero_stage, microbatch)
    value sets.

    With ``fill_cluster`` (default) only points whose total degree equals
    the cluster size survive — the paper's "use every node" constraint;
    switch it off to study partial-cluster placements."""

    mp: Sequence[int] = (1,)
    dp: Sequence[int] = (1,)
    pp: Sequence[int] = (1,)
    ep: Sequence[int] = (1,)
    zero_stages: Sequence[int] = (DEFAULT_ZERO_STAGE,)
    num_microbatches: Sequence[int] = (0,)
    schedules: Sequence[str] = ("1f1b",)
    virtual_stages: Sequence[int] = (0,)
    fill_cluster: bool = True

    def specs(self, num_nodes: int) -> List[ParallelSpec]:
        out = []
        seen = set()
        for mp, dp, pp, ep, z, mb, sched, v in itertools.product(
                self.mp, self.dp, self.pp, self.ep, self.zero_stages,
                self.num_microbatches, self.schedules, self.virtual_stages):
            s = ParallelSpec(mp=mp, dp=dp, pp=pp, ep=ep, zero_stage=z,
                             num_microbatches=mb, schedule=sched,
                             virtual_stages=v)
            if self.fill_cluster and s.num_nodes != num_nodes:
                continue
            if s in seen:   # pp=1 normalizes the pipeline knobs away
                continue
            seen.add(s)
            out.append(s)
        return out


@dataclasses.dataclass(frozen=True)
class ExplicitSpace(StrategySpace):
    """A fixed, ordered list of strategies (cluster size is not checked, so
    partial-cluster what-ifs are allowed)."""

    strategies: Tuple[ParallelSpec, ...]

    def specs(self, num_nodes: int) -> List[ParallelSpec]:
        return list(self.strategies)


StrategiesLike = Union[StrategySpace, ParallelSpec, Iterable, None]


def as_strategy_space(obj: StrategiesLike) -> Optional[StrategySpace]:
    """Coerce user input to a StrategySpace: a space passes through, a
    ParallelSpec or (mp, dp) tuple becomes a one-point ExplicitSpace, an
    iterable of either becomes an ExplicitSpace, None stays None."""
    if obj is None or isinstance(obj, StrategySpace):
        return obj
    if isinstance(obj, ParallelSpec):
        return ExplicitSpace((obj,))
    if isinstance(obj, tuple) and len(obj) == 2 \
            and all(isinstance(x, int) for x in obj):
        return ExplicitSpace((ParallelSpec(mp=obj[0], dp=obj[1]),))
    specs = []
    for item in obj:
        if isinstance(item, ParallelSpec):
            specs.append(item)
        else:
            mp, dp = item
            specs.append(ParallelSpec(mp=mp, dp=dp))
    return ExplicitSpace(tuple(specs))


# ===================================================================== #
# Dotted-path overrides over the frozen config tree
# ===================================================================== #

def get_by_path(obj: Any, path: str) -> Any:
    """Read ``obj.a.b.c`` given ``"a.b.c"``."""
    for part in path.split("."):
        obj = getattr(obj, part)
    return obj


def set_by_path(obj: Any, path: str, value: Any, scale: bool = False) -> Any:
    """Functionally update a nested frozen-dataclass field by dotted path.

    ``set_by_path(cluster, "node.exp_bw", 1e12)`` returns a new cluster;
    with ``scale=True`` the leaf is multiplied by ``value`` instead of
    replaced (the paper's "2x intra-pod bandwidth" style knob)."""
    head, _, rest = path.partition(".")
    if not dataclasses.is_dataclass(obj):
        raise TypeError(f"cannot override {path!r} on non-dataclass "
                        f"{type(obj).__name__}")
    if head not in {f.name for f in dataclasses.fields(obj)}:
        raise AttributeError(
            f"{type(obj).__name__} has no field {head!r} "
            f"(available: {sorted(f.name for f in dataclasses.fields(obj))})")
    if rest:
        new_child = set_by_path(getattr(obj, head), rest, value, scale)
        return dataclasses.replace(obj, **{head: new_child})
    leaf = getattr(obj, head) * value if scale else value
    return dataclasses.replace(obj, **{head: leaf})


@dataclasses.dataclass(frozen=True)
class Axis:
    """One swept knob: a name, its values, and how a value rewrites the
    cluster — a dotted ``path`` (optionally ``mode="scale"``) or a custom
    ``apply(cluster, value) -> cluster``. An axis with neither is a pure
    label axis (it only parameterizes the workload builder or metrics).

    ``kind="placement"`` sweeps the cell's
    :class:`~repro.core.placement.Placement` instead of the cluster: the
    values are placement names (``"paper"``, ``"em-aware"``) or Placement
    instances, and the record column holds the placement label.  The
    helper :func:`placement_axis` builds one."""

    name: str
    values: Sequence[Any]
    path: Optional[str] = None
    mode: str = "set"                                  # "set" | "scale"
    apply: Optional[Callable[[ClusterLike, Any], ClusterLike]] = None
    kind: str = "cluster"                              # "cluster" | "placement"

    def __post_init__(self):
        if self.mode not in ("set", "scale"):
            raise ValueError(f"mode must be 'set' or 'scale', got {self.mode!r}")
        if self.kind not in ("cluster", "placement"):
            raise ValueError(
                f"kind must be 'cluster' or 'placement', got {self.kind!r}")
        if self.path is not None and self.apply is not None:
            raise ValueError("give either path or apply, not both")
        if self.kind == "placement" and (self.path or self.apply):
            raise ValueError("a placement axis takes neither path nor apply")

    def override(self, cluster: ClusterLike, value: Any) -> ClusterLike:
        if self.kind == "placement" or self.apply is None and self.path is None:
            return cluster
        if self.apply is not None:
            return self.apply(cluster, value)
        return set_by_path(cluster, self.path, value,
                           scale=(self.mode == "scale"))


def placement_axis(values: Sequence[PlacementLike] = ("paper", "em-aware"),
                   name: str = "placement") -> Axis:
    """A sweepable placement axis; values are names from
    :func:`repro.core.placement.list_placements` or Placement instances."""
    return Axis(name, tuple(values), kind="placement")


# ===================================================================== #
# Study specification
# ===================================================================== #

@dataclasses.dataclass
class StudyContext:
    """Everything a workload builder / metric / evaluator can see for one
    cell. ``workload``/``breakdown``/``footprint`` are populated as the
    engine progresses through the cell."""

    spec: "StudySpec"
    strategy: Optional[ParallelSpec]
    point: Dict[str, Any]                      # axis name -> swept value
    cluster: Optional[ClusterLike]             # None only in evaluate studies
    placement: Optional[Placement] = None
    workload: Optional[Workload] = None
    breakdown: Optional[IterationBreakdown] = None
    footprint: Optional[FootprintReport] = None
    schedule: Optional[Schedule] = None        # set when the spec has a job


@dataclasses.dataclass
class StudySpec:
    """A declarative COMET study: strategies x axes on a base cluster.

    ``workload`` (default: ``decompose(model, shape, mp, dp, pp, ep)`` —
    the full four-axis analytical decomposition) may read
    anything on the context; list the axis names it depends on in
    ``workload_deps`` so the engine's memoizer keys decompositions
    correctly. ``metrics`` adds derived record columns. ``evaluate``
    replaces the simulator entirely (for studies over measured frontends —
    see experiments/hillclimb_run.py).

    ``placement`` (a :class:`~repro.core.placement.Placement` or its
    registry name) fixes how cells map onto the cluster; a
    ``kind="placement"`` axis sweeps it per cell instead.  ``job`` (a
    :class:`~repro.core.placement.JobSpec`, or ``ctx -> JobSpec`` when it
    depends on the swept point) turns every cell multi-tenant: the engine
    schedules ``job.instances`` concurrent instances over the cluster's
    node groups through ``schedule_model`` (default
    :class:`~repro.core.placement.ScheduleModel`) and writes native
    ``concurrent_instances`` / ``waves`` / ``turnaround`` / ``makespan``
    record columns (the Fig. 13b / Fig. 15 metrics)."""

    name: str
    cluster: Optional[ClusterLike] = None
    model: Optional[ModelConfig] = None
    shape: Optional[ShapeConfig] = None
    axes: Sequence[Axis] = ()
    strategies: StrategiesLike = None
    workload: Optional[Callable[[StudyContext], Workload]] = None
    workload_deps: Sequence[str] = ()
    mem_bw_override: Union[float, str, None] = None    # float | "local" | None
    require_fit: bool = False
    placement: PlacementLike = None
    job: Union[JobSpec, Callable[[StudyContext], JobSpec], None] = None
    schedule_model: Optional[ScheduleModel] = None
    metrics: Dict[str, Callable[[StudyContext], Any]] = \
        dataclasses.field(default_factory=dict)
    evaluate: Optional[Callable[[StudyContext], Dict[str, Any]]] = None

    # Record columns the engine itself writes; an axis shadowing one would
    # silently corrupt select()/pivot()/best().  (A kind="placement" axis
    # *owns* the "placement" column, so it is exempt from the check.)
    RESERVED_COLUMNS = frozenset({
        "study", "strategy", "mp", "dp", "pp", "ep", "zero_stage",
        "num_microbatches", "schedule", "virtual_stages", "placement",
        "bubble_fraction", "infeasible_reason",
        "fp_compute", "fp_exposed_comm", "ig_compute", "ig_exposed_comm",
        "wg_compute", "wg_exposed_comm", "optimizer", "total",
        "feasible", "footprint_bytes", "mem_bw",
        "cost_usd", "tco", "perf_per_dollar",
        "concurrent_instances", "waves", "turnaround", "makespan",
    })

    def __post_init__(self):
        axis_names = [a.name for a in self.axes]
        if len(set(axis_names)) != len(axis_names):
            raise ValueError(f"duplicate axis names: {axis_names}")
        reserved = {a.name for a in self.axes
                    if not (a.kind == "placement" and a.name == "placement")} \
            & self.RESERVED_COLUMNS
        if reserved:
            raise ValueError(
                f"axis names shadow engine record columns: {sorted(reserved)}")
        unknown = set(self.workload_deps) - set(axis_names)
        if unknown:
            raise ValueError(f"workload_deps name unknown axes: {unknown}")
        if isinstance(self.mem_bw_override, str) \
                and self.mem_bw_override != "local":
            raise ValueError("mem_bw_override must be a float, None, "
                             "or the string 'local'")
        get_placement(self.placement)   # fail fast on unknown names


@dataclasses.dataclass
class CellResult:
    """One evaluated cell: its identity plus the raw model objects (for
    programmatic consumers) and the flat ``record`` (for tidy output)."""

    strategy: Optional[ParallelSpec]
    point: Dict[str, Any]
    cluster: Optional[ClusterLike]
    breakdown: Optional[IterationBreakdown]
    footprint: Optional[FootprintReport]
    record: Dict[str, Any]


# ===================================================================== #
# Engine
# ===================================================================== #

def _cells(spec: StudySpec) -> List[Tuple[Optional[ParallelSpec],
                                          Dict[str, Any], ClusterLike,
                                          Optional[Placement]]]:
    """Axis-product-major enumeration; strategies are resolved against each
    cell's *overridden* cluster so a cluster-valued axis (Fig. 15) gets the
    right per-cluster strategy list.  A ``kind="placement"`` axis rewrites
    the cell's placement instead of the cluster (the point keeps the
    placement's label so records stay tidy)."""
    space = as_strategy_space(spec.strategies)
    names = [a.name for a in spec.axes]
    out = []
    for combo in itertools.product(*(a.values for a in spec.axes)):
        point = dict(zip(names, combo))
        cluster = spec.cluster
        pl = get_placement(spec.placement)
        for axis, value in zip(spec.axes, combo):
            if axis.kind == "placement":
                pl = get_placement(value)
                point[axis.name] = pl.label if pl is not None else None
            else:
                cluster = axis.override(cluster, value)
        if cluster is None and spec.evaluate is None:
            raise ValueError(
                f"study {spec.name!r}: no cluster — set StudySpec.cluster "
                "or provide it via an axis apply() (only evaluate-based "
                "studies may run clusterless)")
        if space is None:
            out.append((None, point, cluster, pl))
        else:
            n = cluster.num_nodes if cluster is not None else 0
            for strategy in space.specs(n):
                out.append((strategy, point, cluster, pl))
    return out


def _default_workload(ctx: StudyContext) -> Workload:
    s = ctx.strategy or ParallelSpec()
    if ctx.spec.model is None or ctx.spec.shape is None:
        raise ValueError(f"study {ctx.spec.name!r}: set model+shape or "
                         "provide a workload builder")
    return decompose(ctx.spec.model, ctx.spec.shape, mp=s.mp, dp=s.dp,
                     pp=s.pp, ep=s.ep,
                     num_microbatches=s.num_microbatches or None,
                     schedule=s.schedule,
                     virtual_stages=s.virtual_stages or None)


def _workload_key(spec: StudySpec, strategy: Optional[ParallelSpec],
                  point: Dict[str, Any]) -> tuple:
    return (strategy,
            tuple((n, point[n]) for n in spec.workload_deps))


def _cost_columns(record: Dict[str, Any], cluster: ClusterLike) -> None:
    """Attach cost_usd / tco / perf_per_dollar when the cluster carries a
    CostModel.  perf_per_dollar is iterations-per-second per TCO dollar:
    1 / (iteration_time * tco) — the paper §V-D ranking metric.  Infeasible
    cells get 0.0 so ``best("perf_per_dollar", maximize=True)`` never
    recommends a strategy that does not fit in memory."""
    cost = getattr(cluster, "cost", None)
    if cost is None:
        return
    capex = cost.capex(cluster)
    record["cost_usd"] = capex
    tco = capex + cost.energy_usd(cluster)
    record["tco"] = tco
    total = record.get("total")
    if record.get("feasible", True) and isinstance(total, (int, float)) \
            and total > 0 and tco > 0:
        record["perf_per_dollar"] = 1.0 / (total * tco)
    else:
        record["perf_per_dollar"] = 0.0


_DEFAULT_SCHEDULER = ScheduleModel()


def _job_columns(spec: StudySpec, ctx: StudyContext,
                 record: Dict[str, Any], sim_memo: dict,
                 skey: tuple) -> None:
    """Schedule ``spec.job``'s instances over the cell's node groups and
    attach the multi-tenant columns (Fig. 13b / Fig. 15 metrics).  The
    per-group breakdowns are memoized alongside the simulator calls (the
    same physics repeats across placement/job-only axis values)."""
    job = spec.job(ctx) if callable(spec.job) else spec.job
    if job.nodes_per_instance == 0:
        if ctx.strategy is None:
            raise ValueError(
                f"study {spec.name!r}: JobSpec.nodes_per_instance is 0 and "
                "the study has no strategy to derive it from")
        job = dataclasses.replace(job,
                                  nodes_per_instance=ctx.strategy.num_nodes)
    gkey = ("groups",) + skey
    if gkey not in sim_memo:
        sim_memo[gkey] = group_breakdowns(
            ctx.workload, ctx.cluster,
            zero_stage=(ctx.strategy.zero_stage
                        if ctx.strategy is not None else DEFAULT_ZERO_STAGE),
            mem_bw_override=spec.mem_bw_override,
            placement=ctx.placement)
    per = sim_memo[gkey]
    sched = (spec.schedule_model or _DEFAULT_SCHEDULER).schedule(
        job, ctx.cluster.node_groups, [b.total for b in per],
        fits=[b.feasible for b in per], placement=ctx.placement)
    ctx.schedule = sched
    record.update(concurrent_instances=sched.concurrent, waves=sched.waves,
                  turnaround=sched.turnaround, makespan=sched.makespan)
    # Multi-tenant semantics supersede the synchronous single-job gate:
    # the cell is feasible iff every *hosting* group fits its instances
    # (identical on a homogeneous fleet; on a mixed fleet an EM-aware
    # schedule confined to the EM pods is feasible even though the
    # replicate-everywhere gate is not).
    record["feasible"] = sched.feasible


def _eval_cell(spec: StudySpec, strategy: Optional[ParallelSpec],
               point: Dict[str, Any], cluster: ClusterLike,
               placement: Optional[Placement],
               wl_memo: dict, sim_memo: dict) -> CellResult:
    ctx = StudyContext(spec=spec, strategy=strategy, point=dict(point),
                       cluster=cluster, placement=placement)
    base: Dict[str, Any] = {"study": spec.name}
    if strategy is not None:
        base.update(strategy=strategy.label, mp=strategy.mp, dp=strategy.dp,
                    pp=strategy.pp, ep=strategy.ep,
                    zero_stage=strategy.zero_stage,
                    num_microbatches=strategy.num_microbatches)
    if placement is not None and "placement" not in point:
        base["placement"] = placement.label
    base.update(point)

    if spec.evaluate is not None:
        record = {**base, **spec.evaluate(ctx)}
        if cluster is not None:
            _cost_columns(record, cluster)
        for mname, fn in spec.metrics.items():
            record[mname] = fn(ctx)
        return CellResult(strategy, ctx.point, cluster, None, None, record)

    wkey = _workload_key(spec, strategy, point)
    if wkey not in wl_memo:
        try:
            wl_memo[wkey] = (spec.workload or _default_workload)(ctx)
        except InfeasibleStrategyError as err:
            wl_memo[wkey] = err
    wl = wl_memo[wkey]
    if isinstance(wl, InfeasibleStrategyError):
        # A swept degree this model cannot realize (ep not dividing the
        # experts, pp past the layer count): an infeasible record, not an
        # aborted sweep.  Derives the standard column set from a zeroed
        # IterationBreakdown (one schema for both record shapes) plus every
        # custom metric column (NaN when the metric needs the absent
        # workload) so pivot()/normalize()/best() keep working on mixed
        # results.
        zeroed = IterationBreakdown(
            PhaseBreakdown(), PhaseBreakdown(), PhaseBreakdown(),
            0.0, None, 0.0, False).as_dict()
        record = {**base, **zeroed, "total": float("inf"),
                  "feasible": False, "footprint_bytes": float("inf"),
                  "mem_bw": 0.0, "bubble_fraction": 0.0,
                  "infeasible_reason": str(wl)}
        if spec.job is not None:
            record.update(concurrent_instances=0, waves=0,
                          turnaround=float("inf"), makespan=float("inf"))
        if cluster is not None:
            _cost_columns(record, cluster)
        for mname, fn in spec.metrics.items():
            try:
                record[mname] = fn(ctx)
            except Exception:
                record[mname] = float("nan")
        return CellResult(strategy, ctx.point, cluster, None, None, record)
    ctx.workload = wl
    if strategy is not None and hasattr(ctx.workload, "num_microbatches"):
        # Surface the workload's *resolved* pipeline knobs (the strategy
        # may have asked for 0 = auto; pp == 1 resolves to 1).
        base["num_microbatches"] = ctx.workload.num_microbatches
        base["schedule"] = getattr(ctx.workload, "schedule",
                                   strategy.schedule)
        base["virtual_stages"] = getattr(ctx.workload, "virtual_stages",
                                         strategy.virtual_stages)

    # "local" resolves per node group inside the simulator, so it works on
    # heterogeneous ClusterSpecs too (each group's own node.local_bw).
    override = spec.mem_bw_override
    zero = strategy.zero_stage if strategy is not None else DEFAULT_ZERO_STAGE
    # The simulator never reads the CostModel, so strip it from the memo
    # key: a pure cost-axis sweep (path="cost.usd_per_gb_em") simulates
    # each physical configuration once, not once per price point.
    sim_cluster = cluster
    if dataclasses.is_dataclass(cluster) \
            and getattr(cluster, "cost", None) is not None:
        sim_cluster = dataclasses.replace(cluster, cost=None)
    skey = (wkey, sim_cluster, zero, override, spec.require_fit, placement)
    if skey not in sim_memo:
        sim_memo[skey] = simulate_iteration(
            ctx.workload, cluster, zero_stage=zero,
            mem_bw_override=override, require_fit=spec.require_fit,
            placement=placement)
    br = sim_memo[skey]
    ctx.breakdown = br
    ctx.footprint = br.footprint

    record = {**base, **br.as_dict(),
              "feasible": br.feasible,
              "footprint_bytes": br.footprint.total,
              "mem_bw": br.mem_bw,
              "bubble_fraction": br.bubble_fraction}
    if spec.job is not None:
        _job_columns(spec, ctx, record, sim_memo, skey)
    _cost_columns(record, cluster)
    for mname, fn in spec.metrics.items():
        record[mname] = fn(ctx)
    return CellResult(strategy, ctx.point, cluster, br, br.footprint, record)


# --- optional process-parallel execution ------------------------------- #
# Cells are embarrassingly parallel (§V-E). Closures in specs don't pickle,
# so the spec travels to fork()ed workers via this module global and only
# cell indices cross the pipe. The memo dicts are per-worker-process: each
# fork inherits them empty and fills its own copy, so a worker still
# decomposes each strategy once across the cells it is handed.
_FORK_SPEC: Optional[StudySpec] = None
_FORK_CELLS: List[tuple] = []
_FORK_WL_MEMO: dict = {}
_FORK_SIM_MEMO: dict = {}


def _eval_cell_by_index(i: int) -> CellResult:
    strategy, point, cluster, placement = _FORK_CELLS[i]
    return _eval_cell(_FORK_SPEC, strategy, point, cluster, placement,
                      _FORK_WL_MEMO, _FORK_SIM_MEMO)


def run_study(spec: StudySpec, processes: Optional[int] = None) -> "StudyResult":
    """Evaluate every cell of ``spec``; memoizes workload decompositions
    (keyed by strategy + ``workload_deps``) and simulator calls (keyed by
    workload + overridden cluster + ZeRO stage + bandwidth override).

    ``processes > 1`` fans cells out over a fork()-based process pool
    (POSIX only; falls back to serial elsewhere)."""
    global _FORK_SPEC, _FORK_CELLS
    cells = _cells(spec)
    if processes and processes > 1 and hasattr(os, "fork") \
            and _FORK_SPEC is None:
        # The globals make the fork path non-reentrant; a nested or
        # concurrent parallel run_study falls back to serial instead of
        # clobbering the in-flight study's state.
        import multiprocessing
        _FORK_SPEC, _FORK_CELLS = spec, cells
        _FORK_WL_MEMO.clear()
        _FORK_SIM_MEMO.clear()
        try:
            ctx = multiprocessing.get_context("fork")
            with ctx.Pool(processes=min(processes, len(cells) or 1)) as pool:
                results = pool.map(_eval_cell_by_index, range(len(cells)))
            return StudyResult(spec=spec, cells=results)
        finally:
            _FORK_SPEC, _FORK_CELLS = None, []
    wl_memo: dict = {}
    sim_memo: dict = {}
    results = [_eval_cell(spec, s, p, cl, pl, wl_memo, sim_memo)
               for s, p, cl, pl in cells]
    return StudyResult(spec=spec, cells=results)


# ===================================================================== #
# Results
# ===================================================================== #

@dataclasses.dataclass
class StudyResult:
    """Tidy study output: one record per evaluated cell."""

    spec: StudySpec
    cells: List[CellResult]

    # -- container protocol -------------------------------------------- #
    def __len__(self) -> int:
        return len(self.cells)

    def __iter__(self):
        return iter(self.cells)

    @property
    def records(self) -> List[Dict[str, Any]]:
        return [c.record for c in self.cells]

    # -- selection / reduction ----------------------------------------- #
    def select(self, **where: Any) -> "StudyResult":
        """Cells whose record matches every ``column=value`` filter."""
        kept = [c for c in self.cells
                if all(c.record.get(k) == v for k, v in where.items())]
        return StudyResult(spec=self.spec, cells=kept)

    def column(self, name: str) -> List[Any]:
        return [c.record.get(name) for c in self.cells]

    def best(self, metric: str = "total",
             require_fit_bytes: Optional[float] = None,
             maximize: bool = False) -> CellResult:
        """Cell minimizing ``metric`` (or maximizing it, e.g. for
        ``perf_per_dollar``), optionally capacity-constrained.  Cells whose
        metric is missing or NaN (infeasible-strategy records) are
        skipped."""
        pool = [c for c in self.cells
                if not (c.record.get(metric) is None
                        or (isinstance(c.record.get(metric), float)
                            and math.isnan(c.record[metric])))]
        if require_fit_bytes is not None:
            pool = [c for c in pool
                    if c.record.get("footprint_bytes", 0) <= require_fit_bytes]
        if not pool:
            raise ValueError("no cell satisfies the constraint")
        pick = max if maximize else min
        return pick(pool, key=lambda c: c.record[metric])

    # -- derived columns ------------------------------------------------ #
    def normalize(self, metric: str = "total",
                  value: Optional[float] = None,
                  **where: Any) -> "StudyResult":
        """Add ``<metric>_norm`` = metric / baseline to every record.

        The baseline is ``value`` if given, else the ``metric`` of the
        single cell selected by the ``where`` filters."""
        if value is None:
            base_cells = self.select(**where).cells
            if len(base_cells) != 1:
                raise ValueError(
                    f"normalize baseline filter matched "
                    f"{len(base_cells)} cells, need exactly 1")
            value = base_cells[0].record[metric]
        for c in self.cells:
            c.record[f"{metric}_norm"] = c.record[metric] / value
        return self

    # -- reshaping / export --------------------------------------------- #
    def pivot(self, index: str, columns: str,
              values: str = "total") -> Dict[Any, Dict[Any, Any]]:
        """records -> nested dict ``out[record[index]][record[columns]]``.

        Raises if (index, columns) does not uniquely identify a cell —
        ``select()`` the result down to a unique slice first."""
        out: Dict[Any, Dict[Any, Any]] = {}
        for c in self.cells:
            r = c.record
            row = out.setdefault(r[index], {})
            if r[columns] in row:
                raise ValueError(
                    f"pivot({index!r}, {columns!r}) is ambiguous: multiple "
                    f"cells at ({r[index]!r}, {r[columns]!r}) — select() a "
                    "unique slice before pivoting")
            row[r[columns]] = r[values]
        return out

    def _columns(self) -> List[str]:
        cols: List[str] = []
        for c in self.cells:
            for k in c.record:
                if k not in cols:
                    cols.append(k)
        return cols

    def to_csv(self, path: Optional[str] = None) -> str:
        buf = io.StringIO()
        cols = self._columns()
        w = csv.DictWriter(buf, fieldnames=cols)
        w.writeheader()
        for c in self.cells:
            w.writerow({k: c.record.get(k, "") for k in cols})
        text = buf.getvalue()
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text

    def to_json(self, path: Optional[str] = None) -> str:
        # inf/nan (infeasible-strategy records) are not valid JSON tokens;
        # serialize them as null so strict RFC 8259 parsers accept the file.
        records = [{k: (None if isinstance(v, float) and not math.isfinite(v)
                        else v) for k, v in r.items()}
                   for r in self.records]
        text = json.dumps({"study": self.spec.name, "records": records},
                          indent=1, default=str)
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text
