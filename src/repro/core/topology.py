"""Network topology protocol + the three COMET topology families.

COMET §III-C3 models collectives analytically per topology family (the
paper uses ASTRA-SIM's analytical backend with hierarchical bandwidth-aware
collectives [10], [58]).  This module makes the family set *pluggable*:
:class:`Topology` is a structural protocol — pod size, per-hop
bandwidth/latency (:attr:`Topology.hops`), functional updates
(``with_``/``scaled``), and the collective-time model itself
(:meth:`Topology.collective_time`) — that ``repro.core.collectives`` and
``repro.core.simulator`` consume through the interface.  Adding a new
fabric is one frozen dataclass implementing the protocol; no isinstance
ladder anywhere downstream needs to grow.

Rank placement defaults to the paper's order — MP groups fill consecutive
ranks (pods first), DP groups stride by MP — but is *pluggable*: every
``collective_time`` accepts an optional ``placement`` object (see
:mod:`repro.core.placement`) whose ``group_placement``/``p2p_crosses_pod``
resolve which hops a rank group crosses; ``None`` means the paper order.
All times are seconds for one collective of ``size`` bytes issued by every
member of the group (the usual symmetric-collective convention).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Protocol, Tuple, runtime_checkable

import numpy as np

# --------------------------------------------------------------------- #
# Ring / all-to-all primitives (shared by every topology family)
# --------------------------------------------------------------------- #


def ring_allreduce(size: float, n: int, bw: float, lat: float) -> float:
    """Logical-ring all-reduce: 2(n-1)/n * size / bw + 2(n-1) hops."""
    if n <= 1 or size <= 0:
        return 0.0
    return 2 * (n - 1) / n * size / bw + 2 * (n - 1) * lat


def ring_allgather(size: float, n: int, bw: float, lat: float) -> float:
    """All-gather / reduce-scatter: (n-1)/n * size / bw (one ring pass)."""
    if n <= 1 or size <= 0:
        return 0.0
    return (n - 1) / n * size / bw + (n - 1) * lat


def all_to_all(size: float, n: int, bw: float, lat: float) -> float:
    """All-to-all: each node sends size*(n-1)/n bytes through its link."""
    if n <= 1 or size <= 0:
        return 0.0
    return (n - 1) / n * size / bw + lat


def flat_time(collective: str, size: float, n: int, bw: float,
              lat: float) -> float:
    """One-level (flat) network: dispatch a collective to its ring form."""
    if collective == "all-reduce":
        return ring_allreduce(size, n, bw, lat)
    if collective in ("all-gather", "reduce-scatter"):
        return ring_allgather(size, n, bw, lat)
    if collective == "all-to-all":
        return all_to_all(size, n, bw, lat)
    if collective == "p2p":   # one point-to-point transfer (PP stage hop)
        return size / bw + lat if size > 0 else 0.0
    raise ValueError(f"unknown collective {collective!r}")


# --- batched variants (same formulas over a size *array*) -------------- #
# Consumed by the compiled study engine: one call times every event of a
# (collective, scope) group at once.  The arithmetic mirrors the scalar
# helpers term for term, so batch and scalar paths agree to float
# round-off (tests/test_compiled.py locks the 1e-9 envelope).

def ring_allreduce_batch(sizes: np.ndarray, n: int, bw: float,
                         lat: float) -> np.ndarray:
    if n <= 1:
        return np.zeros(np.shape(sizes))
    t = 2 * (n - 1) / n * sizes / bw + 2 * (n - 1) * lat
    return np.where(sizes > 0, t, 0.0)


def ring_allgather_batch(sizes: np.ndarray, n: int, bw: float,
                         lat: float) -> np.ndarray:
    if n <= 1:
        return np.zeros(np.shape(sizes))
    t = (n - 1) / n * sizes / bw + (n - 1) * lat
    return np.where(sizes > 0, t, 0.0)


def all_to_all_batch(sizes: np.ndarray, n: int, bw: float,
                     lat: float) -> np.ndarray:
    if n <= 1:
        return np.zeros(np.shape(sizes))
    t = (n - 1) / n * sizes / bw + lat
    return np.where(sizes > 0, t, 0.0)


def flat_time_batch(collective: str, sizes: np.ndarray, n: int, bw: float,
                    lat: float) -> np.ndarray:
    """Batched :func:`flat_time`: dispatch one (collective, scope) group."""
    if collective == "all-reduce":
        return ring_allreduce_batch(sizes, n, bw, lat)
    if collective in ("all-gather", "reduce-scatter"):
        return ring_allgather_batch(sizes, n, bw, lat)
    if collective == "all-to-all":
        return all_to_all_batch(sizes, n, bw, lat)
    if collective == "p2p":
        return np.where(sizes > 0, sizes / bw + lat, 0.0)
    raise ValueError(f"unknown collective {collective!r}")


def _group_size(scope: str, mp: int, dp: int, pp: int = 1, ep: int = 1) -> int:
    """Communication-group size for a scope under the four-axis product.

    ``"ep"`` with ep == 1 keeps the legacy mapping onto the MP group;
    ``"dp"`` spans the full DP x EP data group (EP ranks replicate dense
    weights); ``"edp"`` is the expert-gradient group (DP only)."""
    if scope == "mp":
        return mp
    if scope == "ep":
        return ep if ep > 1 else mp
    if scope == "pp":
        return pp
    if scope == "edp":
        return dp
    return dp * ep


# --------------------------------------------------------------------- #
# Rank placement
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class GroupPlacement:
    """How a communication group maps onto pods.

    intra: members co-located per pod; inter: number of pods spanned.
    group size = intra * inter.
    """

    intra: int
    inter: int


def _strided(group: int, stride: int, pod_size: int) -> GroupPlacement:
    """Placement of a group whose peers stride ``stride`` consecutive
    ranks apart (pods fill rank-major)."""
    if stride >= pod_size:
        return GroupPlacement(intra=1, inter=group)
    per_pod = max(1, pod_size // stride)
    per_pod = min(per_pod, group)
    return GroupPlacement(intra=per_pod, inter=max(1, group // per_pod))


@functools.lru_cache(maxsize=65536)
def placement(scope: str, mp: int, dp: int, pod_size: int,
              pp: int = 1, ep: int = 1) -> GroupPlacement:
    """Paper's placement, extended to the four-axis mesh: MP consecutive
    (fills pods first), then EP, then DP, with PP stages outermost.

    Memoized: hop resolution is re-requested by every ``collective_time``
    call (one per communication event per cell), but only ever depends on
    this small integer tuple — the cache turns the per-event cost into a
    dict probe.  ``GroupPlacement`` is frozen, so sharing is safe."""
    if scope == "mp" or (scope == "ep" and ep <= 1):
        # legacy: the EP group rode the MP group
        if mp <= pod_size:
            return GroupPlacement(intra=mp, inter=1)
        return GroupPlacement(intra=pod_size, inter=mp // pod_size)
    if scope == "ep":
        return _strided(ep, mp, pod_size)
    if scope == "pp":
        return _strided(pp, mp * ep * dp, pod_size)
    if scope == "edp":
        return _strided(dp, mp * ep, pod_size)
    # dp: the full DP x EP data group, peers stride by mp
    return _strided(dp * ep, mp, pod_size)


class _PaperOrder:
    """Default hop resolution: the module-level paper rank order.  Stands
    in whenever ``collective_time`` is called without a placement, so the
    families have exactly one code path."""

    @staticmethod
    def group_placement(scope: str, mp: int, dp: int, pod_size: int,
                        pp: int = 1, ep: int = 1) -> "GroupPlacement":
        return placement(scope, mp, dp, pod_size, pp, ep)

    @staticmethod
    def p2p_crosses_pod(mp: int, dp: int, pod_size: int,
                        pp: int = 1, ep: int = 1) -> bool:
        return mp * ep * dp * pp > pod_size


_PAPER_ORDER = _PaperOrder()


# --------------------------------------------------------------------- #
# The protocol
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class Hop:
    """One network level as seen by a node: per-node-per-direction
    bandwidth (bytes/s) and per-message latency (s)."""

    name: str
    bw: float
    latency: float


@runtime_checkable
class Topology(Protocol):
    """Structural interface every topology family implements.

    Consumers (``CollectiveModel``, the simulator, ``CostModel``) talk to
    this protocol only; concrete families are plain frozen dataclasses.
    """

    @property
    def pod_size(self) -> int: ...

    @property
    def hops(self) -> Tuple[Hop, ...]: ...

    @property
    def links_per_node(self) -> int: ...

    def collective_time(self, collective: str, size: float, scope: str,
                        mp: int, dp: int, pp: int = 1, ep: int = 1,
                        placement=None) -> float: ...

    # Families may additionally implement the batched form
    #   collective_time_batch(collective, sizes, scope, mp, dp, pp, ep,
    #                         placement) -> np.ndarray
    # (one (collective, scope) group, a whole size array at once).  It is
    # deliberately *not* part of the structural protocol: downstream
    # families that predate it keep passing isinstance checks, and the
    # compiled engine falls back to per-event scalar calls when absent.

    def with_(self, **updates): ...

    def scaled(self, **factors): ...


class TopologyBase:
    """Functional-update mixin shared by the concrete families."""

    def with_(self, **updates):
        """Return a copy with the named fields replaced."""
        return dataclasses.replace(self, **updates)

    def scaled(self, **factors):
        """Return a copy with each named field multiplied by its factor."""
        return dataclasses.replace(
            self, **{f: getattr(self, f) * v for f, v in factors.items()})


# --------------------------------------------------------------------- #
# Concrete families
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class HierarchicalSwitch(TopologyBase):
    """Two-level switch: fast intra-pod + slower inter-pod (Fig. 7)."""

    pod_size: int
    intra_bw: float                # per-node per-direction, bytes/s
    inter_bw: float
    intra_latency: float = 1e-6
    inter_latency: float = 5e-6

    def scaled(self, intra: float = 1.0, inter: float = 1.0) -> "HierarchicalSwitch":
        return dataclasses.replace(
            self, intra_bw=self.intra_bw * intra, inter_bw=self.inter_bw * inter)

    @property
    def hops(self) -> Tuple[Hop, ...]:
        return (Hop("intra", self.intra_bw, self.intra_latency),
                Hop("inter", self.inter_bw, self.inter_latency))

    @property
    def links_per_node(self) -> int:
        return 2                   # one intra-pod link + one inter-pod uplink

    def collective_time(self, collective: str, size: float, scope: str,
                        mp: int, dp: int, pp: int = 1, ep: int = 1,
                        placement=None) -> float:
        order = placement if placement is not None else _PAPER_ORDER
        if _group_size(scope, mp, dp, pp, ep) <= 1 or size <= 0:
            return 0.0
        if collective == "p2p":
            # Stage neighbours sit mp*ep*dp ranks apart.  Unless the whole
            # pp-stage mesh fits inside one pod, some stage boundary
            # crosses pods — and the simulator gates on the slowest stage,
            # so bill the inter-pod hop.
            if not order.p2p_crosses_pod(mp, dp, self.pod_size, pp, ep):
                return size / self.intra_bw + self.intra_latency
            return size / self.inter_bw + self.inter_latency
        pl = order.group_placement(scope, mp, dp, self.pod_size, pp, ep)
        p, q = pl.intra, pl.inter
        if q <= 1:  # fully intra-pod
            return flat_time(collective, size, p, self.intra_bw,
                             self.intra_latency)
        if p <= 1:  # fully inter-pod
            return flat_time(collective, size, q, self.inter_bw,
                             self.inter_latency)
        # Hierarchical collective [10],[58]: intra RS -> inter stage on
        # size/p -> intra AG.
        if collective == "all-reduce":
            t_intra = 2 * ring_allgather(size, p, self.intra_bw,
                                         self.intra_latency)
            t_inter = ring_allreduce(size / p, q, self.inter_bw,
                                     self.inter_latency)
            return t_intra + t_inter
        if collective in ("all-gather", "reduce-scatter"):
            t_intra = ring_allgather(size, p, self.intra_bw,
                                     self.intra_latency)
            t_inter = ring_allgather(size / p, q, self.inter_bw,
                                     self.inter_latency)
            return t_intra + t_inter
        if collective == "all-to-all":
            # Traffic share crossing pod boundaries vs. staying local.
            n = p * q
            inter_frac = (n - p) / n
            intra_frac = (p - 1) / n
            t_inter = inter_frac * size / self.inter_bw + self.inter_latency
            t_intra = intra_frac * size / self.intra_bw + self.intra_latency
            return max(t_inter, t_intra)
        raise ValueError(f"unknown collective {collective!r}")

    def collective_time_batch(self, collective: str, sizes: np.ndarray,
                              scope: str, mp: int, dp: int, pp: int = 1,
                              ep: int = 1, placement=None) -> np.ndarray:
        """Batched :meth:`collective_time`: same branches, a size array."""
        order = placement if placement is not None else _PAPER_ORDER
        sizes = np.asarray(sizes, dtype=float)
        if _group_size(scope, mp, dp, pp, ep) <= 1:
            return np.zeros(sizes.shape)
        if collective == "p2p":
            if not order.p2p_crosses_pod(mp, dp, self.pod_size, pp, ep):
                return np.where(sizes > 0,
                                sizes / self.intra_bw + self.intra_latency,
                                0.0)
            return np.where(sizes > 0,
                            sizes / self.inter_bw + self.inter_latency, 0.0)
        pl = order.group_placement(scope, mp, dp, self.pod_size, pp, ep)
        p, q = pl.intra, pl.inter
        if q <= 1:
            return flat_time_batch(collective, sizes, p, self.intra_bw,
                                   self.intra_latency)
        if p <= 1:
            return flat_time_batch(collective, sizes, q, self.inter_bw,
                                   self.inter_latency)
        if collective == "all-reduce":
            return 2 * ring_allgather_batch(sizes, p, self.intra_bw,
                                            self.intra_latency) \
                + ring_allreduce_batch(sizes / p, q, self.inter_bw,
                                       self.inter_latency)
        if collective in ("all-gather", "reduce-scatter"):
            return ring_allgather_batch(sizes, p, self.intra_bw,
                                        self.intra_latency) \
                + ring_allgather_batch(sizes / p, q, self.inter_bw,
                                       self.inter_latency)
        if collective == "all-to-all":
            n = p * q
            inter_frac = (n - p) / n
            intra_frac = (p - 1) / n
            t_inter = inter_frac * sizes / self.inter_bw + self.inter_latency
            t_intra = intra_frac * sizes / self.intra_bw + self.intra_latency
            return np.where(sizes > 0, np.maximum(t_inter, t_intra), 0.0)
        raise ValueError(f"unknown collective {collective!r}")


@dataclasses.dataclass(frozen=True)
class Torus(TopologyBase):
    """k-dimensional torus (TPU): per-direction link bandwidth per dim."""

    dims: Tuple[int, ...]
    link_bw: float
    latency: float = 1e-6
    # Optional DCN uplink for multi-pod torus clusters (v5e pods over DCN).
    dcn_bw: float = 0.0
    dcn_latency: float = 10e-6

    @property
    def pod_size(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def hops(self) -> Tuple[Hop, ...]:
        out = (Hop("link", self.link_bw, self.latency),)
        if self.dcn_bw:
            out += (Hop("dcn", self.dcn_bw, self.dcn_latency),)
        return out

    @property
    def links_per_node(self) -> int:
        return 2 * len(self.dims) + (1 if self.dcn_bw else 0)

    def collective_time(self, collective: str, size: float, scope: str,
                        mp: int, dp: int, pp: int = 1, ep: int = 1,
                        placement=None) -> float:
        order = placement if placement is not None else _PAPER_ORDER
        group = _group_size(scope, mp, dp, pp, ep)
        if group <= 1 or size <= 0:
            return 0.0
        if collective == "p2p":
            # One hop to the neighbouring stage; DCN when the pp-stage mesh
            # spills past one torus pod (worst boundary gates, as above).
            if self.dcn_bw and order.p2p_crosses_pod(mp, dp, self.pod_size,
                                                     pp, ep):
                return size / self.dcn_bw + self.dcn_latency
            return size / self.link_bw + self.latency
        return self._time(collective, size, group)

    def collective_time_batch(self, collective: str, sizes: np.ndarray,
                              scope: str, mp: int, dp: int, pp: int = 1,
                              ep: int = 1, placement=None) -> np.ndarray:
        """Batched :meth:`collective_time`: same branches, a size array."""
        order = placement if placement is not None else _PAPER_ORDER
        sizes = np.asarray(sizes, dtype=float)
        group = _group_size(scope, mp, dp, pp, ep)
        if group <= 1:
            return np.zeros(sizes.shape)
        if collective == "p2p":
            if self.dcn_bw and order.p2p_crosses_pod(mp, dp, self.pod_size,
                                                     pp, ep):
                t = sizes / self.dcn_bw + self.dcn_latency
            else:
                t = sizes / self.link_bw + self.latency
            return np.where(sizes > 0, t, 0.0)
        return self._time_batch(collective, sizes, group)

    def _time_batch(self, collective: str, sizes: np.ndarray,
                    group: int) -> np.ndarray:
        """Batched :meth:`_time`: the same per-dimension ring sweeps over a
        size array (every size-independent decision — dims, DCN spill — is
        identical across the batch)."""
        pod = self.pod_size
        bw = 2 * self.link_bw
        if self.dcn_bw and group > pod:
            q = math.ceil(group / pod)
            if collective == "all-reduce":
                t_in = self._time_batch("reduce-scatter", sizes, pod) \
                     + self._time_batch("all-gather", sizes, pod)
                t_out = ring_allreduce_batch(sizes / pod, q, self.dcn_bw,
                                             self.dcn_latency)
                return t_in + t_out
            t_in = self._time_batch(collective, sizes, pod)
            t_out = flat_time_batch(collective, sizes / pod, q, self.dcn_bw,
                                    self.dcn_latency)
            return t_in + t_out
        dims = []
        rem = min(group, pod)
        for d in self.dims:
            if rem <= 1:
                break
            use = min(d, rem)
            dims.append(use)
            rem = max(1, rem // use)
        if not dims:
            return np.zeros(sizes.shape)
        if collective == "all-reduce":
            t, s = np.zeros(sizes.shape), sizes
            for d in dims:
                t = t + ring_allgather_batch(s, d, bw, self.latency)
                s = s / d
            for d in reversed(dims):
                s = s * d
                t = t + ring_allgather_batch(s, d, bw, self.latency)
            return t
        if collective in ("all-gather", "reduce-scatter"):
            t, s = np.zeros(sizes.shape), sizes
            for d in dims:
                t = t + ring_allgather_batch(s, d, bw, self.latency)
                s = s / d
            return t
        if collective == "all-to-all":
            n = 1
            for d in dims:
                n *= d
            return all_to_all_batch(sizes, n, bw * len(dims), self.latency)
        raise ValueError(f"unknown collective {collective!r}")

    def _time(self, collective: str, size: float, group: int) -> float:
        """Multi-dimensional bucket algorithm: per-dimension ring stages.

        Bidirectional links -> ring uses both directions (2x link bw).
        Groups smaller than the full torus use as many dims as needed
        (mesh-axis-major placement)."""
        pod = self.pod_size
        bw = 2 * self.link_bw
        if self.dcn_bw and group > pod:
            # group spans pods over DCN: hierarchical (torus intra + DCN flat)
            q = math.ceil(group / pod)
            if collective == "all-reduce":
                t_in = self._time("reduce-scatter", size, pod) \
                     + self._time("all-gather", size, pod)
                t_out = ring_allreduce(size / pod, q, self.dcn_bw,
                                       self.dcn_latency)
                return t_in + t_out
            t_in = self._time(collective, size, pod)
            t_out = flat_time(collective, size / pod, q, self.dcn_bw,
                              self.dcn_latency)
            return t_in + t_out
        # Decompose the group across torus dims (row-major).
        dims = []
        rem = min(group, pod)
        for d in self.dims:
            if rem <= 1:
                break
            use = min(d, rem)
            dims.append(use)
            rem = max(1, rem // use)
        if not dims:
            return 0.0
        if collective == "all-reduce":
            t, s = 0.0, size
            for d in dims:  # reduce-scatter sweep
                t += ring_allgather(s, d, bw, self.latency)
                s /= d
            for d in reversed(dims):  # all-gather sweep
                s *= d
                t += ring_allgather(s, d, bw, self.latency)
            return t
        if collective in ("all-gather", "reduce-scatter"):
            t, s = 0.0, size
            for d in dims:
                t += ring_allgather(s, d, bw, self.latency)
                s /= d
            return t
        if collective == "all-to-all":
            n = 1
            for d in dims:
                n *= d
            return all_to_all(size, n, bw * len(dims), self.latency)
        raise ValueError(f"unknown collective {collective!r}")


@dataclasses.dataclass(frozen=True)
class SingleSwitch(TopologyBase):
    """One logical switch delivering ``bw`` per node (Dojo model)."""

    bw: float
    latency: float = 1e-6

    @property
    def pod_size(self) -> int:  # flat network: one "pod"
        return 1 << 30

    @property
    def hops(self) -> Tuple[Hop, ...]:
        return (Hop("switch", self.bw, self.latency),)

    @property
    def links_per_node(self) -> int:
        return 1

    def collective_time(self, collective: str, size: float, scope: str,
                        mp: int, dp: int, pp: int = 1, ep: int = 1,
                        placement=None) -> float:
        group = _group_size(scope, mp, dp, pp, ep)
        if group <= 1 or size <= 0:
            return 0.0
        return flat_time(collective, size, group, self.bw, self.latency)

    def collective_time_batch(self, collective: str, sizes: np.ndarray,
                              scope: str, mp: int, dp: int, pp: int = 1,
                              ep: int = 1, placement=None) -> np.ndarray:
        """Batched :meth:`collective_time`: flat network, a size array."""
        sizes = np.asarray(sizes, dtype=float)
        group = _group_size(scope, mp, dp, pp, ep)
        if group <= 1:
            return np.zeros(sizes.shape)
        return flat_time_batch(collective, sizes, group, self.bw,
                               self.latency)
