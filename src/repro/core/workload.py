"""COMET §III-A / §IV-A: model -> per-layer GEMM decomposition.

``decompose(cfg, shape, mp, dp, pp, ep)`` turns a
:class:`repro.configs.ModelConfig` into a :class:`Workload`: an ordered list
of :class:`LayerSpec`, each holding

  * the per-node forward GEMMs / explicit ops (already sharded for the given
    MP degree, with the per-replica batch ``global_batch / (dp * ep)``),
  * the derived input-gradient (IG) and weight-gradient (WG) ops,
  * the communication events per phase (blocking MP collectives in FP/IG,
    non-blocking DP collectives in WG — paper §III-C3),
  * per-node weight bytes and output-activation bytes (footprint model input).

The transformer decomposition follows the paper's Table II (Megatron-style
MP: column-parallel QKV/FFN-in, row-parallel proj/FFN-out, vocab-parallel
embeddings); the additional families (MoE/EP, SSD, hybrid, enc-dec, VLM)
extend the same scheme — each is documented inline.

Four-axis strategies (Megatron-LM / GSPMD style):

  * **PP** — ``pp > 1`` partitions the layer stack into ``pp`` contiguous
    stages balanced by FLOPs (``LayerSpec.stage``), with blocking
    point-to-point activation transfers (``CommEvent("p2p", ..., "pp")``) at
    every stage boundary.  The microbatch count rides on the Workload
    (``num_microbatches``, default ``4 * pp`` capped at the per-replica
    batch) and drives the simulator's GPipe/1F1B bubble accounting.
  * **EP** — ``ep > 1`` shards MoE experts over a dedicated EP mesh axis
    (all-to-all dispatch/combine over scope ``"ep"`` instead of the legacy
    MP-group approximation); non-expert layers treat the EP group as extra
    data parallelism (per-replica batch divides by ``dp * ep``, dense
    gradients all-reduce across it, expert gradients across DP only).

``pp=1, ep=1`` is bit-for-bit the pre-PP/EP decomposition
(tests/test_decompose_golden.py locks this down).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.gemm import CommEvent, ExplicitOp, Gemm, PhaseCost, phase_cost

Op = Union[Gemm, ExplicitOp]

BYTES = 2  # bf16/fp16 operands throughout (paper assumes fp16 activations)


class InfeasibleStrategyError(ValueError):
    """Strategy degrees incompatible with this model — e.g. ``ep`` not
    dividing ``num_experts``, or ``pp`` exceeding the layer count.  The
    study engine turns this into an infeasible record instead of aborting
    the sweep."""


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass
class LayerSpec:
    """One model layer on one node, for one (MP, DP, PP, EP) strategy."""

    name: str
    fwd: List[Op] = dataclasses.field(default_factory=list)
    ig: List[Op] = dataclasses.field(default_factory=list)
    wg: List[Op] = dataclasses.field(default_factory=list)
    comm_fwd: List[CommEvent] = dataclasses.field(default_factory=list)
    comm_ig: List[CommEvent] = dataclasses.field(default_factory=list)
    comm_wg: List[CommEvent] = dataclasses.field(default_factory=list)
    weight_bytes: int = 0          # per-node fp16 weight bytes
    act_out_bytes: int = 0         # per-node output activation bytes
    repeat: int = 1                # layer-stack multiplier
    # Optimizer-update traffic override (bytes). None -> dense Adam accounting
    # (28 B/param on the ZeRO-sharded slice). Sparse layers (embedding bags)
    # set this to the touched-rows traffic instead.
    optim_bytes: Optional[int] = None
    stage: int = 0                 # pipeline stage owning this layer
    # Portion of weight_bytes that is expert-sharded over the EP axis: its
    # gradients all-reduce across DP only ("edp" scope), while the dense
    # remainder syncs across the full DP x EP data group.
    expert_bytes: int = 0

    def add_gemm(self, g: Gemm, has_weight: bool = True) -> None:
        self.fwd.append(g)
        if has_weight:
            self.ig.append(g.transposed_for_ig())
            self.wg.append(g.transposed_for_wg())
            self.weight_bytes += g.k * g.n * g.bytes_per_element
        else:
            # No weights: both gradient GEMMs belong to the IG phase.
            self.ig.append(g.transposed_for_ig())
            self.ig.append(g.transposed_for_wg())

    def phase_cost(self, phase: str, sram_bytes: int) -> PhaseCost:
        ops = {"fp": self.fwd, "ig": self.ig, "wg": self.wg}[phase]
        total = PhaseCost()
        for op in ops:
            total = total + phase_cost(op, sram_bytes)
        return total

    def comm(self, phase: str) -> List[CommEvent]:
        return {"fp": self.comm_fwd, "ig": self.comm_ig, "wg": self.comm_wg}[phase]


@dataclasses.dataclass
class Workload:
    """Ordered per-node layer list + aggregate footprint inputs.

    With ``pp > 1`` the list covers *every* stage (``LayerSpec.stage`` says
    which node group owns a layer; ``stage_layers()`` splits them), so the
    ``total_*`` aggregates describe the whole pipeline's share of one
    replica, not a single node — per-stage views live in
    ``repro.core.memory.stage_footprints``.
    """

    name: str
    layers: List[LayerSpec]
    mp: int
    dp: int
    per_replica_batch: int
    seq_len: int
    pp: int = 1
    ep: int = 1
    num_microbatches: int = 1      # pipeline microbatches (1 when pp == 1)
    schedule: str = "1f1b"         # "gpipe" | "1f1b" | "interleaved"
    virtual_stages: int = 1        # v chunks per node (interleaved only)

    # ------------------------------------------------------------------ #
    def compiled(self):
        """The lowered form of this workload (flat NumPy op/event arrays,
        :class:`repro.core.compiled.CompiledWorkload`), built on first use
        and memoized on the instance — the strategy-dependent half of a
        study cell's cost, paid once per decomposition no matter how many
        cluster cells it is timed against.  The layer list must not be
        mutated after the first call."""
        cw = getattr(self, "_compiled_cache", None)
        if cw is None:
            from repro.core.compiled import compile_workload
            cw = compile_workload(self)
            object.__setattr__(self, "_compiled_cache", cw)
        return cw

    # ------------------------------------------------------------------ #
    def stage_layers(self) -> List[List[LayerSpec]]:
        """Layers grouped by pipeline stage (one group when pp == 1)."""
        if self.pp <= 1:
            return [list(self.layers)]
        out: List[List[LayerSpec]] = [[] for _ in range(self.pp)]
        for ly in self.layers:
            out[ly.stage].append(ly)
        return out

    def comm_events(self):
        """Iterate ``(layer_index, layer, phase, event)`` over every
        communication event, in layer order — ``phase`` is ``"fp"`` /
        ``"ig"`` / ``"wg"``.  The traversal the static analyzer
        (:mod:`repro.analysis`) and the compiled lowering agree on."""
        for i, layer in enumerate(self.layers):
            for phase, events in (("fp", layer.comm_fwd),
                                  ("ig", layer.comm_ig),
                                  ("wg", layer.comm_wg)):
                for ev in events:
                    yield i, layer, phase, ev

    def total_weight_bytes(self) -> int:
        return sum(ly.weight_bytes * ly.repeat for ly in self.layers)

    def total_activation_bytes(self) -> int:
        return sum(ly.act_out_bytes * ly.repeat for ly in self.layers)

    def activation_working_bytes(self) -> int:
        """Activation Working Memory (§IV-B): intermediates between two
        consecutive checkpoints ~= the largest single layer's activations."""
        return max((ly.act_out_bytes for ly in self.layers), default=0)

    def phase_cost(self, phase: str, sram_bytes: int) -> PhaseCost:
        total = PhaseCost()
        for ly in self.layers:
            c = ly.phase_cost(phase, sram_bytes)
            total = total + PhaseCost(c.flops * ly.repeat, c.traffic * ly.repeat)
        return total

    def total_flops(self, sram_bytes: int = 1 << 62) -> int:
        return sum(self.phase_cost(p, sram_bytes).flops for p in ("fp", "ig", "wg"))


# ====================================================================== #
# Transformer-family building blocks (paper Table II, + GQA extension)
# ====================================================================== #

def _shard(n: int, ways: int) -> int:
    """Per-node column count when a dimension is sharded ``ways``-way.

    The analytical model shards fractionally (ceil) even when not evenly
    divisible, as the paper's sub_ff / sub_vocab / per-node-heads terms do.
    (The runtime falls back to replication instead — parallel/sharding.py —
    which only matters for the measured dry-run path, not here.)"""
    if ways <= 1:
        return n
    return _ceil_div(n, ways)


def _attention_layer(
    name: str,
    cfg: ModelConfig,
    batch: int,
    seq_q: int,
    seq_kv: int,
    mp: int,
    d_in: Optional[int] = None,
    d_out: Optional[int] = None,
) -> LayerSpec:
    """Self/cross attention block: QKV proj, scores, context, out proj.

    MP sharding: heads split across MP (column-parallel QKV, row-parallel
    out-proj) -> one blocking all-reduce of the block output in FP and IG.
    Score/context GEMMs are per-sample per-head (Table II's M=b*seq,
    N=b*seq entry is read as the per-sample seq x seq GEMM batched over b).
    """
    d_model = cfg.d_model
    d_in = d_in or d_model
    d_out = d_out or d_model
    hd = cfg.resolved_head_dim
    h_local = _shard(cfg.num_heads, mp)
    kv_local = _shard(cfg.num_kv_heads, mp)
    tokens = batch * seq_q
    kv_tokens = batch * seq_kv
    spec = LayerSpec(name)
    # Projections
    spec.add_gemm(Gemm(tokens, d_in, h_local * hd))                 # Q
    spec.add_gemm(Gemm(kv_tokens, d_in, kv_local * hd))             # K
    spec.add_gemm(Gemm(kv_tokens, d_in, kv_local * hd))             # V
    # Scores + context, batched per (sample, local head) (no weights)
    bh = batch * h_local
    spec.add_gemm(Gemm(seq_q, hd, seq_kv, batch=bh), has_weight=False)
    spec.add_gemm(Gemm(seq_q, seq_kv, hd, batch=bh), has_weight=False)
    # Softmax (element-wise over scores)
    score_elems = bh * seq_q * seq_kv
    spec.fwd.append(ExplicitOp(flops=4 * score_elems,
                               bytes_moved=2 * score_elems * BYTES))
    spec.ig.append(ExplicitOp(flops=4 * score_elems,
                              bytes_moved=2 * score_elems * BYTES))
    # Out projection (row-parallel)
    spec.add_gemm(Gemm(tokens, h_local * hd, d_out))
    # Block output all-reduce across MP (Megatron "g"): blocking
    out_bytes = tokens * d_out * BYTES
    if mp > 1:
        spec.comm_fwd.append(CommEvent("all-reduce", out_bytes, "mp", blocking=True))
        spec.comm_ig.append(CommEvent("all-reduce", tokens * d_in * BYTES, "mp", blocking=True))
    spec.act_out_bytes = out_bytes + tokens * (h_local + 2 * kv_local) * hd * BYTES
    return spec


def _ffn_layer(name: str, cfg: ModelConfig, tokens: int, mp: int,
               d_ff: Optional[int] = None) -> LayerSpec:
    d_ff = d_ff or cfg.d_ff
    ff_local = _shard(d_ff, mp)
    spec = LayerSpec(name)
    spec.add_gemm(Gemm(tokens, cfg.d_model, ff_local))              # up
    if cfg.activation == "swiglu":
        spec.add_gemm(Gemm(tokens, cfg.d_model, ff_local))          # gate
        spec.fwd.append(ExplicitOp(flops=4 * tokens * ff_local,
                                   bytes_moved=3 * tokens * ff_local * BYTES))
    else:
        spec.fwd.append(ExplicitOp(flops=2 * tokens * ff_local,
                                   bytes_moved=2 * tokens * ff_local * BYTES))
    spec.add_gemm(Gemm(tokens, ff_local, cfg.d_model))              # down (row-par)
    out_bytes = tokens * cfg.d_model * BYTES
    if mp > 1:
        spec.comm_fwd.append(CommEvent("all-reduce", out_bytes, "mp", blocking=True))
        spec.comm_ig.append(CommEvent("all-reduce", out_bytes, "mp", blocking=True))
    spec.act_out_bytes = out_bytes + tokens * ff_local * BYTES
    return spec


def _norm_layer(name: str, cfg: ModelConfig, tokens: int) -> LayerSpec:
    spec = LayerSpec(name)
    nbytes = tokens * cfg.d_model * BYTES
    spec.fwd.append(ExplicitOp(flops=5 * tokens * cfg.d_model, bytes_moved=2 * nbytes))
    spec.ig.append(ExplicitOp(flops=8 * tokens * cfg.d_model, bytes_moved=3 * nbytes))
    spec.wg.append(ExplicitOp(flops=2 * tokens * cfg.d_model, bytes_moved=nbytes))
    spec.weight_bytes = cfg.d_model * BYTES
    spec.act_out_bytes = nbytes
    return spec


def _moe_layer(name: str, cfg: ModelConfig, tokens: int, mp: int,
               ep: int = 1) -> LayerSpec:
    """MoE FFN.

    With ``ep > 1``: experts shard over the dedicated EP mesh axis
    (requires num_experts % ep == 0); dispatch + combine are blocking
    all-to-alls over scope ``"ep"`` in FP and again in IG, and each local
    expert's d_ff additionally shards over MP (expert-TP) with the usual
    row-parallel all-reduce.  Expert weight bytes are flagged in
    ``expert_bytes`` so their gradients sync across DP only.

    With ``ep == 1`` (legacy rule, unchanged): EP-over-MP when
    num_experts % mp == 0 (experts spread over the MP group; two blocking
    all-to-alls in FP — dispatch + combine — and two in IG); expert-TP
    otherwise (each expert's d_ff sharded over MP; all-reduce like a dense
    FFN).  Matches parallel/sharding.py's runtime rule.
    """
    moe = cfg.moe
    assert moe is not None
    spec = LayerSpec(name)
    e = moe.num_experts
    mult = 3 if cfg.activation == "swiglu" else 2
    # Router (replicated)
    spec.add_gemm(Gemm(tokens, cfg.d_model, e))
    spec.fwd.append(ExplicitOp(flops=6 * tokens * e,
                               bytes_moved=2 * tokens * e * BYTES))
    routed = tokens * moe.top_k

    def expert_gemms(per_expert: int, d_ff: int, n_experts: int) -> None:
        """Up(+gate) and down GEMMs for n_experts local experts, batched
        (the weight-bytes accounting follows add_gemm's single-instance
        convention, shared by every branch)."""
        spec.add_gemm(Gemm(per_expert, cfg.d_model, d_ff,
                           batch=n_experts * (mult - 1)))
        spec.add_gemm(Gemm(per_expert, d_ff, cfg.d_model, batch=n_experts))

    def dispatch_a2a(size: float, scope: str) -> None:
        """Blocking dispatch + combine all-to-alls, in FP and again in IG."""
        for comm in (spec.comm_fwd, spec.comm_ig):
            comm.append(CommEvent("all-to-all", int(size), scope, True))
            comm.append(CommEvent("all-to-all", int(size), scope, True))

    def mp_allreduce(out_bytes: int) -> None:
        """Row-parallel expert output all-reduce (expert-TP within MP)."""
        if mp > 1:
            spec.comm_fwd.append(CommEvent("all-reduce", out_bytes, "mp", True))
            spec.comm_ig.append(CommEvent("all-reduce", out_bytes, "mp", True))

    if ep > 1:
        if e % ep:
            raise InfeasibleStrategyError(
                f"{name}: num_experts={e} is not divisible by ep={ep}")
        # Balanced routing: each node dispatches its `routed` tokens into
        # the EP all-to-all and receives ~capacity_factor x as many back.
        local_experts = e // ep
        local_tokens = int(routed * moe.capacity_factor)
        w0 = spec.weight_bytes
        expert_gemms(_ceil_div(local_tokens, max(local_experts, 1)),
                     _shard(moe.d_ff, mp), local_experts)
        spec.expert_bytes = spec.weight_bytes - w0
        dispatch_a2a(routed * cfg.d_model * BYTES, "ep")
        mp_allreduce(local_tokens * cfg.d_model * BYTES)
    elif (e % mp == 0) and mp > 1:
        # Legacy EP-over-MP: capacity-factor share of routed tokens.
        local_tokens = int(routed / mp * moe.capacity_factor)
        local_experts = e // mp
        expert_gemms(_ceil_div(local_tokens, max(local_experts, 1)),
                     moe.d_ff, local_experts)
        dispatch_a2a(routed * cfg.d_model * BYTES / mp, "mp")
    else:
        # Expert-TP: every expert's hidden dim sharded over MP.
        expert_gemms(_ceil_div(routed, e), _shard(moe.d_ff, mp), e)
        mp_allreduce(tokens * cfg.d_model * BYTES)
    if moe.shared_expert:
        ff_local = _shard(moe.shared_d_ff, mp)
        spec.add_gemm(Gemm(tokens, cfg.d_model, ff_local, batch=mult - 1))
        spec.add_gemm(Gemm(tokens, ff_local, cfg.d_model))
    spec.act_out_bytes = (routed + tokens) * cfg.d_model * BYTES
    return spec


def _ssm_layer(name: str, cfg: ModelConfig, tokens: int, mp: int) -> LayerSpec:
    """Mamba2 SSD block as chunked GEMMs (state-space duality).

    Heads shard over MP (in_proj column-parallel, out_proj row-parallel ->
    one blocking all-reduce per phase, like attention)."""
    ssm = cfg.ssm
    assert ssm is not None
    d = cfg.d_model
    n = ssm.state_dim
    p = ssm.head_dim
    heads = cfg.ssm_heads
    h_local = _shard(heads, mp)
    di_local = h_local * p
    lc = min(ssm.chunk_size, tokens)
    nchunks = _ceil_div(tokens, lc)
    spec = LayerSpec(name)
    # in_proj: z, x, B, C, dt  (column-parallel)
    n_in = 2 * di_local + 2 * ssm.ngroups * n + h_local
    spec.add_gemm(Gemm(tokens, d, n_in))
    # depthwise conv on (x, B, C)
    conv_ch = di_local + 2 * ssm.ngroups * n
    spec.fwd.append(ExplicitOp(flops=2 * tokens * conv_ch * ssm.conv_width,
                               bytes_moved=2 * tokens * conv_ch * BYTES))
    spec.ig.append(ExplicitOp(flops=4 * tokens * conv_ch * ssm.conv_width,
                              bytes_moved=3 * tokens * conv_ch * BYTES))
    # SSD chunked scan, per local head x chunk:
    #   G = C @ B^T            (lc x n) @ (n x lc)
    #   Y_intra = (G * L) @ X  (lc x lc) @ (lc x p)
    #   S = B^T @ X            (n x lc) @ (lc x p)     [state build]
    #   Y_inter = C @ S_prev   (lc x n) @ (n x p)      [state apply]
    bhc = h_local * nchunks
    spec.add_gemm(Gemm(lc, n, lc, batch=bhc), has_weight=False)
    spec.add_gemm(Gemm(lc, lc, p, batch=bhc), has_weight=False)
    spec.add_gemm(Gemm(n, lc, p, batch=bhc), has_weight=False)
    spec.add_gemm(Gemm(lc, n, p, batch=bhc), has_weight=False)
    # gated norm + out_proj (row-parallel)
    spec.fwd.append(ExplicitOp(flops=7 * tokens * di_local,
                               bytes_moved=3 * tokens * di_local * BYTES))
    spec.add_gemm(Gemm(tokens, di_local, d))
    out_bytes = tokens * d * BYTES
    if mp > 1:
        spec.comm_fwd.append(CommEvent("all-reduce", out_bytes, "mp", True))
        spec.comm_ig.append(CommEvent("all-reduce", out_bytes, "mp", True))
    spec.act_out_bytes = out_bytes + tokens * (n_in + di_local) * BYTES
    return spec


def _embedding_layers(cfg: ModelConfig, tokens: int, mp: int):
    """Vocab-parallel input lookup + output projection (Table II rows 1/14)."""
    sub_vocab = _shard(cfg.padded_vocab, mp)
    d = cfg.d_model
    inp = LayerSpec("input_embedding")
    inp.fwd.append(ExplicitOp(flops=0, bytes_moved=2 * tokens * d * BYTES))
    inp.wg.append(ExplicitOp(flops=tokens * d, bytes_moved=2 * tokens * d * BYTES))
    inp.weight_bytes = sub_vocab * d * BYTES
    inp.act_out_bytes = tokens * d * BYTES
    if mp > 1:
        # partial lookup (masked vocab shard) -> all-reduce of embeddings
        inp.comm_fwd.append(CommEvent("all-reduce", tokens * d * BYTES, "mp", True))
    out = LayerSpec("output_embedding")
    out.add_gemm(Gemm(tokens, d, sub_vocab))
    if cfg.tie_embeddings:
        out.weight_bytes = 0  # shared with input table
    # vocab-parallel softmax/CE: all-reduce of per-token scalars (fp32)
    if mp > 1:
        out.comm_fwd.append(CommEvent("all-reduce", tokens * 4, "mp", True))
        out.comm_ig.append(CommEvent("all-reduce", tokens * d * BYTES, "mp", True))
    out.act_out_bytes = tokens * sub_vocab * BYTES
    return inp, out


def _clone_layer(template: LayerSpec, name: str) -> LayerSpec:
    """A per-instance copy of a template layer.

    ``decompose`` builds each *distinct* layer shape once per strategy and
    stamps the repeated blocks out as clones: the op lists are immutable
    after construction and stay shared (the compiled lowering dedupes on
    exactly that identity), while the comm lists and the ``stage`` slot
    are per-instance — later passes append stage-boundary p2p and DP-grad
    events layer by layer."""
    return dataclasses.replace(
        template, name=name,
        comm_fwd=list(template.comm_fwd),
        comm_ig=list(template.comm_ig),
        comm_wg=list(template.comm_wg))


def _dp_grad_events(layers: Sequence[LayerSpec], dp: int, ep: int = 1) -> None:
    """Attach the WG-phase non-blocking DP gradient collectives (§III-C3).

    ZeRO-2 (os+g) distributes optimizer states and gradients across DP with
    no extra communication volume vs. a plain all-reduce (paper §IV-B), so
    the event stays an all-reduce of the per-node fp16 gradient bytes.

    With ``ep > 1`` dense (non-expert) weights are replicated across the
    whole DP x EP data group, so their gradients all-reduce over scope
    ``"dp"`` (which the collective model sizes as ``dp * ep``); expert
    weights are already EP-sharded and sync across DP only (``"edp"``)."""
    if dp * max(ep, 1) <= 1:
        return
    for ly in layers:
        dense = ly.weight_bytes - ly.expert_bytes
        if dense > 0:
            ly.comm_wg.append(
                CommEvent("all-reduce", dense, "dp", blocking=False))
        if ly.expert_bytes and dp > 1:
            ly.comm_wg.append(
                CommEvent("all-reduce", ly.expert_bytes, "edp", blocking=False))


# ====================================================================== #
# Pipeline-stage partitioning
# ====================================================================== #

def _layer_flops(ly: LayerSpec) -> int:
    """Stage-balancing cost: the layer's FLOPs through the same phase_cost
    accounting the simulator uses (sram irrelevant for the flops term)."""
    return sum(ly.phase_cost(p, 1 << 62).flops for p in ("fp", "ig", "wg"))


def _partition_stages(layers: List[LayerSpec], pp: int,
                      boundary_bytes: int) -> List[LayerSpec]:
    """Partition the layer stack into ``pp`` contiguous FLOP-balanced stages.

    Repeated layers (``repeat > 1``, the enc-dec stacks) are unrolled so a
    stack can straddle a stage boundary.  Each boundary gets a blocking
    point-to-point hidden-state transfer: the sending stage's last layer
    forwards activations in FP, the receiving stage's first layer returns
    the activation gradient in IG (both on scope ``"pp"``).
    """
    expanded: List[LayerSpec] = []
    for ly in layers:
        if ly.repeat == 1:
            expanded.append(ly)
        else:
            for _ in range(ly.repeat):
                expanded.append(dataclasses.replace(
                    ly, repeat=1,
                    comm_fwd=list(ly.comm_fwd), comm_ig=list(ly.comm_ig),
                    comm_wg=list(ly.comm_wg)))
    if pp > len(expanded):
        raise InfeasibleStrategyError(
            f"pp={pp} exceeds the {len(expanded)} partitionable layers")
    costs = [_layer_flops(ly) for ly in expanded]
    remaining = sum(costs)
    n = len(expanded)
    idx = 0
    for s in range(pp):
        stages_left = pp - s
        max_end = n - (stages_left - 1)   # leave >= 1 layer per later stage
        target = remaining / stages_left
        acc = 0
        j = idx
        while j < max_end:
            acc += costs[j]
            j += 1
            if acc >= target:
                break
        j = max(j, idx + 1)
        for k in range(idx, j):
            expanded[k].stage = s
        remaining -= acc
        idx = j
    for k in range(idx, n):              # numerical-edge leftovers
        expanded[k].stage = pp - 1
    stages = [[ly for ly in expanded if ly.stage == s] for s in range(pp)]
    for s in range(pp - 1):
        stages[s][-1].comm_fwd.append(
            CommEvent("p2p", boundary_bytes, "pp", blocking=True))
        stages[s + 1][0].comm_ig.append(
            CommEvent("p2p", boundary_bytes, "pp", blocking=True))
    return expanded


def _resolve_microbatches(num_microbatches: Optional[int],
                          shape: ShapeConfig, pp: int, b_local: int) -> int:
    """Microbatch count: explicit arg > shape knob > 4*pp heuristic, capped
    at the per-replica batch (a microbatch holds >= 1 sample)."""
    if pp <= 1:
        return 1
    m = num_microbatches or getattr(shape, "num_microbatches", 0) or 4 * pp
    return max(1, min(m, b_local))


# ====================================================================== #
# Public decompositions
# ====================================================================== #

def decompose(cfg: ModelConfig, shape: ShapeConfig, mp: int = 1, dp: int = 1,
              pp: int = 1, ep: int = 1,
              override_batch: Optional[int] = None,
              override_seq: Optional[int] = None,
              num_microbatches: Optional[int] = None,
              schedule: str = "1f1b",
              virtual_stages: Optional[int] = None) -> Workload:
    """ModelConfig + shape + (MP, DP, PP, EP) -> per-node Workload.

    ``pp=1, ep=1`` (the defaults) reproduce the pre-PP/EP decomposition
    bit-for-bit; see the module docstring for the four-axis semantics.
    ``schedule="interleaved"`` models Megatron-LM's interleaved 1F1B:
    each node runs ``virtual_stages`` (default 2) non-contiguous model
    chunks, shrinking the pipeline bubble to (pp-1)/(v*m + pp-1) at the
    price of v-fold stage-boundary p2p volume (charged here)."""
    for axis, v in (("mp", mp), ("dp", dp), ("pp", pp), ("ep", ep)):
        if v < 1:
            raise ValueError(f"{axis} must be >= 1, got {v}")
    if schedule not in ("gpipe", "1f1b", "interleaved"):
        raise ValueError(f"schedule must be 'gpipe', '1f1b' or "
                         f"'interleaved', got {schedule!r}")
    if virtual_stages is not None and virtual_stages < 1:
        raise ValueError(f"virtual_stages must be >= 1, got {virtual_stages}")
    if schedule == "interleaved":
        vstages = virtual_stages if virtual_stages is not None else 2
    else:
        vstages = 1                # the knob is interleaved-only
    if pp <= 1:                    # no pipeline: schedule has no effect
        schedule, vstages = "1f1b", 1
    batch = override_batch if override_batch is not None else shape.global_batch
    seq = override_seq if override_seq is not None else shape.seq_len
    # Non-expert layers see the EP group as extra data parallelism.
    b_local = max(1, batch // max(dp * ep, 1))
    decode = shape.kind == "decode"
    # Decode: one new query token per sample attending to a seq-long cache.
    seq_q = 1 if decode else seq
    layers: List[LayerSpec] = []

    if cfg.family == "encdec":
        assert cfg.encdec is not None
        src = int(seq * cfg.encdec.source_frac)
        tgt = seq - src
        tgt_q = 1 if decode else tgt
        t_src, t_tgt = b_local * src, b_local * tgt_q
        inp, out = _embedding_layers(cfg, t_tgt, mp)
        layers.append(inp)
        if not decode:  # decode reuses the precomputed encoder output
            enc = [
                _norm_layer("enc_norm", cfg, t_src),
                _attention_layer("enc_self_attn", cfg, b_local, src, src, mp),
                _ffn_layer("enc_ffn", cfg, t_src, mp),
            ]
            for ly in enc:
                ly.repeat = cfg.encdec.encoder_layers
            layers += enc
        dec = [
            _norm_layer("dec_norm", cfg, t_tgt),
            _attention_layer("dec_self_attn", cfg, b_local, tgt_q, tgt, mp),
            _attention_layer("dec_cross_attn", cfg, b_local, tgt_q, src, mp),
            _ffn_layer("dec_ffn", cfg, t_tgt, mp),
        ]
        for ly in dec:
            ly.repeat = cfg.encdec.decoder_layers
        layers += dec
        layers.append(out)
    else:
        eff_seq, eff_q = seq, seq_q
        if cfg.family == "vlm":
            assert cfg.vision is not None
            eff_seq = seq + cfg.vision.num_patches
            eff_q = 1 if decode else eff_seq
        tokens = b_local * eff_q
        inp, out = _embedding_layers(cfg, tokens, mp)
        layers.append(inp)
        # The block stack repeats a handful of distinct layer shapes; build
        # each shape once and stamp the stack out as clones (identical
        # content — the decompose goldens fingerprint every op dim — at a
        # fraction of the construction cost; this is the strategy-side
        # half of a study cell, so it is squarely on the hot path).
        templates: dict = {}

        def stamp(key: str, name: str, build) -> LayerSpec:
            t = templates.get(key)
            if t is None:
                t = templates[key] = build()
            return _clone_layer(t, name)

        for i in range(cfg.num_layers):
            if cfg.family in ("ssm", "hybrid"):
                layers.append(stamp(
                    "norm", f"norm_{i}",
                    lambda: _norm_layer("norm", cfg, tokens)))
                layers.append(stamp(
                    "ssm", f"ssm_{i}",
                    lambda: _ssm_layer("ssm", cfg, tokens, mp)))
                if (cfg.family == "hybrid" and cfg.hybrid is not None
                        and (i + 1) % cfg.hybrid.attn_every == 0):
                    d_in = (2 * cfg.d_model
                            if cfg.hybrid.attn_concat_embedding else cfg.d_model)
                    layers.append(stamp(
                        "shared_attn", f"shared_attn_{i}",
                        lambda: _attention_layer(
                            "shared_attn", cfg, b_local, eff_q, eff_seq, mp,
                            d_in=d_in, d_out=cfg.d_model)))
            elif cfg.family == "moe":
                assert cfg.moe is not None
                layers.append(stamp(
                    "norm", f"norm_attn_{i}",
                    lambda: _norm_layer("norm", cfg, tokens)))
                layers.append(stamp(
                    "attn", f"attn_{i}",
                    lambda: _attention_layer(
                        "attn", cfg, b_local, eff_q, eff_seq, mp)))
                layers.append(stamp(
                    "norm", f"norm_ffn_{i}",
                    lambda: _norm_layer("norm", cfg, tokens)))
                is_moe = (i % cfg.moe.moe_every) == (cfg.moe.moe_every - 1)
                if is_moe:
                    layers.append(stamp(
                        "moe", f"moe_{i}",
                        lambda: _moe_layer("moe", cfg, tokens, mp, ep)))
                else:
                    layers.append(stamp(
                        "ffn", f"ffn_{i}",
                        lambda: _ffn_layer("ffn", cfg, tokens, mp)))
            else:  # dense / vlm
                layers.append(stamp(
                    "norm", f"norm_attn_{i}",
                    lambda: _norm_layer("norm", cfg, tokens)))
                layers.append(stamp(
                    "attn", f"attn_{i}",
                    lambda: _attention_layer(
                        "attn", cfg, b_local, eff_q, eff_seq, mp)))
                layers.append(stamp(
                    "norm", f"norm_ffn_{i}",
                    lambda: _norm_layer("norm", cfg, tokens)))
                layers.append(stamp(
                    "ffn", f"ffn_{i}",
                    lambda: _ffn_layer("ffn", cfg, tokens, mp)))
        layers.append(out)

    if pp > 1:
        # Boundary tensor between stages: the per-replica hidden state of
        # the trunk (decoder trunk for enc-dec).
        if cfg.family == "encdec":
            tgt = seq - int(seq * cfg.encdec.source_frac)
            boundary_tokens = b_local * (1 if decode else tgt)
        else:
            boundary_tokens = b_local * (1 if decode else seq)
            if cfg.family == "vlm":
                assert cfg.vision is not None
                boundary_tokens = b_local * (
                    1 if decode else seq + cfg.vision.num_patches)
        # Interleaved 1F1B: every microbatch crosses each node boundary
        # once per virtual-stage chunk -> v-fold p2p volume.
        layers = _partition_stages(
            layers, pp, boundary_tokens * cfg.d_model * BYTES * vstages)
    _dp_grad_events(layers, dp, ep)
    suffix = f"_pp{pp}_ep{ep}" if (pp > 1 or ep > 1) else ""
    return Workload(
        name=f"{cfg.arch_id}@{shape.name}[mp{mp}_dp{dp}{suffix}]",
        layers=layers, mp=mp, dp=dp, pp=pp, ep=ep,
        num_microbatches=_resolve_microbatches(num_microbatches, shape,
                                               pp, b_local),
        schedule=schedule, virtual_stages=vstages,
        per_replica_batch=b_local, seq_len=seq,
    )


def decompose_dlrm(dlrm_cfg, global_batch: int, nodes: int) -> Workload:
    """DLRM hybrid strategy (§V-C, Rashidi et al.): embedding tables sharded
    across all nodes (table-wise MP, all-to-all FP/IG), MLPs data-parallel
    (all-reduce WG)."""
    b_local = max(1, global_batch // nodes)
    e = dlrm_cfg.emb_dim
    layers: List[LayerSpec] = []

    # Embedding lookup: each node owns tables/nodes tables, does lookups for
    # the *global* batch on its shard, then all-to-alls pooled vectors.
    local_tables = max(1, dlrm_cfg.num_tables // nodes) \
        if dlrm_cfg.num_tables >= nodes else dlrm_cfg.num_tables / nodes
    emb = LayerSpec("embedding_lookup")
    lookup_rows = int(global_batch * local_tables * dlrm_cfg.lookups_per_table)
    emb.fwd.append(ExplicitOp(flops=lookup_rows * e,  # pooled sum
                              bytes_moved=2 * lookup_rows * e * 4))
    emb.wg.append(ExplicitOp(flops=lookup_rows * e,
                             bytes_moved=2 * lookup_rows * e * 4))
    emb.weight_bytes = int(local_tables * dlrm_cfg.rows_per_table * e * 4)
    # Sparse row-wise Adagrad: only touched rows are updated.
    emb.optim_bytes = int(lookup_rows * e * 12)
    a2a = int(global_batch * local_tables * e * 4)
    # DLRM's node group is consecutive ranks (fills pods first) -> "mp" scope.
    emb.comm_fwd.append(CommEvent("all-to-all", a2a, "mp", blocking=True))
    emb.comm_ig.append(CommEvent("all-to-all", a2a, "mp", blocking=True))
    emb.act_out_bytes = a2a
    layers.append(emb)

    def _mlp(name: str, dims: Sequence[int]) -> None:
        for j, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
            spec = LayerSpec(f"{name}_{j}")
            spec.add_gemm(Gemm(b_local, a, b, bytes_per_element=4))
            spec.act_out_bytes = b_local * b * 4
            layers.append(spec)

    _mlp("bottom_mlp", (dlrm_cfg.num_dense_features,) + dlrm_cfg.bottom_mlp)
    n_feat = dlrm_cfg.num_tables + 1
    interact = LayerSpec("feature_interaction")
    interact.fwd.append(ExplicitOp(
        flops=2 * b_local * n_feat * n_feat * e,
        bytes_moved=2 * b_local * n_feat * e * 4))
    interact.ig.append(ExplicitOp(
        flops=4 * b_local * n_feat * n_feat * e,
        bytes_moved=3 * b_local * n_feat * e * 4))
    interact.act_out_bytes = b_local * (n_feat * (n_feat - 1) // 2) * 4
    layers.append(interact)
    top_in = n_feat * (n_feat - 1) // 2 + dlrm_cfg.bottom_mlp[-1]
    _mlp("top_mlp", (top_in,) + dlrm_cfg.top_mlp)

    # DP all-reduce for MLP grads only (tables update locally).
    for ly in layers:
        if ly.weight_bytes and not ly.name.startswith("embedding"):
            ly.comm_wg.append(CommEvent("all-reduce", ly.weight_bytes, "mp", False))

    return Workload(name=f"{dlrm_cfg.arch_id}[n{nodes}]", layers=layers,
                    mp=nodes, dp=nodes, per_replica_batch=b_local,
                    seq_len=1)
