"""Deterministic synthetic data pipeline (resumable, shardable)."""
from repro.data.pipeline import DataConfig, DataIterator, dlrm_batch, lm_batch  # noqa: F401
