"""Deterministic, resumable, sharded synthetic data pipeline.

Batches are a pure function of (seed, step, shard) — threefry counters, no
filesystem — so (a) any step is reproducible, (b) resume-from-checkpoint
needs only the step counter, and (c) elastic re-sharding (different DP
degree after restart) regenerates identical global batches split
differently. The token stream is Zipf-ish over the vocab with a Markov
structure so the LM loss is learnable (quickstart shows it dropping).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_dense: int = 0             # DLRM dense features
    num_tables: int = 0            # DLRM sparse tables
    lookups: int = 0
    rows: int = 0


def lm_batch(cfg: DataConfig, step: int,
             shard: int = 0, num_shards: int = 1) -> dict:
    """One LM batch shard: {tokens, targets} of (B/num_shards, S)."""
    assert cfg.global_batch % num_shards == 0
    b_local = cfg.global_batch // num_shards
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), shard)
    k1, k2 = jax.random.split(key)
    # Zipf-ish marginals via squared uniform; Markov smoothing for structure.
    u = jax.random.uniform(k1, (b_local, cfg.seq_len + 1))
    base = (jnp.square(u) * cfg.vocab_size).astype(jnp.int32)
    # every even position repeats the previous token's bucket (learnable)
    pos = jnp.arange(cfg.seq_len + 1)
    toks = jnp.where((pos % 2 == 0)[None, :],
                     jnp.roll(base, 1, axis=1), base)
    toks = jnp.clip(toks, 0, cfg.vocab_size - 1)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def dlrm_batch(cfg: DataConfig, step: int,
               shard: int = 0, num_shards: int = 1) -> dict:
    b_local = cfg.global_batch // num_shards
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), shard)
    k1, k2, k3 = jax.random.split(key, 3)
    dense = jax.random.normal(k1, (b_local, cfg.num_dense))
    sparse = jax.random.randint(
        k2, (b_local, cfg.num_tables, cfg.lookups), 0, cfg.rows)
    # label correlated with dense features -> learnable
    labels = (dense.sum(-1) + 0.5 * jax.random.normal(k3, (b_local,)) > 0
              ).astype(jnp.int32)
    return {"dense": dense, "sparse": sparse, "labels": labels}


@dataclasses.dataclass
class DataIterator:
    """Stateful wrapper; ``state()`` / ``restore()`` round-trip through the
    checkpoint."""

    cfg: DataConfig
    step: int = 0
    shard: int = 0
    num_shards: int = 1
    kind: str = "lm"

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        fn = lm_batch if self.kind == "lm" else dlrm_batch
        batch = fn(self.cfg, self.step, self.shard, self.num_shards)
        self.step += 1
        return batch

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])

    def reshard(self, shard: int, num_shards: int) -> "DataIterator":
        """Elastic restart onto a different DP degree: same stream, new
        split (determinism is per global batch, not per shard)."""
        return dataclasses.replace(self, shard=shard, num_shards=num_shards)
