"""repro.fleet: the multi-tenant timeline layer.

COMET's §V-C scheduling story (``ScheduleModel``: waves x iteration
time) priced a *static* fleet.  This package makes the schedule a
timeline: heterogeneous jobs arrive on a trace, queue per node group,
preempt each other by priority, grow/shrink their DP width elastically,
and lend the fleet to bursting tenants — every transition priced by the
``remesh_state`` checkpoint/reshard cost model.  ``FleetSpec`` lowers
straight into ``run_study`` (``fleet.*`` / ``ftrace.*`` / ``fail.*``
dotted-path axes), so fleet policy is a study axis like any cluster
knob.  A ``repro.reliability.FailureTrace`` injects node failures into
the timeline (interval-quantized rollback, wait-vs-shrink degradation)
and surfaces ``failures / lost_work_frac / goodput`` columns.

See docs/fleet_api.md.
"""

from repro.fleet.jobs import FleetJob, FleetJobSpec, WidthProfile
from repro.fleet.resize import (checkpoint_delay, instance_state_bytes,
                                remesh_delay)
from repro.fleet.simulator import (DEGRADATION_POLICIES, FLEET_POLICIES,
                                   FleetEvent, FleetModel, FleetResult,
                                   FleetSimulator, JobOutcome)
from repro.fleet.spec import (FLEET_COLUMNS, FleetPoint, FleetSpec,
                              FleetStudy, build_workload, fleet_record,
                              is_fleet_axis)
from repro.fleet.trace import FLEET_TRACE_KINDS, FleetTrace

__all__ = [
    "DEGRADATION_POLICIES",
    "FLEET_COLUMNS",
    "FLEET_POLICIES",
    "FLEET_TRACE_KINDS",
    "FleetEvent",
    "FleetJob",
    "FleetJobSpec",
    "FleetModel",
    "FleetPoint",
    "FleetResult",
    "FleetSimulator",
    "FleetSpec",
    "FleetStudy",
    "FleetTrace",
    "JobOutcome",
    "WidthProfile",
    "build_workload",
    "checkpoint_delay",
    "fleet_record",
    "instance_state_bytes",
    "is_fleet_axis",
    "remesh_delay",
]
