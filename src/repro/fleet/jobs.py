"""Heterogeneous fleet jobs: :class:`FleetJobSpec` extends the static
:class:`repro.core.placement.JobSpec` with the per-job knobs a timeline
needs — model identity, arrival time, iteration count, priority, the
elastic width menu, and the burst-parallel phase length.

A :class:`FleetJob` is the runtime pairing of a spec with its
:class:`WidthProfile` table — per-group iteration times (re-queried from
the study engines at every allowed width) plus the checkpoint payload
the resize/preemption cost model charges for.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Tuple

from repro.core.placement import JobSpec


@dataclasses.dataclass(frozen=True)
class FleetJobSpec(JobSpec):
    """One fleet tenant.

    Extends ``JobSpec`` (``instances`` / ``nodes_per_instance`` /
    ``max_nodes`` / ``name``) with:

    * ``model`` — registry model identity (``"dlrm"`` lowers through
      :func:`repro.core.workload.decompose_dlrm`, anything else through
      :func:`repro.core.workload.decompose` with ``mp`` fixed and
      DP = width / mp — the elastic-DP convention);
    * ``arrival`` / ``iterations`` — when the job enters the queue and
      how many iterations each instance must run (the trace rewrites
      both);
    * ``priority`` — larger preempts smaller;
    * ``widths`` — the elastic DP width menu in nodes per instance
      (empty = static at ``nodes_per_instance``);
    * ``burst_iters`` — > 0 marks the first ``burst_iters`` iterations
      as a burst-parallel phase that may borrow the fleet;
    * ``preemptible`` — whether higher-priority tenants may checkpoint
      this job off its nodes;
    * ``on_failure`` — per-job degradation policy when a node failure
      kills an instance: ``"wait"`` re-queues at the base width,
      ``"shrink"`` at the narrowest menu width; ``""`` (default)
      inherits ``FleetModel.degradation``.
    """

    model: str = ""
    mp: int = 1
    global_batch: int = 4096
    arrival: float = 0.0
    iterations: int = 1
    priority: int = 0
    widths: Tuple[int, ...] = ()
    burst_iters: int = 0
    preemptible: bool = True
    on_failure: str = ""

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.mp < 1:
            raise ValueError(f"mp must be >= 1, got {self.mp}")
        if self.nodes_per_instance < 1:
            raise ValueError("a fleet job needs an explicit "
                             "nodes_per_instance >= 1, got "
                             f"{self.nodes_per_instance}")
        if self.arrival < 0:
            raise ValueError(f"arrival must be >= 0, got {self.arrival}")
        if self.iterations < 1:
            raise ValueError(
                f"iterations must be >= 1, got {self.iterations}")
        if self.burst_iters < 0:
            raise ValueError(
                f"burst_iters must be >= 0, got {self.burst_iters}")
        for w in self.widths:
            if w < 1:
                raise ValueError(f"widths must be >= 1, got {self.widths}")
        if self.on_failure not in ("", "wait", "shrink"):
            raise ValueError(
                f"on_failure must be '', 'wait' or 'shrink', "
                f"got {self.on_failure!r}")

    @property
    def base_width(self) -> int:
        return self.nodes_per_instance

    @property
    def width_menu(self) -> Tuple[int, ...]:
        """The allowed instance widths, ascending, always containing the
        base width."""
        return tuple(sorted(set(self.widths) | {self.nodes_per_instance}))

    @property
    def elastic(self) -> bool:
        return len(self.width_menu) > 1


@dataclasses.dataclass(frozen=True)
class WidthProfile:
    """How one instance of a job behaves at one width: per-node-group
    iteration time and memory fit (``iter_times[g]`` / ``fits[g]`` in
    ``cluster.node_groups`` order), plus the instance's checkpoint
    payload in bytes — what preemption writes out and what an elastic
    resize must move through storage and ``device_put`` again."""

    iter_times: Tuple[float, ...]
    fits: Tuple[bool, ...]
    state_bytes: float = 0.0

    def __post_init__(self) -> None:
        if len(self.iter_times) != len(self.fits):
            raise ValueError("one fit flag per node group required")
        for t in self.iter_times:
            # inf marks an unsimulatable group (paired with fits=False);
            # nan would silently poison every downstream finish time.
            if t != t or t < 0:
                raise ValueError(
                    f"iteration times must be >= 0 and not NaN, got "
                    f"{self.iter_times}")


@dataclasses.dataclass(frozen=True)
class FleetJob:
    """A spec bound to its measured width profiles, ready to simulate.
    ``profiles`` must cover every width in ``spec.width_menu``."""

    spec: FleetJobSpec
    profiles: Mapping[int, WidthProfile]
    uid: int = 0

    def __post_init__(self) -> None:
        missing = [w for w in self.spec.width_menu if w not in self.profiles]
        if missing:
            raise ValueError(
                f"job {self.spec.name!r}: no WidthProfile for widths "
                f"{missing}")

    def profile(self, width: int) -> WidthProfile:
        return self.profiles[width]

    @property
    def state_bytes(self) -> float:
        return self.profiles[self.spec.base_width].state_bytes


__all__ = ["FleetJob", "FleetJobSpec", "WidthProfile"]
