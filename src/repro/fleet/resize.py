"""The fleet's one resize/preemption cost formula, priced the way
:func:`repro.launch.elastic.remesh_state` actually works.

``remesh_state`` restores the latest checkpoint onto a different mesh:
the checkpointer stores every state leaf *unsharded*, so an elastic
DP grow/shrink is (1) the full model-state payload through checkpoint
storage, then (2) a ``device_put`` of every leaf under the new mesh's
shardings — a redistribution over the training interconnect.  Hence:

    resize_delay = state_bytes / checkpoint_bw + state_bytes / reshard_bw

Preemption pays only the storage half per direction (write on preempt,
read on restore); a burst lend/return is a preempt/restore pair plus a
fixed per-hand-off overhead.

``instance_state_bytes`` sizes the payload for a registry workload the
way the checkpointer does: one unsharded copy of the model states
(fp16 weights + fp16 grads + fp32 Adam master/moments — ZeRO's 16
bytes/param), activations excluded.
"""

from __future__ import annotations

from repro.core.memory import FP16, GRAD, OPTIM
from repro.core.workload import Workload


def checkpoint_delay(state_bytes: float, checkpoint_bw: float) -> float:
    """One direction through checkpoint storage (preempt writes it,
    restore reads it back)."""
    if checkpoint_bw <= 0:
        raise ValueError(f"checkpoint_bw must be > 0, got {checkpoint_bw}")
    return state_bytes / checkpoint_bw


def remesh_delay(state_bytes: float, checkpoint_bw: float,
                 reshard_bw: float) -> float:
    """Elastic resize cost: checkpoint bytes through storage plus the
    ``device_put`` reshard onto the new mesh (the ``remesh_state``
    path)."""
    if reshard_bw <= 0:
        raise ValueError(f"reshard_bw must be > 0, got {reshard_bw}")
    return checkpoint_delay(state_bytes, checkpoint_bw) \
        + state_bytes / reshard_bw


def instance_state_bytes(workload: Workload) -> float:
    """Checkpoint payload for one instance of ``workload``: the
    unsharded model states exactly as the checkpointer lays them out —
    16 bytes per parameter (fp16 weights/grads + fp32 Adam states) over
    every layer the instance owns, replicas excluded (one copy is
    written no matter the DP degree).  ``layers`` holds the per-MP-shard
    view, so the unsharded payload scales back up by ``mp``."""
    shard = sum(ly.weight_bytes * ly.repeat for ly in workload.layers) / FP16
    params = shard * max(1, workload.mp)
    return (FP16 + GRAD + OPTIM) * params


__all__ = ["checkpoint_delay", "instance_state_bytes", "remesh_delay"]
