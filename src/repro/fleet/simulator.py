"""Discrete-event fleet timeline over per-group node capacities.

The PR-4 :class:`~repro.core.placement.ScheduleModel` prices a job as
``waves * iter_time`` on an otherwise-empty fleet.  The
:class:`FleetSimulator` generalizes that to a timeline: jobs arrive,
queue, preempt each other, grow and shrink their DP width, and lend the
fleet to bursting tenants — every transition priced by the
``remesh_state`` cost model in :mod:`repro.fleet.resize`.

Design contract (the degenerate-equivalence golden): admission is
*plan-sticky*.  When a job's instances enter the queue they are planned
with the exact fixed ``ScheduleModel`` greedy against the currently
free nodes, and stay on their planned group at the planned concurrency
until an event (preemption, lend, resize) disturbs them.  Undisturbed
wave successions compute finish times as ``anchor + wave * duration``
(multiplication, never accumulation), so a static single-job no-event
trace reproduces ``ScheduleModel.schedule`` makespan bit-for-bit —
work-stealing between groups would beat the analytic model and is
deliberately not done.

Policies (:class:`FleetModel.policy`):

* ``static`` — queue + plan-sticky admission only: the timeline twin of
  a static ``ScheduleModel`` allocation;
* ``elastic`` — adds priority preemption, elastic DP grow (into idle
  nodes, when the saved compute outweighs the resize delay) and shrink
  (shedding nodes to admit waiting higher-priority work);
* ``elastic+burst`` — additionally lets a job's marked burst phase
  borrow lower-priority tenants' nodes for its first ``burst_iters``
  iterations (lend/return hand-offs priced as checkpoint/restore plus
  ``lend_overhead``).

Fault injection (PR 10): a :class:`repro.reliability.FailureTrace`
passed to the simulator downs nodes mid-timeline.  A failure first
absorbs idle capacity; the remainder kills running instances
(lowest-priority, latest-arrival first), whose work rolls back to the
last *interval-quantized* checkpoint boundary — the cadence is the
fixed ``FleetModel.ckpt_interval_s`` or the per-segment Young–Daly
optimum, and every running segment's iteration time is inflated by
``1 + C/tau`` to charge the checkpoint writes themselves.  Capacity
returns at the repair event.  The per-job degradation policy
(``on_failure``, defaulting to ``FleetModel.degradation``) chooses
wait-for-repair (re-queue at the base width) vs shrink-to-survive
(re-queue at the narrowest menu width).  With no trace (or a disabled
one) every inflation factor is exactly 1.0 and no new events enter the
heap: the timeline is bit-for-bit the failure-free one.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.placement import (JobSpec, Placement, ScheduleModel,
                                  get_placement)
from repro.fleet.jobs import FleetJob, WidthProfile
from repro.fleet.resize import checkpoint_delay, remesh_delay
from repro.reliability.trace import FailureEvent, FailureTrace

FLEET_POLICIES: Tuple[str, ...] = ("static", "elastic", "elastic+burst")
DEGRADATION_POLICIES: Tuple[str, ...] = ("wait", "shrink")


@dataclasses.dataclass(frozen=True)
class FleetModel:
    """The sweepable fleet knobs (``fleet.*`` dotted paths).

    ``checkpoint_bw`` / ``reshard_bw`` feed the one
    :func:`repro.fleet.resize.remesh_delay` formula; ``lend_overhead``
    is the fixed per-hand-off tax a burst lend/return adds on top of
    the checkpoint/restore pair.  ``preemption`` only takes effect
    under the elastic policies — ``static`` is the pure
    ``ScheduleModel``-equivalent baseline.

    ``degradation`` is the fleet-default failure policy a job without
    an ``on_failure`` override inherits (``"wait"`` re-queues a killed
    instance at its base width; ``"shrink"`` re-queues it at the
    narrowest menu width so it can restart on degraded capacity).
    ``ckpt_interval_s`` fixes the checkpoint cadence fault injection
    quantizes rollback to; 0 picks the per-segment Young–Daly optimum
    from the active failure trace's rate.  Both are inert without a
    failure trace."""

    policy: str = "elastic+burst"
    checkpoint_bw: float = 40e9
    reshard_bw: float = 100e9
    preemption: bool = True
    lend_overhead: float = 1.0
    degradation: str = "wait"
    ckpt_interval_s: float = 0.0

    def __post_init__(self) -> None:
        if self.policy not in FLEET_POLICIES:
            raise ValueError(f"policy must be one of {FLEET_POLICIES}, "
                             f"got {self.policy!r}")
        if self.degradation not in DEGRADATION_POLICIES:
            raise ValueError(
                f"degradation must be one of {DEGRADATION_POLICIES}, "
                f"got {self.degradation!r}")
        if self.ckpt_interval_s < 0:
            raise ValueError(f"ckpt_interval_s must be >= 0 (0 = "
                             f"Young–Daly), got {self.ckpt_interval_s}")

    @property
    def elastic(self) -> bool:
        return self.policy != "static"

    @property
    def burst(self) -> bool:
        return self.policy == "elastic+burst"

    @property
    def preempt(self) -> bool:
        return self.preemption and self.policy != "static"


@dataclasses.dataclass(frozen=True)
class FleetEvent:
    """One timeline transition, with the post-event per-group
    allocation snapshot (the capacity-conservation witness)."""

    time: float
    kind: str        # arrive|start|finish|complete|preempt|resume|grow|
    #                  shrink|lend|return|fail|fail_node|repair|fault
    job: str
    group: int
    width: int
    alloc: Tuple[int, ...]


@dataclasses.dataclass
class JobOutcome:
    """Per-job fate over the timeline."""

    name: str
    uid: int
    arrival: float
    priority: int
    first_start: float = math.inf
    finish: float = math.inf
    completed: bool = False
    feasible: bool = True
    preemptions: int = 0
    resizes: int = 0
    bursts: int = 0
    failures: int = 0

    @property
    def turnaround(self) -> float:
        return self.finish - self.arrival


def _pct(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (the serving convention)."""
    if not values:
        return math.inf
    s = sorted(values)
    return s[min(len(s) - 1, max(0, math.ceil(q * len(s)) - 1))]


@dataclasses.dataclass(frozen=True)
class FleetResult:
    """The timeline's outcome: per-job fates, the full event log, and
    the aggregate columns a fleet study emits."""

    outcomes: Tuple[JobOutcome, ...]
    events: Tuple[FleetEvent, ...]
    capacities: Tuple[int, ...]
    makespan: float
    busy_node_seconds: float
    useful_node_seconds: float = 0.0
    lost_node_seconds: float = 0.0

    @property
    def turnarounds(self) -> Tuple[float, ...]:
        return tuple(o.turnaround for o in self.outcomes if o.completed)

    @property
    def turnaround_p50(self) -> float:
        return _pct(self.turnarounds, 0.50)

    @property
    def turnaround_p99(self) -> float:
        return _pct(self.turnarounds, 0.99)

    @property
    def fleet_util(self) -> float:
        cap = sum(self.capacities)
        if cap <= 0 or self.makespan <= 0:
            return 0.0
        return self.busy_node_seconds / (cap * self.makespan)

    @property
    def preemptions(self) -> int:
        return sum(o.preemptions for o in self.outcomes)

    @property
    def resize_events(self) -> int:
        return sum(o.resizes for o in self.outcomes)

    @property
    def burst_events(self) -> int:
        return sum(o.bursts for o in self.outcomes)

    @property
    def jobs_completed(self) -> int:
        return sum(1 for o in self.outcomes if o.completed)

    @property
    def failures(self) -> int:
        """Instance kills charged to node failures (not preemptions)."""
        return sum(o.failures for o in self.outcomes)

    @property
    def lost_work_frac(self) -> float:
        """Failure-discarded compute as a fraction of busy node-time."""
        if self.busy_node_seconds <= 0:
            return 0.0
        return self.lost_node_seconds / self.busy_node_seconds

    @property
    def goodput(self) -> float:
        """Credited-iteration compute as a fraction of busy node-time
        (checkpoint writes, restores, remeshes and rework are the
        complement)."""
        if self.busy_node_seconds <= 0:
            return 0.0
        return self.useful_node_seconds / self.busy_node_seconds

    @property
    def feasible(self) -> bool:
        return all(o.feasible for o in self.outcomes) \
            and all(o.completed for o in self.outcomes)


# --------------------------------------------------------------------- #
# Internal runtime state
# --------------------------------------------------------------------- #

@dataclasses.dataclass
class _GroupView:
    """The free-node view ScheduleModel plans against."""

    num_nodes: int


@dataclasses.dataclass
class _Job:
    job: FleetJob
    outcome: JobOutcome
    instances: List["_Inst"] = dataclasses.field(default_factory=list)
    arrived: bool = False
    burst_done: bool = False

    @property
    def priority(self) -> int:
        return self.job.spec.priority

    @property
    def done(self) -> bool:
        return all(i.state == "done" for i in self.instances)


@dataclasses.dataclass
class _Inst:
    job: _Job
    idx: int
    remaining: int
    state: str = "queued"        # queued | running | blocked | done
    group: int = -1              # planned / hosting group (-1 = unplanned)
    width: int = 0               # current/pending width
    alloc: int = 0               # nodes actually held
    conc_cap: int = 1            # planned concurrency cap on the group
    it: float = 0.0              # per-iteration seconds at current width
    anchor: float = 0.0          # wave timing origin
    wave: int = 0                # finish = anchor + wave * dur
    dur: float = 0.0             # one full run at current width, seconds
    compute_start: float = 0.0
    pending: float = 0.0         # restore/reshard delay before next segment
    burst_width: int = 0         # > 0: next segment is the burst phase
    seg_iters: int = 0           # iterations covered by the running segment
    resizing: bool = False       # a remesh is in flight
    epoch: int = 0               # invalidates stale heap events
    f: float = 1.0               # checkpoint-cadence inflation (1 + C/tau)
    tau: float = math.inf        # checkpoint interval for this segment

    @property
    def key(self) -> Tuple[int, float, int, int]:
        return (-self.job.priority, self.job.outcome.arrival,
                self.job.job.uid, self.idx)


class FleetSimulator:
    """Replay a set of :class:`FleetJob` over per-group node capacities
    under a :class:`FleetModel` policy."""

    def __init__(self, capacities: Sequence[int],
                 model: Optional[FleetModel] = None,
                 placement: object = None,
                 schedule_model: Optional[ScheduleModel] = None,
                 failures: Optional[FailureTrace] = None,
                 pod_sizes: Optional[Sequence[int]] = None) -> None:
        if not capacities or any(c < 1 for c in capacities):
            raise ValueError(
                f"capacities must be positive per group, got {capacities}")
        self.capacities: Tuple[int, ...] = tuple(int(c) for c in capacities)
        self.model = model or FleetModel()
        self.placement: Optional[Placement] = get_placement(placement)
        self.scheduler = schedule_model or ScheduleModel()
        self.failures = failures
        self.pod_sizes: Optional[Tuple[int, ...]] = \
            tuple(int(p) for p in pod_sizes) if pod_sizes is not None \
            else None
        if self.pod_sizes is not None \
                and len(self.pod_sizes) != len(self.capacities):
            raise ValueError(
                f"pod_sizes must match capacities per group, got "
                f"{len(self.pod_sizes)} vs {len(self.capacities)}")

    # ------------------------------------------------------------------ #
    def run(self, jobs: Sequence[FleetJob]) -> FleetResult:
        st = _RunState(self, jobs)
        return st.run()


class _RunState:
    """One timeline execution (FleetSimulator stays reusable)."""

    def __init__(self, sim: FleetSimulator, jobs: Sequence[FleetJob]) -> None:
        self.sim = sim
        self.model = sim.model
        self.cap = list(sim.capacities)
        self.free = list(sim.capacities)
        self.jobs: List[_Job] = []
        for j in jobs:
            out = JobOutcome(name=j.spec.name, uid=j.uid,
                             arrival=j.spec.arrival,
                             priority=j.spec.priority)
            job = _Job(job=j, outcome=out)
            for k in range(j.spec.instances):
                job.instances.append(
                    _Inst(job=job, idx=k, remaining=j.spec.iterations,
                          width=j.spec.base_width))
            self.jobs.append(job)
        self.heap: List[Tuple[float, int, str, object]] = []
        self.seq = 0
        self.now = 0.0
        self.events: List[FleetEvent] = []
        self.busy = 0.0
        self._last_t = 0.0
        # (job uid, group, width, dur) -> (anchor, wave) wave-succession
        # hints left by finish events, consumed by same-timestamp admission
        self.hints: Dict[Tuple[int, int, int, float], Tuple[float, int]] = {}
        # --- fault injection (all zero / empty when no trace) ---------- #
        self.ftrace = sim.failures
        self.rel = self.ftrace is not None and self.ftrace.enabled
        self.down = [0] * len(self.cap)          # nodes currently failed
        self.transit_down = [0] * len(self.cap)  # failed while ckpt-writing
        self.useful = 0.0                        # credited compute node-s
        self.lost = 0.0                          # failure-discarded node-s
        if self.rel and self.ftrace is not None:
            for fe in self.ftrace.materialize(self.cap, sim.pod_sizes):
                self._push(fe.time, "fail_node", fe)

    # --- bookkeeping --------------------------------------------------- #
    def _advance(self, t: float) -> None:
        used = sum(self.cap) - sum(self.free) - sum(self.down)
        self.busy += used * (t - self._last_t)
        self._last_t = t
        self.now = t

    def _push(self, t: float, kind: str, payload: object) -> None:
        heapq.heappush(self.heap, (t, self.seq, kind, payload))
        self.seq += 1

    def _emit(self, kind: str, job: str, group: int, width: int) -> None:
        alloc = tuple(c - f for c, f in zip(self.cap, self.free))
        self.events.append(FleetEvent(self.now, kind, job, group, width,
                                      alloc))

    def _delay(self, bytes_: float) -> float:
        return checkpoint_delay(bytes_, self.model.checkpoint_bw)

    def _remesh(self, bytes_: float) -> float:
        return remesh_delay(bytes_, self.model.checkpoint_bw,
                            self.model.reshard_bw)

    def _ckpt(self, job: "_Job", width: int) -> Tuple[float, float]:
        """(inflation factor, checkpoint interval) for a segment of
        ``job`` at ``width``: the fixed ``FleetModel.ckpt_interval_s``
        cadence or the per-segment Young–Daly optimum at the trace's
        node failure rate.  Exactly ``(1.0, inf)`` without failures —
        the bit-for-bit degenerate."""
        if not self.rel or self.ftrace is None:
            return 1.0, math.inf
        tau = self.model.ckpt_interval_s
        if tau <= 0:
            lam = width * self.ftrace.rate_per_node
            if lam <= 0:
                return 1.0, math.inf    # explicit trace, no cadence set
            write = self._delay(job.job.state_bytes)
            tau = math.sqrt(2.0 * write / lam) if write > 0 else math.inf
        if not (tau > 0) or math.isinf(tau):
            return 1.0, math.inf
        return 1.0 + self._delay(job.job.state_bytes) / tau, tau

    # --- planning ------------------------------------------------------ #
    def _plan(self, job: _Job, avail: Sequence[int], width: int,
              queued: List[_Inst]) -> Optional[Tuple[List[int], List[int],
                                                     bool]]:
        """ScheduleModel greedy against an availability vector: returns
        (counts, conc, feasible) per group, or None when nothing can be
        assigned at all."""
        prof = job.job.profile(width)
        views = [_GroupView(n) for n in avail]
        spec = JobSpec(instances=len(queued), nodes_per_instance=width,
                       max_nodes=job.job.spec.max_nodes,
                       name=job.job.spec.name)
        try:
            sched = self.sim.scheduler.schedule(
                spec, views, list(prof.iter_times), fits=list(prof.fits),
                placement=self.sim.placement)
        except ValueError:
            return None
        counts = [0] * len(avail)
        conc = [0] * len(avail)
        for g in sched.groups:
            counts[g.group] = g.instances
            conc[g.group] = max(1, g.concurrent)
        return counts, conc, sched.feasible

    def _admissible(self, counts: Sequence[int], conc: Sequence[int],
                    width: int, avail: Sequence[int]) -> bool:
        """Would this plan's first wave actually obtain nodes?  (The
        legacy oversubscribed fallback clamps an instance to the whole
        group, so ``min(width, cap)`` is the allocation unit.)"""
        return any(c > 0 and avail[g] >= min(width, self.cap[g])
                   for g, c in enumerate(counts) if conc[g] > 0)

    def _assign(self, job: _Job, queued: List[_Inst], counts: Sequence[int],
                conc: Sequence[int], width: int, feasible: bool) -> None:
        it = 0
        for g, n in enumerate(counts):
            for _ in range(n):
                inst = queued[it]
                inst.group = g
                inst.width = width
                inst.conc_cap = conc[g]
                it += 1
        job.outcome.feasible = job.outcome.feasible and feasible

    def _reclaimable(self, pred: "Callable[[_Inst], int]") -> List[int]:
        """Per-group nodes recoverable from running instances matching
        ``pred`` (used for shrink/preempt/lend planning)."""
        out = [0] * len(self.cap)
        for job in self.jobs:
            for inst in job.instances:
                if inst.state == "running":
                    out[inst.group] += pred(inst)
        return out

    # --- event loop ---------------------------------------------------- #
    def run(self) -> FleetResult:
        for job in self.jobs:
            self._push(job.job.spec.arrival, "arrive", job)
        while self.heap:
            t, _, kind, payload = heapq.heappop(self.heap)
            self._advance(t)
            if kind == "arrive":
                self._on_arrive(payload)          # type: ignore[arg-type]
            elif kind == "finish":
                self._on_finish(payload)          # type: ignore[arg-type]
            elif kind == "free":
                self._on_free(payload)            # type: ignore[arg-type]
            elif kind == "resize":
                self._on_resize(payload)          # type: ignore[arg-type]
            elif kind == "fail_node":
                self._on_fail_node(payload)       # type: ignore[arg-type]
            elif kind == "repair":
                self._on_repair(payload)          # type: ignore[arg-type]
            self.hints.clear()
        makespan = max((o.finish for o in self.outcomes() if o.completed),
                       default=0.0)
        return FleetResult(outcomes=tuple(self.outcomes()),
                           events=tuple(self.events),
                           capacities=tuple(self.cap),
                           makespan=makespan,
                           busy_node_seconds=self.busy,
                           useful_node_seconds=self.useful,
                           lost_node_seconds=self.lost)

    def outcomes(self) -> List[JobOutcome]:
        return [j.outcome for j in self.jobs]

    # --- handlers ------------------------------------------------------ #
    def _on_arrive(self, job: _Job) -> None:
        job.arrived = True
        self._emit("arrive", job.job.spec.name, -1, job.job.spec.base_width)
        if self.model.burst and job.job.spec.burst_iters > 0 \
                and not job.burst_done and job.job.spec.instances == 1:
            self._try_burst(job)
        self._dispatch()

    def _on_finish(self, payload: object) -> None:
        inst, epoch = payload  # type: ignore[misc]
        if epoch != inst.epoch:
            return
        job = inst.job
        inst.remaining -= inst.seg_iters
        self.useful += inst.seg_iters * (inst.it / inst.f) * inst.alloc
        self.free[inst.group] += inst.alloc
        was_burst = inst.burst_width > 0
        if was_burst:
            inst.burst_width = 0
            job.burst_done = True
            self._emit("return", job.job.spec.name, inst.group, inst.width)
        if inst.remaining <= 0:
            inst.state = "done"
            # wave-succession hint: an identical queued sibling admitted
            # at this exact timestamp inherits (anchor, wave) so its
            # finish stays anchor + (wave+1) * dur — multiplication, not
            # accumulation.
            if not was_burst:
                self.hints[(job.job.uid, inst.group, inst.width, inst.dur)] \
                    = (inst.anchor, inst.wave)
            self._emit("finish", job.job.spec.name, inst.group, inst.width)
            if job.done:
                job.outcome.finish = self.now
                job.outcome.completed = True
                self._emit("complete", job.job.spec.name, inst.group,
                           inst.width)
        else:
            # burst phase over: re-queue the tail at base width, paying
            # the reshard back down.
            inst.state = "queued"
            inst.group = -1
            inst.alloc = 0
            inst.width = job.job.spec.base_width
            inst.pending = self._remesh(job.job.state_bytes)
        inst.epoch += 1
        self._dispatch()

    def _on_free(self, payload: object) -> None:
        """Checkpoint write finished after a preempt/lend: the nodes
        come back (unless a failure downed them mid-write — those are
        already counted in ``down`` and return at their repair)."""
        group, nodes = payload  # type: ignore[misc]
        taken = min(nodes, self.transit_down[group])
        self.transit_down[group] -= taken
        self.free[group] += nodes - taken
        self._dispatch()

    def _on_resize(self, payload: object) -> None:
        """Grow/shrink redistribution finished: apply the new width and
        restart the compute segment."""
        inst, epoch, new_width = payload  # type: ignore[misc]
        if epoch != inst.epoch:
            return
        job = inst.job
        prof = job.job.profile(new_width)
        # allocation is always clamped to the hosting group (the
        # oversubscribed legacy convention): a shrink whose new width
        # still exceeds the group frees nothing extra.
        unit = min(new_width, self.cap[inst.group])
        if unit < inst.alloc:
            self.free[inst.group] += inst.alloc - unit
        inst.alloc = unit
        inst.width = new_width
        inst.f, inst.tau = self._ckpt(job, new_width)
        inst.it = prof.iter_times[inst.group] * inst.f
        inst.anchor = self.now
        inst.wave = 1
        inst.dur = inst.remaining * inst.it
        inst.seg_iters = inst.remaining
        inst.compute_start = self.now
        inst.resizing = False
        inst.epoch += 1
        self._push(inst.anchor + inst.dur, "finish", (inst, inst.epoch))
        self._dispatch()

    # --- fault injection ----------------------------------------------- #
    def _on_fail_node(self, ev: FailureEvent) -> None:
        """``ev.nodes`` nodes of group ``ev.group`` go down: idle
        capacity absorbs the hit first, then running instances die
        (lowest-priority, latest-arrival first).  Nodes mid-checkpoint
        (a preempt/lend write in flight) are downed via the transit
        debt their pending free event settles."""
        g = ev.group
        want = min(ev.nodes, self.cap[g] - self.down[g])
        if want <= 0:
            return
        self.down[g] += want
        absorbed = min(want, self.free[g])
        self.free[g] -= absorbed
        need = want - absorbed
        if need > 0:
            victims = sorted(
                (i for j in self.jobs for i in j.instances
                 if i.state == "running" and i.group == g and i.alloc > 0),
                key=lambda i: i.key, reverse=True)
            for v in victims:
                if need <= 0:
                    break
                hit = min(need, v.alloc)
                need -= hit
                self._kill(v, hit)
        # any leftover lands on nodes whose checkpoint write is in flight
        self.transit_down[g] += need
        self._emit("fail_node", "fleet", g, want)
        self._push(self.now + ev.repair_s, "repair", (g, want))
        self._dispatch()

    def _on_repair(self, payload: object) -> None:
        """Repaired nodes rejoin the pool: outstanding transit debt is
        cancelled first (those nodes free when their write event
        fires), the rest move down -> free."""
        group, nodes = payload  # type: ignore[misc]
        taken = min(nodes, self.transit_down[group])
        self.transit_down[group] -= taken
        self.down[group] -= taken
        back = min(nodes - taken, self.down[group])
        self.down[group] -= back
        self.free[group] += back
        self._emit("repair", "fleet", group, nodes)
        self._dispatch()

    def _kill(self, inst: _Inst, down_nodes: int) -> None:
        """A node failure kills this instance: work rolls back to the
        last interval-quantized checkpoint boundary, surviving nodes
        free immediately (the job died — no checkpoint write), and the
        instance re-queues per its degradation policy with the restore
        charge."""
        job = inst.job
        self._fail_credit(inst)
        self.free[inst.group] += inst.alloc - down_nodes
        group = inst.group
        if inst.burst_width > 0:
            inst.burst_width = 0
            job.burst_done = True
        job.outcome.failures += 1
        inst.alloc = 0
        inst.resizing = False
        if inst.remaining <= 0:
            # the last interval boundary already committed the segment
            inst.state = "done"
            self._emit("finish", job.job.spec.name, group, inst.width)
            if job.done:
                job.outcome.finish = self.now
                job.outcome.completed = True
                self._emit("complete", job.job.spec.name, group, inst.width)
            return
        policy = job.job.spec.on_failure or self.model.degradation
        width = job.job.spec.width_menu[0] if policy == "shrink" \
            else job.job.spec.base_width
        inst.state = "queued"
        inst.group = -1
        inst.width = width
        inst.pending = self._delay(job.job.state_bytes)
        self._emit("fault", job.job.spec.name, group, width)

    def _fail_credit(self, inst: _Inst) -> None:
        """Interval-quantized rollback: only whole checkpoint intervals
        before the failure are committed; everything since the last
        boundary is discarded into ``lost``."""
        elapsed = max(0.0, self.now - inst.compute_start)
        done = 0
        if inst.it > 0 and elapsed > 0 and inst.tau > 0 \
                and not math.isinf(inst.tau):
            committed = math.floor(elapsed / inst.tau) * inst.tau
            done = min(inst.seg_iters, int(committed / inst.it))
        inst.remaining -= done
        self.useful += done * (inst.it / inst.f) * inst.alloc
        self.lost += max(0.0, elapsed - done * inst.it) * inst.alloc
        inst.epoch += 1

    # --- admission ----------------------------------------------------- #
    def _queued(self, job: _Job, planned: Optional[bool] = None
                ) -> List[_Inst]:
        out = [i for i in job.instances if i.state == "queued"]
        if planned is None:
            return out
        return [i for i in out if (i.group >= 0) == planned]

    def _dispatch(self) -> None:
        progress = True
        while progress:
            progress = False
            # 1. plan jobs with unplanned queued instances, priority first
            for job in sorted((j for j in self.jobs if j.arrived
                               and self._queued(j, planned=False)),
                              key=lambda j: (-j.priority, j.outcome.arrival,
                                             j.job.uid)):
                if self._plan_job(job):
                    progress = True
            # 2. admit planned queued instances into free nodes
            for inst in sorted((i for j in self.jobs if j.arrived
                                for i in self._queued(j, planned=True)),
                               key=lambda i: i.key):
                if self._try_start(inst):
                    progress = True
        if self.model.elastic:
            self._try_grow()

    def _plan_job(self, job: _Job) -> bool:
        queued = self._queued(job, planned=False)
        if not queued:
            return False
        width = queued[0].width
        plan = self._plan(job, self.free, width, queued)
        if plan is not None and plan[2] and self._admissible(
                plan[0], plan[1], width, self.free):
            self._assign(job, queued, *plan[:2], width, plan[2])
            return True
        # not feasibly placeable on what's free: reclaim via shrink,
        # then preemption
        if self.model.elastic and self._reclaim_for(job, width, queued):
            return True
        # can it ever run?  Plan against full capacity: if even that is
        # infeasible, adopt the legacy oversubscribed convention (flagged
        # infeasible — record parity with ScheduleModel); a job that IS
        # feasible at full capacity instead waits for its fitting groups
        # to free rather than squatting on a non-fitting one.
        full = self._plan(job, self.cap, width, queued)
        if full is None:
            job.outcome.feasible = False
            job.outcome.completed = False
            for i in queued:
                i.state = "done"
                i.remaining = 0
            self._emit("fail", job.job.spec.name, -1, width)
            return False
        if not full[2] and plan is not None and self._admissible(
                plan[0], plan[1], width, self.free):
            self._assign(job, queued, *plan[:2], width, plan[2])
            return True
        return False

    def _reclaim_for(self, job: _Job, width: int, queued: List[_Inst]
                     ) -> bool:
        """Free nodes for ``job`` by shrinking elastic lower-priority
        tenants, then preempting them outright (policy permitting)."""
        pr = job.priority

        def shrinkable(inst: _Inst) -> int:
            menu = inst.job.job.spec.width_menu
            if inst.job.priority >= pr or not inst.job.job.spec.elastic \
                    or inst.burst_width > 0 or inst.resizing:
                return 0
            return max(0, inst.alloc - min(menu[0], self.cap[inst.group]))

        def preemptable(inst: _Inst) -> int:
            if inst.job.priority >= pr \
                    or not inst.job.job.spec.preemptible \
                    or inst.burst_width > 0 or inst.resizing:
                return 0
            return inst.alloc

        for pred, action in ((shrinkable, self._shrink),
                             (preemptable, self._preempt)):
            if pred is preemptable and not self.model.preempt:
                continue
            extra = self._reclaimable(pred)
            avail = [f + e for f, e in zip(self.free, extra)]
            plan = self._plan(job, avail, width, queued)
            if plan is None or not plan[2] \
                    or not self._admissible(plan[0], plan[1], width, avail):
                continue
            counts, conc, feas = plan
            # reclaim in each group this plan lands on, neediest first
            for g, c in enumerate(counts):
                need = conc[g] * min(width, self.cap[g]) - self.free[g]
                if c == 0 or need <= 0:
                    continue
                victims = sorted(
                    (i for job2 in self.jobs for i in job2.instances
                     if i.state == "running" and i.group == g and pred(i)),
                    key=lambda i: (i.job.priority, i.job.outcome.arrival))
                freed = 0
                for v in victims:
                    if freed >= need:
                        break
                    freed += action(v)
            self._assign(job, queued, counts, conc, width, feas)
            return True
        return False

    def _try_start(self, inst: _Inst) -> bool:
        g = inst.group
        job = inst.job
        unit = min(inst.width, self.cap[g])
        running = sum(1 for i in job.instances
                      if i.state == "running" and i.group == g
                      and i.burst_width == 0)
        if inst.burst_width == 0 and running >= inst.conc_cap:
            return False
        if self.free[g] < unit:
            return False
        self.free[g] -= unit
        inst.alloc = unit
        inst.state = "running"
        width = inst.burst_width or inst.width
        prof = job.job.profile(width)
        inst.f, inst.tau = self._ckpt(job, width)
        inst.it = prof.iter_times[g] * inst.f
        inst.seg_iters = min(inst.remaining, job.job.spec.burst_iters) \
            if inst.burst_width else inst.remaining
        inst.dur = inst.seg_iters * inst.it
        hint = self.hints.pop((job.job.uid, g, inst.width, inst.dur), None) \
            if inst.pending == 0.0 and not inst.burst_width else None
        if hint is not None:
            inst.anchor, inst.wave = hint[0], hint[1] + 1
        else:
            inst.anchor = self.now + inst.pending
            inst.wave = 1
        inst.pending = 0.0
        inst.compute_start = inst.anchor + (inst.wave - 1) * inst.dur
        inst.epoch += 1
        self._push(inst.anchor + inst.wave * inst.dur, "finish",
                   (inst, inst.epoch))
        if job.outcome.first_start > self.now:
            job.outcome.first_start = self.now
        if inst.burst_width:
            job.outcome.bursts += 1
            self._emit("lend", job.job.spec.name, g, inst.burst_width)
        self._emit("start", job.job.spec.name, g, width)
        return True

    # --- disturbances -------------------------------------------------- #
    def _interrupt(self, inst: _Inst) -> None:
        """Stop a running segment at the current iteration boundary:
        credit completed iterations, invalidate the pending finish."""
        done = 0
        if self.now > inst.compute_start and inst.it > 0:
            done = min(inst.seg_iters,
                       int((self.now - inst.compute_start) / inst.it))
        inst.remaining -= done
        self.useful += done * (inst.it / inst.f) * inst.alloc
        inst.epoch += 1

    def _preempt(self, inst: _Inst, kind: str = "preempt") -> int:
        """Checkpoint a running instance off its nodes; they free once
        the write completes, the victim re-queues with the restore
        charge (plus the lend hand-off tax when this is a burst lend)."""
        self._interrupt(inst)
        job = inst.job
        nodes, group = inst.alloc, inst.group
        bytes_ = job.job.state_bytes
        tax = self.model.lend_overhead if kind == "lend" else 0.0
        self._push(self.now + self._delay(bytes_) + tax, "free",
                   (group, nodes))
        inst.state = "queued"
        inst.group = -1
        inst.alloc = 0
        inst.width = job.job.spec.base_width
        inst.pending = self._delay(bytes_) + tax
        job.outcome.preemptions += 1
        self._emit(kind, job.job.spec.name, group, inst.width)
        return nodes

    def _lend(self, inst: _Inst) -> int:
        return self._preempt(inst, kind="lend")

    def _shrink(self, inst: _Inst) -> int:
        """Elastic shed to the narrowest width: nodes free once the
        remesh completes."""
        self._interrupt(inst)
        job = inst.job
        new = job.job.spec.width_menu[0]
        freed = inst.alloc - min(new, self.cap[inst.group])
        inst.state = "running"
        inst.resizing = True
        job.outcome.resizes += 1
        self._emit("shrink", job.job.spec.name, inst.group, new)
        self._push(self.now + self._remesh(job.job.state_bytes), "resize",
                   (inst, inst.epoch, new))
        return freed

    def _try_grow(self) -> None:
        """Grow elastic tenants into idle nodes when nothing is queued
        and the saved compute outweighs the remesh delay."""
        if any(self._queued(j) for j in self.jobs if j.arrived):
            return
        for job in self.jobs:
            if not job.job.spec.elastic:
                continue
            for inst in job.instances:
                if inst.state != "running" or inst.burst_width > 0 \
                        or inst.resizing:
                    continue
                if self.now < inst.compute_start or inst.it <= 0:
                    continue
                g = inst.group
                menu = job.job.spec.width_menu
                left = inst.seg_iters - int(
                    (self.now - inst.compute_start) / inst.it)
                cost = self._remesh(job.job.state_bytes)
                best = 0
                for w in menu:
                    # only grow into real nodes: a width beyond the
                    # hosting group would claim speedup it cannot host.
                    if w <= inst.width or w > self.cap[g] \
                            or w - inst.alloc > self.free[g]:
                        continue
                    prof = job.job.profile(w)
                    if not prof.fits[g]:
                        continue
                    f_w, _ = self._ckpt(job, w)
                    gain = left * (inst.it - prof.iter_times[g] * f_w)
                    if gain > cost:
                        best = w
                if best:
                    self._interrupt(inst)
                    self.free[g] -= best - inst.alloc
                    inst.alloc = best
                    inst.resizing = True
                    job.outcome.resizes += 1
                    self._emit("grow", job.job.spec.name, g, best)
                    self._push(self.now + cost, "resize",
                               (inst, inst.epoch, best))

    def _try_burst(self, job: _Job) -> None:
        """On arrival of a burst-marked job: pick the widest obtainable
        width on the best group (free nodes + what lower-priority
        tenants can lend) and pause the lenders."""
        spec = job.job.spec
        inst = job.instances[0]
        menu = spec.width_menu
        pr = spec.priority

        def lendable(i: _Inst) -> int:
            if i.job.priority >= pr or not i.job.job.spec.preemptible \
                    or i.burst_width > 0 or i.resizing:
                return 0
            return i.alloc

        lend = self._reclaimable(lendable)
        best_g, best_w = -1, 0
        for g in range(len(self.cap)):
            budget = min(self.free[g] + lend[g],
                         spec.max_nodes or self.cap[g])
            for w in menu:
                prof = job.job.profile(w)
                if w <= budget and prof.fits[g] and w > best_w:
                    best_g, best_w = g, w
        if best_g < 0 or best_w <= spec.base_width:
            return    # bursting buys nothing; take the normal path
        need = best_w - self.free[best_g]
        if need > 0:
            victims = sorted(
                (i for j2 in self.jobs for i in j2.instances
                 if i.state == "running" and i.group == best_g
                 and lendable(i)),
                key=lambda i: (i.job.priority, i.job.outcome.arrival))
            freed = 0
            for v in victims:
                if freed >= need:
                    break
                freed += self._lend(v)
        inst.group = best_g
        inst.width = best_w
        inst.burst_width = best_w
        inst.conc_cap = 1
        inst.pending = self._remesh(job.job.state_bytes)


__all__ = ["DEGRADATION_POLICIES", "FLEET_POLICIES", "FleetEvent",
           "FleetModel", "FleetResult", "FleetSimulator", "JobOutcome"]
