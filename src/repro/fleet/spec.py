"""Study-native fleet wiring: ``FleetSpec`` -> ``run_study``.

A :class:`FleetSpec` is the multi-tenant twin of
:class:`repro.serving.ServingSpec`: a job mix + cluster + fleet-policy
knobs + arrival trace, swept over axes.  ``run_study`` accepts it
directly (via :meth:`FleetSpec.to_study`) and emits the timeline-native
columns ``fleet_util / turnaround_p50 / turnaround_p99 / preemptions /
resize_events / burst_events / jobs_completed`` next to the usual cost
columns (``total`` is the timeline makespan, so ``perf_per_dollar``
prices the whole fleet's throughput per TCO dollar).

Axes whose dotted path starts with ``fleet.`` / ``ftrace.`` / ``fail.``
rewrite the fleet point (``Axis("policy", ("static", "elastic+burst"),
path="fleet.policy")``, ``Axis("rate", (...), path="ftrace.rate")``,
``Axis("mtbf", (...), path="fail.mtbf_hours")``) through the same
:func:`repro.core.study.set_by_path` machinery cluster axes use.  The
``failures`` trace (default: disabled) injects node failures into the
timeline and populates the ``failures / lost_work_frac / goodput``
columns.  Per-iteration times are re-queried from the compiled study
engine at every width on a job's elastic menu
(:func:`repro.core.simulator.group_breakdowns_compiled`), memoized per
(job identity, width, cluster).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

from repro.core.cluster import ClusterLike
from repro.core.simulator import group_breakdowns_compiled
from repro.core.study import (Axis, StudyContext, StudySpec, check_path,
                              set_by_path)
from repro.core.workload import Workload, decompose, decompose_dlrm
from repro.fleet.jobs import FleetJob, FleetJobSpec, WidthProfile
from repro.fleet.resize import instance_state_bytes
from repro.fleet.simulator import FleetModel, FleetResult, FleetSimulator
from repro.fleet.trace import FleetTrace
from repro.reliability.trace import FailureTrace

FLEET_COLUMNS: Tuple[str, ...] = (
    "fleet_util", "turnaround_p50", "turnaround_p99", "preemptions",
    "resize_events", "burst_events", "jobs_completed", "failures",
    "lost_work_frac", "goodput")

_POINT_FIELDS: Tuple[str, ...] = ("fleet", "ftrace", "fail")


@dataclasses.dataclass(frozen=True)
class FleetPoint:
    """The per-cell fleet state dotted-path axes rewrite."""

    fleet: FleetModel
    ftrace: FleetTrace
    fail: FailureTrace = dataclasses.field(default_factory=FailureTrace)


def is_fleet_axis(axis: Axis) -> bool:
    """True when the axis path rewrites the fleet point, not the
    cluster (``fleet.* / ftrace.*``)."""
    return (axis.kind == "cluster" and axis.path is not None
            and axis.path.partition(".")[0] in _POINT_FIELDS)


def build_workload(spec: FleetJobSpec, width: int) -> Workload:
    """Lower one job at one width: DLRM jobs shard over all ``width``
    nodes (the §V-C hybrid strategy); anything else decomposes with
    ``mp`` fixed and DP = width / mp — the elastic-DP convention the
    resize events re-query."""
    from repro.configs import get_config, get_dlrm_config
    from repro.configs.base import ShapeConfig
    if spec.model.startswith("dlrm"):
        return decompose_dlrm(get_dlrm_config(), spec.global_batch, width)
    if width % spec.mp != 0:
        raise ValueError(
            f"job {spec.name!r}: width {width} not divisible by mp={spec.mp}")
    shape = ShapeConfig(f"fleet-{spec.name}", 4096, spec.global_batch,
                        "train")
    return decompose(get_config(spec.model), shape, mp=spec.mp,
                     dp=width // spec.mp)


@dataclasses.dataclass
class FleetSpec:
    """A declarative fleet study: templates + trace + policy knobs.

    ``jobs`` are the template mix the trace stamps arrivals onto
    (``ftrace.kind == "static"`` replays them verbatim).  ``placement``
    resolves through the core registry (``"paper"`` / ``"em-aware"``);
    ``metrics`` adds derived columns exactly as on ``StudySpec``."""

    name: str
    jobs: Tuple[FleetJobSpec, ...]
    cluster: Optional[ClusterLike] = None
    fleet: FleetModel = dataclasses.field(default_factory=FleetModel)
    ftrace: FleetTrace = dataclasses.field(
        default_factory=lambda: FleetTrace(kind="static"))
    failures: FailureTrace = dataclasses.field(default_factory=FailureTrace)
    axes: Sequence[Axis] = ()
    placement: Any = "paper"
    zero_stage: int = 2
    metrics: Dict[str, Callable[[StudyContext], Any]] = \
        dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.jobs:
            raise ValueError("a fleet study needs at least one job template")
        point = self.point()
        for axis in self.axes:
            if is_fleet_axis(axis):
                check_path(point, axis.path or "")

    def point(self) -> FleetPoint:
        return FleetPoint(self.fleet, self.ftrace, self.failures)

    def to_study(self) -> "FleetStudy":
        """Lower to a StudySpec the study engine runs unchanged: fleet
        axes become label axes the evaluator folds back into the fleet
        point; everything else passes through."""
        fleet_axes = [a for a in self.axes if is_fleet_axis(a)]
        study_axes = [dataclasses.replace(a, path=None)
                      if is_fleet_axis(a) else a for a in self.axes]
        spec = self
        profile_memo: Dict[Any, WidthProfile] = {}

        def evaluate(ctx: StudyContext) -> Dict[str, Any]:
            point = spec.point()
            for axis in fleet_axes:
                point = set_by_path(point, axis.path or "",
                                    ctx.point[axis.name],
                                    scale=(axis.mode == "scale"))
            placement = ctx.placement if ctx.placement is not None \
                else spec.placement
            return fleet_record(ctx.cluster, spec, point, placement,
                                profile_memo)

        return FleetStudy(
            name=self.name, cluster=self.cluster, axes=tuple(study_axes),
            placement=self.placement, metrics=dict(self.metrics),
            evaluate=evaluate, fleet=self)


@dataclasses.dataclass
class FleetStudy(StudySpec):
    """The lowered StudySpec, carrying its source :class:`FleetSpec` so
    ``run_study(validate=)`` can run the F1xx fleet rules on it."""

    fleet: Optional[FleetSpec] = None


# --------------------------------------------------------------------- #
# The per-cell evaluator
# --------------------------------------------------------------------- #

def _infeasible(reason: str) -> Dict[str, Any]:
    return {"fleet_util": 0.0, "turnaround_p50": float("inf"),
            "turnaround_p99": float("inf"), "preemptions": 0,
            "resize_events": 0, "burst_events": 0, "jobs_completed": 0,
            "failures": 0, "lost_work_frac": 0.0, "goodput": 0.0,
            "makespan": float("inf"), "total": float("inf"),
            "feasible": False, "n_events": 0,
            "infeasible_reason": reason}


def _profiles(job: FleetJobSpec, cluster: ClusterLike, zero_stage: int,
              placement: Any,
              memo: Dict[Any, WidthProfile]) -> Dict[int, WidthProfile]:
    """Per-width profiles for one job on one cluster, timed by the
    compiled study engine (re-queried at every width on the elastic
    menu, memoized across cells)."""
    out: Dict[int, WidthProfile] = {}
    groups = cluster.node_groups
    for width in job.width_menu:
        try:
            ckey = (job.model, job.mp, job.global_batch, width, zero_stage,
                    cluster, getattr(placement, "label", placement))
            hash(ckey)
        except TypeError:
            ckey = None
        if ckey is not None and ckey in memo:
            out[width] = memo[ckey]
            continue
        wl = build_workload(job, width)
        per = group_breakdowns_compiled(
            wl.compiled(), cluster, zero_stage=zero_stage,
            placement=placement, env_cache={})
        prof = WidthProfile(
            iter_times=tuple(b.total for b in per),
            fits=tuple(b.feasible for b in per),
            state_bytes=instance_state_bytes(wl))
        if ckey is not None:
            memo[ckey] = prof
        out[width] = prof
    return out


def fleet_record(cluster: Optional[ClusterLike], spec: FleetSpec,
                 point: FleetPoint, placement: Any,
                 profile_memo: Optional[Dict[Any, WidthProfile]] = None,
                 ) -> Dict[str, Any]:
    """Evaluate one fleet cell: materialize the trace over the template
    mix, profile every (job, width) on the cell's cluster, replay the
    timeline, attach the fleet columns."""
    if cluster is None:
        return _infeasible("fleet study needs a cluster")
    from repro.core.placement import get_placement
    placement = get_placement(placement)
    memo = profile_memo if profile_memo is not None else {}
    try:
        specs = point.ftrace.materialize(spec.jobs)
    except ValueError as exc:
        return _infeasible(str(exc))
    jobs = []
    for uid, js in enumerate(specs):
        try:
            profiles = _profiles(js, cluster, spec.zero_stage, placement,
                                 memo)
        except ValueError as exc:
            return _infeasible(str(exc))
        jobs.append(FleetJob(spec=js, profiles=profiles, uid=uid))
    groups = cluster.node_groups
    sim = FleetSimulator(
        capacities=[g.num_nodes for g in groups],
        model=point.fleet, placement=placement,
        failures=point.fail,
        pod_sizes=[min(getattr(g.topology, "pod_size", g.num_nodes),
                       g.num_nodes) for g in groups])
    res: FleetResult = sim.run(jobs)
    return {
        "fleet_util": res.fleet_util,
        "turnaround_p50": res.turnaround_p50,
        "turnaround_p99": res.turnaround_p99,
        "preemptions": res.preemptions,
        "resize_events": res.resize_events,
        "burst_events": res.burst_events,
        "jobs_completed": res.jobs_completed,
        "failures": res.failures,
        "lost_work_frac": res.lost_work_frac,
        "goodput": res.goodput,
        "makespan": res.makespan,
        # "total" prices the cell: 1 / (makespan * tco) becomes the
        # fleet's perf_per_dollar through the standard cost columns.
        "total": res.makespan if res.makespan > 0 else float("inf"),
        "feasible": res.feasible,
        "n_events": len(res.events),
    }


__all__ = ["FLEET_COLUMNS", "FleetPoint", "FleetSpec", "FleetStudy",
           "build_workload", "fleet_record", "is_fleet_axis"]
