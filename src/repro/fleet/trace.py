"""Deterministic fleet arrival/duration traces.

:class:`FleetTrace` is the training twin of
:class:`repro.serving.traffic.TrafficTrace`: a frozen knob bundle whose
job stream regenerates from the seed, so a dotted-path axis
(``Axis("rate", (...), path="ftrace.rate")``) rewrites the trace like
any other study knob — ``dataclasses.replace`` + re-materialize.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Sequence, Tuple

import numpy as np

from repro.fleet.jobs import FleetJobSpec

FLEET_TRACE_KINDS: Tuple[str, ...] = ("static", "poisson", "uniform")


@dataclasses.dataclass(frozen=True)
class FleetTrace:
    """A job-arrival process over a template mix.

    * ``static`` — the templates ARE the trace: each template's own
      ``arrival`` / ``iterations`` are kept verbatim (the degenerate,
      no-churn fleet — a single static template reproduces
      ``ScheduleModel`` exactly);
    * ``poisson`` — ``num_jobs`` arrivals with exponential interarrivals
      at ``rate`` jobs/s, cycling the template mix;
    * ``uniform`` — deterministic ``1/rate`` spacing (closed-form
      sanity).

    ``mean_iterations > 0`` additionally redraws each job's iteration
    count from a geometric-like exponential around the mean (min 1);
    ``0`` keeps every template's own ``iterations``.
    """

    kind: str = "poisson"
    rate: float = 1.0 / 300.0
    num_jobs: int = 8
    seed: int = 0
    mean_iterations: int = 0

    def __post_init__(self) -> None:
        if self.kind not in FLEET_TRACE_KINDS:
            raise ValueError(f"kind must be one of {FLEET_TRACE_KINDS}, "
                             f"got {self.kind!r}")

    @cached_property
    def arrivals(self) -> Tuple[float, ...]:
        """Arrival times in seconds from t=0 (empty for ``static`` — the
        templates carry their own)."""
        if self.kind == "static":
            return ()
        if self.rate <= 0 or self.num_jobs <= 0:
            raise ValueError(
                f"trace needs rate > 0 and num_jobs > 0, got "
                f"rate={self.rate}, num_jobs={self.num_jobs}")
        n = self.num_jobs
        if self.kind == "uniform":
            step = 1.0 / self.rate
            return tuple(i * step for i in range(n))
        rng = np.random.default_rng(self.seed)
        gaps = rng.exponential(1.0 / self.rate, size=n)
        gaps[0] = 0.0
        return tuple(np.cumsum(gaps).tolist())

    def materialize(self, templates: Sequence[FleetJobSpec]
                    ) -> Tuple[FleetJobSpec, ...]:
        """Stamp the trace onto the template mix: one spec per arrival
        (templates cycled), with ``arrival`` — and, when
        ``mean_iterations`` is set, ``iterations`` — rewritten.  The
        ``static`` kind returns the templates untouched."""
        if not templates:
            raise ValueError("fleet trace needs at least one job template")
        if self.kind == "static":
            return tuple(templates)
        arrivals = self.arrivals
        iters: Tuple[int, ...] = ()
        if self.mean_iterations > 0:
            rng = np.random.default_rng(self.seed + 1)
            draws = rng.exponential(float(self.mean_iterations),
                                    size=len(arrivals))
            iters = tuple(max(1, int(round(d))) for d in draws)
        out = []
        for i, t in enumerate(arrivals):
            tpl = templates[i % len(templates)]
            spec = dataclasses.replace(
                tpl, name=f"{tpl.name}#{i}", arrival=float(t))
            if iters:
                spec = dataclasses.replace(spec, iterations=iters[i])
            out.append(spec)
        return tuple(out)

    @property
    def duration(self) -> float:
        return self.arrivals[-1] if self.arrivals else 0.0


__all__ = ["FLEET_TRACE_KINDS", "FleetTrace"]
