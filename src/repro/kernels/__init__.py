"""Pallas TPU kernels for the perf-critical compute layers.

flash_attention — causal GQA flash attention (VMEM tiles, MXU-aligned)
ssd_scan        — Mamba2 SSD chunked scan (state carried in VMEM scratch)
rmsnorm         — fused norm
embedding_bag   — pooled DLRM lookups (explicit-DMA gather)

ops.py: jit'd wrappers (native on TPU, interpret-mode/ref elsewhere).
ref.py: pure-jnp oracles for the allclose tests.
"""

from repro.kernels import ops, ref  # noqa: F401
