"""Pooled embedding-bag lookup — Pallas TPU kernel (DLRM hot spot).

Each grid step handles one (sample, table) pair: gathers L rows from the
table shard resident in HBM/ANY memory by dynamic index and accumulates the
pooled sum in VMEM. On TPU this becomes a sequence of DMA row fetches —
the analogue of the GPU's per-warp gather, adapted to the explicit-DMA TPU
memory hierarchy (no hardware gather on the vector unit).

tables: (T, R, E); indices: (B, T, L) int32 -> out: (B, T, E).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bag_kernel(idx_ref, table_ref, o_ref):
    lpool = idx_ref.shape[-1]

    def body(i, acc):
        row = idx_ref[0, 0, i]
        # Index the leading (blocked) dim with a length-1 dslice too: a bare
        # int here trips pallas' load discharge rule (no .shape on int).
        return acc + pl.load(
            table_ref,
            (pl.dslice(0, 1), pl.dslice(row, 1), slice(None)))[0, 0].astype(
                jnp.float32)

    e = table_ref.shape[-1]
    acc = jax.lax.fori_loop(0, lpool, body,
                            jnp.zeros((e,), jnp.float32))
    o_ref[0, 0] = acc.astype(o_ref.dtype)


def embedding_bag(tables: jax.Array, indices: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    """tables: (T, R, E); indices: (B, T, L) -> (B, T, E)."""
    t, r, e = tables.shape
    b, t2, lpool = indices.shape
    assert t == t2
    grid = (b, t)
    out = pl.pallas_call(
        _bag_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, lpool), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, r, e), lambda bi, ti: (ti, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, e), lambda bi, ti: (bi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, e), tables.dtype),
        interpret=interpret,
    )(indices, tables)
    return out
