"""Flash attention forward — Pallas TPU kernel.

TPU-native adaptation: the GPU flash-attention algorithm is re-tiled for
VMEM + MXU. Query/key blocks are MXU-aligned (multiples of 128 on the
contraction dims); the softmax running statistics (m, l) and the fp32
accumulator live in VMEM scratch that persists across the sequential
kv-block grid dimension (TPU grids execute in order, unlike CUDA thread
blocks — this replaces the GPU kernel's shared-memory reduction).

GQA is handled in the index map: the kv-head block index is derived from
the query-head grid index (``h // group``), so KV is never materialized
per-query-head in HBM.

Layout: q (b, h, sq, d), k/v (b, hkv, skv, d) -> out (b, h, sq, d).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, bq: int, bk: int,
                  sq_valid: int, skv_valid: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    # Skip fully-masked blocks (strictly above the causal diagonal).
    run = (k_start <= q_start + bq - 1) if causal else True

    @pl.when(run)
    def _body():
        q = q_ref[0, 0]                       # (bq, d)
        k = k_ref[0, 0]                       # (bk, d)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < skv_valid                # kv padding
        if causal:
            mask &= kpos <= qpos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, block_q: int = 512,
                        block_k: int = 512,
                        interpret: bool = True) -> jax.Array:
    """q: (b, h, sq, d); k/v: (b, hkv, skv, d). Returns (b, h, sq, d)."""
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert h % hkv == 0
    group = h // hkv
    bq = min(block_q, max(1, sq))
    bk = min(block_k, max(1, skv))
    pq = (-sq) % bq
    pk = (-skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = qp.shape[2] // bq
    nk = kp.shape[2] // bk
    grid = (b, h, nq, nk)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, bq=bq, bk=bk,
        sq_valid=sq, skv_valid=skv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, group=group:
                         (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, group=group:
                         (bi, hi // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max m
            pltpu.VMEM((bq,), jnp.float32),       # running denom l
            pltpu.VMEM((bq, d), jnp.float32),     # fp32 accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :sq, :]
