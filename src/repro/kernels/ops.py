"""jit'd public wrappers around the Pallas kernels.

On TPU the kernels lower natively; everywhere else (this CPU container, the
dry-run) they run in ``interpret=True`` mode or fall back to the jnp oracle.
``use_pallas()`` picks the default; every op takes an explicit override.

The model code calls these through ``repro.models`` only where the fusion
matters (attention inner loop, SSD scan); see DESIGN.md §Kernels for the
integration policy.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag as _bag_kernel
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm_kernel
from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel


def on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def use_pallas() -> bool:
    """Native Pallas on TPU; interpret-mode Pallas elsewhere is opt-in
    (slow on CPU — tests enable it explicitly)."""
    return on_tpu()


@functools.partial(jax.jit, static_argnames=("causal", "impl"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True,
                    impl: Optional[str] = None) -> jax.Array:
    """q: (b, h, sq, d), k/v: (b, hkv, skv, d)."""
    impl = impl or ("pallas" if use_pallas() else "ref")
    if impl == "pallas":
        return flash_attention_fwd(q, k, v, causal=causal,
                                   interpret=not on_tpu())
    return ref.attention_ref(q, k, v, causal=causal)


@functools.partial(jax.jit, static_argnames=("impl",))
def ssd(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
        cmat: jax.Array, impl: Optional[str] = None):
    impl = impl or ("pallas" if use_pallas() else "ref")
    if impl == "pallas":
        return _ssd_kernel(x, dt, a, bmat, cmat, interpret=not on_tpu())
    return ref.ssd_ref(x, dt, a, bmat, cmat)


@functools.partial(jax.jit, static_argnames=("eps", "impl"))
def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5,
            impl: Optional[str] = None) -> jax.Array:
    impl = impl or ("pallas" if use_pallas() else "ref")
    if impl == "pallas":
        return _rmsnorm_kernel(x, gamma, eps=eps, interpret=not on_tpu())
    return ref.rmsnorm_ref(x, gamma, eps)


@functools.partial(jax.jit, static_argnames=("impl",))
def embedding_bag(tables: jax.Array, indices: jax.Array,
                  impl: Optional[str] = None) -> jax.Array:
    impl = impl or ("pallas" if use_pallas() else "ref")
    if impl == "pallas":
        return _bag_kernel(tables, indices, interpret=not on_tpu())
    return ref.embedding_bag_ref(tables, indices)
