"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are deliberately the *simplest correct* implementations — the SSD
oracle is the literal per-step recurrence, not the chunked algorithm — so
kernel tests catch algorithmic errors, not shared bugs.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q: (b, h, sq, d); k/v: (b, hkv, skv, d)."""
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=1)
        v = jnp.repeat(v, h // hkv, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    s = s / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), v)


def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array,
            bmat: jax.Array, cmat: jax.Array):
    """Literal SSM recurrence, one step at a time.

    x: (b, h, s, p); dt: (b, h, s); a: (h,); bmat/cmat: (b, h, s, n).
    Returns (y: (b, h, s, p), final_state: (b, h, p, n))."""
    b, h, s, p = x.shape
    n = bmat.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp                      # (b,h,p),(b,h),(b,h,n),(b,h,n)
        da = jnp.exp(dtt * a)                      # (b, h)
        upd = jnp.einsum("bhp,bhn->bhpn", xt * dtt[..., None], bt)
        state = state * da[..., None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", ct, state)
        return state, y

    xs = (x.transpose(2, 0, 1, 3).astype(jnp.float32),
          dt.transpose(2, 0, 1).astype(jnp.float32),
          bmat.transpose(2, 0, 1, 3).astype(jnp.float32),
          cmat.transpose(2, 0, 1, 3).astype(jnp.float32))
    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    final, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 2, 0, 3).astype(x.dtype), final


def rmsnorm_ref(x: jax.Array, gamma: jax.Array,
                eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps))
            * gamma.astype(jnp.float32)).astype(x.dtype)


def embedding_bag_ref(tables: jax.Array, indices: jax.Array) -> jax.Array:
    """tables: (T, R, E); indices: (B, T, L) -> (B, T, E)."""
    gathered = jax.vmap(
        lambda tbl, idx: tbl[idx], in_axes=(0, 1), out_axes=1
    )(tables, indices)                             # (B, T, L, E)
    return gathered.sum(axis=2)
