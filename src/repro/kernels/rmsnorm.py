"""Fused RMSNorm — Pallas TPU kernel.

Row-blocked: each grid step normalizes a (rows x d) VMEM tile in fp32 and
applies the gain, fusing what XLA would otherwise emit as several HBM
round-trips on the (tokens, d_model) activation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (out * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, gamma: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 256, interpret: bool = True) -> jax.Array:
    """x: (..., d); gamma: (d,)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for dim in orig_shape[:-1]:
        rows *= dim
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, gamma)
    return out[:rows].reshape(orig_shape)
