"""Mamba2 SSD chunked scan — Pallas TPU kernel.

TPU-native adaptation of the SSD algorithm (state-space duality): each chunk
becomes three MXU GEMMs (CB^T masked "attention", state build, state apply);
the (p x n) inter-chunk state is carried in fp32 VMEM scratch across the
sequential chunk grid dimension. On GPU this recurrence needs a separate
kernel launch or grid-wide sync; the TPU sequential grid makes it a single
kernel.

All decay terms are exp of non-positive cumsums (A < 0, dt > 0), so the
kernel is numerically stable without rescaling.

Layout: x (b, h, s, p), dt (b, h, s), A (h,), Bmat/Cmat (b, h, s, n)
        -> y (b, h, s, p), final_state (b, h, p, n).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_out_ref,
                state_scr, *, chunk: int, s_valid: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)        # (Q, p)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q,)
    a = a_ref[0].astype(jnp.float32)           # scalar decay rate (negative)
    bm = b_ref[0, 0].astype(jnp.float32)       # (Q, n)
    cm = c_ref[0, 0].astype(jnp.float32)       # (Q, n)

    # Zero padded tail positions (dt = 0 -> identity recurrence).
    pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0)
    dt = jnp.where(pos < s_valid, dt, 0.0)

    dA = dt * a                                 # (Q,) <= 0
    cs = jnp.cumsum(dA)
    # L[i, j] = exp(sum_{j+1..i} dA) for i >= j else 0.
    diff = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    dtx = x * dt[:, None]                       # (Q, p)
    # Diagonal (within-chunk) term.
    G = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    y = jax.lax.dot_general(G * L, dtx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, p)
    # Off-diagonal: apply carried state.
    prev = state_scr[...]                       # (p, n)
    decay_in = jnp.exp(cs)                      # (Q,)
    y += decay_in[:, None] * jax.lax.dot_general(
        cm, prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (Q, n) x (p, n)^T
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # State update: S = S * exp(sum dA) + (dtx * decay_to_end)^T @ B.
    decay_out = jnp.exp(cs[-1] - cs)            # (Q,)
    new_state = prev * jnp.exp(cs[-1]) + jax.lax.dot_general(
        dtx * decay_out[:, None], bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # (p, n)
    state_scr[...] = new_state

    @pl.when(ci == nc - 1)
    def _emit_state():
        st_out_ref[0, 0] = new_state


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array,
             bmat: jax.Array, cmat: jax.Array, *, chunk: int = 256,
             interpret: bool = True):
    """Returns (y: (b, h, s, p), final_state: (b, h, p, n))."""
    b, h, s, p = x.shape
    n = bmat.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad)))
        bmat = jnp.pad(bmat, ((0, 0), (0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = x.shape[2] // chunk
    grid = (b, h, nc)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, s_valid=s)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, bmat, cmat)
    return y[:, :, :s, :], st
