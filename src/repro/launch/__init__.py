"""Launchers: production mesh, multi-pod dry-run, train/serve drivers.

NOTE: importing repro.launch.dryrun sets XLA_FLAGS (512 host devices) as its
first statement — import it only in dedicated processes, never from tests.
"""
