import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs abstract state/batch/cache (ShapeDtypeStruct — no memory),
  3. jit-lowers the step (train_step / prefill_step / serve_step) with the
     full sharding contract from parallel/{sharding,zero}.py,
  4. .compile()s it — sharding mismatches, impossible layouts, and OOM at
     compile time all fail HERE, which is the point of the exercise,
  5. records memory_analysis / cost_analysis / per-collective bytes and the
     three roofline terms (core/hlo.py) into experiments/dryrun/*.json.

Usage:
  python -m repro.launch.dryrun --arch internlm2-20b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, all_cells, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.hlo import RooflineTerms, model_flops_util
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    abstract_cache,
    abstract_params,
    input_specs,
    model_flops,
)
from repro.models import get_model
from repro.parallel import (
    batch_shardings,
    cache_shardings,
    param_shardings,
    plan_memory,
)
from repro.train.train_step import jit_train_step
from repro.train.optimizer import AdamWConfig


def _abstract_state(cfg, plan):
    from repro.models import get_model
    from repro.train.optimizer import init_state

    model = get_model(cfg)
    opt_cfg = AdamWConfig(state_dtype=plan.opt_dtype,
                          use_master=plan.use_master)

    def build():
        params = model.init_params(jax.random.PRNGKey(0), cfg,
                                   dtype=jnp.bfloat16)
        return {"params": params, "opt": init_state(params, opt_cfg)}

    return jax.eval_shape(build)


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               remat_override: Optional[str] = None,
               cfg_transform=None, plan_transform=None):
    """Lower + compile one cell. Returns (compiled, info dict).

    ``cfg_transform`` / ``plan_transform`` are the §Perf hillclimb hooks:
    they rewrite the ModelConfig / MemoryPlan for a variant before
    lowering (e.g. MoE dispatch mode, remat policy, microbatch count)."""
    cfg = get_config(arch)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    tp = mesh.shape["model"]
    dp = chips // tp
    plan = plan_memory(cfg, tp=tp, dp=dp, shape=shape)
    if plan_transform is not None:
        plan = plan_transform(plan)
    if remat_override is not None:
        import dataclasses
        plan = dataclasses.replace(plan, remat=remat_override)
    model = get_model(cfg)
    batch = input_specs(cfg, shape)

    with mesh:
        if shape.kind == "train":
            state = _abstract_state(cfg, plan)
            step = jit_train_step(cfg, plan, mesh, state, batch,
                                  donate=False)
            rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
            lowered = step.lower(state, batch, rng)
        elif shape.kind == "prefill":
            params = abstract_params(cfg)
            cache = abstract_cache(cfg, shape)
            p_sh = param_shardings(cfg, params, mesh, fsdp=plan.fsdp)
            c_sh = cache_shardings(cfg, mesh, cache)
            b_sh = batch_shardings(mesh, batch, cfg)
            extras = {k: batch[k] for k in batch if k != "tokens"}

            def prefill_step(params, tokens, cache, extras):
                return model.prefill(params, cfg, tokens, cache, **extras)

            fn = jax.jit(prefill_step,
                         in_shardings=(p_sh, b_sh["tokens"], c_sh,
                                       {k: b_sh[k] for k in extras}),
                         out_shardings=(NamedSharding(mesh, P()), c_sh))
            lowered = fn.lower(params, batch["tokens"], cache, extras)
        else:  # decode -> serve_step
            params = abstract_params(cfg)
            cache = abstract_cache(cfg, shape)
            p_sh = param_shardings(cfg, params, mesh, fsdp=plan.fsdp)
            c_sh = cache_shardings(cfg, mesh, cache)
            b_sh = batch_shardings(mesh, batch, cfg)

            def serve_step(params, cache, tokens):
                return model.decode_step(params, cfg, cache, tokens)

            fn = jax.jit(serve_step,
                         in_shardings=(p_sh, c_sh, b_sh["tokens"]),
                         out_shardings=(NamedSharding(mesh, P()), c_sh))
            lowered = fn.lower(params, cache, batch["tokens"])

        t0 = time.monotonic()
        compiled = lowered.compile()
        compile_s = time.monotonic() - t0

    info = analyze(compiled, cfg, shape, chips)
    info.update({
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "zero_stage": plan.zero_stage,
        "opt_dtype": plan.opt_dtype, "remat": plan.remat,
        "microbatches": plan.microbatches,
        "compile_s": round(compile_s, 1),
    })
    return compiled, info


def analyze(compiled, cfg: ModelConfig, shape: ShapeConfig,
            chips: int) -> Dict:
    """Roofline terms + memory/cost analysis from the compiled artifact.

    Uses the trip-count-weighted HLO walk (core/hlo_analyzer) — XLA's own
    cost_analysis counts while-loop bodies once, which under-reports every
    scan-over-layers model (recorded alongside for reference)."""
    from repro.core.hlo_analyzer import analyze_hlo

    hlo = compiled.as_text()
    cost = analyze_hlo(hlo)
    coll = {k: int(v) for k, v in cost.coll.items()}
    flops = cost.flops * chips
    hbm = cost.bytes * chips
    terms = RooflineTerms(
        flops=flops, hbm_bytes=hbm,
        coll_bytes=float(sum(coll.values())) * chips,
        chips=chips, coll_breakdown=coll)
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, list):
        xla_cost = xla_cost[0]
    mf = model_flops(cfg, shape)
    info = terms.as_dict()
    info["model_flops"] = mf
    info["model_flops_util"] = model_flops_util(mf, terms)
    info["coll_breakdown"] = {k: v for k, v in coll.items() if v}
    info["xla_unweighted_flops"] = float(xla_cost.get("flops", 0.0)) * chips
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
                "output_bytes": getattr(ma, "output_size_in_bytes", 0),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
                "generated_code_bytes":
                    getattr(ma, "generated_code_size_in_bytes", 0),
            }
    except Exception:   # CPU backend may not implement it
        pass
    info["memory_analysis"] = mem
    return info


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str) -> Dict:
    tag = f"{arch}_{shape_name}_{'2x16x16' if multi_pod else '16x16'}"
    try:
        _, info = lower_cell(arch, shape_name, multi_pod)
        info["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        info = {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(info, f, indent=1, default=str)
    return info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch, shape_name, runnable, _ in all_cells():
            if runnable:
                cells.append((arch, shape_name))
    else:
        cells.append((args.arch, args.shape))
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    for arch, shape_name in cells:
        for mp in meshes:
            tag = f"{arch}_{shape_name}_{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    prev = json.load(f)
                if prev.get("status") == "ok":
                    continue
            t0 = time.monotonic()
            info = run_cell(arch, shape_name, mp, args.out)
            status = info["status"]
            extra = ""
            if status == "ok":
                extra = (f" dom={info['dominant']}"
                         f" frac={info['roofline_fraction']:.3f}"
                         f" compile={info['compile_s']}s")
            else:
                extra = " " + info["error"][:120]
            print(f"[{time.monotonic()-t0:7.1f}s] {arch:28s}"
                  f" {shape_name:12s} {info['mesh']:8s} {status}{extra}",
                  flush=True)


if __name__ == "__main__":
    main()
