"""Elastic restart utilities.

On a real cluster, a restart after node failure may come up with a
different healthy-slice size. The pieces that make this work live in:

  * checkpoint/checkpointer.py — leaves stored unsharded; ``restore`` takes
    the NEW mesh's shardings and device_puts each leaf under them,
  * data/pipeline.py — ``DataIterator.reshard`` re-splits the same
    deterministic stream across the new DP degree,
  * train/trainer.py — straggler watchdog + preemption flush.

``remesh_state`` is the one-call wrapper the launcher uses.
"""

from __future__ import annotations


from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.parallel.policy import MemoryPlan
from repro.train.train_step import state_shardings


def remesh_state(cfg: ModelConfig, plan: MemoryPlan, manager: CheckpointManager,
                 state_template, new_mesh):
    """Restore the latest checkpoint onto a different mesh."""
    sh = state_shardings(cfg, plan, state_template, new_mesh)
    state, extra = manager.restore_latest(target=state_template, shardings=sh)
    return state, extra, sh
