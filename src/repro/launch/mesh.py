"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before the first
jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods = 512 chips as (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small host-device mesh for tests (XLA_FLAGS device_count >= d*m)."""
    return jax.make_mesh((data, model), ("data", "model"))
