"""Serving driver: batched requests through the continuous-batching engine.

    python -m repro.launch.serve --arch smollm-135m --reduced \
        --num-requests 8 --max-new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.serve import Engine, EngineConfig, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    model = get_model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init_params(rng, cfg, dtype=jnp.float32)
    engine = Engine(cfg, params,
                    EngineConfig(max_batch=args.max_batch,
                                 max_seq=args.max_seq, seed=args.seed),
                    dtype=jnp.float32)
    rs = np.random.RandomState(args.seed)
    t0 = time.monotonic()
    for i in range(args.num_requests):
        plen = int(rs.randint(4, 24))
        prompt = rs.randint(0, cfg.vocab_size, size=plen).astype(np.int32)
        engine.submit(Request(uid=i, prompt=prompt,
                              max_new_tokens=args.max_new_tokens))
    done = engine.run_until_drained()
    dt = time.monotonic() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"  req {r.uid}: prompt[:4]={list(r.prompt[:4])} "
              f"out[:8]={r.out_tokens[:8]}")


if __name__ == "__main__":
    main()
