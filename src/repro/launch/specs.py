"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No device allocation anywhere: batches, params, optimizer states, and
decode caches are all abstract (jax.eval_shape / ShapeDtypeStruct), so the
dry-run can lower+compile full-size models on a 512-device host mesh.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import get_model

I32 = jnp.int32
BF16 = jnp.bfloat16


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(d) for d in shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Model inputs for one cell (modality frontends stubbed as embeddings)."""
    gb, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": _sds((gb, s), I32), "targets": _sds((gb, s), I32)}
        if cfg.family == "vlm":
            batch["patches"] = _sds((gb, cfg.vision.num_patches, cfg.d_model),
                                    BF16)
        if cfg.family == "encdec":
            src = int(s * cfg.encdec.source_frac)
            batch["tokens"] = _sds((gb, s - src), I32)
            batch["targets"] = _sds((gb, s - src), I32)
            batch["frames"] = _sds((gb, src, cfg.d_model), BF16)
        return batch
    if shape.kind == "prefill":
        out = {"tokens": _sds((gb, s), I32)}
        if cfg.family == "vlm":
            out["patches"] = _sds((gb, cfg.vision.num_patches, cfg.d_model),
                                  BF16)
        if cfg.family == "encdec":
            src = int(s * cfg.encdec.source_frac)
            out["tokens"] = _sds((gb, s - src), I32)
            out["frames"] = _sds((gb, src, cfg.d_model), BF16)
        return out
    # decode: one new token against a seq_len-deep cache
    return {"tokens": _sds((gb, 1), I32)}


def abstract_params(cfg: ModelConfig, dtype=BF16):
    model = get_model(cfg)
    return jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0), cfg, dtype=dtype))


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig, dtype=BF16):
    model = get_model(cfg)
    gb, s = shape.global_batch, shape.seq_len
    max_seq = s + (cfg.vision.num_patches if cfg.family == "vlm" else 0)
    if shape.kind == "decode":
        max_seq += 1
    kw = {}
    if cfg.family == "encdec":
        kw["src_len"] = int(s * cfg.encdec.source_frac)
        max_seq = s - kw["src_len"] + 1
    return jax.eval_shape(
        lambda: model.init_cache(cfg, gb, max_seq, dtype=dtype, **kw))


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D for inference forward passes
    (N = active params, D = tokens processed this step)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch  # decode: 1 token per sequence
