"""End-to-end training driver.

    python -m repro.launch.train --arch smollm-135m --steps 300 \
        --reduced --ckpt-dir /tmp/ckpt --resume auto

On this CPU container use ``--reduced`` (the same code path lowers the full
configs on the production mesh via dryrun.py). Auto-resume restores the
latest checkpoint — including the data-iterator cursor — and an elastic
restart onto a different device count re-shards state transparently.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, DataIterator
from repro.parallel import plan_memory
from repro.train import (
    AdamWConfig,
    Trainer,
    TrainerConfig,
    init_train_state,
    make_train_step,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--resume", default="no", choices=["no", "auto"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    plan = plan_memory(cfg, tp=1, dp=1)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 1),
                          state_dtype=plan.opt_dtype,
                          use_master=plan.use_master)
    rng = jax.random.PRNGKey(args.seed)
    state = init_train_state(cfg, plan, rng, opt_cfg, dtype=jnp.float32)
    step_fn = jax.jit(make_train_step(cfg, plan, opt_cfg))
    data = DataIterator(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=args.seed))
    trainer = Trainer(step_fn, state, data, TrainerConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_interval=args.ckpt_interval, log_interval=10, seed=args.seed))
    if args.resume == "auto":
        resumed = trainer.try_resume()
        print(f"resume: {'restored step ' + str(trainer.step) if resumed else 'fresh start'}")
    summary = trainer.run(rng)
    print("summary:", summary)


if __name__ == "__main__":
    main()
