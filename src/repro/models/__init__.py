"""Model registry: family -> implementation module.

Every module implements the same functional API:
  init_params(key, cfg, dtype) -> params
  forward(params, cfg, tokens, **modality_kwargs) -> (logits, aux, cache)
  loss(params, cfg, batch) -> (scalar, metrics)
  init_cache(cfg, batch, max_seq, dtype) -> cache      (decoder archs)
  prefill(params, cfg, tokens, cache, **kw) -> (logits, cache)
  decode_step(params, cfg, cache, tokens) -> (logits, cache)
"""

from repro.configs.base import ModelConfig
from repro.models import encdec, mamba, transformer


def get_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer
    if cfg.family in ("ssm", "hybrid"):
        return mamba
    if cfg.family == "encdec":
        return encdec
    raise ValueError(f"unknown family {cfg.family!r}")
