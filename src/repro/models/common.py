"""Shared model layers: norms, RoPE, GQA attention (full / blockwise /
cached-decode), FFN, MoE block, embeddings.

Everything is functional JAX: parameters are nested dicts of jnp arrays,
layers are pure functions. Layer stacks use stacked parameters + lax.scan so
the lowered HLO stays O(1) in depth (compile time matters at 512 devices).

The blockwise attention here is the pure-JAX (flash-style) algorithm that the
Pallas kernel in ``repro.kernels.flash_attention`` implements on-chip; on CPU
and in the dry-run the models run this path (see DESIGN.md §Kernels).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

DEFAULT_DTYPE = jnp.bfloat16

# Sequence length at/above which attention switches to the blockwise
# (flash-style) path to avoid materializing seq x seq score tensors.
BLOCKWISE_THRESHOLD = 4096
Q_BLOCK = 1024
KV_BLOCK = 1024


# --------------------------------------------------------------------- #
# Initializers
# --------------------------------------------------------------------- #

def dense_init(key, shape, dtype=DEFAULT_DTYPE, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=DEFAULT_DTYPE):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------- #

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------- #
# RoPE (with partial-rotary support for chatglm3's "2d RoPE")
# --------------------------------------------------------------------- #

def rope_frequencies(head_dim: int, fraction: float, theta: float,
                     positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for the rotary fraction of the head dim.

    positions: (..., seq) int32. Returns (..., seq, rot_dim//2) fp32 each."""
    rot_dim = int(head_dim * fraction)
    rot_dim -= rot_dim % 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32)
                                / rot_dim))
    angles = positions[..., None].astype(jnp.float32) * inv_freq
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (batch, seq, heads, head_dim); cos/sin: (batch, seq, rot//2)."""
    rot = 2 * cos.shape[-1]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    c = cos[..., None, :].astype(x.dtype)  # broadcast over heads
    s = sin[..., None, :].astype(x.dtype)
    # interleave back
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([y, x_pass], axis=-1) if x_pass.shape[-1] else y


# --------------------------------------------------------------------- #
# Attention
# --------------------------------------------------------------------- #

def _repeat_kv(k: jax.Array, num_q_heads: int) -> jax.Array:
    """(b, s, kv_heads, d) -> (b, s, q_heads, d) by group broadcast."""
    kv_heads = k.shape[-2]
    if kv_heads == num_q_heads:
        return k
    reps = num_q_heads // kv_heads
    return jnp.repeat(k, reps, axis=-2)


def naive_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True,
                    q_offset: int = 0) -> jax.Array:
    """Reference attention. q: (b, sq, h, d), k/v: (b, skv, h_kv, d)."""
    b, sq, h, d = q.shape
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(k.shape[1])
        mask = kpos[None, :] <= qpos[:, None]
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        q_block: int = Q_BLOCK,
                        kv_block: int = KV_BLOCK) -> jax.Array:
    """Flash-style attention: O(seq) memory via running-max softmax.

    Outer scan over query blocks, inner scan over kv blocks. This is the
    jnp oracle of the Pallas flash kernel (same tiling, on-chip there)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    # Pad to block multiples.
    pq = (-sq) % q_block
    pk = (-skv) % kv_block
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // q_block, kp.shape[1] // kv_block
    scale = 1.0 / math.sqrt(d)

    kb = kp.reshape(b, nk, kv_block, h, d)
    vb = vp.reshape(b, nk, kv_block, h, d)

    def q_step(_, qi):
        qblk, qidx = qi  # (b, qb, h, d), scalar block index

        def kv_step(carry, ki):
            acc, m, denom = carry
            kblk, vblk, kidx = ki
            logits = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk)
            logits = logits.astype(jnp.float32) * scale
            if causal:
                qpos = qidx * q_block + jnp.arange(q_block)
                kpos = kidx * kv_block + jnp.arange(kv_block)
                mask = kpos[None, :] <= qpos[:, None]
                logits = jnp.where(mask[None, None], logits, -1e30)
            # mask kv padding
            kvalid = (kidx * kv_block + jnp.arange(kv_block)) < skv
            logits = jnp.where(kvalid[None, None, None, :], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            denom_new = denom * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(qblk.dtype), vblk)
            acc_new = acc * alpha[..., None] + pv.astype(jnp.float32)
            return (acc_new, m_new, denom_new), None

        acc0 = jnp.zeros((b, h, q_block, d), jnp.float32)
        m0 = jnp.full((b, h, q_block), -jnp.inf, jnp.float32)
        denom0 = jnp.zeros((b, h, q_block), jnp.float32)
        (acc, m, denom), _ = jax.lax.scan(
            kv_step, (acc0, m0, denom0),
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
             jnp.arange(nk)))
        out = acc / jnp.maximum(denom, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    qb = qp.reshape(b, nq, q_block, h, d).transpose(1, 0, 2, 3, 4)
    _, outs = jax.lax.scan(q_step, None, (qb, jnp.arange(nq)))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nq * q_block, h, d)
    return out[:, :sq].transpose(0, 1, 2, 3)


def attention(q, k, v, causal=True, q_offset: int = 0):
    """Dispatch: blockwise for long sequences, naive otherwise."""
    if q.shape[1] >= BLOCKWISE_THRESHOLD and q.shape[1] == k.shape[1]:
        return blockwise_attention(q, k, v, causal=causal)
    return naive_attention(q, k, v, causal=causal, q_offset=q_offset)


# --------------------------------------------------------------------- #
# GQA attention block (params + apply, with optional KV cache)
# --------------------------------------------------------------------- #

def init_attention_params(key, d_in: int, d_out: int, num_heads: int,
                          num_kv_heads: int, head_dim: int,
                          dtype=DEFAULT_DTYPE) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d_in, num_heads * head_dim), dtype),
        "wk": dense_init(k2, (d_in, num_kv_heads * head_dim), dtype),
        "wv": dense_init(k3, (d_in, num_kv_heads * head_dim), dtype),
        "wo": dense_init(k4, (num_heads * head_dim, d_out), dtype,
                         scale=1.0 / math.sqrt(num_heads * head_dim)),
    }


def _batch_shard(t: jax.Array) -> jax.Array:
    """Constrain the leading (batch) dim over ("data", "model") — used when
    attention heads cannot shard over the model axis (see ModelConfig
    .attn_batch_shard)."""
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        t, P(("data", "model"), *([None] * (t.ndim - 1))))


def attention_block(
    params: dict,
    x: jax.Array,                   # (b, s, d_in)
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_fraction: float = 1.0,
    rope_theta: float = 10_000.0,
    causal: bool = True,
    positions: Optional[jax.Array] = None,
    kv_cache: Optional[dict] = None,   # {"k","v": (b, max_s, hkv, d), "pos"}
    xkv: Optional[jax.Array] = None,   # cross-attention source
    precomputed_kv: bool = False,      # kv_cache holds frozen cross K/V
    batch_shard: bool = False,         # shard batch over ("data","model")
) -> Tuple[jax.Array, Optional[dict]]:
    b, s, _ = x.shape
    src = x if xkv is None else xkv
    q = (x @ params["wq"]).reshape(b, s, num_heads, head_dim)
    k = (src @ params["wk"]).reshape(b, src.shape[1], num_kv_heads, head_dim)
    v = (src @ params["wv"]).reshape(b, src.shape[1], num_kv_heads, head_dim)
    if batch_shard and kv_cache is None:
        q, k, v = _batch_shard(q), _batch_shard(k), _batch_shard(v)

    # Cache position clock is a PER-SEQUENCE (b,) vector so continuous
    # batching can host sequences at different depths in one static batch.
    offset = None
    if kv_cache is not None and not precomputed_kv:
        offset = kv_cache["pos"]
        if offset.ndim == 0:
            offset = jnp.broadcast_to(offset, (b,))
    if rope_fraction > 0 and xkv is None and not precomputed_kv:
        base = jnp.arange(s)[None, :]
        qpos = (positions if positions is not None
                else (base + offset[:, None] if offset is not None else base))
        cos, sin = rope_frequencies(head_dim, rope_fraction, rope_theta, qpos)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = None
    if kv_cache is not None and not precomputed_kv and xkv is None:
        kd = k.astype(kv_cache["k"].dtype)
        vd = v.astype(kv_cache["v"].dtype)
        if s == 1:
            # decode: per-sequence scatter at each slot's own position
            bi = jnp.arange(b)
            kc = kv_cache["k"].at[bi, offset].set(kd[:, 0])
            vc = kv_cache["v"].at[bi, offset].set(vd[:, 0])
        else:
            # prefill: fresh cache, all slots start at 0
            kc = jax.lax.dynamic_update_slice(kv_cache["k"], kd, (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(kv_cache["v"], vd, (0, 0, 0, 0))
        new_cache = {"k": kc, "v": vc, "pos": offset + s}
        # Attend over the full cache with per-sequence position masking.
        kpos = jnp.arange(kc.shape[1])                       # (S,)
        qpos = jnp.arange(s)[None, :] + offset[:, None]      # (b, s)
        mask = (kpos[None, None, :] <= qpos[:, :, None])     # (b, s, S)
        kk = _repeat_kv(kc.astype(q.dtype), num_heads)
        vv = _repeat_kv(vc.astype(q.dtype), num_heads)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32)
        logits = logits / math.sqrt(head_dim)
        logits = jnp.where(mask[:, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    elif kv_cache is not None:  # cross-attention with precomputed KV cache
        kk = _repeat_kv(kv_cache["k"].astype(q.dtype), num_heads)
        vv = _repeat_kv(kv_cache["v"].astype(q.dtype), num_heads)
        out = naive_attention(q, kk, vv, causal=False)
        new_cache = kv_cache
    else:
        out = attention(q, k, v, causal=causal)
    out = out.reshape(b, s, num_heads * head_dim)
    return out @ params["wo"], new_cache


# --------------------------------------------------------------------- #
# FFN
# --------------------------------------------------------------------- #

def init_ffn_params(key, d_model: int, d_ff: int, activation: str,
                    dtype=DEFAULT_DTYPE) -> dict:
    ks = jax.random.split(key, 3)
    if activation == "swiglu":
        return {
            "wg": dense_init(ks[0], (d_model, d_ff), dtype),
            "wu": dense_init(ks[1], (d_model, d_ff), dtype),
            "wd": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    return {
        "wu": dense_init(ks[0], (d_model, d_ff), dtype),
        "wd": dense_init(ks[1], (d_ff, d_model), dtype),
    }


def ffn_block(params: dict, x: jax.Array, activation: str) -> jax.Array:
    if activation == "swiglu":
        return (jax.nn.silu(x @ params["wg"]) * (x @ params["wu"])) @ params["wd"]
    return jax.nn.gelu(x @ params["wu"]) @ params["wd"]


# --------------------------------------------------------------------- #
# MoE block (capacity-based top-k routing, EP/expert-TP shardable)
# --------------------------------------------------------------------- #

def init_moe_params(key, d_model: int, d_ff: int, num_experts: int,
                    activation: str, shared_d_ff: int = 0,
                    dtype=DEFAULT_DTYPE) -> dict:
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d_model, num_experts), jnp.float32),
        "we_up": dense_init(ks[1], (num_experts, d_model, d_ff), dtype),
        "we_down": dense_init(ks[2], (num_experts, d_ff, d_model), dtype),
    }
    if activation == "swiglu":
        p["we_gate"] = dense_init(ks[3], (num_experts, d_model, d_ff), dtype)
    if shared_d_ff:
        p["shared"] = init_ffn_params(ks[4], d_model, shared_d_ff,
                                      activation, dtype)
    return p


def moe_block(params: dict, x: jax.Array, *, top_k: int,
              capacity_factor: float, activation: str,
              aux_loss_weight: float = 0.0,
              dispatch: str = "gather") -> Tuple[jax.Array, jax.Array]:
    """MoE FFN. x: (b, s, d). Expert weights are stacked on a leading
    experts axis so the sharding rules can place them on the model axis
    (EP) or shard d_ff (expert-TP) — see parallel/sharding.py.

    dispatch="gather": capacity-based per-expert top-C token selection
    (drops overflow). dispatch="dense": every expert on every token,
    weighted by the combine matrix — more FLOPs but zero dispatch
    collectives (the §Perf fix for fine-grained expert-TP MoEs).
    Returns (y, aux_loss)."""
    b, s, d = x.shape
    e = params["we_up"].shape[0]
    xt = x.reshape(b * s, d)
    t = b * s
    logits = (xt.astype(jnp.float32) @ params["router"])  # (t, e)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)     # (t, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    # (t, e) combine matrix with only top-k nonzero
    combine = jnp.zeros((t, e), jnp.float32).at[
        jnp.arange(t)[:, None], gate_idx].set(gate_vals)

    if dispatch == "dense":
        cw = combine.astype(xt.dtype)                      # (t, e)
        if activation == "swiglu":
            he = jax.nn.silu(jnp.einsum("td,edf->tef", xt,
                                        params["we_gate"]))
            he = he * jnp.einsum("td,edf->tef", xt, params["we_up"])
        else:
            he = jax.nn.gelu(jnp.einsum("td,edf->tef", xt,
                                        params["we_up"]))
        y = jnp.einsum("tef,te,efd->td", he, cw, params["we_down"])
        if "shared" in params:
            y = y + ffn_block(params["shared"], xt, activation)
        density = combine.mean(axis=0)
        aux = aux_loss_weight * e * jnp.sum(density * probs.mean(axis=0))
        return y.reshape(b, s, d), aux
    # Per-expert capacity selection. Single-token decode steps use exact
    # capacity (= t) so serving never drops; full sequences use the standard
    # capacity factor (overflow dropped, as in Switch/GShard training).
    if s == 1:
        cap = t
    else:
        cap = max(1, int(t * top_k * capacity_factor / e))
        cap = min(cap, t)
    sel_val, sel_idx = jax.lax.top_k(combine.T, cap)      # (e, cap)
    xe = xt[sel_idx]                                      # (e, cap, d)
    if activation == "swiglu":
        he = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["we_gate"]))
        he = he * jnp.einsum("ecd,edf->ecf", xe, params["we_up"])
    else:
        he = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, params["we_up"]))
    ye = jnp.einsum("ecf,efd->ecd", he, params["we_down"])
    ye = ye * sel_val[..., None].astype(ye.dtype)
    y = jnp.zeros((t, d), ye.dtype).at[sel_idx.reshape(-1)].add(
        ye.reshape(e * cap, d))
    if "shared" in params:
        y = y + ffn_block(params["shared"], xt, activation)
    # Load-balancing aux loss (Switch-style).
    density = combine.mean(axis=0)                        # (e,)
    router_prob = probs.mean(axis=0)
    aux = aux_loss_weight * e * jnp.sum(density * router_prob)
    return y.reshape(b, s, d), aux


# --------------------------------------------------------------------- #
# Embedding / unembedding
# --------------------------------------------------------------------- #

def cross_entropy_loss(logits: jax.Array, targets: jax.Array,
                       ignore_id: int = -1) -> jax.Array:
    """Mean token NLL in fp32. logits: (..., V), targets: (...) int32.

    The gold logit is extracted with an iota-compare reduction rather than
    take_along_axis: a gather along a vocab-parallel-sharded axis would
    force GSPMD to all-gather the full logits, while the masked reduction
    partitions cleanly (each vocab shard contributes its local max/sum)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    onehot = (vocab_iota == targets[..., None].astype(jnp.int32))
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    mask = (targets != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
