"""DLRM — the paper's §V-C case-study model, runnable at reduced scale.

Bottom MLP over dense features, embedding-bag lookups over sparse features,
pairwise feature interaction, top MLP -> CTR logit. The full 1.2T config is
exercised analytically (core.workload.decompose_dlrm); this module provides
the real JAX model for smoke tests / examples and the embedding-bag kernel's
integration point.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.dlrm_1p2t import DLRMConfig
from repro.models.common import dense_init, embed_init


def init_params(key, cfg: DLRMConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, 4)

    def mlp(k, dims):
        ks = jax.random.split(k, len(dims) - 1)
        return [{"w": dense_init(ki, (a, b), dtype),
                 "b": jnp.zeros((b,), dtype)}
                for ki, a, b in zip(ks, dims[:-1], dims[1:])]

    n_feat = cfg.num_tables + 1
    top_in = n_feat * (n_feat - 1) // 2 + cfg.bottom_mlp[-1]
    return {
        "tables": embed_init(
            keys[0], (cfg.num_tables, cfg.rows_per_table, cfg.emb_dim), dtype),
        "bottom": mlp(keys[1], (cfg.num_dense_features,) + cfg.bottom_mlp),
        "top": mlp(keys[2], (top_in,) + cfg.top_mlp),
    }


def _run_mlp(layers, x, final_linear=False):
    for i, lyr in enumerate(layers):
        x = x @ lyr["w"] + lyr["b"]
        if not (final_linear and i == len(layers) - 1):
            x = jax.nn.relu(x)
    return x


def embedding_bag(tables: jax.Array, indices: jax.Array) -> jax.Array:
    """Pooled (sum) lookups. tables: (T, R, E); indices: (b, T, L) int32.

    Returns (b, T, E). This is the jnp oracle mirrored by the Pallas
    ``embedding_bag`` kernel."""
    gathered = jax.vmap(
        lambda tbl, idx: tbl[idx], in_axes=(0, 1), out_axes=1
    )(tables, indices)                     # (b, T, L, E)
    return gathered.sum(axis=2)


def forward(params: dict, cfg: DLRMConfig, dense: jax.Array,
            sparse: jax.Array) -> jax.Array:
    """dense: (b, num_dense); sparse: (b, T, L) int32 -> logits (b,)."""
    bot = _run_mlp(params["bottom"], dense)            # (b, E)
    emb = embedding_bag(params["tables"], sparse)      # (b, T, E)
    feats = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (b, T+1, E)
    inter = jnp.einsum("bie,bje->bij", feats, feats)
    iu = jnp.triu_indices(feats.shape[1], k=1)
    inter_flat = inter[:, iu[0], iu[1]]                # (b, nC2)
    top_in = jnp.concatenate([inter_flat, bot], axis=-1)
    return _run_mlp(params["top"], top_in, final_linear=True)[:, 0]


def loss(params: dict, cfg: DLRMConfig, batch: dict) -> Tuple[jax.Array, dict]:
    """batch: {dense, sparse, labels (b,) in {0,1}} -> BCE loss."""
    logits = forward(params, cfg, batch["dense"], batch["sparse"])
    labels = batch["labels"].astype(jnp.float32)
    logits = logits.astype(jnp.float32)
    bce = jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return bce, {"bce": bce}
