"""Encoder-decoder backbone (seamless-m4t-large-v2).

The audio frontend is a STUB per the assignment: the model consumes
precomputed frame embeddings ``frames: (b, src_len, d_model)`` (what the
w2v-BERT conv feature extractor would produce). The transformer backbone —
24-layer encoder, 24-layer decoder with self+cross attention — is real.

Serving: ``prefill`` encodes the source and precomputes per-layer cross-
attention K/V once; ``decode_step`` then runs the decoder with a growing
self-attention cache against the frozen cross K/V (standard enc-dec serving).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (
    DEFAULT_DTYPE,
    attention_block,
    cross_entropy_loss,
    dense_init,
    embed_init,
    ffn_block,
    init_attention_params,
    init_ffn_params,
    rms_norm,
)
from repro.models.transformer import apply_remat


def _enc_layers(cfg: ModelConfig) -> int:
    assert cfg.encdec is not None
    return cfg.encdec.encoder_layers


def _dec_layers(cfg: ModelConfig) -> int:
    assert cfg.encdec is not None
    return cfg.encdec.decoder_layers


# --------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------- #

def init_params(key, cfg: ModelConfig, dtype=DEFAULT_DTYPE) -> dict:
    hd = cfg.resolved_head_dim
    keys = jax.random.split(key, 6)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": init_attention_params(k1, cfg.d_model, cfg.d_model,
                                          cfg.num_heads, cfg.num_kv_heads,
                                          hd, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "ffn": init_ffn_params(k2, cfg.d_model, cfg.d_ff,
                                   cfg.activation, dtype),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "self_attn": init_attention_params(k1, cfg.d_model, cfg.d_model,
                                               cfg.num_heads, cfg.num_kv_heads,
                                               hd, dtype),
            "lnx": jnp.ones((cfg.d_model,), dtype),
            "cross_attn": init_attention_params(k2, cfg.d_model, cfg.d_model,
                                                cfg.num_heads, cfg.num_kv_heads,
                                                hd, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "ffn": init_ffn_params(k3, cfg.d_model, cfg.d_ff,
                                   cfg.activation, dtype),
        }

    return {
        "embed": embed_init(keys[0], (cfg.padded_vocab, cfg.d_model), dtype),
        "encoder": jax.vmap(enc_layer)(
            jax.random.split(keys[1], _enc_layers(cfg))),
        "decoder": jax.vmap(dec_layer)(
            jax.random.split(keys[2], _dec_layers(cfg))),
        "ln_enc": jnp.ones((cfg.d_model,), dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "head": dense_init(keys[3], (cfg.d_model, cfg.padded_vocab), dtype),
    }


# --------------------------------------------------------------------- #
# Encoder / decoder stacks
# --------------------------------------------------------------------- #

def encode(params: dict, cfg: ModelConfig, frames: jax.Array,
           remat: Optional[str] = "dots") -> jax.Array:
    hd = cfg.resolved_head_dim
    frames = frames.astype(params["embed"].dtype)

    def layer(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        attn, _ = attention_block(
            lp["attn"], h, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=hd,
            rope_fraction=cfg.rope_fraction, rope_theta=cfg.rope_theta,
            causal=False)
        x = x + attn
        x = x + ffn_block(lp["ffn"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                          cfg.activation)
        return x

    layer = apply_remat(layer, remat)

    def body(x, lp):
        return layer(x, lp), None

    x, _ = jax.lax.scan(body, frames, params["encoder"])
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def decode_stack(params: dict, cfg: ModelConfig, x: jax.Array,
                 enc_out: Optional[jax.Array],
                 cache: Optional[dict] = None,
                 remat: Optional[str] = "dots"
                 ) -> Tuple[jax.Array, Optional[dict]]:
    """Decoder trunk. Either ``enc_out`` (training: cross-KV computed on the
    fly) or ``cache`` (serving: self cache + frozen cross-KV) is given."""
    hd = cfg.resolved_head_dim

    def layer(x, scanned):
        lp = scanned["layer"]
        self_kv = None
        cross_kv = None
        if scanned.get("self_k") is not None:
            self_kv = {"k": scanned["self_k"], "v": scanned["self_v"],
                       "pos": scanned["pos"]}
            cross_kv = {"k": scanned["cross_k"], "v": scanned["cross_v"],
                        "pos": jnp.zeros((), jnp.int32)}
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        attn, new_self = attention_block(
            lp["self_attn"], h, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=hd,
            rope_fraction=cfg.rope_fraction, rope_theta=cfg.rope_theta,
            causal=True, kv_cache=self_kv)
        x = x + attn
        h = rms_norm(x, lp["lnx"], cfg.norm_eps)
        attn, _ = attention_block(
            lp["cross_attn"], h, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=hd,
            rope_fraction=0.0, causal=False,
            kv_cache=cross_kv, xkv=enc_out,
            precomputed_kv=cross_kv is not None)
        x = x + attn
        x = x + ffn_block(lp["ffn"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                          cfg.activation)
        return x, new_self

    scanned = {"layer": params["decoder"]}
    if cache is not None:
        scanned["self_k"] = cache["self_k"]
        scanned["self_v"] = cache["self_v"]
        scanned["cross_k"] = cache["cross_k"]
        scanned["cross_v"] = cache["cross_v"]
        L = cache["self_k"].shape[0]
        scanned["pos"] = jnp.broadcast_to(cache["pos"],
                                          (L,) + cache["pos"].shape)
        layer_fn = layer
    else:
        layer_fn = apply_remat(lambda x, sc: layer(x, sc)[0], remat)

    if cache is None:
        def body(x, sc):
            return layer_fn(x, sc), None
        x, _ = jax.lax.scan(body, x, scanned)
        new_cache = None
    else:
        def body(x, sc):
            x, new_self = layer(x, sc)
            return x, new_self
        x, selfs = jax.lax.scan(body, x, scanned)
        new_cache = dict(cache)
        new_cache["self_k"] = selfs["k"]
        new_cache["self_v"] = selfs["v"]
        new_cache["pos"] = cache["pos"] + x.shape[1]
    return rms_norm(x, params["ln_f"], cfg.norm_eps), new_cache


# --------------------------------------------------------------------- #
# Public API
# --------------------------------------------------------------------- #

def forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
            frames: jax.Array, remat: Optional[str] = "dots"
            ) -> Tuple[jax.Array, jax.Array, None]:
    enc_out = encode(params, cfg, frames, remat)
    x = jnp.take(params["embed"], tokens, axis=0)
    x, _ = decode_stack(params, cfg, x, enc_out, remat=remat)
    return x @ params["head"], jnp.zeros((), jnp.float32), None


def loss(params: dict, cfg: ModelConfig, batch: dict,
         remat: Optional[str] = "dots") -> Tuple[jax.Array, dict]:
    logits, aux, _ = forward(params, cfg, batch["tokens"],
                             frames=batch["frames"], remat=remat)
    ce = cross_entropy_loss(logits, batch["targets"])
    return ce + aux, {"ce": ce, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=DEFAULT_DTYPE, src_len: int = 0) -> dict:
    hd = cfg.resolved_head_dim
    L = _dec_layers(cfg)
    return {
        "self_k": jnp.zeros((L, batch, max_seq, cfg.num_kv_heads, hd), dtype),
        "self_v": jnp.zeros((L, batch, max_seq, cfg.num_kv_heads, hd), dtype),
        "cross_k": jnp.zeros((L, batch, src_len, cfg.num_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((L, batch, src_len, cfg.num_kv_heads, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def precompute_cross_kv(params: dict, cfg: ModelConfig,
                        enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-decoder-layer cross K/V of the encoder output: (L, b, src, hkv, d)."""
    hd = cfg.resolved_head_dim
    b, src, _ = enc_out.shape

    def one(lp):
        k = (enc_out @ lp["cross_attn"]["wk"]).reshape(
            b, src, cfg.num_kv_heads, hd)
        v = (enc_out @ lp["cross_attn"]["wv"]).reshape(
            b, src, cfg.num_kv_heads, hd)
        return k, v

    ks, vs = jax.vmap(one)(params["decoder"])
    return ks, vs


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array, cache: dict,
            frames: jax.Array) -> Tuple[jax.Array, dict]:
    enc_out = encode(params, cfg, frames, remat=None)
    ck, cv = precompute_cross_kv(params, cfg, enc_out)
    cache = dict(cache)
    cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
    cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
    x = jnp.take(params["embed"], tokens, axis=0)
    x, cache = decode_stack(params, cfg, x, None, cache=cache, remat=None)
    return (x @ params["head"])[:, -1:, :], cache


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jax.Array) -> Tuple[jax.Array, dict]:
    x = jnp.take(params["embed"], tokens, axis=0)
    x, cache = decode_stack(params, cfg, x, None, cache=cache, remat=None)
    return x @ params["head"], cache
