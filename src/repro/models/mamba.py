"""Mamba2 (SSD, state-space duality) and Zamba2-style hybrid models.

SSD chunked algorithm (Dao & Gu, arXiv:2405.21060): the sequence is split
into chunks of length Q; within a chunk the recurrence is computed as a
masked "attention" (C B^T * L) @ X GEMM; across chunks a small state
recurrence (H, P, N) is carried by lax.scan. This maps the SSM onto MXU
GEMMs — the TPU-native adaptation of the paper's compute model — and is the
jnp oracle for the Pallas ``ssd_scan`` kernel.

Zamba2 hybrid: a Mamba2 trunk where ONE shared attention block (one set of
weights) is applied every ``attn_every`` layers on concat(h, initial_emb).

Decode carries (conv_state, ssm_state) per layer — O(1) in context length,
which is why the SSM/hybrid archs run the long_500k shape.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.models.common import (
    DEFAULT_DTYPE,
    attention_block,
    cross_entropy_loss,
    dense_init,
    embed_init,
    ffn_block,
    init_attention_params,
    init_ffn_params,
    rms_norm,
)
from repro.models.transformer import apply_remat


def _dims(cfg: ModelConfig):
    ssm = cfg.ssm
    di = cfg.d_inner
    heads = cfg.ssm_heads
    n_in = 2 * di + 2 * ssm.ngroups * ssm.state_dim + heads
    conv_ch = di + 2 * ssm.ngroups * ssm.state_dim
    return ssm, di, heads, n_in, conv_ch


# --------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------- #

def _init_ssm_layer(key, cfg: ModelConfig, dtype):
    """Projections are stored per component (z, x, B, C, dt) rather than as
    one fused in_proj so each can carry its own PartitionSpec: z/x shard
    over heads (model axis); B/C/dt are small and replicated."""
    ssm, di, heads, n_in, conv_ch = _dims(cfg)
    gn = ssm.ngroups * ssm.state_dim
    ks = jax.random.split(key, 8)
    conv_scale = 1.0 / math.sqrt(ssm.conv_width)
    return {
        "ln": jnp.ones((cfg.d_model,), dtype),
        "wz": dense_init(ks[0], (cfg.d_model, di), dtype),
        "wx": dense_init(ks[1], (cfg.d_model, di), dtype),
        "wB": dense_init(ks[2], (cfg.d_model, gn), dtype),
        "wC": dense_init(ks[3], (cfg.d_model, gn), dtype),
        "wdt": dense_init(ks[4], (cfg.d_model, heads), dtype),
        "conv_wx": dense_init(ks[5], (ssm.conv_width, di), dtype,
                              scale=conv_scale),
        "conv_wB": dense_init(ks[6], (ssm.conv_width, gn), dtype,
                              scale=conv_scale),
        "conv_wC": dense_init(ks[7], (ssm.conv_width, gn), dtype,
                              scale=conv_scale),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(jnp.float32),
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "norm_g": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], (di, cfg.d_model), dtype),
    }


def init_params(key, cfg: ModelConfig, dtype=DEFAULT_DTYPE) -> dict:
    keys = jax.random.split(key, 6)
    layer_keys = jax.random.split(keys[1], cfg.num_layers)
    params = {
        "embed": embed_init(keys[0], (cfg.padded_vocab, cfg.d_model), dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "layers": jax.vmap(lambda k: _init_ssm_layer(k, cfg, dtype))(layer_keys),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[2],
                                    (cfg.d_model, cfg.padded_vocab), dtype)
    if cfg.family == "hybrid":
        assert cfg.hybrid is not None
        d_in = (2 * cfg.d_model if cfg.hybrid.attn_concat_embedding
                else cfg.d_model)
        params["shared_attn"] = {
            "ln": jnp.ones((d_in,), dtype),
            "attn": init_attention_params(
                keys[3], d_in, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.resolved_head_dim, dtype),
            "ln_ffn": jnp.ones((cfg.d_model,), dtype),
            "ffn": init_ffn_params(keys[4], cfg.d_model, cfg.d_ff,
                                   cfg.activation, dtype),
        }
    return params


# --------------------------------------------------------------------- #
# SSD chunked scan (train / prefill)
# --------------------------------------------------------------------- #

def _segsum(dA: jax.Array) -> jax.Array:
    """dA: (..., Q) -> L: (..., Q, Q), L[i,j] = sum_{k=j+1..i} dA_k (i>=j),
    -inf above the diagonal."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                B: jax.Array, C: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD over a full sequence.

    x:  (b, s, h, p)    inputs per head
    dt: (b, s, h)       softplus-ed step sizes (fp32)
    A:  (h,)            negative decay rates (fp32)
    B:  (b, s, g, n)    input projections (g groups broadcast over heads)
    C:  (b, s, g, n)    output projections
    Returns (y: (b, s, h, p), final_state: (b, h, p, n))."""
    b, s, h, p = x.shape
    g, n = B.shape[-2], B.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // chunk
    # reshape to chunks
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    dA = dtc * A  # (b, nc, Q, h)
    dA_hlast = dA.transpose(0, 1, 3, 2)              # (b, nc, h, Q)
    cs = jnp.cumsum(dA_hlast, axis=-1)               # within-chunk cumsum
    L = jnp.exp(_segsum(dA_hlast))                   # (b, nc, h, Q, Q)

    reps = h // g
    Bh = jnp.repeat(Bc, reps, axis=3) if g != h else Bc  # (b,nc,Q,h,n)
    Ch = jnp.repeat(Cc, reps, axis=3) if g != h else Cc

    dtx = xc * dtc[..., None].astype(xc.dtype)        # (b, nc, Q, h, p)

    # Diagonal (within-chunk) term: masked attention GEMMs.
    Gm = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh).astype(jnp.float32)
    M = Gm * L
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", M.astype(xc.dtype), dtx)

    # Per-chunk end states.
    decay_to_end = jnp.exp(cs[..., -1:] - cs)         # (b, nc, h, Q)
    states = jnp.einsum("bcqhn,bchq,bcqhp->bchpn",
                        Bh, decay_to_end.astype(xc.dtype), dtx)

    # Inter-chunk recurrence over nc chunks.
    total_decay = jnp.exp(cs[..., -1])                # (b, nc, h)
    s0 = (init_state if init_state is not None
          else jnp.zeros((b, h, p, n), jnp.float32))

    def chunk_step(state, inputs):
        st_c, dec_c = inputs                          # (b,h,p,n), (b,h)
        prev = state
        new = prev * dec_c[..., None, None] + st_c.astype(jnp.float32)
        return new, prev

    (final_state, prevs) = jax.lax.scan(
        chunk_step,
        s0,
        (states.transpose(1, 0, 2, 3, 4), total_decay.transpose(1, 0, 2)))
    prev_states = prevs.transpose(1, 0, 2, 3, 4)      # (b, nc, h, p, n)

    # Off-diagonal term: contribution of carried state into each position.
    decay_from_start = jnp.exp(cs).astype(xc.dtype)   # (b, nc, h, Q)
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp",
                       Ch, prev_states.astype(xc.dtype), decay_from_start)

    y = (y_diag + y_off).reshape(b, nc * chunk, h, p)
    return y[:, :s], final_state


# --------------------------------------------------------------------- #
# Mamba2 layer (full-sequence and single-step decode)
# --------------------------------------------------------------------- #

def _project(cfg: ModelConfig, lp: dict, x: jax.Array):
    """x: (..., d) -> (z, xbc_raw, dt) with xbc_raw = concat(x', B, C)."""
    z = x @ lp["wz"]
    xbc = jnp.concatenate([x @ lp["wx"], x @ lp["wB"], x @ lp["wC"]], axis=-1)
    dt = x @ lp["wdt"]
    return z, xbc, dt


def _conv_weight(lp: dict) -> jax.Array:
    return jnp.concatenate([lp["conv_wx"], lp["conv_wB"], lp["conv_wC"]],
                           axis=-1)


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xbc: (batch, s, ch), w: (width, ch)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def mamba_layer(lp: dict, cfg: ModelConfig, x: jax.Array,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence Mamba2 block. x: (b, s, d).

    Returns (out, final_ssm_state, conv_tail) where conv_tail is the last
    (width-1) raw xbc columns — the decode conv state."""
    ssm, di, heads, n_in, conv_ch = _dims(cfg)
    gn = ssm.ngroups * ssm.state_dim
    z, xbc_raw, dt = _project(cfg, lp, x)
    width = ssm.conv_width
    pad_needed = max(0, width - 1 - xbc_raw.shape[1])
    tail = xbc_raw[:, -(width - 1):, :]
    if pad_needed:
        tail = jnp.pad(tail, ((0, 0), (pad_needed, 0), (0, 0)))
    xbc = _causal_conv(xbc_raw, _conv_weight(lp), lp["conv_b"])
    xi, B, C = jnp.split(xbc, [di, di + gn], axis=-1)
    b_, s = x.shape[0], x.shape[1]
    xi = xi.reshape(b_, s, heads, ssm.head_dim)
    B = B.reshape(b_, s, ssm.ngroups, ssm.state_dim)
    C = C.reshape(b_, s, ssm.ngroups, ssm.state_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"])
    y, state = ssd_chunked(xi, dt, A, B, C, ssm.chunk_size, init_state)
    y = y + xi * lp["D"][:, None].astype(xi.dtype)
    y = y.reshape(b_, s, di)
    y = rms_norm(y * jax.nn.silu(z), lp["norm_g"], cfg.norm_eps)
    out = checkpoint_name(y @ lp["out_proj"], "block_out")
    return out, state, tail


def mamba_decode_step(lp: dict, cfg: ModelConfig, x: jax.Array,
                      conv_state: jax.Array, ssm_state: jax.Array
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token recurrent step. x: (b, 1, d).

    conv_state: (b, width-1, conv_ch); ssm_state: (b, h, p, n)."""
    ssm, di, heads, n_in, conv_ch = _dims(cfg)
    gn = ssm.ngroups * ssm.state_dim
    z, xbc, dt = _project(cfg, lp, x[:, 0, :])
    # conv: append new column, take causal window
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)
    w = _conv_weight(lp)
    out = jnp.einsum("bwc,wc->bc", window, w)
    xbc = jax.nn.silu(out + lp["conv_b"])
    new_conv_state = window[:, 1:, :]
    xi, B, C = jnp.split(xbc, [di, di + gn], axis=-1)
    b_ = x.shape[0]
    xi = xi.reshape(b_, heads, ssm.head_dim)
    B = B.reshape(b_, ssm.ngroups, ssm.state_dim)
    C = C.reshape(b_, ssm.ngroups, ssm.state_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])  # (b, h)
    A = -jnp.exp(lp["A_log"])
    dA = jnp.exp(dt * A)                                           # (b, h)
    reps = heads // ssm.ngroups
    Bh = jnp.repeat(B, reps, axis=1) if ssm.ngroups != heads else B
    Ch = jnp.repeat(C, reps, axis=1) if ssm.ngroups != heads else C
    dtx = xi * dt[..., None].astype(xi.dtype)                      # (b, h, p)
    new_state = (ssm_state * dA[..., None, None]
                 + jnp.einsum("bhn,bhp->bhpn", Bh.astype(jnp.float32),
                              dtx.astype(jnp.float32)))
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), new_state)
    y = y.astype(xi.dtype) + xi * lp["D"][:, None].astype(xi.dtype)
    y = y.reshape(b_, di)
    y = rms_norm(y * jax.nn.silu(z), lp["norm_g"], cfg.norm_eps)
    out = (y @ lp["out_proj"])[:, None, :]
    return out, new_conv_state, new_state


# --------------------------------------------------------------------- #
# Shared attention block (zamba2)
# --------------------------------------------------------------------- #

def _shared_attn(params: dict, cfg: ModelConfig, h: jax.Array,
                 emb0: jax.Array, kv_cache: Optional[dict]
                 ) -> Tuple[jax.Array, Optional[dict]]:
    assert cfg.hybrid is not None
    if cfg.hybrid.attn_concat_embedding:
        a_in = jnp.concatenate([h, emb0], axis=-1)
    else:
        a_in = h
    a_in = rms_norm(a_in, params["ln"], cfg.norm_eps)
    attn_out, new_cache = attention_block(
        params["attn"], a_in,
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_fraction=cfg.rope_fraction,
        rope_theta=cfg.rope_theta, causal=True, kv_cache=kv_cache)
    h = h + attn_out
    h = h + ffn_block(params["ffn"],
                      rms_norm(h, params["ln_ffn"], cfg.norm_eps),
                      cfg.activation)
    return h, new_cache


# --------------------------------------------------------------------- #
# Trunk + public API
# --------------------------------------------------------------------- #

def _stack_slice(tree, idx):
    return jax.tree.map(lambda a: a[idx], tree)


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
            cache: Optional[dict] = None,
            remat: Optional[str] = "dots"
            ) -> Tuple[jax.Array, jax.Array, Optional[dict]]:
    """Full-sequence forward (train / prefill).

    cache (prefill only): dict with conv/ssm/attn state buffers to fill."""
    x = jnp.take(params["embed"], tokens, axis=0)
    emb0 = x
    # Pure SSM scans one layer per step; hybrid scans one attn_every-group
    # per step (the shared attention block closes over the group boundary).
    every = cfg.hybrid.attn_every if cfg.family == "hybrid" else 1
    n_groups = cfg.num_layers // every
    lp_stacked = jax.tree.map(
        lambda a: a.reshape((n_groups, every) + a.shape[1:]), params["layers"])

    collect_state = cache is not None

    def group(x, scanned):
        lp = scanned["layers"]
        conv_sts, ssm_sts = [], []
        for j in range(every):
            sub = _stack_slice(lp, j)
            y, st, tail = mamba_layer(
                sub, cfg, rms_norm(x, sub["ln"], cfg.norm_eps))
            x = x + y
            if collect_state:
                ssm_sts.append(st)
                conv_sts.append(tail)
        new_attn_cache = None
        if cfg.family == "hybrid":
            kv = scanned.get("attn_cache")
            x, new_attn_cache = _shared_attn(params["shared_attn"], cfg, x,
                                             emb0, kv)
        return x, conv_sts, ssm_sts, new_attn_cache

    group_fn = apply_remat(lambda x, sc: group(x, sc)[0],
                           remat if not collect_state else None)

    if not collect_state:
        def scan_body(x, scanned):
            return group_fn(x, scanned), None
        x, _ = jax.lax.scan(scan_body, x, {"layers": lp_stacked})
        new_cache = None
    else:
        # Prefill: scan over groups, collecting per-layer states as ys.
        def scan_body(x, scanned):
            x, csts, sts, ac = group(x, scanned)
            ys = {"conv": jnp.stack(csts), "ssm": jnp.stack(sts)}
            if ac is not None:
                ys["attn_k"] = ac["k"]
                ys["attn_v"] = ac["v"]
            return x, ys

        scanned = {"layers": lp_stacked}
        if cfg.family == "hybrid" and cache.get("attn_k") is not None:
            scanned["attn_cache"] = {
                "k": cache["attn_k"], "v": cache["attn_v"],
                "pos": jnp.broadcast_to(cache["pos"],
                                        (n_groups,) + cache["pos"].shape)}
        x, ys = jax.lax.scan(scan_body, x, scanned)
        new_cache = {
            "conv": ys["conv"].reshape(cache["conv"].shape).astype(
                cache["conv"].dtype),
            "ssm": ys["ssm"].reshape(cache["ssm"].shape),
            "pos": cache["pos"] + tokens.shape[1],
        }
        if "attn_k" in ys:
            new_cache["attn_k"] = ys["attn_k"]
            new_cache["attn_v"] = ys["attn_v"]

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    return x @ head, jnp.zeros((), jnp.float32), new_cache


def loss(params: dict, cfg: ModelConfig, batch: dict,
         remat: Optional[str] = "dots") -> Tuple[jax.Array, dict]:
    logits, aux, _ = forward(params, cfg, batch["tokens"], remat=remat)
    ce = cross_entropy_loss(logits, batch["targets"])
    return ce + aux, {"ce": ce, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=DEFAULT_DTYPE) -> dict:
    ssm, di, heads, n_in, conv_ch = _dims(cfg)
    cache = {
        "conv": jnp.zeros((cfg.num_layers, batch, ssm.conv_width - 1,
                           conv_ch), dtype),
        "ssm": jnp.zeros((cfg.num_layers, batch, heads, ssm.head_dim,
                          ssm.state_dim), jnp.float32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if cfg.family == "hybrid":
        n_groups = cfg.num_layers // cfg.hybrid.attn_every
        hd = cfg.resolved_head_dim
        cache["attn_k"] = jnp.zeros(
            (n_groups, batch, max_seq, cfg.num_kv_heads, hd), dtype)
        cache["attn_v"] = jnp.zeros_like(cache["attn_k"])
    return cache


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array,
            cache: dict) -> Tuple[jax.Array, dict]:
    logits, _, cache = forward(params, cfg, tokens, cache=cache, remat=None)
    return logits[:, -1:, :], cache


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jax.Array) -> Tuple[jax.Array, dict]:
    """tokens: (b, 1). Recurrent single-step through all layers."""
    x = jnp.take(params["embed"], tokens, axis=0)
    emb0 = x
    every = cfg.hybrid.attn_every if cfg.family == "hybrid" else 1
    n_groups = cfg.num_layers // every
    lp_stacked = jax.tree.map(
        lambda a: a.reshape((n_groups, every) + a.shape[1:]), params["layers"])
    conv_c = cache["conv"].reshape((n_groups, every) + cache["conv"].shape[1:])
    ssm_c = cache["ssm"].reshape((n_groups, every) + cache["ssm"].shape[1:])

    def scan_body(x, scanned):
        lp = scanned["layers"]
        csts, ssts = [], []
        for j in range(every):
            sub = _stack_slice(lp, j)
            y, cst, sst = mamba_decode_step(
                sub, cfg, rms_norm(x, sub["ln"], cfg.norm_eps),
                scanned["conv"][j], scanned["ssm"][j])
            x = x + y
            csts.append(cst)
            ssts.append(sst)
        ys = {"conv": jnp.stack(csts), "ssm": jnp.stack(ssts)}
        if cfg.family == "hybrid":
            kv = {"k": scanned["attn_k"], "v": scanned["attn_v"],
                  "pos": scanned["pos"]}
            x, nc = _shared_attn(params["shared_attn"], cfg, x, emb0, kv)
            ys["attn_k"] = nc["k"]
            ys["attn_v"] = nc["v"]
        return x, ys

    scanned = {"layers": lp_stacked, "conv": conv_c, "ssm": ssm_c}
    if cfg.family == "hybrid":
        scanned["attn_k"] = cache["attn_k"]
        scanned["attn_v"] = cache["attn_v"]
        scanned["pos"] = jnp.broadcast_to(
            cache["pos"], (n_groups,) + cache["pos"].shape)
    x, ys = jax.lax.scan(scan_body, x, scanned)

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = x @ head
    new_cache = {
        "conv": ys["conv"].reshape(cache["conv"].shape),
        "ssm": ys["ssm"].reshape(cache["ssm"].shape),
        "pos": cache["pos"] + 1,
    }
    if cfg.family == "hybrid":
        new_cache["attn_k"] = ys["attn_k"]
        new_cache["attn_v"] = ys["attn_v"]
    return logits, new_cache
