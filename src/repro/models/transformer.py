"""Decoder-only transformer LM: dense GQA, interleaved MoE, and VLM variants.

One implementation covers the dense family (internlm2, chatglm3, minitron,
smollm), the MoE family (llama4-maverick: interleaved MoE + shared expert;
granite: every-layer fine-grained MoE), and the VLM backbone (internvl2:
precomputed patch embeddings prepended to the token stream).

Layer trunk = lax.scan over stacked parameters; one scan step processes one
"super-block" of ``moe_every`` layers (dense models: 1 layer/step), keeping
the HLO O(1) in depth. Remat policy is a knob (see ``apply_remat``).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.models.common import (
    DEFAULT_DTYPE,
    attention_block,
    cross_entropy_loss,
    dense_init,
    embed_init,
    ffn_block,
    init_attention_params,
    init_ffn_params,
    init_moe_params,
    moe_block,
    rms_norm,
)


def _moe_every(cfg: ModelConfig) -> int:
    return cfg.moe.moe_every if cfg.moe is not None else 1


def _n_blocks(cfg: ModelConfig) -> int:
    me = _moe_every(cfg)
    assert cfg.num_layers % me == 0
    return cfg.num_layers // me


# --------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------- #

def init_params(key, cfg: ModelConfig, dtype=DEFAULT_DTYPE) -> dict:
    hd = cfg.resolved_head_dim
    me = _moe_every(cfg)
    nb = _n_blocks(cfg)
    keys = jax.random.split(key, 8)

    def stack(init_fn, key, n):
        ks = jax.random.split(key, n)
        return jax.vmap(init_fn)(ks)

    # Dense sub-layers exist in every layer position: stack over (nb, me).
    def layer_init(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        p = {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": init_attention_params(
                k1, cfg.d_model, cfg.d_model, cfg.num_heads,
                cfg.num_kv_heads, hd, dtype),
        }
        return p

    def dense_ffn_init(k):
        return init_ffn_params(k, cfg.d_model, cfg.d_ff, cfg.activation, dtype)

    params = {
        "embed": embed_init(keys[0], (cfg.padded_vocab, cfg.d_model), dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "layers": stack(layer_init, keys[1], cfg.num_layers),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(
            keys[2], (cfg.d_model, cfg.padded_vocab), dtype)

    if cfg.moe is not None:
        # Dense FFNs at non-MoE positions (me-1 per block).
        if me > 1:
            params["dense_ffn"] = stack(dense_ffn_init, keys[3],
                                        nb * (me - 1))

        def moe_init(k):
            return init_moe_params(
                k, cfg.d_model, cfg.moe.d_ff, cfg.moe.num_experts,
                cfg.activation,
                shared_d_ff=(cfg.moe.shared_d_ff if cfg.moe.shared_expert
                             else 0),
                dtype=dtype)

        params["moe"] = stack(moe_init, keys[4], nb)
    else:
        params["dense_ffn"] = stack(dense_ffn_init, keys[3], cfg.num_layers)
    return params


# --------------------------------------------------------------------- #
# Layer stack
# --------------------------------------------------------------------- #

def _reshape_blocks(tree, nb: int, me: int):
    """(nb*me, ...) stacked params -> (nb, me, ...)."""
    return jax.tree.map(lambda x: x.reshape((nb, me) + x.shape[1:]), tree)


def apply_remat(fn, policy: Optional[str]):
    if policy is None or policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if policy == "blocks":
        # Save the post-collective block outputs (tagged "block_out") so the
        # backward replay recomputes block-local math but NOT the Megatron
        # all-reduces — trades L*b*s*d bytes of saved activations for a third
        # of the MP collective traffic (§Perf hillclimb, EXPERIMENTS.md).
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "block_out"))
    raise ValueError(f"unknown remat policy {policy!r}")


def _trunk(params: dict, cfg: ModelConfig, x: jax.Array, *,
           positions: Optional[jax.Array],
           cache: Optional[dict],
           remat: Optional[str] = "dots"
           ) -> Tuple[jax.Array, Optional[dict]]:
    """Run all layers. x: (b, s, d). cache: stacked per-layer KV or None."""
    me = _moe_every(cfg)
    nb = _n_blocks(cfg)
    hd = cfg.resolved_head_dim
    moe_cfg = cfg.moe

    layer_stack = _reshape_blocks(params["layers"], nb, me)
    if moe_cfg is not None and me > 1:
        dense_stack = _reshape_blocks(params["dense_ffn"], nb, me - 1)
    elif moe_cfg is None:
        dense_stack = _reshape_blocks(params["dense_ffn"], nb, me)
    else:
        dense_stack = None

    def block(x, scanned):
        """One super-block of ``me`` layers; MoE at the last position."""
        lp = scanned["layers"]          # (me, ...) sub-stack
        aux_total = jnp.zeros((), jnp.float32)
        kc_out = []
        for j in range(me):
            sub = jax.tree.map(lambda a, j=j: a[j], lp)
            h = rms_norm(x, sub["ln1"], cfg.norm_eps)
            kv = None
            if scanned.get("cache") is not None:
                kv = {"k": scanned["cache"]["k"][j],
                      "v": scanned["cache"]["v"][j],
                      "pos": scanned["cache"]["pos"]}
            attn_out, new_kv = attention_block(
                sub["attn"], h,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=hd, rope_fraction=cfg.rope_fraction,
                rope_theta=cfg.rope_theta, causal=True,
                positions=positions, kv_cache=kv,
                batch_shard=cfg.attn_batch_shard)
            attn_out = checkpoint_name(
                attn_out, "block_out")
            x = x + attn_out
            h = rms_norm(x, sub["ln2"], cfg.norm_eps)
            is_moe = moe_cfg is not None and j == me - 1
            if is_moe:
                mp = scanned["moe"]
                y, aux = moe_block(
                    mp, h, top_k=moe_cfg.top_k,
                    capacity_factor=moe_cfg.capacity_factor,
                    activation=cfg.activation,
                    aux_loss_weight=moe_cfg.aux_loss_weight,
                    dispatch=moe_cfg.dispatch)
                aux_total = aux_total + aux
            else:
                dp_idx = j if moe_cfg is not None else j
                fp = jax.tree.map(lambda a: a[dp_idx], scanned["dense"]) \
                    if scanned.get("dense") is not None else None
                y = ffn_block(fp, h, cfg.activation)
            y = checkpoint_name(y, "block_out")
            x = x + y
            if new_kv is not None:
                kc_out.append(new_kv)
        new_cache = None
        if kc_out:
            new_cache = {"k": jnp.stack([c["k"] for c in kc_out]),
                         "v": jnp.stack([c["v"] for c in kc_out])}
        return x, aux_total, new_cache

    block = apply_remat(block, remat if cache is None else None)

    def scan_body(carry, scanned):
        x, aux = carry
        x, aux_b, new_cache = block(x, scanned)
        return (x, aux + aux_b), new_cache

    scanned = {"layers": layer_stack}
    if dense_stack is not None:
        scanned["dense"] = dense_stack
    if moe_cfg is not None:
        scanned["moe"] = params["moe"]
    if cache is not None:
        # cache["k"]: (L, b, s_max, hkv, hd) -> (nb, me, ...)
        scanned["cache"] = {
            "k": cache["k"].reshape((nb, me) + cache["k"].shape[1:]),
            "v": cache["v"].reshape((nb, me) + cache["v"].shape[1:]),
            "pos": jnp.broadcast_to(cache["pos"], (nb,) + cache["pos"].shape),
        }

    (x, aux), caches = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                                    scanned)
    new_cache = None
    if caches is not None and cache is not None:
        new_cache = {
            "k": caches["k"].reshape(cache["k"].shape),
            "v": caches["v"].reshape(cache["v"].shape),
            "pos": cache["pos"] + x.shape[1],
        }
    return x, aux, new_cache


# --------------------------------------------------------------------- #
# Public API
# --------------------------------------------------------------------- #

def forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
            patches: Optional[jax.Array] = None,
            cache: Optional[dict] = None,
            remat: Optional[str] = "dots"
            ) -> Tuple[jax.Array, jax.Array, Optional[dict]]:
    """tokens: (b, s) int32; patches: (b, p, d) for VLM.

    Returns (logits, aux_loss, new_cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    x, aux, new_cache = _trunk(params, cfg, x, positions=None, cache=cache,
                               remat=remat)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = x @ head
    return logits, aux, new_cache


def loss(params: dict, cfg: ModelConfig, batch: dict,
         remat: Optional[str] = "dots") -> Tuple[jax.Array, dict]:
    logits, aux, _ = forward(params, cfg, batch["tokens"],
                             patches=batch.get("patches"), remat=remat)
    n_patch = 0 if batch.get("patches") is None else batch["patches"].shape[1]
    logits = logits[:, n_patch:, :]
    ce = cross_entropy_loss(logits, batch["targets"])
    total = ce + aux
    return total, {"ce": ce, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=DEFAULT_DTYPE) -> dict:
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((batch,), jnp.int32)}


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array, cache: dict,
            patches: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, dict]:
    logits, _, cache = forward(params, cfg, tokens, patches=patches,
                               cache=cache, remat=None)
    return logits[:, -1:, :], cache


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jax.Array) -> Tuple[jax.Array, dict]:
    """tokens: (b, 1) — one new token per sequence."""
    logits, _, cache = forward(params, cfg, tokens, cache=cache, remat=None)
    return logits, cache
