"""Distribution layer: mesh axes, sharding rules, ZeRO, pipeline, compression."""

from repro.parallel.mesh import (  # noqa: F401
    MODEL_AXIS,
    build_mesh,
    dp_axes,
    dp_size,
    fsdp_axes,
    mp_size,
)
from repro.parallel.policy import MemoryPlan, plan_memory  # noqa: F401
from repro.parallel.sharding import (  # noqa: F401
    batch_shardings,
    cache_shardings,
    param_shardings,
    param_spec,
)
from repro.parallel.zero import opt_state_shardings  # noqa: F401
