"""Gradient compression: int8 error-feedback reduction.

Intended for the slowest link in the hierarchy — the cross-pod DCN gradient
reduction (the COMET network model shows DP collectives over inter-pod links
dominate exposed WG time at low MP; compressing them 2-4x moves exactly that
term). Error feedback keeps the quantization bias out of the converged
model (Seide et al. / EF-SGD).

``compressed_psum`` is used inside shard_map over a DP axis; the train step
keeps an ``error`` buffer per parameter in the training state.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str,
                    error: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Sum ``x`` across ``axis_name`` exchanging int8 + one fp32 scale.

    Returns (sum, new_error). Wire bytes: 1/4 of fp32, 1/2 of bf16."""
    val = x.astype(jnp.float32)
    if error is not None:
        val = val + error
    q, scale = quantize_int8(val)
    new_error = val - dequantize_int8(q, scale)
    qs = jax.lax.all_gather(q, axis_name)            # (n, ...) int8
    ss = jax.lax.all_gather(scale, axis_name)        # (n,)
    ss = ss.reshape((ss.shape[0],) + (1,) * (qs.ndim - 1))
    total = jnp.sum(qs.astype(jnp.float32) * ss, axis=0)
    return total.astype(x.dtype), new_error


def compression_ratio(dtype=jnp.bfloat16) -> float:
    return jnp.dtype(dtype).itemsize / 1.0
