"""Mesh construction and axis conventions.

Axis convention (matching the COMET paper's MP/DP vocabulary):
  "pod"   — inter-pod data parallelism over DCN (multi-pod meshes)
  "data"  — intra-pod data parallelism over ICI
  "model" — tensor/expert parallelism (the paper's MP)

DP degree = pod * data; MP degree = model.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DATA_AXES: Tuple[str, ...] = ("pod", "data")
MODEL_AXIS = "model"


def build_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    return jax.make_mesh(tuple(shape), tuple(axes))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes present in this mesh, outermost first."""
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def mp_size(mesh: Mesh) -> int:
    return mesh.shape.get(MODEL_AXIS, 1)


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes used for FSDP-style parameter sharding: intra-pod data axis only
    (all-gathering parameters over DCN every step would be prohibitive —
    the COMET network model quantifies exactly this; see DESIGN.md)."""
    return ("data",) if "data" in mesh.axis_names else ()


def named(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))
