"""GPipe-style pipeline parallelism via shard_map + ppermute.

Opt-in capability (the assigned production mesh uses DP x TP; PP becomes
profitable past ICI-domain limits — COMET's collective model quantifies the
crossover). The schedule is the classic GPipe fill-drain: M microbatches
over S stages, bubble fraction (S-1)/(M+S-1).

``gpipe`` is differentiable end-to-end: ppermute is linear, so jax.grad
produces the reversed communication schedule for the backward pass
automatically — no hand-written backward pipeline.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

PIPE_AXIS = "pipe"


def gpipe(
    stage_fn: Callable,            # (stage_params, x_mb) -> y_mb
    stage_params,                  # pytree stacked on leading S axis
    x: jax.Array,                  # (M, mb, ...) microbatched input
    *,
    mesh: Mesh,
    axis: str = PIPE_AXIS,
) -> jax.Array:
    """Returns (M, mb, ...) outputs of the final stage."""
    s = mesh.shape[axis]
    m = x.shape[0]

    def body(params, xs):
        # params: leading stage axis of size 1 on each device
        local = jax.tree.map(lambda a: a[0], params)
        idx = jax.lax.axis_index(axis)
        state = jnp.zeros_like(stage_fn(local, xs[0]))  # activation buffer
        outs = jnp.zeros((m,) + state.shape, state.dtype)
        perm = [(i, (i + 1) % s) for i in range(s)]
        for t in range(m + s - 1):
            mb = min(t, m - 1)
            x_in = jnp.where(idx == 0, xs[mb], state)
            y = stage_fn(local, x_in)
            out_mb = t - (s - 1)
            if out_mb >= 0:
                write = jnp.where(idx == s - 1, y, outs[out_mb])
                outs = outs.at[out_mb].set(write)
            state = jax.lax.ppermute(y, axis, perm)
        # broadcast final-stage outputs to all pipe ranks
        outs = jax.lax.psum(
            jnp.where(idx == s - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),       # params sharded by stage, x replicated
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x)


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
