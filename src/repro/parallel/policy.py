"""Memory planner: COMET's footprint model applied to the runtime.

Before building the training state, ``plan_memory`` runs the same
model-state accounting as ``core.memory`` against the target mesh and HBM
capacity and picks:

  * the ZeRO stage (1 = optimizer states over DP; 3 = params+grads too),
  * the optimizer state dtype (fp32 Adam, or bf16 moments + stochastic
    rounding when even ZeRO-3 fp32 states exceed HBM — e.g. llama4-400B's
    4.8 TB of fp32 Adam states on a 4 TB pod),
  * the remat policy.

This is the paper's methodology closed into the loop: the analytical model
*decides* the runtime configuration instead of only reporting it.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.core.cluster import V5E_HBM_CAP


@dataclasses.dataclass(frozen=True)
class MemoryPlan:
    zero_stage: int                # 1 or 3 (param fsdp)
    opt_dtype: str                 # "float32" | "bfloat16"
    use_master: bool               # fp32 master copy of bf16 params
    remat: str                     # "none" | "dots" | "full"
    est_bytes_per_chip: float
    microbatches: int = 1          # gradient-accumulation steps
    notes: str = ""

    @property
    def fsdp(self) -> bool:
        return self.zero_stage >= 3


def _state_bytes(params: float, tp: int, dp: int, zero: int,
                 opt_bytes: float) -> float:
    """Per-chip bytes: bf16 params + bf16 grads + optimizer states."""
    p_shard = params / tp
    if zero >= 3:
        return (2 + 2 + opt_bytes) * p_shard / dp
    return (2 + 2) * p_shard + opt_bytes * p_shard / dp


def _activation_plan(cfg: ModelConfig, shape, dp: int,
                     act_budget: float) -> tuple:
    """(microbatches, remat) so remat-saved residuals fit the budget.

    Under per-layer remat the live activation set is dominated by the saved
    layer inputs: L * b_micro * seq * d_model * 2 bytes (SSM blocks carry a
    wider d_inner working set -> family factor)."""
    if shape is None or shape.kind != "train":
        return 1, "dots"
    b_local = max(1, shape.global_batch // max(dp, 1))
    seq = shape.seq_len
    if cfg.family == "vlm" and cfg.vision is not None:
        seq += cfg.vision.num_patches
    factor = {"ssm": 3.0, "hybrid": 3.5}.get(cfg.family, 1.5)
    layers = cfg.num_layers
    if cfg.family == "encdec" and cfg.encdec is not None:
        layers = cfg.encdec.encoder_layers + 2 * cfg.encdec.decoder_layers

    def saved(b_micro: int) -> float:
        return layers * b_micro * seq * cfg.d_model * 2 * factor

    m = 1
    while saved(-(-b_local // m)) > act_budget and m < b_local:
        m *= 2
    # "dots" (saves projection outputs too, ~4x) only when it still fits
    remat = "dots" if saved(-(-b_local // m)) * 4 <= act_budget else "full"
    return m, remat


def plan_memory(cfg: ModelConfig, tp: int, dp: int,
                hbm_bytes: float = V5E_HBM_CAP,
                shape=None) -> MemoryPlan:
    """Pick the cheapest configuration that fits.

    State preference order (cheapest communication first): ZeRO-1 fp32 ->
    ZeRO-3 fp32 -> ZeRO-3 bf16 moments (+ stochastic rounding, no master).
    Then size gradient accumulation + remat so activations fit the rest."""
    params = float(cfg.param_count())
    budget = hbm_bytes * 0.75
    candidates = [
        (1, "float32", True, 12.0,
         "ZeRO-1: fp32 Adam (m, v, master) sharded over DP"),
        (3, "float32", True, 12.0,
         "ZeRO-3: params+grads+states sharded over DP (FSDP)"),
        (3, "bfloat16", False, 4.0,
         "ZeRO-3 + bf16 moments, no master (stochastic rounding)"),
    ]
    chosen = None
    for zero, dtype, master, opt_bytes, note in candidates:
        est = _state_bytes(params, tp, dp, zero, opt_bytes)
        # grad accumulators during the microbatch scan (bf16 when the plan
        # already concedes bf16 moments — llama4-class memory pressure)
        acc_bytes = 2.0 if dtype == "bfloat16" else 4.0
        est += acc_bytes * params / tp / (dp if zero >= 3 else 1)
        if est <= budget:
            chosen = (zero, dtype, master, est, note)
            break
    if chosen is None:
        est = _state_bytes(params, tp, dp, 3, 4.0)
        return MemoryPlan(3, "bfloat16", False, "full", est, 1,
                          "over budget even at ZeRO-3/bf16 — needs more "
                          "chips or host offload (COMET Eqn 3 territory)")
    zero, dtype, master, est, note = chosen
    act_budget = max(hbm_bytes - est - 2e9, 2e9)
    micro, remat = _activation_plan(cfg, shape, dp, act_budget)
    return MemoryPlan(zero, dtype, master, remat, est, micro, note)
