"""Per-family parameter/activation/cache PartitionSpec rules.

Megatron-style tensor parallelism over the "model" axis:
  column-parallel: wq/wk/wv, FFN up/gate, SSM z/x projections, vocab embed
  row-parallel:    wo, FFN down, SSM out_proj, LM head (vocab dim)
MoE: experts axis over "model" (EP) when divisible, else each expert's d_ff
     over "model" (expert-TP) — granite's 40 experts on 16 ranks.
GQA: KV projections shard by kv-head only when kv_heads % tp == 0, else
     replicate (standard GQA-TP practice; chatglm kv=2, llama4 40 q-heads).

The universal fallback is REPLICATE-IF-NOT-DIVISIBLE, applied per tensor —
smollm's 9 heads simply replicate attention while its FFN still shards.

FSDP (ZeRO-3) additionally shards each parameter's largest replicated dim
over the intra-pod "data" axis — chosen by the memory planner
(parallel/policy.py) for archs whose states exceed HBM (llama4, internvl2).
"""

from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.parallel.mesh import MODEL_AXIS, dp_axes, fsdp_axes, mp_size

# Leaf-name classification -----------------------------------------------
# (matched on the final dict key of the parameter path)
_COLUMN_LAST = {"wq", "wk", "wv", "wg", "wu", "wz", "wx", "conv_wx",
                "norm_g"}       # shard LAST dim over model
_ROW_PENULT = {"wo", "wd", "out_proj"}  # shard dim -2 over model
_REPLICATED = {"ln", "ln1", "ln2", "lnx", "ln_f", "ln_enc", "ln_ffn",
               "wB", "wC", "wdt", "conv_wB", "conv_wC", "conv_b",
               "router", "b", "dt_bias"}
_HEAD_VEC = {"A_log", "D"}      # (..., H) vectors: shard last over model
_EXPERT = {"we_up", "we_gate", "we_down"}


def _divisible(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def _model_dim_ok(cfg: ModelConfig, name: str, shape: Tuple[int, ...],
                  tp: int) -> bool:
    """Column shards must also respect head boundaries for attention."""
    if name in ("wq", "wo"):
        return _divisible(cfg.num_heads, tp)
    if name in ("wk", "wv"):
        return _divisible(cfg.num_kv_heads, tp)
    return True


def param_spec(cfg: ModelConfig, path: Tuple[str, ...],
               shape: Tuple[int, ...], mesh: Mesh,
               fsdp: bool = False) -> P:
    """PartitionSpec for one parameter leaf."""
    tp = mp_size(mesh)
    name = path[-1]
    spec = [None] * len(shape)

    def try_model(dim: int) -> bool:
        if _divisible(shape[dim], tp):
            spec[dim] = MODEL_AXIS
            return True
        return False

    if name == "embed":
        try_model(0)                       # vocab-parallel (padded)
    elif name == "head":
        try_model(len(shape) - 1)
    elif name in _EXPERT:
        # (L', E, D, F): EP over experts if divisible, else expert-TP.
        e_dim = len(shape) - 3
        if not try_model(e_dim):
            ff_dim = (len(shape) - 1 if name in ("we_up", "we_gate")
                      else len(shape) - 2)
            try_model(ff_dim)
    elif name in _COLUMN_LAST:
        if _model_dim_ok(cfg, name, shape, tp):
            try_model(len(shape) - 1)
    elif name in _ROW_PENULT and len(shape) >= 2:
        if _model_dim_ok(cfg, name, shape, tp):
            try_model(len(shape) - 2)
    elif name in _HEAD_VEC:
        try_model(len(shape) - 1)
    elif name in _REPLICATED:
        pass
    # (unknown names stay replicated — safe default)

    if fsdp:
        fax = fsdp_axes(mesh)
        if fax:
            fsize = int(np.prod([mesh.shape[a] for a in fax]))
            # largest still-unsharded divisible dim
            cands = [(shape[d], d) for d in range(len(shape))
                     if spec[d] is None and _divisible(shape[d], fsize)]
            if cands:
                _, d = max(cands)
                spec[d] = fax if len(fax) > 1 else fax[0]
    return P(*spec)


def param_shardings(cfg: ModelConfig, params_shape_tree, mesh: Mesh,
                    fsdp: bool = False):
    """Tree of NamedShardings matching a params tree (of arrays or
    ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape_tree)
    specs = []
    for path, leaf in flat:
        keys = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path)
        specs.append(NamedSharding(
            mesh, param_spec(cfg, keys, tuple(leaf.shape), mesh, fsdp)))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ----------------------------------------------------------------------- #
# Batch / activation / cache shardings
# ----------------------------------------------------------------------- #

def batch_spec(mesh: Mesh, shape: Tuple[int, ...],
               seq_shard: bool = False) -> P:
    """(B, S, ...) batches: B over the DP axes when divisible; tiny batches
    (long_500k's B=1) shard S over data instead when S divides."""
    axes = dp_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    spec = [None] * len(shape)
    if axes and shape[0] % dp == 0 and shape[0] >= dp:
        spec[0] = axes if len(axes) > 1 else axes[0]
    elif (seq_shard and "data" in mesh.axis_names and len(shape) > 1
          and shape[1] % mesh.shape["data"] == 0):
        spec[1] = "data"
    return P(*spec)


def batch_shardings(mesh: Mesh, batch: dict, cfg: ModelConfig) -> dict:
    out = {}
    for k, v in batch.items():
        out[k] = NamedSharding(mesh, batch_spec(mesh, tuple(v.shape),
                                                seq_shard=(k == "tokens")))
    return out


def kv_cache_spec(cfg: ModelConfig, mesh: Mesh, name: str,
                  shape: Tuple[int, ...]) -> P:
    """Decode caches. KV: (L, B, S, Hkv, hd) — B over DP when divisible,
    heads over model when divisible; B=1 long-context caches shard S over
    the data axis instead. SSM states: (L, B, H, p, n) — H over model."""
    tp = mp_size(mesh)
    axes = dp_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    spec = [None] * len(shape)
    if name in ("k", "v", "attn_k", "attn_v", "self_k", "self_v",
                "cross_k", "cross_v"):
        if axes and shape[1] % dp == 0 and shape[1] >= dp:
            spec[1] = axes if len(axes) > 1 else axes[0]
        elif "data" in mesh.axis_names and shape[2] % mesh.shape["data"] == 0:
            spec[2] = "data"
        if _divisible(shape[3], tp):
            spec[3] = MODEL_AXIS
    elif name == "ssm":
        if axes and shape[1] % dp == 0 and shape[1] >= dp:
            spec[1] = axes if len(axes) > 1 else axes[0]
        if _divisible(shape[2], tp):
            spec[2] = MODEL_AXIS
    elif name == "conv":
        if axes and shape[1] % dp == 0 and shape[1] >= dp:
            spec[1] = axes if len(axes) > 1 else axes[0]
    return P(*spec)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = []
    for path, leaf in flat:
        keys = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        if keys[-1] == "pos" or leaf.ndim == 0:
            out.append(NamedSharding(mesh, P()))
        else:
            out.append(NamedSharding(
                mesh, kv_cache_spec(cfg, mesh, keys[-1], tuple(leaf.shape))))
    return jax.tree_util.tree_unflatten(treedef, out)
