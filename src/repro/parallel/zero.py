"""ZeRO optimizer-state sharding (paper §IV-B: ZeRO-DP os+g default).

Optimizer states (Adam m/v + optional fp32 master) follow the parameter's
PartitionSpec and are *additionally* sharded over the intra-pod "data" axis
(ZeRO-1). Under ZeRO-3 the parameter spec already carries the data axis, so
states simply inherit it. The SPMD partitioner materializes the implied
reduce-scatter(grads) + all-gather(params) — the paper's "no extra
communication volume vs. plain all-reduce" property.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.parallel.mesh import fsdp_axes
from repro.parallel.policy import MemoryPlan
from repro.parallel.sharding import param_spec


def opt_state_spec(cfg: ModelConfig, path: Tuple[str, ...],
                   shape: Tuple[int, ...], mesh: Mesh,
                   plan: MemoryPlan) -> P:
    base = param_spec(cfg, path, shape, mesh, fsdp=plan.fsdp)
    if plan.fsdp:
        return base  # already data-sharded
    fax = fsdp_axes(mesh)
    if not fax:
        return base
    fsize = int(np.prod([mesh.shape[a] for a in fax]))
    spec = list(base) + [None] * (len(shape) - len(base))
    cands = [(shape[d], d) for d in range(len(shape))
             if spec[d] is None and fsize > 1 and shape[d] % fsize == 0]
    if cands:
        _, d = max(cands)
        spec[d] = fax if len(fax) > 1 else fax[0]
    return P(*spec)


def opt_state_shardings(cfg: ModelConfig, params_shape_tree, mesh: Mesh,
                        plan: MemoryPlan):
    import jax
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape_tree)
    out = []
    for path, leaf in flat:
        keys = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        out.append(NamedSharding(
            mesh, opt_state_spec(cfg, keys, tuple(leaf.shape), mesh, plan)))
    return jax.tree_util.tree_unflatten(treedef, out)
