"""repro.reliability: failure-aware cluster DSE.

At COMET's target scale (thousands of nodes, week-long runs) node MTBF,
checkpoint bandwidth, and restart policy are provisioning axes like
compute and network.  This package prices them two ways:

* **Closed form** — :class:`FailureModel` + the Young–Daly optimal
  checkpoint interval turn every training study cell into
  ``ckpt_interval_s / ckpt_overhead_frac / expected_restarts /
  goodput_frac`` columns (``StudySpec.reliability`` attaches the model;
  ``reliability.*`` dotted-path axes sweep it), and
  ``goodput_per_dollar`` re-ranks clusters failure-aware.
* **Fault injection** — :class:`FailureTrace` feeds failure/repair
  events into the :class:`repro.fleet.FleetSimulator` timeline: a
  failed node kills its instance back to the last interval-quantized
  checkpoint boundary, capacity returns at repair, and the per-job
  degradation policy chooses wait-for-repair vs elastic
  shrink-to-survive.

See docs/reliability_api.md.
"""

from repro.reliability.trace import (BLAST_RADII, FAILURE_TRACE_KINDS,
                                     FailureEvent, FailureTrace)
from repro.reliability.model import (FailureModel, daly_interval,
                                     goodput_frac, overhead,
                                     reliability_columns)

__all__ = [
    "BLAST_RADII",
    "FAILURE_TRACE_KINDS",
    "FailureEvent",
    "FailureModel",
    "FailureTrace",
    "daly_interval",
    "goodput_frac",
    "overhead",
    "reliability_columns",
]
