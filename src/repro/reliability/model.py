"""Closed-form failure-aware goodput: the Young–Daly checkpoint model.

The cost primitives are the fleet's own (:mod:`repro.fleet.resize`):
one checkpoint write is ``instance_state_bytes / ckpt_bw`` — exactly
what a preemption already pays in the timeline — and a restart reads it
back at ``restore_bw`` after the ``mttr_hours`` repair.

With per-node MTBF ``m`` hours on an ``N``-node synchronous job, the
job-level failure rate is ``lam = N / (m * 3600)`` per second.  Writing
a checkpoint costs ``C`` seconds every ``tau`` seconds; each failure
loses half an interval plus the restart cost ``R`` on average.  The
overhead per useful second is

    h(tau) = C / tau + lam * (tau / 2 + R)

minimized at the Young–Daly interval ``tau* = sqrt(2 C / lam)``, and

    goodput_frac = 1 / (1 + h(tau))

is the fraction of wall-clock that is useful training.  ``lam == 0``
(MTBF = inf) gives ``h = 0`` and ``goodput_frac = 1.0`` exactly — the
degenerate equivalence every pre-reliability record relies on.

See docs/reliability_api.md for the full derivation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

from repro.fleet.resize import checkpoint_delay
from repro.reliability.trace import BLAST_RADII, FailureTrace


def daly_interval(write_cost_s: float, failure_rate: float) -> float:
    """The Young–Daly optimal checkpoint interval ``sqrt(2 C / lam)``
    (exact minimizer of ``C/tau + lam*tau/2``); ``inf`` when failures
    never happen — checkpointing then costs pure overhead."""
    if write_cost_s < 0:
        raise ValueError(f"write cost must be >= 0, got {write_cost_s}")
    if failure_rate < 0:
        raise ValueError(f"failure rate must be >= 0, got {failure_rate}")
    if failure_rate == 0.0:
        return math.inf
    if write_cost_s == 0.0:
        return 0.0
    return math.sqrt(2.0 * write_cost_s / failure_rate)


def overhead(interval_s: float, write_cost_s: float, failure_rate: float,
             restart_cost_s: float = 0.0) -> float:
    """Expected non-useful seconds per useful second at checkpoint
    cadence ``interval_s``: the write amortized over the interval, plus
    the failure-rate-weighted half-interval rework and restart cost."""
    if failure_rate == 0.0:
        return 0.0
    if interval_s <= 0:
        return math.inf
    return (write_cost_s / interval_s
            + failure_rate * (interval_s / 2.0 + restart_cost_s))


def goodput_frac(interval_s: float, write_cost_s: float,
                 failure_rate: float,
                 restart_cost_s: float = 0.0) -> float:
    """Useful fraction of wall-clock: ``1 / (1 + h(tau))`` in (0, 1]."""
    h = overhead(interval_s, write_cost_s, failure_rate, restart_cost_s)
    if math.isinf(h):
        return 0.0
    return 1.0 / (1.0 + h)


@dataclasses.dataclass(frozen=True)
class FailureModel:
    """The sweepable reliability knobs (``reliability.*`` dotted paths).

    * ``mtbf_hours`` — per-node mean time between failures (``inf``
      disables failure modeling: every column degenerates exactly);
    * ``mttr_hours`` — repair time per failure;
    * ``ckpt_bw`` — checkpoint-storage write bandwidth (the write cost
      ``C`` through :func:`repro.fleet.resize.checkpoint_delay`);
    * ``restore_bw`` — restart read bandwidth (0 = same as ``ckpt_bw``);
    * ``interval_s`` — fixed checkpoint cadence; 0 picks the Young–Daly
      optimum per cell (the naive-vs-optimal headline axis);
    * ``run_hours`` — the nominal run length ``expected_restarts``
      prices (and the Y102 sanity bound for fixed intervals);
    * ``blast`` — correlated radius for the generated trace.
    """

    mtbf_hours: float = 50_000.0
    mttr_hours: float = 0.5
    ckpt_bw: float = 40e9
    restore_bw: float = 0.0
    interval_s: float = 0.0
    run_hours: float = 168.0
    blast: str = "node"

    def __post_init__(self) -> None:
        if not self.mtbf_hours > 0:
            raise ValueError(
                f"mtbf_hours must be > 0 (inf disables failures), "
                f"got {self.mtbf_hours}")
        if not (self.mttr_hours >= 0 and math.isfinite(self.mttr_hours)):
            raise ValueError(
                f"mttr_hours must be finite and >= 0, got {self.mttr_hours}")
        if not (self.ckpt_bw > 0 and math.isfinite(self.ckpt_bw)):
            raise ValueError(
                f"ckpt_bw must be finite and > 0, got {self.ckpt_bw}")
        if not (self.restore_bw >= 0 and math.isfinite(self.restore_bw)):
            raise ValueError(
                f"restore_bw must be >= 0 (0 = ckpt_bw), "
                f"got {self.restore_bw}")
        if not self.interval_s >= 0:
            raise ValueError(
                f"interval_s must be >= 0 (0 = Young–Daly optimum), "
                f"got {self.interval_s}")
        if not self.run_hours > 0:
            raise ValueError(f"run_hours must be > 0, got {self.run_hours}")
        if self.blast not in BLAST_RADII:
            raise ValueError(f"blast must be one of {BLAST_RADII}, "
                             f"got {self.blast!r}")

    @property
    def enabled(self) -> bool:
        return math.isfinite(self.mtbf_hours)

    def failure_rate(self, num_nodes: int) -> float:
        """Job-level failures per second at cluster scale ``N``."""
        if not self.enabled or num_nodes <= 0:
            return 0.0
        return num_nodes / (self.mtbf_hours * 3600.0)

    def write_cost_s(self, state_bytes: float) -> float:
        """One checkpoint write through storage (the preemption cost)."""
        return checkpoint_delay(state_bytes, self.ckpt_bw)

    def restart_cost_s(self, state_bytes: float) -> float:
        """Repair plus the restore read of the checkpoint payload."""
        bw = self.restore_bw if self.restore_bw > 0 else self.ckpt_bw
        return self.mttr_hours * 3600.0 + checkpoint_delay(state_bytes, bw)

    def interval_for(self, state_bytes: float, num_nodes: int) -> float:
        """The effective cadence: the fixed ``interval_s`` when set,
        else the Young–Daly optimum for this (payload, scale)."""
        if self.interval_s > 0:
            return self.interval_s
        return daly_interval(self.write_cost_s(state_bytes),
                             self.failure_rate(num_nodes))

    def trace(self, seed: int = 0,
              horizon_hours: Optional[float] = None) -> FailureTrace:
        """A deterministic :class:`FailureTrace` with this model's
        MTBF/MTTR/blast knobs (the fleet-simulator hand-off)."""
        return FailureTrace(
            kind="poisson" if self.enabled else "none",
            mtbf_hours=self.mtbf_hours, mttr_hours=self.mttr_hours,
            blast=self.blast,
            horizon_hours=(horizon_hours if horizon_hours is not None
                           else self.run_hours),
            seed=seed)


def reliability_columns(model: FailureModel, state_bytes: float,
                        num_nodes: int) -> Dict[str, Any]:
    """The closed-form record columns for one study cell: checkpoint
    cadence, its overhead, expected restarts over ``run_hours``, and the
    goodput fraction.  With ``mtbf_hours = inf`` the columns are exactly
    ``{interval: inf, overhead: 0, restarts: 0, goodput: 1.0}`` — a
    pre-reliability record scaled by 1.0."""
    lam = model.failure_rate(num_nodes)
    write = model.write_cost_s(state_bytes)
    restart = model.restart_cost_s(state_bytes)
    tau = model.interval_for(state_bytes, num_nodes)
    good = 1.0 if lam == 0.0 else goodput_frac(tau, write, lam, restart)
    # fraction of wall-clock spent writing checkpoints: (C/tau) useful-
    # seconds-worth per useful second, scaled back to wall by goodput
    ckpt_frac = 0.0 if lam == 0.0 or tau <= 0 or math.isinf(tau) \
        else (write / tau) * good
    run_s = model.run_hours * 3600.0
    restarts = 0.0 if good <= 0 else lam * (run_s / good)
    return {
        "ckpt_interval_s": tau,
        "ckpt_overhead_frac": ckpt_frac,
        "expected_restarts": restarts,
        "goodput_frac": good,
    }


__all__ = ["FailureModel", "daly_interval", "goodput_frac", "overhead",
           "reliability_columns"]
