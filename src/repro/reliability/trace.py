"""Deterministic failure traces.

:class:`FailureTrace` is the reliability twin of
:class:`repro.fleet.trace.FleetTrace` / the serving ``TrafficTrace``: a
frozen knob bundle whose event stream regenerates from the seed, so a
dotted-path axis (``Axis("mtbf", (...), path="fail.mtbf_hours")``)
rewrites the trace like any other study knob — ``dataclasses.replace``
plus re-materialize.

The default ``kind="none"`` trace is the degenerate, failure-free fleet:
``materialize`` returns no events and every consumer takes the exact
pre-reliability code path (the bit-for-bit equivalence golden).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

FAILURE_TRACE_KINDS: Tuple[str, ...] = ("none", "poisson", "explicit")
BLAST_RADII: Tuple[str, ...] = ("node", "pod")


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One node-group failure: ``nodes`` nodes of ``group`` go down at
    ``time`` and come back ``repair_s`` seconds later."""

    time: float
    group: int
    nodes: int = 1
    repair_s: float = 900.0

    def __post_init__(self) -> None:
        if self.time < 0:
            raise ValueError(f"failure time must be >= 0, got {self.time}")
        if self.group < 0:
            raise ValueError(f"group must be >= 0, got {self.group}")
        if self.nodes < 1:
            raise ValueError(f"nodes must be >= 1, got {self.nodes}")
        if not (self.repair_s >= 0 and math.isfinite(self.repair_s)):
            raise ValueError(
                f"repair_s must be finite and >= 0, got {self.repair_s}")


@dataclasses.dataclass(frozen=True)
class FailureTrace:
    """A failure process over a cluster's node groups.

    * ``none`` — the degenerate failure-free trace (the default; every
      consumer behaves exactly as before this trace existed);
    * ``poisson`` — per-group exponential failure gaps at the per-node
      rate ``1 / mtbf_hours``, regenerated deterministically from
      ``seed`` until ``horizon_hours``;
    * ``explicit`` — replay ``events`` verbatim (deterministic tests and
      the headline study).

    ``blast`` picks the correlated radius: ``"node"`` downs one node per
    failure; ``"pod"`` downs the failing node's whole pod (switch-level
    blast — resolved against the cluster's ``Topology.pod_size`` at
    materialize time).
    """

    kind: str = "none"
    mtbf_hours: float = math.inf
    mttr_hours: float = 0.25
    blast: str = "node"
    horizon_hours: float = 24.0
    seed: int = 0
    events: Tuple[FailureEvent, ...] = ()

    def __post_init__(self) -> None:
        if self.kind not in FAILURE_TRACE_KINDS:
            raise ValueError(f"kind must be one of {FAILURE_TRACE_KINDS}, "
                             f"got {self.kind!r}")
        if self.blast not in BLAST_RADII:
            raise ValueError(f"blast must be one of {BLAST_RADII}, "
                             f"got {self.blast!r}")

    @property
    def enabled(self) -> bool:
        """True when materialize can produce events — the one gate every
        consumer checks before leaving the failure-free fast path."""
        if self.kind == "none":
            return False
        if self.kind == "explicit":
            return bool(self.events)
        return self.mtbf_hours > 0 and math.isfinite(self.mtbf_hours)

    @property
    def rate_per_node(self) -> float:
        """Failures per node-second (0.0 when disabled)."""
        if not self.enabled or self.kind == "explicit":
            return 0.0
        return 1.0 / (self.mtbf_hours * 3600.0)

    def materialize(self, group_sizes: Sequence[int],
                    pod_sizes: Optional[Sequence[int]] = None,
                    ) -> Tuple[FailureEvent, ...]:
        """The event stream over a cluster with ``group_sizes`` nodes per
        group.  ``pod_sizes`` (same order) sizes the ``blast="pod"``
        radius; absent, a pod is the whole group, clamped to it."""
        if not self.enabled:
            return ()
        if self.kind == "explicit":
            for ev in self.events:
                if ev.group >= len(group_sizes):
                    raise ValueError(
                        f"failure event names group {ev.group} but the "
                        f"cluster has {len(group_sizes)} group(s)")
            return tuple(sorted(self.events, key=lambda e: (e.time, e.group)))
        horizon = self.horizon_hours * 3600.0
        repair = self.mttr_hours * 3600.0
        out: List[FailureEvent] = []
        for g, n in enumerate(group_sizes):
            if n < 1:
                continue
            blast = 1
            if self.blast == "pod":
                pod = pod_sizes[g] if pod_sizes is not None else n
                blast = max(1, min(int(pod), int(n)))
            # the group fails at n * per-node rate; each draw downs
            # ``blast`` nodes (a pod blast takes its switch down with it)
            rng = np.random.default_rng([self.seed, g])
            scale = self.mtbf_hours * 3600.0 / n
            t = 0.0
            while True:
                t += float(rng.exponential(scale))
                if t >= horizon:
                    break
                out.append(FailureEvent(time=t, group=g, nodes=blast,
                                        repair_s=repair))
        return tuple(sorted(out, key=lambda e: (e.time, e.group)))


__all__ = ["BLAST_RADII", "FAILURE_TRACE_KINDS", "FailureEvent",
           "FailureTrace"]
