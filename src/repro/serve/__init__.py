"""Serving: batched continuous-batching engine + decode steps."""
from repro.serve.engine import Engine, EngineConfig, Request  # noqa: F401
