"""Batched serving engine: continuous-batching prefill/decode loop.

Requests enter a queue; the engine packs up to ``max_batch`` active
sequences into one static decode batch (slots). Each engine tick runs one
``decode_step`` for every active slot; finished sequences (EOS or length
cap) free their slot, and queued requests are prefilled into free slots.
Per-slot KV/SSM caches live in the batched cache tree; slot refill uses
single-sequence prefill + cache splice — the standard static-slot
continuous batching design (vLLM-style, without paged attention).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import get_model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0           # 0 -> greedy
    out_tokens: Optional[List[int]] = None


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 512
    eos_id: int = -1                   # -1: never stops early
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.ecfg = ecfg
        self.dtype = dtype
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}       # slot -> request
        self.remaining: Dict[int, int] = {}
        self.temps: Dict[int, float] = {}          # slot -> temperature
        self.cache = self.model.init_cache(
            cfg, ecfg.max_batch, ecfg.max_seq, dtype=dtype)
        self.last_tokens = jnp.zeros((ecfg.max_batch, 1), jnp.int32)
        self._rng = jax.random.PRNGKey(ecfg.seed)
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_step(p, cfg, c, t))

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new_tokens
        if total > self.ecfg.max_seq:
            raise ValueError(
                f"request {req.uid}: prompt_len ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) = {total} exceeds "
                f"max_seq ({self.ecfg.max_seq}); the decode cache would "
                "overflow mid-generation")
        req.out_tokens = []
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.ecfg.max_batch) if i not in self.active]

    def _splice_cache(self, slot: int, seq_cache) -> None:
        """Copy a single-sequence cache into batch position ``slot``."""
        def splice(batched, single, key):
            if key == "pos":
                return batched.at[slot].set(single[0])
            # batch axis: KV (L, B, S, H, d) -> axis 1; conv/ssm also axis 1
            return batched.at[:, slot:slot + 1].set(single)
        self.cache = {
            k: splice(self.cache[k], seq_cache[k], k) for k in self.cache}

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            seq_cache = self.model.init_cache(
                self.cfg, 1, self.ecfg.max_seq, dtype=self.dtype)
            logits, seq_cache = self.model.prefill(
                self.params, self.cfg, prompt, seq_cache)
            self._splice_cache(slot, seq_cache)
            tok = self._sample(logits[:, -1, :], req.temperature)
            self.last_tokens = self.last_tokens.at[slot, 0].set(tok[0])
            req.out_tokens.append(int(tok[0]))
            self.active[slot] = req
            self.remaining[slot] = req.max_new_tokens - 1
            self.temps[slot] = req.temperature

    def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._rng, key = jax.random.split(self._rng)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)

    def _sample_slots(self, logits: jax.Array) -> jax.Array:
        """Per-slot decode sampling: greedy for slots at temperature <= 0,
        categorical at each slot's own temperature otherwise.  The RNG
        only advances when some active slot actually samples, so
        all-greedy batches stay bit-for-bit reproducible."""
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        temps = np.zeros((self.ecfg.max_batch,), np.float32)
        for slot, t in self.temps.items():
            if t > 0:
                temps[slot] = t
        if not temps.any():
            return greedy
        self._rng, key = jax.random.split(self._rng)
        hot = jnp.asarray(temps > 0)
        safe = jnp.asarray(np.where(temps > 0, temps, 1.0))
        sampled = jax.random.categorical(
            key, logits / safe[:, None], axis=-1).astype(jnp.int32)
        return jnp.where(hot, sampled, greedy)

    # ------------------------------------------------------------------ #
    def tick(self) -> List[Request]:
        """One engine step. Returns requests completed this tick."""
        self._admit()
        done: List[Request] = []
        if not self.active:
            return done
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.last_tokens)
        next_tokens = self._sample_slots(logits[:, 0, :])
        self.last_tokens = next_tokens[:, None]
        for slot in list(self.active):
            req = self.active[slot]
            tok = int(next_tokens[slot])
            req.out_tokens.append(tok)
            self.remaining[slot] -= 1
            if tok == self.ecfg.eos_id or self.remaining[slot] <= 0:
                done.append(req)
                del self.active[slot]
                del self.remaining[slot]
                del self.temps[slot]
        return done

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        out: List[Request] = []
        for _ in range(max_ticks):
            out.extend(self.tick())
            if not self.active and not self.queue:
                break
        return out
