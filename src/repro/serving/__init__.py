"""repro.serving — analytic serving-fleet design-space exploration.

The serving twin of the training DSE stack: prefill/decode roofline
workloads (:mod:`~repro.serving.workload`), arrival-process traffic and
the SLO fleet queue (:mod:`~repro.serving.traffic`), disaggregation as a
placement (:mod:`~repro.serving.placement`), and the ``run_study``
wiring (:mod:`~repro.serving.spec`).  See docs/serving_api.md.
"""

from repro.serving.placement import (COLOCATED, DISAGGREGATED,
                                     ColocatedPlacement,
                                     DisaggregatedPlacement, PhasePlan,
                                     get_serving_placement, kv_transfer_time,
                                     list_serving_placements)
from repro.serving.spec import (SERVING_COLUMNS, ServingPoint, ServingSpec,
                                ServingStudy, is_serving_axis,
                                serving_placement_axis, serving_record)
from repro.serving.traffic import (FleetMetrics, ReplicaProfile, SLOSpec,
                                   TrafficTrace, simulate_colocated,
                                   simulate_disaggregated)
from repro.serving.workload import ServingModel, ServingWorkload, TickTrace

__all__ = [
    "COLOCATED", "DISAGGREGATED", "ColocatedPlacement",
    "DisaggregatedPlacement", "FleetMetrics", "PhasePlan", "ReplicaProfile",
    "SERVING_COLUMNS", "SLOSpec", "ServingModel", "ServingPoint",
    "ServingSpec", "ServingStudy", "ServingWorkload", "TickTrace",
    "TrafficTrace", "get_serving_placement", "is_serving_axis",
    "kv_transfer_time", "list_serving_placements", "serving_placement_axis",
    "serving_record", "simulate_colocated", "simulate_disaggregated",
]
