"""Prefill/decode disaggregation as a first-class Placement.

:class:`ColocatedPlacement` and :class:`DisaggregatedPlacement`
implement the PR-4 :class:`repro.core.placement.Placement` protocol (so
``placement_axis`` sweeps them and study records carry their labels) and
add one serving-specific hook: :meth:`phase_plan`, mapping the serving
*phases* onto a cluster's heterogeneous pod groups the way
``assign_stages`` maps pipeline stages.

Disaggregation routes every request's KV cache from its prefill pod to
its decode pod; :func:`kv_transfer_time` prices that hand-off over the
pod fabric's outermost hop (prefill and decode pods are distinct pods by
construction).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core.cluster import NodeGroup
from repro.core.placement import _PaperOrderMixin
from repro.core.topology import Topology


@dataclasses.dataclass(frozen=True)
class PhasePlan:
    """Node-group indices serving each phase.  Colocated fleets list
    every group under both phases; disaggregated fleets partition them."""

    prefill: Tuple[int, ...]
    decode: Tuple[int, ...]

    @property
    def disaggregated(self) -> bool:
        return set(self.prefill) != set(self.decode)


@dataclasses.dataclass(frozen=True)
class ColocatedPlacement(_PaperOrderMixin):
    """Every pod group hosts full replicas that both prefill and decode
    (the ``repro.serve.engine`` behavior: admissions stall the batch)."""

    @property
    def label(self) -> str:
        return "colocated"

    def phase_plan(self, groups: Sequence[NodeGroup]) -> PhasePlan:
        every = tuple(range(len(groups)))
        return PhasePlan(prefill=every, decode=every)

    def assign_stages(self, stage_bytes: Sequence[float],
                      groups: Sequence[NodeGroup],
                      nodes_per_stage: int) -> Optional[Tuple[int, ...]]:
        return None

    def instance_groups(self, fits: Sequence[bool]) -> Tuple[int, ...]:
        return tuple(range(len(fits)))


@dataclasses.dataclass(frozen=True)
class DisaggregatedPlacement(_PaperOrderMixin):
    """Prefill pods vs decode pods over heterogeneous pod groups.

    ``decode_groups`` pins the node-group indices that decode (the rest
    prefill); ``None`` auto-assigns — the roomiest groups (largest
    per-node ``total_cap``, i.e. the EM pods, which hold the most KV
    slots) decode, at least one group per phase.  On a single-group
    (homogeneous) cluster both phases share group 0 and the evaluator
    splits its *nodes* by ``prefill_frac`` instead.

    An explicitly empty ``decode_groups`` is a fleet that can never emit
    a token past the first — the V104 analysis rule rejects it."""

    decode_groups: Optional[Tuple[int, ...]] = None
    prefill_frac: float = 0.5

    def __post_init__(self) -> None:
        if not 0.0 < self.prefill_frac < 1.0:
            raise ValueError(f"prefill_frac must be in (0, 1), "
                             f"got {self.prefill_frac}")

    @property
    def label(self) -> str:
        if self.decode_groups is None:
            return "disaggregated"
        return "disaggregated[" + \
            ",".join(map(str, self.decode_groups)) + "]"

    def phase_plan(self, groups: Sequence[NodeGroup]) -> PhasePlan:
        every = tuple(range(len(groups)))
        if self.decode_groups is not None:
            decode = tuple(self.decode_groups)
            bad = [g for g in decode if not 0 <= g < len(groups)]
            if bad:
                raise ValueError(
                    f"DisaggregatedPlacement decode_groups {sorted(bad)} "
                    f"out of range for {len(groups)} node group(s)")
            prefill = tuple(i for i in every if i not in decode)
            return PhasePlan(prefill=prefill or decode, decode=decode)
        if len(groups) == 1:
            return PhasePlan(prefill=every, decode=every)
        # Roomiest groups decode; split the order in half, decode side
        # first, keeping at least one group per phase.
        order = sorted(every, key=lambda i: (groups[i].node.total_cap,
                                             groups[i].num_nodes),
                       reverse=True)
        n_dec = max(1, len(groups) // 2)
        decode = tuple(sorted(order[:n_dec]))
        prefill = tuple(sorted(order[n_dec:]))
        return PhasePlan(prefill=prefill, decode=decode)

    def assign_stages(self, stage_bytes: Sequence[float],
                      groups: Sequence[NodeGroup],
                      nodes_per_stage: int) -> Optional[Tuple[int, ...]]:
        return None

    def instance_groups(self, fits: Sequence[bool]) -> Tuple[int, ...]:
        return tuple(range(len(fits)))


COLOCATED = ColocatedPlacement()
DISAGGREGATED = DisaggregatedPlacement()

_SERVING_PLACEMENTS = {
    "colocated": COLOCATED,
    "disaggregated": DISAGGREGATED,
}


def list_serving_placements() -> Tuple[str, ...]:
    return tuple(sorted(_SERVING_PLACEMENTS))


def get_serving_placement(obj: object) -> ColocatedPlacement | DisaggregatedPlacement:
    """Coerce a serving placement name or instance."""
    if isinstance(obj, (ColocatedPlacement, DisaggregatedPlacement)):
        return obj
    if isinstance(obj, str):
        if obj not in _SERVING_PLACEMENTS:
            raise KeyError(
                f"unknown serving placement {obj!r} "
                f"(available: {list(list_serving_placements())})")
        return _SERVING_PLACEMENTS[obj]
    raise TypeError("expected a serving Placement or its name, "
                    f"got {type(obj).__name__}")


def kv_transfer_time(size_bytes: float, topology: Topology) -> float:
    """Price one request's KV hand-off (prefill pod -> decode pod) over
    the fabric's outermost (slowest) hop."""
    hop = topology.hops[-1]
    return size_bytes / hop.bw + hop.latency
