"""Study-native serving wiring: ``ServingSpec`` -> ``run_study``.

A :class:`ServingSpec` is the serving twin of
:class:`repro.core.study.StudySpec`: a model + cluster + serving knobs +
traffic trace + SLO, swept over axes.  ``run_study`` accepts it directly
(via :meth:`ServingSpec.to_study`) and emits the SLO-native record
columns ``ttft_p50 / ttft_p99 / tpot / goodput / goodput_per_dollar``
next to the usual ``cost_usd`` / ``tco`` cost columns.

Axes whose dotted path starts with ``serving.`` / ``trace.`` / ``slo.``
rewrite the serving point (``Axis("rate", (4, 16), path="trace.rate")``,
``Axis("max_batch", (8, 32), path="serving.max_batch")``) through the
same :func:`repro.core.study.set_by_path` machinery cluster axes use;
every other axis (cluster apply/path axes, ``placement_axis``) behaves
exactly as in a training study.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.cluster import ClusterLike, NodeGroup
from repro.core.memory import effective_memory_bw
from repro.core.study import (Axis, StudyContext, StudySpec, check_path,
                              placement_axis, set_by_path)
from repro.serving.placement import (ColocatedPlacement,
                                     DisaggregatedPlacement, PhasePlan,
                                     get_serving_placement, kv_transfer_time)
from repro.serving.traffic import (FleetMetrics, ReplicaProfile, SLOSpec,
                                   TrafficTrace, simulate_colocated,
                                   simulate_disaggregated)
from repro.serving.workload import ServingModel, ServingWorkload

SERVING_COLUMNS: Tuple[str, ...] = (
    "ttft_p50", "ttft_p99", "tpot", "goodput", "goodput_per_dollar")

_POINT_FIELDS: Tuple[str, ...] = ("serving", "trace", "slo")


@dataclasses.dataclass(frozen=True)
class ServingPoint:
    """The per-cell serving state dotted-path axes rewrite."""

    serving: ServingModel
    trace: TrafficTrace
    slo: SLOSpec


def is_serving_axis(axis: Axis) -> bool:
    """True when the axis path rewrites the serving point, not the
    cluster (``serving.* / trace.* / slo.*``)."""
    return (axis.kind == "cluster" and axis.path is not None
            and axis.path.partition(".")[0] in _POINT_FIELDS)


def serving_placement_axis(
        values: Sequence[object] = ("colocated", "disaggregated"),
        name: str = "placement") -> Axis:
    """A placement axis over serving placements; names resolve through
    :func:`repro.serving.placement.get_serving_placement` (the core
    registry only knows the training placements)."""
    return placement_axis(tuple(get_serving_placement(v) for v in values),
                          name=name)


@dataclasses.dataclass
class ServingSpec:
    """A declarative serving-fleet study.

    ``placement`` is a serving placement (``"colocated"`` /
    ``"disaggregated"`` / an instance); sweep it per cell with
    :func:`serving_placement_axis`.  ``metrics`` adds derived columns
    exactly as on :class:`StudySpec`."""

    name: str
    model: ModelConfig
    cluster: Optional[ClusterLike] = None
    serving: ServingModel = dataclasses.field(default_factory=ServingModel)
    trace: TrafficTrace = dataclasses.field(default_factory=TrafficTrace)
    slo: SLOSpec = dataclasses.field(default_factory=SLOSpec)
    axes: Sequence[Axis] = ()
    placement: Any = "colocated"
    metrics: Dict[str, Callable[[StudyContext], Any]] = \
        dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        get_serving_placement(self.placement)    # fail fast on bad names
        point = self.point()
        for axis in self.axes:
            if is_serving_axis(axis):
                check_path(point, axis.path or "")

    def point(self) -> ServingPoint:
        return ServingPoint(self.serving, self.trace, self.slo)

    def to_study(self) -> "ServingStudy":
        """Lower to a StudySpec the study engine runs unchanged: serving
        axes become label axes the evaluator folds back into the serving
        point; everything else passes through."""
        serving_axes = [a for a in self.axes if is_serving_axis(a)]
        study_axes = [dataclasses.replace(a, path=None)
                      if is_serving_axis(a) else a for a in self.axes]
        base_placement = get_serving_placement(self.placement)
        spec = self

        def evaluate(ctx: StudyContext) -> Dict[str, Any]:
            point = spec.point()
            for axis in serving_axes:
                point = set_by_path(point, axis.path or "",
                                    ctx.point[axis.name],
                                    scale=(axis.mode == "scale"))
            placement = ctx.placement if ctx.placement is not None \
                else base_placement
            return serving_record(ctx.cluster, spec.model, point, placement)

        return ServingStudy(
            name=self.name, cluster=self.cluster, model=self.model,
            axes=tuple(study_axes), placement=base_placement,
            metrics=dict(self.metrics), evaluate=evaluate, serving=self)


@dataclasses.dataclass
class ServingStudy(StudySpec):
    """The lowered StudySpec, carrying its source :class:`ServingSpec`
    so ``run_study(validate=)`` can run the V1xx serving rules on it."""

    serving: Optional[ServingSpec] = None


# --------------------------------------------------------------------- #
# The per-cell evaluator
# --------------------------------------------------------------------- #

def _infeasible(reason: str) -> Dict[str, Any]:
    return {"ttft_p50": float("inf"), "ttft_p99": float("inf"),
            "tpot": float("inf"), "goodput": 0.0,
            "goodput_per_dollar": 0.0, "throughput": 0.0,
            "num_replicas": 0, "feasible": False,
            "footprint_bytes": float("inf"), "mem_bw": 0.0,
            "infeasible_reason": reason}


def _colocated_profiles(wl: ServingWorkload, groups: Sequence[NodeGroup],
                        plan: PhasePlan) -> List[ReplicaProfile]:
    npr = wl.serving.nodes_per_replica
    out: List[ReplicaProfile] = []
    for gi in plan.decode:
        g = groups[gi]
        slots = wl.slots_that_fit(g.node)
        count = g.num_nodes // npr
        if slots < 1 or count < 1:
            continue
        out.append(ReplicaProfile(
            prefill_time=wl.prefill_time(g.node),
            decode_curve=wl.decode_curve(g.node, max_batch=slots),
            max_batch=slots, count=count))
    return out


def _prefill_fits(wl: ServingWorkload, g: NodeGroup) -> bool:
    """A prefill server holds the weights plus one prompt's KV."""
    npr = wl.serving.nodes_per_replica
    free = g.node.total_cap * npr - wl.weight_bytes
    return free >= wl.kv_bytes_for(wl.serving.prompt_len)


def serving_record(cluster: Optional[ClusterLike], cfg: ModelConfig,
                   point: ServingPoint, placement: object) -> Dict[str, Any]:
    """Evaluate one serving cell: build the fleet the placement implies,
    replay the trace through the fleet queue, attach the SLO columns."""
    if cluster is None:
        return _infeasible("serving study needs a cluster")
    wl = ServingWorkload(cfg, point.serving)
    try:
        n_arrivals = len(point.trace.arrivals)
    except ValueError as exc:
        return _infeasible(str(exc))
    if n_arrivals == 0:
        return _infeasible("empty traffic trace")
    pl = get_serving_placement(placement)
    groups = cluster.node_groups
    plan = pl.phase_plan(groups)
    npr = point.serving.nodes_per_replica
    decode_steps = wl.decode_steps
    pre: List[ReplicaProfile]
    dec: List[ReplicaProfile]

    if isinstance(pl, DisaggregatedPlacement) and not plan.disaggregated:
        # Homogeneous cluster: split the single group's nodes by
        # prefill_frac instead of partitioning groups.
        g = groups[plan.decode[0]]
        total = g.num_nodes // npr
        n_pre = max(1, int(round(pl.prefill_frac * total)))
        n_dec = total - n_pre
        slots = wl.slots_that_fit(g.node)
        if n_dec < 1 or slots < 1 or not _prefill_fits(wl, g):
            return _infeasible("disaggregated split does not fit the fleet")
        pre = [ReplicaProfile(wl.prefill_time(g.node), (0.0,), 1,
                              count=n_pre)]
        dec = [ReplicaProfile(0.0, wl.decode_curve(g.node, max_batch=slots),
                              slots, count=n_dec)]
        kv_delay = kv_transfer_time(wl.kv_bytes_for(point.serving.prompt_len),
                                    cluster.topology)
        metrics = simulate_disaggregated(pre, dec, decode_steps, point.trace,
                                         point.slo, kv_delay=kv_delay)
        hot = g.node
        n_replicas = n_dec
    elif isinstance(pl, DisaggregatedPlacement):
        pre = []
        for gi in plan.prefill:
            g = groups[gi]
            count = g.num_nodes // npr
            if count < 1 or not _prefill_fits(wl, g):
                continue
            pre.append(ReplicaProfile(wl.prefill_time(g.node), (0.0,), 1,
                                      count=count))
        dec = []
        for gi in plan.decode:
            g = groups[gi]
            slots = wl.slots_that_fit(g.node)
            count = g.num_nodes // npr
            if slots < 1 or count < 1:
                continue
            dec.append(ReplicaProfile(
                0.0, wl.decode_curve(g.node, max_batch=slots), slots,
                count=count))
        if not pre or not dec:
            return _infeasible(
                "disaggregated plan has no feasible "
                + ("prefill" if not pre else "decode") + " replicas")
        kv_delay = kv_transfer_time(wl.kv_bytes_for(point.serving.prompt_len),
                                    cluster.topology)
        metrics = simulate_disaggregated(pre, dec, decode_steps, point.trace,
                                         point.slo, kv_delay=kv_delay)
        hot = groups[plan.decode[0]].node
        n_replicas = sum(r.count for r in dec)
    else:
        replicas = _colocated_profiles(wl, groups, plan)
        if not replicas:
            return _infeasible("no node group fits a single KV slot "
                               "next to the weights")
        metrics = simulate_colocated(replicas, decode_steps, point.trace,
                                     point.slo)
        hot = max((groups[gi].node for gi in plan.decode
                   if wl.fits(groups[gi].node)),
                  key=lambda n: wl.slots_that_fit(n))
        n_replicas = sum(r.count for r in replicas)

    footprint = wl.replica_bytes(wl.slots_that_fit(hot))
    record: Dict[str, Any] = {
        "ttft_p50": metrics.ttft_p50, "ttft_p99": metrics.ttft_p99,
        "tpot": metrics.tpot, "goodput": metrics.goodput,
        "throughput": metrics.throughput, "num_replicas": n_replicas,
        "feasible": True, "footprint_bytes": footprint,
        "mem_bw": effective_memory_bw(hot, footprint),
    }
    cost = getattr(cluster, "cost", None)
    tco = cost.tco(cluster) if cost is not None else 0.0
    record["goodput_per_dollar"] = \
        metrics.goodput / tco if tco > 0 else 0.0
    return record


__all__ = [
    "SERVING_COLUMNS", "ServingPoint", "ServingSpec", "ServingStudy",
    "FleetMetrics", "is_serving_axis", "serving_placement_axis",
    "serving_record", "ColocatedPlacement", "DisaggregatedPlacement",
]
