"""Arrival processes and the discrete-time serving-fleet queue.

:class:`TrafficTrace` generates request arrival times from its knobs
(kind/rate/num_requests/seed), so it is a frozen dataclass that
dotted-path axes rewrite like any other: ``dataclasses.replace(trace,
rate=32.0)`` — i.e. an ``Axis(path="trace.rate")`` — regenerates the
arrivals from the same seed.  "Millions of users" is a requests/s sweep:
the trace is the load curve, the fleet queue converts it into SLO
metrics.

The fleet queue replays the engine tick loop per replica against the
trace: :func:`simulate_colocated` (every replica prefills *and* decodes,
admissions stall the batch — the engine's actual behavior) and
:func:`simulate_disaggregated` (dedicated prefill servers feed dedicated
decode replicas, each request paying a KV-transfer delay between
phases).  Both emit :class:`FleetMetrics`: TTFT percentiles, mean TPOT,
and goodput — requests meeting *both* SLO terms per second of makespan.
"""

from __future__ import annotations

import dataclasses
import heapq
from functools import cached_property
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

TRACE_KINDS: Tuple[str, ...] = ("poisson", "uniform", "bursty")


@dataclasses.dataclass(frozen=True)
class TrafficTrace:
    """An arrival process: ``num_requests`` arrivals at ``rate`` req/s.

    * ``poisson`` — exponential interarrivals (the M/... baseline);
    * ``uniform`` — deterministic 1/rate spacing (closed-form sanity);
    * ``bursty``  — two-state Markov-modulated Poisson: bursts arrive at
      ``burst_factor`` x the quiet rate, the chain spends ``burst_frac``
      of its time bursting, and the mix averages back to ``rate``.
    """

    kind: str = "poisson"
    rate: float = 8.0
    num_requests: int = 64
    seed: int = 0
    burst_factor: float = 4.0
    burst_frac: float = 0.25

    def __post_init__(self) -> None:
        if self.kind not in TRACE_KINDS:
            raise ValueError(f"kind must be one of {TRACE_KINDS}, "
                             f"got {self.kind!r}")

    @cached_property
    def arrivals(self) -> Tuple[float, ...]:
        """Sorted arrival times in seconds from t=0."""
        if self.rate <= 0 or self.num_requests <= 0:
            raise ValueError(
                f"trace needs rate > 0 and num_requests > 0, got "
                f"rate={self.rate}, num_requests={self.num_requests}")
        n = self.num_requests
        if self.kind == "uniform":
            step = 1.0 / self.rate
            return tuple(i * step for i in range(n))
        rng = np.random.default_rng(self.seed)
        if self.kind == "poisson":
            gaps = rng.exponential(1.0 / self.rate, size=n)
            gaps[0] = 0.0
            return tuple(np.cumsum(gaps).tolist())
        # bursty: stationary burst probability burst_frac, sticky states.
        quiet = self.rate / (1.0 - self.burst_frac
                             + self.burst_frac * self.burst_factor)
        rates = (quiet, quiet * self.burst_factor)
        state = 1 if rng.random() < self.burst_frac else 0
        t, out = 0.0, [0.0]
        for _ in range(n - 1):
            t += float(rng.exponential(1.0 / rates[state]))
            out.append(t)
            if rng.random() < 0.1:   # sticky sojourns: ~10 arrivals/state
                state = 1 if rng.random() < self.burst_frac else 0
        return tuple(out)

    @property
    def duration(self) -> float:
        return self.arrivals[-1] if self.arrivals else 0.0


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """The service-level objective both phases are judged against:
    time-to-first-token (queueing + prefill) and time-per-output-token
    (decode cadence, KV transfer and stalls included)."""

    ttft: float = 2.0     # seconds
    tpot: float = 0.1     # seconds per generated token


@dataclasses.dataclass(frozen=True)
class ReplicaProfile:
    """One replica as the fleet queue sees it: prefill service time per
    request, decode tick time at every occupancy (``decode_curve[b-1]``),
    and the slot count.  ``count`` stamps out identical replicas."""

    prefill_time: float
    decode_curve: Tuple[float, ...]
    max_batch: int
    count: int = 1

    def decode_time(self, occupancy: int) -> float:
        return self.decode_curve[min(occupancy, len(self.decode_curve)) - 1]


@dataclasses.dataclass(frozen=True)
class FleetMetrics:
    """SLO-native outcome of one trace against one fleet."""

    ttft_p50: float
    ttft_p99: float
    tpot: float                  # mean seconds per generated token
    goodput: float               # SLO-met requests per second of makespan
    throughput: float            # completed requests per second of makespan
    completed: int
    slo_met: int


def _pct(values: Sequence[float], q: float) -> float:
    if not values:
        return float("inf")
    ordered = sorted(values)
    idx = int(round(q * (len(ordered) - 1)))
    return ordered[idx]


def _metrics(arrivals: Sequence[float], ttft: List[float],
             finish: List[float], first: List[float],
             decode_steps: int, slo: SLOSpec) -> FleetMetrics:
    tpots = [(finish[i] - first[i]) / decode_steps
             for i in range(len(finish))]
    met = sum(1 for i in range(len(finish))
              if ttft[i] <= slo.ttft and tpots[i] <= slo.tpot)
    makespan = max(finish) - min(arrivals) if finish else float("inf")
    span = makespan if makespan > 0 else float("inf")
    return FleetMetrics(
        ttft_p50=_pct(ttft, 0.50), ttft_p99=_pct(ttft, 0.99),
        tpot=sum(tpots) / len(tpots) if tpots else float("inf"),
        goodput=met / span, throughput=len(finish) / span,
        completed=len(finish), slo_met=met)


def _expand(replicas: Sequence[ReplicaProfile]) -> List[ReplicaProfile]:
    out: List[ReplicaProfile] = []
    for r in replicas:
        out.extend([dataclasses.replace(r, count=1)] * r.count)
    return out


def simulate_colocated(replicas: Sequence[ReplicaProfile],
                       decode_steps: int,
                       trace: TrafficTrace,
                       slo: SLOSpec) -> FleetMetrics:
    """Engine-faithful colocated fleet: each tick a replica admits from
    the shared FIFO queue (each admission one serial prefill, stalling
    every slot), then decodes all active slots once.  Admission prefill
    interference is exactly why disaggregation exists."""
    fleet = _expand(replicas)
    if not fleet:
        raise ValueError("simulate_colocated needs at least one replica")
    arrivals = trace.arrivals
    n = len(arrivals)
    ttft = [0.0] * n
    first = [0.0] * n
    finish = [0.0] * n
    nxt = 0                                   # arrival cursor
    queue: List[int] = []
    # replica state: (clock, idx); active[idx]: slot -> (req, remaining)
    clocks = [(0.0, i) for i in range(len(fleet))]
    heapq.heapify(clocks)
    active: List[Dict[int, Tuple[int, int]]] = [{} for _ in fleet]
    done = 0
    while done < n:
        clock, ri = heapq.heappop(clocks)
        rep = fleet[ri]
        while nxt < n and arrivals[nxt] <= clock:
            queue.append(nxt)
            nxt += 1
        slots = active[ri]
        if not slots and not queue:
            if nxt >= n:
                continue                      # idle replica, trace drained
            heapq.heappush(clocks, (max(clock, arrivals[nxt]), ri))
            continue
        t = clock
        for slot in range(rep.max_batch):
            if slot in slots or not queue:
                continue
            req = queue.pop(0)
            t += rep.prefill_time
            first[req] = t
            ttft[req] = t - arrivals[req]
            slots[slot] = (req, decode_steps)
        if slots:
            t += rep.decode_time(len(slots))
            for slot in list(slots):
                req, remaining = slots[slot]
                if remaining - 1 <= 0:
                    finish[req] = t
                    done += 1
                    del slots[slot]
                else:
                    slots[slot] = (req, remaining - 1)
        heapq.heappush(clocks, (t, ri))
    return _metrics(arrivals, ttft, finish, first, decode_steps, slo)


def simulate_disaggregated(prefill: Sequence[ReplicaProfile],
                           decode: Sequence[ReplicaProfile],
                           decode_steps: int,
                           trace: TrafficTrace,
                           slo: SLOSpec,
                           kv_delay: float = 0.0) -> FleetMetrics:
    """Two-stage fleet: dedicated prefill servers (serial, one request at
    a time — no batch to stall) hand finished prompts to decode replicas
    after a per-request ``kv_delay`` (the KV-cache transfer over the pod
    fabric).  Decode replicas run pure decode ticks, never prefilling."""
    pre = _expand(prefill)
    dec = _expand(decode)
    if not pre or not dec:
        raise ValueError("simulate_disaggregated needs at least one "
                         "prefill and one decode replica")
    arrivals = trace.arrivals
    n = len(arrivals)
    ttft = [0.0] * n
    first = [0.0] * n
    finish = [0.0] * n
    # Stage 1: earliest-free prefill server, serial service.
    free = [(0.0, i) for i in range(len(pre))]
    heapq.heapify(free)
    ready: List[Tuple[float, int]] = []       # (decode-ready time, req)
    for req, arr in enumerate(arrivals):
        t0, si = heapq.heappop(free)
        t = max(arr, t0) + pre[si].prefill_time
        first[req] = t
        ttft[req] = t - arr
        heapq.heappush(free, (t, si))
        ready.append((t + kv_delay, req))
    ready.sort()
    # Stage 2: decode replicas tick over the ready queue.
    clocks = [(0.0, i) for i in range(len(dec))]
    heapq.heapify(clocks)
    active: List[Dict[int, Tuple[int, int]]] = [{} for _ in dec]
    queue: List[int] = []
    nxt = 0
    done = 0
    while done < n:
        clock, ri = heapq.heappop(clocks)
        rep = dec[ri]
        while nxt < n and ready[nxt][0] <= clock:
            queue.append(ready[nxt][1])
            nxt += 1
        slots = active[ri]
        if not slots and not queue:
            if nxt >= n:
                continue
            heapq.heappush(clocks, (max(clock, ready[nxt][0]), ri))
            continue
        for slot in range(rep.max_batch):
            if slot in slots or not queue:
                continue
            slots[slot] = (queue.pop(0), decode_steps)
        t = clock + rep.decode_time(len(slots))
        for slot in list(slots):
            req, remaining = slots[slot]
            if remaining - 1 <= 0:
                finish[req] = t
                done += 1
                del slots[slot]
            else:
                slots[slot] = (req, remaining - 1)
        heapq.heappush(clocks, (t, ri))
    return _metrics(arrivals, ttft, finish, first, decode_steps, slo)
