"""Analytic serving-replica model: prefill/decode roofline phases + KV memory.

One *replica* is ``nodes_per_replica`` nodes holding a full copy of the
model and up to ``max_batch`` KV-cache slots, running the static-slot
continuous-batching loop of :mod:`repro.serve.engine`: each tick admits
queued requests into free slots (one single-sequence prefill each, which
stalls the whole batch) and then runs one decode step for every active
slot.

The two phases sit on opposite ends of the roofline:

* **prefill** — one request's prompt as M = prompt_len GEMMs; high
  operational intensity, compute-bound on every registry node;
* **decode** — one token per active slot (M = batch GEMMs) plus the KV
  reads (``context * kv_bytes_per_token`` per slot per tick); OI of order
  the batch size, memory-bandwidth-bound until the slots fill up — the
  utilization axis.

KV-cache footprint is the memory axis: ``2 * L * S * H_kv * d * bytes``
per slot (k and v, every layer, ``max_seq`` positions), gated like
:mod:`repro.core.memory` gates training footprints — against
``total_cap`` including expanded-memory pods, with the decode roofline
slope degraded by :func:`repro.core.memory.effective_memory_bw` when the
working set spills past local HBM.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

from repro.configs.base import ModelConfig
from repro.core.cluster import NodeConfig
from repro.core.gemm import ExplicitOp, Gemm, PhaseCost, phase_cost
from repro.core.memory import FootprintReport, effective_memory_bw
from repro.core.roofline import RooflinePoint, compute_delay


@dataclasses.dataclass(frozen=True)
class ServingModel:
    """The sweepable serving knobs (dotted-path axes resolve here).

    ``kv_bytes`` overrides the per-token per-slot KV-cache bytes derived
    from the model config (``2 * L * H_kv * d * bytes_per_element``);
    0 means derive.  ``nodes_per_replica`` spreads one replica's weights
    and KV slots over several nodes (tensor-parallel serving); phase
    times assume the shards run in parallel."""

    max_batch: int = 16
    max_seq: int = 2048
    prompt_len: int = 512
    max_new_tokens: int = 64
    bytes_per_element: int = 2
    kv_bytes: float = 0.0
    nodes_per_replica: int = 1

    def __post_init__(self) -> None:
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.nodes_per_replica < 1:
            raise ValueError("nodes_per_replica must be >= 1, "
                             f"got {self.nodes_per_replica}")
        if self.prompt_len + self.max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt_len {self.prompt_len} + max_new_tokens "
                f"{self.max_new_tokens} exceeds max_seq {self.max_seq}")


@dataclasses.dataclass(frozen=True)
class TickTrace:
    """The engine-shaped schedule of one replica draining a request list:
    how many prefills ran, how many decode ticks, and the batch occupancy
    of each — the structure the tier-2 cross-check locks against
    :class:`repro.serve.engine.Engine`."""

    occupancy: Tuple[int, ...]          # active slots at each decode tick
    admitted: Tuple[int, ...]           # prefills folded into each tick
    prefills: int

    @property
    def ticks(self) -> int:
        return len(self.occupancy)


Op = Union[Gemm, ExplicitOp]


class ServingWorkload:
    """Roofline-priced analytic model of one serving replica."""

    def __init__(self, cfg: ModelConfig, serving: ServingModel) -> None:
        self.cfg = cfg
        self.serving = serving

    # -- memory axis ---------------------------------------------------- #
    @property
    def kv_bytes_per_token(self) -> float:
        """Per-slot KV bytes for one cached position: 2 (k and v) * L *
        H_kv * d * bytes, or the ``serving.kv_bytes`` override."""
        if self.serving.kv_bytes > 0:
            return self.serving.kv_bytes
        cfg = self.cfg
        return float(2 * cfg.num_layers * cfg.num_kv_heads
                     * cfg.resolved_head_dim * self.serving.bytes_per_element)

    @property
    def kv_slot_bytes(self) -> float:
        """Full per-slot KV footprint: the engine allocates ``max_seq``
        positions per slot up front (static slots, no paging)."""
        return self.kv_bytes_per_token * self.serving.max_seq

    @property
    def weight_bytes(self) -> float:
        return float(self.cfg.param_count()) * self.serving.bytes_per_element

    def kv_bytes_for(self, tokens: int) -> float:
        """KV bytes actually written for ``tokens`` cached positions (the
        prefill->decode transfer size under disaggregation)."""
        return self.kv_bytes_per_token * tokens

    def replica_bytes(self, batch: Optional[int] = None) -> float:
        """Per-node working set: this node's shard of the weights plus its
        share of ``batch`` full KV slots."""
        b = self.serving.max_batch if batch is None else batch
        return (self.weight_bytes + b * self.kv_slot_bytes) \
            / self.serving.nodes_per_replica

    def slots_that_fit(self, node: NodeConfig) -> int:
        """How many KV slots a replica on ``node`` can actually hold
        (capped at ``max_batch``), gating against ``total_cap`` so
        expanded-memory pods count their pool."""
        free = node.total_cap * self.serving.nodes_per_replica \
            - self.weight_bytes
        if free < self.kv_slot_bytes:
            return 0
        return min(self.serving.max_batch, int(free // self.kv_slot_bytes))

    def fits(self, node: NodeConfig) -> bool:
        return self.slots_that_fit(node) >= 1

    def replica_report(self, node: NodeConfig,
                       batch: Optional[int] = None) -> FootprintReport:
        """``memory``-style feasibility report for one replica node:
        model states = the weight shard, working memory = the KV slots."""
        b = self.serving.max_batch if batch is None else batch
        npr = self.serving.nodes_per_replica
        states = self.weight_bytes / npr
        kv = b * self.kv_slot_bytes / npr
        total = states + kv
        return FootprintReport(states, kv, total,
                               fits_local=total <= node.local_cap,
                               fits_total=total <= node.total_cap)

    # -- phase costs ---------------------------------------------------- #
    @property
    def decode_steps(self) -> int:
        """Decode ticks one request occupies a slot for.  Mirrors the
        engine: prefill emits the first token and sets ``remaining =
        max_new_tokens - 1``; the next tick always decodes once before
        checking, so a one-token request still costs one decode tick."""
        return max(1, self.serving.max_new_tokens - 1)

    @property
    def mean_context(self) -> int:
        """Expected cached context mid-generation."""
        ctx = self.serving.prompt_len + self.decode_steps // 2
        return min(ctx, self.serving.max_seq)

    def _linear_ops(self, m: int) -> List[Op]:
        """The per-layer projection/FFN GEMMs for ``m`` token rows, plus
        the LM head — everything except attention itself."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        bpe = self.serving.bytes_per_element
        qkv_out = (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
        per_layer: List[Op] = [
            Gemm(m, cfg.d_model, qkv_out, bytes_per_element=bpe),
            Gemm(m, cfg.num_heads * hd, cfg.d_model, bytes_per_element=bpe),
        ]
        ffn_mats = 3 if cfg.activation == "swiglu" else 2
        up = ffn_mats - 1
        per_layer += [Gemm(m, cfg.d_model, cfg.d_ff, bytes_per_element=bpe)
                      for _ in range(up)]
        per_layer += [Gemm(m, cfg.d_ff, cfg.d_model, bytes_per_element=bpe)]
        ops: List[Op] = per_layer * cfg.num_layers
        ops.append(Gemm(m, cfg.d_model, cfg.vocab_size, bytes_per_element=bpe))
        return ops

    def prefill_ops(self, prompt_len: Optional[int] = None) -> List[Op]:
        """One request's prompt pass: M = prompt_len GEMMs plus the
        quadratic attention score/value GEMMs per head per layer."""
        cfg = self.cfg
        s = self.serving.prompt_len if prompt_len is None else prompt_len
        hd = cfg.resolved_head_dim
        bpe = self.serving.bytes_per_element
        ops = self._linear_ops(s)
        ops += [Gemm(s, hd, s, batch=cfg.num_heads, bytes_per_element=bpe),
                Gemm(s, s, hd, batch=cfg.num_heads, bytes_per_element=bpe)
                ] * cfg.num_layers
        return ops

    def decode_ops(self, batch: int,
                   context: Optional[int] = None) -> List[Op]:
        """One decode tick for ``batch`` active slots: M = batch GEMMs
        (weights stream once per tick) plus the per-slot KV reads, priced
        through ``kv_bytes_per_token`` so a ``serving.kv_bytes`` sweep
        moves footprint and decode traffic coherently."""
        cfg = self.cfg
        ctx = self.mean_context if context is None else context
        ops = self._linear_ops(batch)
        attn_flops = 4 * batch * cfg.num_heads * cfg.resolved_head_dim * ctx
        kv_read = batch * ctx * self.kv_bytes_per_token / cfg.num_layers
        ops += [ExplicitOp(attn_flops, int(kv_read))] * cfg.num_layers
        return ops

    def _cost(self, ops: Sequence[Op], node: NodeConfig) -> PhaseCost:
        total = PhaseCost()
        npr = self.serving.nodes_per_replica
        for op in ops:
            total = total + phase_cost(op, int(node.sram_bytes))
        if npr > 1:  # shards run in parallel across the replica's nodes
            total = PhaseCost(total.flops // npr, total.traffic // npr)
        return total

    def prefill_point(self, node: NodeConfig,
                      prompt_len: Optional[int] = None) -> RooflinePoint:
        return compute_delay(self._cost(self.prefill_ops(prompt_len), node),
                             node)

    def decode_point(self, node: NodeConfig, batch: int,
                     context: Optional[int] = None,
                     mem_bw: Optional[float] = None) -> RooflinePoint:
        """Roofline point of one decode tick at ``batch`` occupancy.  The
        slope defaults to :func:`effective_memory_bw` at the replica's
        working set, so slots spilling into expanded memory slow every
        tick — the capacity/bandwidth trade the EM studies sweep."""
        if mem_bw is None:
            mem_bw = effective_memory_bw(node, self.replica_bytes(batch))
        return compute_delay(self._cost(self.decode_ops(batch, context),
                                        node), node, mem_bw=mem_bw)

    def prefill_time(self, node: NodeConfig,
                     prompt_len: Optional[int] = None) -> float:
        return self.prefill_point(node, prompt_len).delay

    def decode_time(self, node: NodeConfig, batch: int,
                    context: Optional[int] = None) -> float:
        return self.decode_point(node, batch, context).delay

    def decode_curve(self, node: NodeConfig,
                     max_batch: Optional[int] = None) -> Tuple[float, ...]:
        """Tick time at every occupancy 1..max_batch (the utilization
        axis, ready for the fleet queue)."""
        b = self.serving.max_batch if max_batch is None else max_batch
        return tuple(self.decode_time(node, i) for i in range(1, b + 1))

    # -- engine-shaped schedule ----------------------------------------- #
    def engine_schedule(self, num_requests: int,
                        new_tokens: Optional[Sequence[int]] = None,
                        max_batch: Optional[int] = None) -> TickTrace:
        """Mirror the :class:`repro.serve.engine.Engine` tick loop exactly
        (FIFO admission into free slots, one decode step for all active
        slots per tick, retire at ``remaining <= 0``) for a backlog of
        ``num_requests`` requests all queued up front.  ``new_tokens``
        gives per-request ``max_new_tokens`` (default: the workload's)."""
        cap = self.serving.max_batch if max_batch is None else max_batch
        budgets = [max(1, n - 1) for n in (
            new_tokens if new_tokens is not None
            else [self.serving.max_new_tokens] * num_requests)]
        queue = list(range(len(budgets)))
        active: dict[int, int] = {}          # slot -> remaining decode ticks
        occupancy: List[int] = []
        admitted: List[int] = []
        prefills = 0
        while queue or active:
            admit_now = 0
            for slot in range(cap):
                if slot in active or not queue:
                    continue
                active[slot] = budgets[queue.pop(0)]
                prefills += 1
                admit_now += 1
            occupancy.append(len(active))
            admitted.append(admit_now)
            for slot in list(active):
                active[slot] -= 1
                if active[slot] <= 0:
                    del active[slot]
        return TickTrace(tuple(occupancy), tuple(admitted), prefills)

    def schedule_time(self, trace: TickTrace, node: NodeConfig) -> float:
        """Roofline wall-clock of an engine-shaped schedule: every prefill
        stalls the batch, every tick decodes at its occupancy."""
        curve = self.decode_curve(node, max_batch=max(trace.occupancy,
                                                      default=1))
        pre = self.prefill_time(node)
        return trace.prefills * pre + sum(curve[occ - 1]
                                          for occ in trace.occupancy if occ)
