"""Training: optimizer, jit'd step factory, fault-tolerant trainer."""
from repro.train.optimizer import AdamWConfig, apply_updates, init_state  # noqa: F401
from repro.train.train_step import (  # noqa: F401
    init_train_state,
    jit_train_step,
    make_train_step,
    state_shardings,
)
from repro.train.trainer import Trainer, TrainerConfig  # noqa: F401
