"""AdamW with ZeRO-aware state dtypes.

Modes (picked by the memory planner, parallel/policy.py):
  * fp32 Adam: bf16 params + fp32 master + fp32 m/v  (16 B/param — the
    paper's ZeRO accounting),
  * bf16 moments, no master, stochastic rounding on the bf16 param update
    (4 B/param) — for models whose fp32 states exceed the pod (llama4-400B).

Functional: ``init_state`` / ``apply_updates`` over pytrees; state sharding
is applied by the caller via parallel/zero.py specs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"       # "float32" | "bfloat16"
    use_master: bool = True
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_state(params, cfg: AdamWConfig) -> dict:
    sdt = jnp.dtype(cfg.state_dtype)
    state = {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.use_master:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def _stochastic_round(x: jax.Array, key: jax.Array,
                      dtype=jnp.bfloat16) -> jax.Array:
    """Unbiased fp32 -> bf16 rounding (replaces the master copy).

    The one-ulp neighbor is taken by integer-incrementing the bf16 bit
    pattern toward x (fp32 nextafter would round back to the same bf16)."""
    y = x.astype(dtype)                      # round-to-nearest baseline
    yf = y.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(y, jnp.uint16)
    toward_up = x > yf
    delta = jnp.where(toward_up == (yf >= 0),
                      jnp.uint16(1), jnp.uint16(0) - jnp.uint16(1))
    neighbor = jax.lax.bitcast_convert_type(bits + delta, dtype)
    nf = neighbor.astype(jnp.float32)
    span = jnp.abs(nf - yf)
    frac = jnp.where(span > 0, jnp.abs(x - yf) / span, 0.0)
    r = jax.random.uniform(key, x.shape)
    return jnp.where(r < frac, neighbor, y)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(params, grads, state: dict, cfg: AdamWConfig,
                  rng: Optional[jax.Array] = None
                  ) -> Tuple[dict, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)
    use_master = cfg.use_master and "master" in state

    flat_params, treedef = jax.tree_util.tree_flatten(params)
    flat_grads = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(state["m"])[0]
    flat_v = jax.tree_util.tree_flatten(state["v"])[0]
    flat_master = (jax.tree_util.tree_flatten(state["master"])[0]
                   if use_master else [None] * len(flat_params))
    keys = (list(jax.random.split(rng, len(flat_params)))
            if rng is not None else [None] * len(flat_params))

    new_p, new_m, new_v, new_master = [], [], [], []
    for p, g, m, v, mst, k in zip(flat_params, flat_grads, flat_m,
                                  flat_v, flat_master, keys):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        upd = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps)
        base = mst if use_master else p.astype(jnp.float32)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            upd = upd + cfg.weight_decay * base
        newf = base - lr * upd
        if use_master:
            new_master.append(newf)
            new_p.append(newf.astype(p.dtype))
        elif p.dtype == jnp.bfloat16 and k is not None:
            new_p.append(_stochastic_round(newf, k))
        else:
            new_p.append(newf.astype(p.dtype))
        new_m.append(m2.astype(sdt))
        new_v.append(v2.astype(sdt))

    def unf(leaves):
        return jax.tree_util.tree_unflatten(treedef, leaves)
    new_state = {"m": unf(new_m), "v": unf(new_v), "step": step}
    if use_master:
        new_state["master"] = unf(new_master)
    return unf(new_p), new_state, {"lr": lr, "grad_norm": gnorm}
