"""The jit'd training step + its sharding contract.

``make_train_step`` binds (model, config, memory plan, optimizer config)
into a pure (state, batch, rng) -> (state, metrics) function; shardings for
every state leaf come from parallel/{sharding,zero}.py so the same function
lowers on any mesh — this is the object the multi-pod dry-run compiles.

Gradient accumulation: the memory planner sizes ``plan.microbatches`` so
remat-saved activations fit HBM; the step scans over microbatches
accumulating fp32 grads. Before the optimizer, grads are constrained to the
optimizer-state sharding (ZeRO-1's reduce-scatter — without the constraint
GSPMD all-gathers the data-sharded Adam states to full size instead).
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.parallel.mesh import dp_axes
from repro.parallel.policy import MemoryPlan
from repro.parallel.sharding import batch_shardings, param_shardings
from repro.parallel.zero import opt_state_shardings
from repro.train.optimizer import AdamWConfig, apply_updates, init_state


def make_train_step(cfg: ModelConfig, plan: MemoryPlan,
                    opt_cfg: Optional[AdamWConfig] = None,
                    batch_dp_axes: Optional[Tuple[str, ...]] = None,
                    grad_shardings=None) -> Callable:
    """(state, batch, rng) -> (state, metrics). state = {params, opt}."""
    model = get_model(cfg)
    opt_cfg = opt_cfg or AdamWConfig(state_dtype=plan.opt_dtype,
                                     use_master=plan.use_master)
    m = max(1, plan.microbatches)
    acc_dtype = (jnp.bfloat16 if plan.opt_dtype == "bfloat16"
                 else jnp.float32)

    def loss_fn(params, mb):
        return model.loss(params, cfg, mb, remat=plan.remat)

    def _constrain_batch(mb):
        if not batch_dp_axes:
            return mb
        ax = batch_dp_axes if len(batch_dp_axes) > 1 else batch_dp_axes[0]
        return jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x, P(ax, *([None] * (x.ndim - 1)))), mb)

    def train_step(state, batch, rng):
        params = state["params"]
        if m <= 1:
            (loss, parts), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, _constrain_batch(batch))
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            mbatch = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                batch)

            def body(carry, mb):
                acc_loss, acc_parts, acc_g = carry
                (mb_loss, parts), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, _constrain_batch(mb))
                acc_g = jax.tree.map(
                    lambda a, x: a + x.astype(acc_dtype) / m, acc_g, g)
                acc_parts = jax.tree.map(lambda a, x: a + x / m,
                                         acc_parts, parts)
                return (acc_loss + mb_loss / m, acc_parts, acc_g), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            zero_parts = {"ce": jnp.zeros((), jnp.float32),
                          "aux": jnp.zeros((), jnp.float32)}
            (loss, parts, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_parts, zero_g),
                mbatch)
        if grad_shardings is not None:
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, grad_shardings)
        new_params, new_opt, opt_metrics = apply_updates(
            params, grads, state["opt"], opt_cfg, rng)
        metrics = {"loss": loss, **parts, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(cfg: ModelConfig, plan: MemoryPlan, rng,
                     opt_cfg: Optional[AdamWConfig] = None,
                     dtype=jnp.bfloat16) -> dict:
    model = get_model(cfg)
    opt_cfg = opt_cfg or AdamWConfig(state_dtype=plan.opt_dtype,
                                     use_master=plan.use_master)
    params = model.init_params(rng, cfg, dtype=dtype)
    return {"params": params, "opt": init_state(params, opt_cfg)}


def state_shardings(cfg: ModelConfig, plan: MemoryPlan, state_shapes,
                    mesh: Mesh):
    """NamedShardings for the full train state pytree."""
    p_sh = param_shardings(cfg, state_shapes["params"], mesh, fsdp=plan.fsdp)
    opt = state_shapes["opt"]
    o_sh = {
        "m": opt_state_shardings(cfg, opt["m"], mesh, plan),
        "v": opt_state_shardings(cfg, opt["v"], mesh, plan),
        "step": NamedSharding(mesh, P()),
    }
    if "master" in opt:
        o_sh["master"] = opt_state_shardings(cfg, opt["master"], mesh, plan)
    return {"params": p_sh, "opt": o_sh}


def jit_train_step(cfg: ModelConfig, plan: MemoryPlan, mesh: Mesh,
                   state_shapes, batch_shapes,
                   opt_cfg: Optional[AdamWConfig] = None,
                   donate: bool = True):
    """pjit the step with explicit in/out shardings (dry-run entry point)."""
    st_sh = state_shardings(cfg, plan, state_shapes, mesh)
    step = make_train_step(cfg, plan, opt_cfg,
                           batch_dp_axes=dp_axes(mesh),
                           grad_shardings=st_sh["opt"]["m"])
    b_sh = batch_shardings(mesh, batch_shapes, cfg)
    rng_sh = NamedSharding(mesh, P())
    metrics_sh = NamedSharding(mesh, P())
    return jax.jit(
        step,
        in_shardings=(st_sh, b_sh, rng_sh),
        out_shardings=(st_sh, metrics_sh),
        donate_argnums=(0,) if donate else (),
    )
