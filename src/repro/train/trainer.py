"""Fault-tolerant training loop.

Production concerns handled here:
  * checkpoint/restart — CheckpointManager cadence + auto-resume (data
    iterator state travels inside the checkpoint),
  * preemption — SIGTERM/SIGINT trigger one final forced checkpoint before
    exit (the standard TPU-pod eviction contract),
  * straggler mitigation — a per-step wall-time watchdog tracks a robust
    (median) step time; steps slower than ``straggler_factor``x median are
    counted and surfaced, and an optional callback lets the launcher
    re-shard away from slow hosts (on real multi-host topologies this is
    where you'd swap the data shard / alert the scheduler),
  * elastic restart — restoring onto a different mesh re-shards state via
    the checkpoint layer; the data iterator re-splits the same stream.
"""

from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import CheckpointManager
from repro.data.pipeline import DataIterator


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_interval: int = 100
    ckpt_keep: int = 3
    log_interval: int = 10
    straggler_factor: float = 3.0
    seed: int = 0


class Trainer:
    def __init__(self, step_fn: Callable, state, data: DataIterator,
                 cfg: TrainerConfig,
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 state_shardings=None):
        self.step_fn = step_fn
        self.state = state
        self.data = data
        self.cfg = cfg
        self.on_straggler = on_straggler
        self.state_shardings = state_shardings
        self.step = 0
        self.step_times: List[float] = []
        self.straggler_steps = 0
        self.metrics_log: List[Dict] = []
        self._preempted = False
        self.manager = (CheckpointManager(cfg.ckpt_dir, cfg.ckpt_interval,
                                          cfg.ckpt_keep)
                        if cfg.ckpt_dir else None)

    # ------------------------------------------------------------------ #
    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not on main thread (tests)

    def try_resume(self) -> bool:
        if self.manager is None or self.manager.latest_step() is None:
            return False
        state, extra = self.manager.restore_latest(
            target=self.state, shardings=self.state_shardings)
        self.state = state
        self.step = int(extra.get("step", 0))
        self.data.restore(extra.get("data", {"step": self.step}))
        return True

    # ------------------------------------------------------------------ #
    def _watchdog(self, dt: float) -> None:
        self.step_times.append(dt)
        window = self.step_times[-50:]
        if len(window) >= 10:
            med = statistics.median(window)
            if dt > self.cfg.straggler_factor * med:
                self.straggler_steps += 1
                if self.on_straggler is not None:
                    self.on_straggler(self.step, dt / med)

    def _checkpoint(self, force: bool = False) -> None:
        if self.manager is None:
            return
        extra = {"step": self.step, "data": self.data.state()}
        self.manager.maybe_save(self.step, self.state, extra, force=force)

    # ------------------------------------------------------------------ #
    def run(self, rng: Optional[jax.Array] = None) -> Dict:
        self._install_signal_handlers()
        rng = rng if rng is not None else jax.random.PRNGKey(self.cfg.seed)
        last_metrics: Dict = {}
        while self.step < self.cfg.total_steps and not self._preempted:
            batch = next(self.data)
            step_rng = jax.random.fold_in(rng, self.step)
            t0 = time.monotonic()
            self.state, metrics = self.step_fn(self.state, batch, step_rng)
            metrics = jax.tree.map(
                lambda x: float(np.asarray(jax.device_get(x))), metrics)
            dt = time.monotonic() - t0
            self._watchdog(dt)
            self.step += 1
            if self.step % self.cfg.log_interval == 0 or \
                    self.step == self.cfg.total_steps:
                row = {"step": self.step, "time_s": dt, **metrics}
                self.metrics_log.append(row)
                print(" ".join(
                    f"{k}={v:.5g}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in row.items()), flush=True)
            last_metrics = metrics
            self._checkpoint()
        # final / preemption flush
        self._checkpoint(force=True)
        if self.manager:
            self.manager.wait()
        return {
            "final_step": self.step,
            "preempted": self._preempted,
            "straggler_steps": self.straggler_steps,
            "median_step_s": (statistics.median(self.step_times)
                              if self.step_times else 0.0),
            **{f"final_{k}": v for k, v in last_metrics.items()},
        }
