"""Frozen copy of the seed `repro.core.dse` (pre-Study-API) used as the
golden reference: tests/test_study.py asserts the declarative rewrites in
`repro.core.dse` reproduce these numbers bit-for-bit. Do not modernize.

Original docstring:
COMET §V: design-space-exploration studies (one function per case study).

Each function returns plain dicts/lists so benchmarks can print CSV and tests
can assert the paper's qualitative claims. All studies are embarrassingly
parallel in principle; here they run serially in well under the paper's
"few hours" turnaround (§V-E) because ASTRA-lite is analytical end-to-end.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.cluster import (
    ClusterConfig,
    HierarchicalSwitch,
    TABLE_III_CLUSTERS,
)
from repro.core.memory import per_node_footprint
from repro.core.simulator import simulate_iteration
from repro.core.strategy import StrategyResult
from repro.core.workload import decompose, decompose_dlrm

GB = 1e9


def power_of_two_strategies(num_nodes):
    """Seed copy of the pre-Study-API enumerator."""
    out = []
    mp = num_nodes
    while mp >= 1:
        out.append((mp, num_nodes // mp))
        mp //= 2
    return out


def sweep_strategies(cfg, shape, cluster, zero_stage=2, mem_bw_override=None,
                     min_mp=1, max_mp=None, workload_fn=None):
    """Seed copy of the pre-Study-API Fig. 8 engine."""
    decomp = workload_fn or decompose
    results = []
    for mp, dp in power_of_two_strategies(cluster.num_nodes):
        if mp < min_mp or (max_mp is not None and mp > max_mp):
            continue
        wl = decomp(cfg, shape, mp=mp, dp=dp)
        br = simulate_iteration(wl, cluster, zero_stage=zero_stage,
                                mem_bw_override=mem_bw_override)
        fp = per_node_footprint(wl, cluster.node, zero_stage)
        results.append(StrategyResult(mp, dp, br, fp.total))
    return results


# --------------------------------------------------------------------- #
# §V-B1 / Fig. 8: MP-DP sweep at fixed memory bandwidth
# --------------------------------------------------------------------- #

def mpdp_sweep(cfg: ModelConfig, shape: ShapeConfig, cluster: ClusterConfig,
               assume_infinite_capacity: bool = True,
               min_mp: int = 1) -> List[StrategyResult]:
    """Training-time breakdown for each (MP, DP); §V-B1 assumes infinite
    per-node capacity at baseline bandwidth."""
    override = cluster.node.local_bw if assume_infinite_capacity else None
    return sweep_strategies(cfg, shape, cluster, mem_bw_override=override,
                            min_mp=min_mp)


# --------------------------------------------------------------------- #
# §V-B2 / Fig. 9: expanded-memory bandwidth heatmap
# --------------------------------------------------------------------- #

def memory_expansion_heatmap(
    cfg: ModelConfig,
    shape: ShapeConfig,
    cluster: ClusterConfig,
    em_bandwidths_gbs: Sequence[float] = (100, 250, 500, 750, 1000, 1500, 2000),
    strategies: Optional[Sequence[tuple]] = None,
) -> Dict[str, Dict[float, float]]:
    """runtime[strategy_label][bw_EM_GBs], normalized by the caller.

    Expanded capacity is sized to whatever the strategy needs (the y-axis is
    a proxy for required capacity — paper Fig. 9)."""
    strategies = strategies or power_of_two_strategies(cluster.num_nodes)
    out: Dict[str, Dict[float, float]] = {}
    for mp, dp in strategies:
        label = f"MP{mp}_DP{dp}"
        out[label] = {}
        wl = decompose(cfg, shape, mp=mp, dp=dp)
        for bw in em_bandwidths_gbs:
            node = cluster.node.with_expansion(cap=1e15, bw=bw * GB)
            br = simulate_iteration(wl, cluster.with_node(node))
            out[label][bw] = br.total
    return out


# --------------------------------------------------------------------- #
# §V-B3 / Fig. 10: per-node compute-capability scaling
# --------------------------------------------------------------------- #

def compute_scaling(
    cfg: ModelConfig,
    shape: ShapeConfig,
    cluster: ClusterConfig,
    mp: int,
    dp: int,
    compute_factors: Sequence[float] = (0.5, 1.0, 2.0, 4.0, 8.0),
    em_bandwidths_gbs: Sequence[float] = (500, 1000, 2000),
) -> Dict[float, Dict[float, float]]:
    """runtime[compute_factor][bw_EM_GBs] for a fixed strategy."""
    wl = decompose(cfg, shape, mp=mp, dp=dp)
    out: Dict[float, Dict[float, float]] = {}
    for f in compute_factors:
        out[f] = {}
        for bw in em_bandwidths_gbs:
            node = cluster.node.scaled_compute(f).with_expansion(1e15, bw * GB)
            br = simulate_iteration(wl, cluster.with_node(node))
            out[f][bw] = br.total
    return out


# --------------------------------------------------------------------- #
# §V-B4 / Fig. 11: intra-/inter-pod bandwidth scaling
# --------------------------------------------------------------------- #

def network_scaling(
    cfg: ModelConfig,
    shape: ShapeConfig,
    cluster: ClusterConfig,
    mp: int,
    dp: int,
    intra_factors: Sequence[float] = (0.5, 1.0, 2.0, 4.0),
    inter_factors: Sequence[float] = (0.5, 1.0, 2.0, 4.0),
) -> Dict[tuple, float]:
    """runtime[(intra_factor, inter_factor)] at baseline compute/memory."""
    assert isinstance(cluster.topology, HierarchicalSwitch)
    wl = decompose(cfg, shape, mp=mp, dp=dp)
    out: Dict[tuple, float] = {}
    for fi in intra_factors:
        for fo in inter_factors:
            topo = cluster.topology.scaled(intra=fi, inter=fo)
            br = simulate_iteration(
                wl, cluster.with_topology(topo),
                mem_bw_override=cluster.node.local_bw)
            out[(fi, fo)] = br.total
    return out


# --------------------------------------------------------------------- #
# §V-B4 / Fig. 12: fixed-aggregate bandwidth re-balancing
# --------------------------------------------------------------------- #

def bandwidth_rebalance(
    cfg: ModelConfig,
    shape: ShapeConfig,
    cluster: ClusterConfig,
    mp: int,
    dp: int,
    ratios: Sequence[float] = (1, 2, 3, 4, 5, 6, 7, 8, 9.6, 12, 16),
) -> Dict[float, float]:
    """runtime[inter:intra ratio 1:r] with intra+inter = aggregate constant.

    Baseline DGX: 300 + 31.25 = 331.25 GB/s aggregate; ratio 1:9.6."""
    assert isinstance(cluster.topology, HierarchicalSwitch)
    agg = cluster.topology.intra_bw + cluster.topology.inter_bw
    wl = decompose(cfg, shape, mp=mp, dp=dp)
    out: Dict[float, float] = {}
    for r in ratios:
        inter = agg / (1 + r)
        intra = agg - inter
        topo = dataclasses.replace(cluster.topology, intra_bw=intra,
                                   inter_bw=inter)
        br = simulate_iteration(
            wl, cluster.with_topology(topo),
            mem_bw_override=cluster.node.local_bw)
        out[r] = br.total
    return out


# --------------------------------------------------------------------- #
# §V-C / Fig. 13: DLRM cluster-size sweep + memory-expansion study
# --------------------------------------------------------------------- #

def dlrm_cluster_size_sweep(
    dlrm_cfg,
    cluster: ClusterConfig,
    global_batch: int = 4096,
    node_counts: Sequence[int] = (64, 32, 16, 8),
) -> Dict[int, dict]:
    """Single-instance DLRM training breakdown vs cluster size (Fig. 13a)."""
    out: Dict[int, dict] = {}
    for n in node_counts:
        wl = decompose_dlrm(dlrm_cfg, global_batch, n)
        sub = dataclasses.replace(cluster, num_nodes=n)
        node = cluster.node.with_expansion(cap=1e15, bw=cluster.node.local_bw)
        br = simulate_iteration(wl, sub.with_node(node))
        from repro.core.memory import per_node_footprint
        rep = per_node_footprint(wl, cluster.node)
        out[n] = {**br.as_dict(), "footprint_gb": rep.total / GB}
    return out


def dlrm_memory_expansion(
    dlrm_cfg,
    cluster: ClusterConfig,
    global_batch: int = 4096,
    total_nodes: int = 64,
    num_instances: int = 8,
    em_bandwidths_gbs: Sequence[float] = (250, 500, 800, 1000, 1500, 2000),
    nodes_per_instance_opts: Sequence[int] = (64, 32, 16, 8),
) -> Dict[int, Dict[float, float]]:
    """Fig. 13b: turnaround of ``num_instances`` DLRMs on 64 nodes.

    Using fewer nodes per instance needs expanded memory but runs
    ceil(64/n) instances concurrently: turnaround = iter_time * n_waves."""
    out: Dict[int, Dict[float, float]] = {}
    for n in nodes_per_instance_opts:
        out[n] = {}
        concurrent = max(1, total_nodes // n)
        waves = -(-num_instances // concurrent)
        wl = decompose_dlrm(dlrm_cfg, global_batch, n)
        sub = dataclasses.replace(cluster, num_nodes=n)
        for bw in em_bandwidths_gbs:
            node = cluster.node.with_expansion(cap=1e15, bw=bw * GB)
            br = simulate_iteration(wl, sub.with_node(node))
            out[n][bw] = br.total * waves
    return out


# --------------------------------------------------------------------- #
# §V-D / Fig. 15: comparative training across 11 clusters
# --------------------------------------------------------------------- #

def cluster_comparison(
    transformer_cfg: ModelConfig,
    transformer_shape: ShapeConfig,
    dlrm_cfg,
    dlrm_batch: int = 4096,
    clusters: Optional[Dict[str, ClusterConfig]] = None,
) -> Dict[str, Dict[str, float]]:
    """runtime[cluster][workload] for Transformer-1T + 8 DLRM instances.

    Transformer: best feasible (MP, DP) per cluster (capacity-constrained).
    DLRM: nodes-per-instance per the paper (mem0: 64, mem1: 16, mem2: 8)."""
    clusters = clusters or TABLE_III_CLUSTERS
    out: Dict[str, Dict[str, float]] = {}
    for name, cl in clusters.items():
        res: Dict[str, float] = {}
        # ---- Transformer-1T on the whole cluster
        sweep = sweep_strategies(transformer_cfg, transformer_shape, cl)
        fit = [r for r in sweep
               if r.footprint_bytes <= cl.node.total_cap and
               r.breakdown.feasible]
        res["transformer-1t"] = (min(r.total for r in fit) if fit
                                 else float("inf"))
        # ---- 8 DLRM instances
        if cl.node.exp_cap > 0.75 * cl.node.local_cap:
            nodes_per = 16 if cl.node.exp_bw <= 500 * GB else 8
        else:
            nodes_per = min(64, cl.num_nodes)
        concurrent = max(1, min(cl.num_nodes, 64) // nodes_per)
        waves = -(-8 // concurrent)
        wl = decompose_dlrm(dlrm_cfg, dlrm_batch, nodes_per)
        sub = dataclasses.replace(cl, num_nodes=nodes_per)
        br = simulate_iteration(wl, sub)
        res["dlrm"] = br.total * waves
        out[name] = res
    return out
