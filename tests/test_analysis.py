"""repro.analysis: rule packs fire on planted violations, stay silent on
registry objects, and the run_study validate gate never changes records."""

import copy
import dataclasses
import json
import warnings

import pytest

from repro.analysis import (
    AnalysisError,
    RuleConfig,
    analyze_cluster,
    analyze_compiled,
    analyze_study,
    analyze_workload,
    has_errors,
    list_rules,
    max_severity,
)
from repro.analysis.__main__ import main as analysis_main
from repro.configs import get_config, get_dlrm_config
from repro.configs.base import ShapeConfig
from repro.core import dse
from repro.core.cluster import (
    BASELINE_DGX_A100,
    CostModel,
    get_cluster,
    list_clusters,
)
from repro.core.gemm import CommEvent
from repro.core.study import (
    ENGINES,
    Axis,
    GridSpace,
    StudySpec,
    check_path,
    placement_axis,
    run_study,
)
from repro.core.workload import decompose

SHAPE = ShapeConfig("paper", 2048, 1024, "train")
SMALL_SHAPE = ShapeConfig("small", 512, 64, "train")


@pytest.fixture(scope="module")
def small_cfg():
    return get_config("smollm-135m")


@pytest.fixture(scope="module")
def small_cluster():
    return dataclasses.replace(BASELINE_DGX_A100, num_nodes=8)


def codes(diags):
    return sorted({d.code for d in diags})


# ===================================================================== #
# Framework
# ===================================================================== #

class TestFramework:
    def test_registry_covers_all_packs(self):
        packs = {r.pack for r in list_rules()}
        assert packs == {"workload", "compiled", "study", "cluster",
                         "serving", "search", "fleet", "reliability"}
        assert len(list_rules("workload")) == 5
        assert len(list_rules("compiled")) == 5
        assert len(list_rules("serving")) == 4
        assert len(list_rules("search")) == 3
        assert len(list_rules("reliability")) == 5

    def test_rule_config_disable(self, small_cfg):
        wl = decompose(small_cfg, SMALL_SHAPE, mp=2, dp=4)
        wl.layers[0].stage = 3
        assert codes(analyze_workload(wl)) == ["W104"]
        cfg = RuleConfig(disable=frozenset({"W104"}))
        assert analyze_workload(wl, config=cfg) == []

    def test_rule_config_severity_override(self, small_cfg):
        wl = decompose(small_cfg, SMALL_SHAPE, mp=2, dp=4)
        wl.layers[0].comm_fwd.append(
            CommEvent("all-reduce", 8, "pp", True))
        cfg = RuleConfig(disable=frozenset({"W104"}),
                         severity={"W102": "error"})
        diags = analyze_workload(wl, config=cfg)
        assert codes(diags) == ["W102"] and has_errors(diags)

    def test_rule_config_rejects_unknown_severity(self):
        with pytest.raises(ValueError, match="unknown severity"):
            RuleConfig(severity={"W101": "fatal"})

    def test_max_severity(self, small_cfg):
        wl = decompose(small_cfg, SMALL_SHAPE, mp=2, dp=4)
        assert max_severity(analyze_workload(wl)) is None
        wl.layers[0].stage = 9
        assert max_severity(analyze_workload(wl)) == "error"


# ===================================================================== #
# W1xx: workload rules
# ===================================================================== #

class TestWorkloadRules:
    def test_clean_decompositions(self, small_cfg):
        for kw in (dict(mp=2, dp=4), dict(mp=1, dp=4, pp=2),
                   dict(mp=2, dp=2, pp=2, ep=1)):
            wl = decompose(small_cfg, SMALL_SHAPE, **kw)
            assert analyze_workload(wl) == []

    def test_w101_bad_scope(self, small_cfg):
        wl = decompose(small_cfg, SMALL_SHAPE, mp=2, dp=4)
        wl.layers[1].comm_fwd.append(
            CommEvent("all-reduce", 100, "xx", False))
        diags = analyze_workload(wl)
        assert codes(diags) == ["W101"] and has_errors(diags)

    def test_w102_degenerate_group(self, small_cfg):
        wl = decompose(small_cfg, SMALL_SHAPE, mp=2, dp=4)
        wl.layers[0].comm_wg.append(
            CommEvent("all-reduce", 64, "ep", False))  # ep=1 -> group of mp=2
        wl2 = decompose(small_cfg, SMALL_SHAPE, mp=1, dp=8)
        wl2.layers[0].comm_fwd.append(
            CommEvent("all-gather", 64, "mp", True))   # mp=1 -> no-op
        assert analyze_workload(wl) == []
        diags = analyze_workload(wl2)
        assert codes(diags) == ["W102"]
        assert all(d.severity == "warning" for d in diags)

    def test_w103_conservation_violation(self, small_cfg):
        wl = decompose(small_cfg, SMALL_SHAPE, mp=2, dp=4, pp=2)
        other = decompose(small_cfg, ShapeConfig("big", 1024, 64, "train"),
                          mp=2, dp=4)
        assert codes(analyze_workload(wl, baseline=other)) == ["W103"]

    def test_w103_holds_across_factorizations(self, small_cfg):
        base = decompose(small_cfg, SMALL_SHAPE, mp=2, dp=8)
        for kw in (dict(mp=2, dp=8, pp=1), dict(mp=2, dp=4, pp=2),
                   dict(mp=2, dp=4, ep=2)):
            wl = decompose(small_cfg, SMALL_SHAPE, **kw)
            assert analyze_workload(wl, baseline=base) == []

    def test_w103_skips_mismatched_baselines(self, small_cfg):
        wl = decompose(small_cfg, SMALL_SHAPE, mp=2, dp=4)
        other_mp = decompose(small_cfg, SMALL_SHAPE, mp=4, dp=2)
        assert analyze_workload(wl, baseline=other_mp) == []

    def test_w104_orphan_stage(self, small_cfg):
        wl = decompose(small_cfg, SMALL_SHAPE, mp=2, dp=4)
        wl.layers[0].stage = 5
        diags = analyze_workload(wl)
        assert codes(diags) == ["W104"] and has_errors(diags)

    def test_w104_missing_stage(self, small_cfg):
        wl = decompose(small_cfg, SMALL_SHAPE, mp=1, dp=4, pp=2)
        for layer in wl.layers:
            layer.stage = 0
        assert "W104" in codes(analyze_workload(wl))

    def test_w104_p2p_off_boundary(self, small_cfg):
        wl = decompose(small_cfg, SMALL_SHAPE, mp=1, dp=4, pp=2)
        wl.layers[1].comm_fwd.append(CommEvent("p2p", 64, "pp", True))
        assert codes(analyze_workload(wl)) == ["W104"]

    def test_w105_negative_bytes(self, small_cfg):
        wl = decompose(small_cfg, SMALL_SHAPE, mp=2, dp=4)
        wl.layers[0].comm_ig.append(CommEvent("all-reduce", -5, "dp", False))
        diags = analyze_workload(wl)
        assert codes(diags) == ["W105"] and has_errors(diags)

    def test_w105_bad_layer_fields(self, small_cfg):
        wl = decompose(small_cfg, SMALL_SHAPE, mp=2, dp=4)
        wl.layers[2].weight_bytes = float("inf")
        wl.layers[3].repeat = 0
        diags = analyze_workload(wl)
        assert codes(diags) == ["W105"] and len(diags) >= 2


# ===================================================================== #
# C1xx: compiled rules
# ===================================================================== #

class TestCompiledRules:
    @pytest.fixture(scope="class")
    def pair(self, small_cfg):
        wl = decompose(small_cfg, SMALL_SHAPE, mp=2, dp=4, pp=2)
        return wl, wl.compiled()

    def test_clean_lowering(self, pair):
        wl, cw = pair
        assert analyze_compiled(cw) == []
        assert analyze_compiled(cw, workload=wl) == []

    def test_c101_missing_stage(self, pair):
        wl, cw = pair
        mut = copy.deepcopy(cw)
        mut.stages.pop()
        assert "C101" in codes(analyze_compiled(mut, workload=wl))

    def test_c102_dropped_event(self, pair):
        wl, cw = pair
        mut = copy.deepcopy(cw)
        p = mut.stages[0].fwd
        for field in ("ev_pos", "ev_comm", "ev_blocking", "ev_scope",
                      "ev_phase"):
            setattr(p, field, getattr(p, field)[:-1])
        diags = analyze_compiled(mut, workload=wl)
        assert "C102" in codes(diags) and has_errors(diags)

    def test_c103_mutated_bytes(self, pair):
        wl, cw = pair
        mut = copy.deepcopy(cw)
        mut.stages[0].comm_sizes[0] += 7.0
        assert "C103" in codes(analyze_compiled(mut, workload=wl))

    def test_c104_mutated_counts(self, pair):
        wl, cw = pair
        mut = copy.deepcopy(cw)
        mut.stages[0].counts[0, 0] += 1
        assert codes(analyze_compiled(mut, workload=wl)) == ["C104"]

    def test_c105_mutated_optimizer_totals(self, pair):
        wl, cw = pair
        mut = copy.deepcopy(cw)
        mut.stages[1].dense_w += 100.0
        assert codes(analyze_compiled(mut, workload=wl)) == ["C105"]

    def test_registry_models_lower_cleanly(self):
        for arch in ("granite-moe-3b-a800m", "mamba2-780m"):
            cfg = get_config(arch)
            wl = decompose(cfg, SMALL_SHAPE, mp=2, dp=2, ep=2)
            assert analyze_compiled(wl.compiled()) == []


# ===================================================================== #
# S1xx: study rules + the construction-time path check (satellite 1)
# ===================================================================== #

class TestStudyRules:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_typo_path_fails_at_construction(self, engine, small_cfg,
                                             small_cluster):
        """The misspelled dotted path raises the available-fields error
        before run_study can fork a worker, under either engine."""
        with pytest.raises(AttributeError,
                           match="no field 'peak_flpos'.*available"):
            spec = StudySpec(
                name="typo", model=small_cfg, shape=SMALL_SHAPE,
                cluster=small_cluster, strategies=(2, 4),
                axes=[Axis("flops", (0.5, 2.0), path="node.peak_flpos",
                           mode="scale")])
            run_study(spec, engine=engine)

    def test_nested_typo_path(self, small_cfg, small_cluster):
        with pytest.raises(AttributeError, match="no field 'intra_bandwith'"):
            StudySpec(name="typo", model=small_cfg, shape=SMALL_SHAPE,
                      cluster=small_cluster,
                      axes=[Axis("bw", (1.0,),
                                 path="topology.intra_bandwith")])

    def test_path_behind_apply_axis_is_deferred(self, small_cfg,
                                                small_cluster):
        # An apply axis may swap the cluster type, so a later path can only
        # be resolved at run time — construction must not reject it.
        spec = StudySpec(
            name="deferred", model=small_cfg, shape=SMALL_SHAPE,
            cluster=small_cluster,
            axes=[Axis("swap", (1,), apply=lambda cl, _: cl),
                  Axis("maybe", (1.0,), path="node.peak_flpos")])
        assert spec.axes[1].path == "node.peak_flpos"

    def test_check_path_resolves_valid_paths(self, small_cluster):
        check_path(small_cluster, "node.peak_flops")
        check_path(small_cluster, "topology.intra_bw")
        with pytest.raises(TypeError, match="non-dataclass"):
            check_path(small_cluster, "num_nodes.nope")

    def test_s101_on_mutated_axes(self, small_cfg, small_cluster):
        spec = StudySpec(name="s", model=small_cfg, shape=SMALL_SHAPE,
                         cluster=small_cluster, strategies=(2, 4))
        spec.axes = [Axis("bad", (1.0,), path="node.nope")]
        assert codes(analyze_study(spec)) == ["S101"]

    def test_s102_metric_shadows_record_column(self, small_cfg,
                                               small_cluster):
        spec = StudySpec(name="s", model=small_cfg, shape=SMALL_SHAPE,
                         cluster=small_cluster, strategies=(2, 4),
                         metrics={"total": lambda ctx: 0.0})
        diags = analyze_study(spec)
        assert codes(diags) == ["S102"] and has_errors(diags)

    def test_s103_unknown_placement_value(self, small_cfg, small_cluster):
        spec = StudySpec(name="s", model=small_cfg, shape=SMALL_SHAPE,
                         cluster=small_cluster, strategies=(2, 4),
                         axes=[placement_axis(("paper", "not-a-placement"))])
        assert codes(analyze_study(spec)) == ["S103"]

    def test_s104_empty_strategy_space(self, small_cfg, small_cluster):
        spec = StudySpec(name="s", model=small_cfg, shape=SMALL_SHAPE,
                         cluster=small_cluster,
                         strategies=GridSpace(mp=(3,), dp=(5,)))
        diags = analyze_study(spec)
        assert codes(diags) == ["S104"]
        assert max_severity(diags) == "warning"

    def test_figure_studies_are_clean(self):
        for name, spec in dse.figure_studies().items():
            diags = [d for d in analyze_study(spec) if d.severity == "error"]
            assert diags == [], f"{name}: {diags}"


# ===================================================================== #
# K1xx: cluster rules
# ===================================================================== #

class TestClusterRules:
    def test_registry_clusters_have_no_errors(self):
        for name in list_clusters():
            diags = analyze_cluster(get_cluster(name))
            assert not has_errors(diags), f"{name}: {diags}"

    def test_k101_ragged_pod(self, small_cluster):
        ragged = dataclasses.replace(small_cluster, num_nodes=12)
        diags = analyze_cluster(ragged)
        assert codes(diags) == ["K101"]
        assert max_severity(diags) == "warning"

    def test_k102_inverted_hierarchy(self, small_cluster):
        topo = dataclasses.replace(
            small_cluster.topology,
            inter_bw=small_cluster.topology.intra_bw * 4)
        assert codes(analyze_cluster(
            small_cluster.with_topology(topo))) == ["K102"]

    def test_k103_negative_price(self, small_cluster):
        bad = small_cluster.with_cost(CostModel(usd_per_node=-1.0))
        diags = analyze_cluster(bad)
        assert codes(diags) == ["K103"] and has_errors(diags)

    def test_k103_missing_cost_is_info(self, small_cluster):
        diags = analyze_cluster(small_cluster.with_cost(None))
        assert codes(diags) == ["K103"]
        assert max_severity(diags) == "info"

    def test_k104_zero_flops(self, small_cluster):
        bad = small_cluster.with_node(
            dataclasses.replace(small_cluster.node, peak_flops=0.0))
        diags = analyze_cluster(bad)
        assert codes(diags) == ["K104"] and has_errors(diags)

    def test_k104_em_capacity_without_bandwidth(self, small_cluster):
        node = small_cluster.node.with_expansion(cap=1e12, bw=0.0)
        assert codes(analyze_cluster(
            small_cluster.with_node(node))) == ["K104"]


# ===================================================================== #
# run_study(validate=...)
# ===================================================================== #

class TestValidateGate:
    def _bad_spec(self, small_cfg, small_cluster):
        spec = StudySpec(name="bad", model=small_cfg, shape=SMALL_SHAPE,
                         cluster=small_cluster, strategies=(2, 4))
        spec.axes = [Axis("bad", (1.0,), path="node.nope")]
        return spec

    def test_error_mode_raises(self, small_cfg, small_cluster):
        with pytest.raises(AnalysisError) as exc:
            run_study(self._bad_spec(small_cfg, small_cluster),
                      validate="error")
        assert any(d.code == "S101" for d in exc.value.diagnostics)

    def test_warn_mode_warns_and_runs(self, small_cfg, small_cluster):
        spec = StudySpec(name="empty", model=small_cfg, shape=SMALL_SHAPE,
                         cluster=small_cluster,
                         strategies=GridSpace(mp=(3,), dp=(5,)))
        with pytest.warns(UserWarning, match="S104"):
            res = run_study(spec, validate="warn")
        assert len(res) == 0

    def test_off_mode_is_silent(self, small_cfg, small_cluster):
        spec = StudySpec(name="empty", model=small_cfg, shape=SMALL_SHAPE,
                         cluster=small_cluster,
                         strategies=GridSpace(mp=(3,), dp=(5,)))
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            run_study(spec, validate="off")

    def test_unknown_mode_rejected(self, small_cfg, small_cluster):
        spec = StudySpec(name="s", model=small_cfg, shape=SMALL_SHAPE,
                         cluster=small_cluster, strategies=(2, 4))
        with pytest.raises(ValueError, match="validate"):
            run_study(spec, validate="loud")


class TestValidateEquivalence:
    """validate= must be purely observational: identical records with the
    gate on and off, across every paper-figure study (reduced grids)."""

    @staticmethod
    def figure_specs():
        t = get_config("transformer-1t")
        d = get_dlrm_config()
        base = BASELINE_DGX_A100
        return {
            "fig8": dse.mpdp_study(t, SHAPE, base),
            "fig9": dse.memory_expansion_study(
                t, SHAPE, base, em_bandwidths_gbs=(100, 1000, 2000),
                strategies=[(32, 32), (8, 128)]),
            "fig10": dse.compute_scaling_study(
                t, SHAPE, base, 8, 128, compute_factors=(0.5, 1.0, 2.0),
                em_bandwidths_gbs=(500, 2000)),
            "fig11": dse.network_scaling_study(
                t, SHAPE, base, 64, 16, intra_factors=(0.5, 2.0),
                inter_factors=(1.0, 2.0)),
            "fig12": dse.bandwidth_rebalance_study(
                t, SHAPE, base, 64, 16, ratios=(1, 6, 9.6, 16)),
            "fig13a": dse.dlrm_cluster_size_study(
                d, base, global_batch=65536, node_counts=(64, 16, 8)),
            "fig13b": dse.dlrm_memory_expansion_study(
                d, base, global_batch=65536, em_bandwidths_gbs=(500, 2000),
                nodes_per_instance_opts=(64, 8)),
        }

    @pytest.mark.parametrize("fig", ["fig8", "fig9", "fig10", "fig11",
                                     "fig12", "fig13a", "fig13b"])
    def test_records_identical(self, fig):
        spec = self.figure_specs()[fig]
        off = run_study(spec, validate="off")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            on = run_study(spec, validate="warn")
        assert off.records == on.records


# ===================================================================== #
# CLI
# ===================================================================== #

class TestCli:
    def test_subset_sweep_exits_zero(self, capsys):
        rc = analysis_main(["--models", "smollm-135m", "--clusters", "dojo"])
        assert rc == 0
        assert "OK" in capsys.readouterr().out

    def test_json_report(self, tmp_path, capsys):
        out = tmp_path / "report.json"
        rc = analysis_main(["--models", "smollm-135m", "--clusters", "dojo",
                            "--json", str(out)])
        assert rc == 0
        report = json.loads(out.read_text())
        assert report["errors"] == 0
        assert report["models"] == ["smollm-135m"]

    def test_list_rules(self, capsys):
        assert analysis_main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for code in ("W101", "C103", "S101", "K104"):
            assert code in out

    def test_error_findings_exit_nonzero(self, monkeypatch, capsys):
        from repro.analysis import Diagnostic
        from repro.analysis import __main__ as cli
        monkeypatch.setattr(cli, "sweep", lambda *a, **k: [
            Diagnostic("W101", "error", "somewhere", "planted")])
        rc = cli.main(["--models", "smollm-135m", "--clusters", "dojo"])
        assert rc == 1
        assert "W101" in capsys.readouterr().out

    def test_disable_flag(self, monkeypatch):
        from repro.analysis import __main__ as cli
        captured = {}

        def fake_sweep(models, clusters, config=None):
            captured["config"] = config
            return []

        monkeypatch.setattr(cli, "sweep", fake_sweep)
        rc = cli.main(["--models", "smollm-135m", "--clusters", "dojo",
                       "--disable", "W102", "--severity", "K101=error"])
        assert rc == 0
        assert not captured["config"].enabled("W102")
        assert captured["config"].severity["K101"] == "error"
