"""Tests for the composable cluster layer: Topology protocol, PodSpec /
ClusterSpec, CostModel + cost columns, registry helpers.

Golden guarantees: a homogeneous ClusterSpec reproduces the seed Table III
numbers exactly through the same simulator path, and cost columns are
monotone in $/node and invariant under pod-count refactorings of the same
hardware (hypothesis property tests)."""

import dataclasses

import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.cluster import (
    B_HYBRID_EM,
    BASELINE_DGX_A100,
    TABLE_III_CLUSTERS,
    ClusterSpec,
    CostModel,
    NodeConfig,
    PodSpec,
    get_cluster,
    list_clusters,
)
from repro.core.collectives import CollectiveModel
from repro.core.memory import cluster_footprint
from repro.core.simulator import simulate_iteration
from repro.core.study import Axis, ParallelSpec, StudySpec, run_study
from repro.core.topology import (
    HierarchicalSwitch,
    SingleSwitch,
    Topology,
    Torus,
)
from repro.core.workload import decompose

GB = 1e9
SHAPE = ShapeConfig("paper", 2048, 1024, "train")
SMALL_SHAPE = ShapeConfig("small", 512, 64, "train")


@pytest.fixture(scope="module")
def tcfg():
    return get_config("transformer-1t")


@pytest.fixture(scope="module")
def small_cfg():
    return get_config("smollm-135m")


@pytest.fixture(scope="module")
def small_wl(small_cfg):
    return decompose(small_cfg, SMALL_SHAPE, mp=4, dp=2)


# ===================================================================== #
# Topology protocol
# ===================================================================== #

class TestTopologyProtocol:
    TOPOS = (BASELINE_DGX_A100.topology,
             Torus(dims=(4, 4), link_bw=48 * GB),
             Torus(dims=(4, 4), link_bw=48 * GB, dcn_bw=25 * GB),
             SingleSwitch(bw=1000 * GB))

    @pytest.mark.parametrize("topo", TOPOS, ids=lambda t: type(t).__name__)
    def test_implements_protocol(self, topo):
        assert isinstance(topo, Topology)
        assert topo.pod_size >= 1
        assert topo.links_per_node >= 1
        assert all(h.bw > 0 for h in topo.hops)

    @pytest.mark.parametrize("topo", TOPOS, ids=lambda t: type(t).__name__)
    @pytest.mark.parametrize("coll", ("all-reduce", "all-gather",
                                      "reduce-scatter", "all-to-all"))
    def test_collective_model_dispatches_through_protocol(self, topo, coll):
        """CollectiveModel.time == the protocol method, for every family."""
        cm = CollectiveModel(topo, mp=8, dp=2)
        assert cm.time(coll, 1e9, "mp") == \
            topo.collective_time(coll, 1e9, "mp", 8, 2)
        assert cm.time(coll, 1e9, "mp") > 0

    def test_trivial_group_is_free(self):
        topo = SingleSwitch(bw=1000 * GB)
        assert topo.collective_time("all-reduce", 1e9, "dp", 8, 1) == 0.0
        assert topo.collective_time("all-reduce", 0.0, "mp", 8, 1) == 0.0

    def test_functional_updates(self):
        hs = BASELINE_DGX_A100.topology
        assert hs.with_(pod_size=16).pod_size == 16
        assert hs.scaled(intra=2).intra_bw == 2 * hs.intra_bw  # legacy form
        t = Torus(dims=(4, 4), link_bw=48 * GB)
        assert t.scaled(link_bw=2.0).link_bw == 96 * GB
        assert t.with_(dcn_bw=25 * GB).dcn_bw == 25 * GB

    def test_unknown_topology_rejected(self):
        with pytest.raises(TypeError, match="Topology protocol"):
            CollectiveModel(object(), mp=8, dp=2).time("all-reduce", 1e9, "mp")


# ===================================================================== #
# ClusterSpec: homogeneous golden equivalence + heterogeneous semantics
# ===================================================================== #

class TestHomogeneousGolden:
    """A homogeneous ClusterSpec must reproduce the seed Table III numbers
    exactly (same floats) through the ClusterConfig shim path."""

    @pytest.mark.parametrize("name,mp,dp", [("B1", 64, 16), ("B1", 8, 128),
                                            ("dojo", 64, 1)])
    def test_spec_matches_shim(self, tcfg, name, mp, dp):
        shim = get_cluster(name)
        spec = ClusterSpec.homogeneous(shim.name, shim.node, shim.num_nodes,
                                       shim.topology, cost=shim.cost)
        wl = decompose(tcfg, SHAPE, mp=mp, dp=dp)
        a = simulate_iteration(wl, shim)
        b = simulate_iteration(wl, spec)
        assert a.as_dict() == b.as_dict()
        assert a.feasible == b.feasible
        assert a.footprint.total == b.footprint.total

    def test_to_spec_roundtrip(self, small_wl):
        cl = dataclasses.replace(BASELINE_DGX_A100, num_nodes=8)
        spec = cl.to_spec()
        assert spec.num_nodes == 8
        assert not spec.is_heterogeneous
        assert spec.node == cl.node
        assert simulate_iteration(small_wl, spec).as_dict() == \
            simulate_iteration(small_wl, cl).as_dict()

    def test_table_iii_specs_preserve_registry(self, small_wl):
        for name, cl in TABLE_III_CLUSTERS.items():
            spec = cl.to_spec()
            assert spec.num_nodes == cl.num_nodes, name
            assert simulate_iteration(small_wl, spec).total == \
                simulate_iteration(small_wl, cl).total, name


class TestHeterogeneous:
    def _hybrid(self, plain, em, net, count=2, npp=4):
        return ClusterSpec(
            name="hy", interconnect=net,
            pods=(PodSpec(plain, count=count, nodes_per_pod=npp),
                  PodSpec(em, count=count, nodes_per_pod=npp)))

    def test_shape_accessors(self):
        assert B_HYBRID_EM.num_nodes == 1024
        assert B_HYBRID_EM.is_heterogeneous
        assert len(B_HYBRID_EM.node_groups) == 2
        with pytest.raises(ValueError, match="heterogeneous"):
            B_HYBRID_EM.node

    def test_node_groups_merge_identical_pods(self):
        node = BASELINE_DGX_A100.node
        spec = ClusterSpec(
            name="s", interconnect=BASELINE_DGX_A100.topology,
            pods=(PodSpec(node, count=2, nodes_per_pod=8),
                  PodSpec(node, count=3, nodes_per_pod=8)))
        (g,) = spec.node_groups
        assert g.num_nodes == 40
        assert not spec.is_heterogeneous

    def test_empty_pods_rejected(self):
        with pytest.raises(ValueError, match="no pods"):
            ClusterSpec(name="s", pods=(),
                        interconnect=BASELINE_DGX_A100.topology)

    def test_slowest_group_gates(self, small_wl):
        """Mixing in slower-compute pods degrades to the slow group."""
        net = HierarchicalSwitch(4, 300 * GB, 31.25 * GB)
        fast = BASELINE_DGX_A100.node
        slow = fast.scaled_compute(0.25)
        mixed = self._hybrid(fast, slow, net)
        t_mixed = simulate_iteration(small_wl, mixed).total
        t_slow = simulate_iteration(
            small_wl, ClusterSpec.homogeneous("slow", slow, 8, net)).total
        t_fast = simulate_iteration(
            small_wl, ClusterSpec.homogeneous("fast", fast, 8, net)).total
        assert t_mixed == t_slow > t_fast

    def test_feasibility_requires_every_group(self, tcfg):
        """MP8 fits EM pods but not plain pods -> hybrid infeasible."""
        wl = decompose(tcfg, SHAPE, mp=8, dp=128)
        assert simulate_iteration(wl, get_cluster("B1")).feasible
        br = simulate_iteration(wl, B_HYBRID_EM)
        assert not br.feasible
        rep = cluster_footprint(wl, B_HYBRID_EM)
        assert not rep.fits_total
        assert rep.total == br.footprint.total

    def test_require_fit_zeroes_infeasible_hybrid(self, tcfg):
        wl = decompose(tcfg, SHAPE, mp=8, dp=128)
        br = simulate_iteration(wl, B_HYBRID_EM, require_fit=True)
        assert not br.feasible and br.total == 0.0

    def test_per_pod_fabric_overrides_interconnect(self, small_wl):
        """A pod group with a faster private fabric communicates faster."""
        node = BASELINE_DGX_A100.node
        slow_net = HierarchicalSwitch(4, 30 * GB, 3 * GB)
        fast_net = HierarchicalSwitch(4, 300 * GB, 31.25 * GB)
        base = ClusterSpec.homogeneous("s", node, 8, slow_net)
        upgraded = base.with_pods(
            (PodSpec(node, count=2, nodes_per_pod=4, fabric=fast_net),))
        assert simulate_iteration(small_wl, upgraded).total <= \
            simulate_iteration(small_wl, base).total
        assert upgraded.node_groups[0].topology == fast_net

    def test_map_nodes(self):
        spec = B_HYBRID_EM.map_nodes(lambda n: n.scaled_compute(2.0))
        for g in spec.node_groups:
            assert g.node.peak_flops == 2 * 625e12

    def test_with_node_with_topology_shim_parity(self):
        node = BASELINE_DGX_A100.node
        spec = B_HYBRID_EM.with_node(node)
        assert not spec.is_heterogeneous and spec.node == node
        fast = B_HYBRID_EM.interconnect.scaled(intra=2)
        assert B_HYBRID_EM.with_topology(fast).topology == fast

    def test_mem_bw_override_local_on_hetero(self, tcfg):
        """'local' resolves per node group, so it works on mixed specs."""
        wl = decompose(tcfg, SHAPE, mp=64, dp=16)
        a = simulate_iteration(wl, B_HYBRID_EM, mem_bw_override="local")
        b = simulate_iteration(wl, get_cluster("B1"),
                               mem_bw_override=get_cluster("B1").node.local_bw)
        assert a.mem_bw == b.mem_bw
        res = run_study(StudySpec(
            name="t", model=tcfg, shape=SHAPE, cluster=B_HYBRID_EM,
            strategies=ParallelSpec(mp=64, dp=16), mem_bw_override="local"))
        assert res.cells[0].record["mem_bw"] == \
            B_HYBRID_EM.node_groups[0].node.local_bw

    def test_collective_model_rejects_mixed_fabrics(self):
        node = BASELINE_DGX_A100.node
        net = HierarchicalSwitch(4, 300 * GB, 31.25 * GB)
        mixed = ClusterSpec(
            "m", (PodSpec(node, 1, 4, fabric=net.scaled(intra=2)),
                  PodSpec(node, 1, 4)), net)
        with pytest.raises(ValueError, match="per-pod fabrics"):
            CollectiveModel(mixed, mp=4, dp=2)
        # uniform-fabric hetero specs are fine
        CollectiveModel(B_HYBRID_EM, mp=4, dp=2)

    def test_collective_model_honors_single_pod_fabric(self):
        """CollectiveModel must agree with the simulator when one fabric
        overrides the interconnect."""
        node = BASELINE_DGX_A100.node
        fabric = HierarchicalSwitch(4, 300 * GB, 31.25 * GB)
        spec = ClusterSpec(
            "f", (PodSpec(node, 2, 4, fabric=fabric),),
            interconnect=SingleSwitch(bw=25 * GB))
        cm = CollectiveModel(spec, mp=4, dp=2)
        assert cm.time("all-reduce", 1e9, "mp") == \
            fabric.collective_time("all-reduce", 1e9, "mp", 4, 2)

    def test_em_pod_frac_validated(self, tcfg):
        from repro.core import dse
        spec = dse.hetero_cost_study(tcfg, SHAPE, em_pod_fractions=(1.5,),
                                     strategies=[(64, 16)])
        with pytest.raises(ValueError, match=r"em_pod_frac must be in"):
            run_study(spec)


# ===================================================================== #
# CostModel + study columns
# ===================================================================== #

class TestCostModel:
    COST = CostModel(usd_per_node=10_000, usd_per_gb_local=20,
                     usd_per_gb_em=5, usd_per_link=100, usd_per_kwh=0.1,
                     amortization_years=2.0)
    NODE = NodeConfig("n", 100e12, 80 * GB, 2000 * GB, 40e6,
                      exp_cap=400 * GB, exp_bw=500 * GB, tdp_watts=500)

    def test_capex_hand_check(self):
        net = HierarchicalSwitch(8, 300 * GB, 31.25 * GB)  # 2 links/node
        spec = ClusterSpec.homogeneous("s", self.NODE, 16, net,
                                       cost=self.COST)
        per_node = 10_000 + 20 * 80 + 5 * 400 + 100 * 2
        assert self.COST.capex(spec) == pytest.approx(16 * per_node)

    def test_energy_hand_check(self):
        net = SingleSwitch(bw=1000 * GB)
        spec = ClusterSpec.homogeneous("s", self.NODE, 16, net,
                                       cost=self.COST)
        kwh = 16 * 0.5 * 8760 * 2.0
        assert self.COST.energy_usd(spec) == pytest.approx(kwh * 0.1)
        assert self.COST.tco(spec) == pytest.approx(
            self.COST.capex(spec) + self.COST.energy_usd(spec))

    def test_registry_clusters_carry_costs(self):
        for name in list_clusters():
            cl = get_cluster(name)
            assert cl.cost is not None, name
            assert cl.cost.tco(cl) > 0, name

    def test_study_emits_cost_columns(self, small_cfg):
        cluster = dataclasses.replace(BASELINE_DGX_A100, num_nodes=8)
        res = run_study(StudySpec(
            name="t", model=small_cfg, shape=SMALL_SHAPE, cluster=cluster,
            strategies=ParallelSpec(mp=4, dp=2)))
        r = res.cells[0].record
        assert r["cost_usd"] == cluster.cost.capex(cluster)
        assert r["tco"] == cluster.cost.tco(cluster)
        assert r["perf_per_dollar"] == pytest.approx(
            1.0 / (r["total"] * r["tco"]))

    def test_no_cost_model_no_columns(self, small_cfg):
        cluster = dataclasses.replace(BASELINE_DGX_A100, num_nodes=8,
                                      cost=None)
        res = run_study(StudySpec(
            name="t", model=small_cfg, shape=SMALL_SHAPE, cluster=cluster,
            strategies=ParallelSpec(mp=4, dp=2)))
        assert "cost_usd" not in res.cells[0].record

    def test_cost_axis_is_sweepable(self, small_cfg):
        """The MAD-Max-style question: how does $/GB-EM move the ranking?"""
        cluster = dataclasses.replace(
            get_cluster("B1"), num_nodes=8,
            node=get_cluster("B1").node)
        res = run_study(StudySpec(
            name="t", model=small_cfg, shape=SMALL_SHAPE, cluster=cluster,
            strategies=ParallelSpec(mp=4, dp=2),
            axes=[Axis("em_usd", (4.0, 8.0, 16.0),
                       path="cost.usd_per_gb_em")]))
        costs = res.column("cost_usd")
        assert costs[0] < costs[1] < costs[2]
        totals = res.column("total")
        assert totals[0] == totals[1] == totals[2]  # pure price knob

    def test_infeasible_cells_get_zero_perf_per_dollar(self, tcfg):
        """best(maximize=True) must never recommend a strategy that does
        not fit: infeasible cells score 0."""
        res = run_study(StudySpec(
            name="t", model=tcfg, shape=SHAPE, cluster=get_cluster("B0"),
            strategies=[(64, 16), (8, 128)]))  # MP8 doesn't fit B0
        by_strat = {c.record["strategy"]: c.record for c in res}
        assert not by_strat["MP8_DP128"]["feasible"]
        assert by_strat["MP8_DP128"]["perf_per_dollar"] == 0.0
        best = res.best("perf_per_dollar", maximize=True)
        assert best.record["feasible"]

    def test_cost_axis_shares_one_simulation(self, small_cfg, monkeypatch):
        """A pure price sweep simulates each physical config once.
        Instrumented per engine: the reference path through
        study.simulate_iteration, the compiled path through
        simulator.time_compiled (one batched prefetch)."""
        import repro.core.simulator as sim_mod
        import repro.core.study as study_mod
        spec = StudySpec(
            name="t", model=small_cfg, shape=SMALL_SHAPE,
            cluster=dataclasses.replace(BASELINE_DGX_A100, num_nodes=8),
            strategies=ParallelSpec(mp=4, dp=2),
            axes=[Axis("em_usd", (4.0, 8.0, 16.0),
                       path="cost.usd_per_gb_em")])
        calls = []
        real = study_mod.simulate_iteration
        monkeypatch.setattr(study_mod, "simulate_iteration",
                            lambda *a, **k: calls.append(1) or real(*a, **k))
        run_study(spec, engine="reference")
        assert len(calls) == 1
        batches = []
        real_tc = sim_mod.time_compiled
        monkeypatch.setattr(sim_mod, "time_compiled",
                            lambda *a, **k: batches.append(1)
                            or real_tc(*a, **k))
        run_study(spec, engine="compiled")
        assert len(batches) == 1

    def test_best_maximize_ranks_perf_per_dollar(self, small_cfg):
        cluster = dataclasses.replace(BASELINE_DGX_A100, num_nodes=8)
        res = run_study(StudySpec(
            name="t", model=small_cfg, shape=SMALL_SHAPE, cluster=cluster,
            strategies=ParallelSpec(mp=4, dp=2),
            axes=[Axis("f", (1.0, 2.0), path="node.peak_flops",
                       mode="scale")]))
        best = res.best("perf_per_dollar", maximize=True)
        assert best.record["perf_per_dollar"] == \
            max(res.column("perf_per_dollar"))

    def test_axis_cannot_shadow_cost_columns(self, small_cfg):
        with pytest.raises(ValueError, match="shadow"):
            StudySpec(name="t", model=small_cfg, shape=SMALL_SHAPE,
                      axes=[Axis("perf_per_dollar", (1,))])


class TestHeteroStudyEndToEnd:
    def test_hetero_cost_study_runs(self, tcfg):
        """Acceptance: hetero + cost study end-to-end via StudySpec with
        cost_usd / perf_per_dollar columns in its StudyResult."""
        from repro.core import dse
        res = run_study(dse.hetero_cost_study(
            tcfg, SHAPE, em_pod_fractions=(0.0, 0.5, 1.0),
            strategies=[(64, 16), (8, 128)]))
        assert len(res) == 6
        for r in res.records:
            assert {"cost_usd", "tco", "perf_per_dollar"} <= set(r)
        # more EM pods -> strictly more capex for the same interconnect
        capex = res.pivot(index="em_pod_frac", columns="strategy",
                          values="cost_usd")
        assert capex[0.0]["MP64_DP16"] < capex[0.5]["MP64_DP16"] \
            < capex[1.0]["MP64_DP16"]
        # MP8 only feasible with EM everywhere (plain pods can't hold it)
        feas = res.pivot(index="em_pod_frac", columns="strategy",
                         values="feasible")
        assert feas[1.0]["MP8_DP128"] and not feas[0.5]["MP8_DP128"]
        # and the full-EM small-MP cell wins perf-per-dollar outright
        ranked = dse.hetero_cost_ranking(
            tcfg, SHAPE, em_pod_fractions=(0.0, 0.5, 1.0),
            strategies=[(64, 16), (8, 128)])
        assert ranked[0]["strategy"] == "MP8_DP128"
        assert ranked[0]["em_pod_frac"] == 1.0


# ===================================================================== #
# Registry helpers
# ===================================================================== #

class TestRegistry:
    def test_list_clusters_sorted_and_complete(self):
        names = list_clusters()
        assert names == sorted(names)
        assert {"dgx-a100-1k", "B1", "dojo", "tpu-v4",
                "b-hybrid-em"} <= set(names)
        for name in names:
            assert get_cluster(name).num_nodes > 0

    def test_did_you_mean_suggestion(self):
        with pytest.raises(KeyError, match="did you mean.*dgx-a100-1k"):
            get_cluster("dgx-a100")
        with pytest.raises(KeyError, match="did you mean"):
            get_cluster("topu-v4")

    def test_gibberish_still_lists_available(self):
        with pytest.raises(KeyError, match="available"):
            get_cluster("zzzzqqqq")


# ===================================================================== #
# Deterministic refactoring-invariance spot checks (the full hypothesis
# property versions live in tests/test_property.py, which is skipped when
# hypothesis is unavailable).
# ===================================================================== #

class TestRefactoringInvariance:
    NET = HierarchicalSwitch(4, 300 * GB, 31.25 * GB)
    NODE = NodeConfig("n", 100e12, 80 * GB, 2000 * GB, 40e6, tdp_watts=400)
    COST = CostModel(usd_per_node=10_000, usd_per_gb_local=20,
                     usd_per_link=100, usd_per_kwh=0.1)

    @pytest.mark.parametrize("cut", (1, 2, 3))
    def test_cost_and_sim_invariant_under_pod_refactoring(self, cut,
                                                          small_wl):
        """The same hardware split into differently-sized PodSpec groups
        prices and simulates identically."""
        one = ClusterSpec("one", (PodSpec(self.NODE, 4, 4),), self.NET,
                          cost=self.COST)
        two = ClusterSpec("two", (PodSpec(self.NODE, cut, 4),
                                  PodSpec(self.NODE, 4 - cut, 4)),
                          self.NET, cost=self.COST)
        assert one.num_nodes == two.num_nodes
        assert self.COST.capex(one) == pytest.approx(self.COST.capex(two))
        assert self.COST.tco(one) == pytest.approx(self.COST.tco(two))
        assert simulate_iteration(small_wl, one).as_dict() == \
            simulate_iteration(small_wl, two).as_dict()
