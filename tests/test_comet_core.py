"""Unit tests for the COMET core: traffic model, roofline, memory model,
collective cost models, ASTRA-lite simulator."""

import math

import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.cluster import (
    BASELINE_DGX_A100,
    DOJO,
    TPU_V4,
    NodeConfig,
    get_cluster,
)
from repro.core.collectives import CollectiveModel, placement
from repro.core.gemm import Gemm, gemm_traffic_bytes
from repro.core.memory import (
    effective_memory_bw,
    hybrid_bandwidth,
    model_state_bytes,
    per_node_footprint,
)
from repro.core.roofline import attainable_perf, compute_delay, ridge_point
from repro.core.simulator import simulate_iteration
from repro.core.workload import decompose, decompose_dlrm

GB = 1e9
SHAPE = ShapeConfig("paper", 2048, 1024, "train")


class TestTrafficModel:
    def test_infinite_buffer_reaches_compulsory_traffic(self):
        u, v, w = 10_000, 20_000, 5_000
        assert gemm_traffic_bytes(u, v, w, 10**12) == u + v + w

    def test_small_buffer_inflates_traffic(self):
        u, v, w = 10_000, 20_000, 5_000
        t_small = gemm_traffic_bytes(u, v, w, 100)
        t_big = gemm_traffic_bytes(u, v, w, 10**9)
        assert t_small > t_big

    def test_tiling_smaller_operand_wins(self):
        # paper: for U < V, Psi_1 (tile U) gives ~V-U less movement
        u, v, w, s = 1_000, 100_000, 500, 100
        psi1 = math.ceil(u / s) * v + u
        psi2 = math.ceil(v / s) * u + v
        assert psi1 < psi2
        assert gemm_traffic_bytes(u, v, w, s) == psi1 + w

    def test_gemm_flops_and_transposes(self):
        g = Gemm(64, 128, 256)
        assert g.flops() == 2 * 64 * 128 * 256
        assert g.transposed_for_ig().flops() == g.flops()
        assert g.transposed_for_wg().flops() == g.flops()


class TestRoofline:
    NODE = NodeConfig("test", 100e12, 80 * GB, 2000 * GB, 40e6)

    def test_ridge_point(self):
        assert ridge_point(self.NODE) == pytest.approx(50.0)

    def test_compute_bound_above_ridge(self):
        from repro.core.gemm import PhaseCost
        cost = PhaseCost(flops=int(1e15), traffic=int(1e12))  # OI = 1000
        pt = compute_delay(cost, self.NODE)
        assert pt.bound == "compute"
        assert pt.delay == pytest.approx(1e15 / 100e12)

    def test_memory_bound_below_ridge(self):
        from repro.core.gemm import PhaseCost
        cost = PhaseCost(flops=int(1e12), traffic=int(1e12))  # OI = 1
        pt = compute_delay(cost, self.NODE)
        assert pt.bound == "memory"
        assert pt.delay == pytest.approx(1e12 / (1 * 2000 * GB))

    def test_bandwidth_shifts_attainable(self):
        assert attainable_perf(10, 100e12, 2000 * GB) == 10 * 2000 * GB
        assert attainable_perf(10, 100e12, 4000 * GB) == 10 * 4000 * GB


class TestHybridMemory:
    def test_paper_eqn3_example(self):
        # 240GB accessed, 80GB LM @2TB/s, EM @1TB/s -> 1.2TB/s
        bw = hybrid_bandwidth(240 * GB, 80 * GB, 2000 * GB, 1000 * GB)
        assert bw == pytest.approx(1200 * GB, rel=0.01)

    def test_fits_local_uses_local_bw(self):
        node = NodeConfig("n", 1e12, 80 * GB, 2000 * GB, 40e6,
                          exp_cap=400 * GB, exp_bw=500 * GB)
        assert effective_memory_bw(node, 50 * GB) == 2000 * GB
        assert effective_memory_bw(node, 200 * GB) < 2000 * GB


class TestZeroFootprint:
    def test_stages_ordering(self):
        p, dp = 1e9, 64
        vals = [model_state_bytes(p, dp, z) for z in (0, 1, 2, 3)]
        assert vals[0] > vals[1] > vals[2] > vals[3]

    def test_baseline_is_16_bytes_per_param(self):
        assert model_state_bytes(1e9, 64, 0) == 16e9

    def test_zero3_scales_with_dp(self):
        assert model_state_bytes(1e9, 64, 3) == pytest.approx(16e9 / 64)

    def test_fig6_trends(self):
        """ZeRO-3 flat in MP; baseline grows as MP shrinks (Fig. 6)."""
        cfg = get_config("transformer-1t")
        n = 1024
        base, z3 = [], []
        for mp in (1024, 64, 8, 1):
            wl = decompose(cfg, SHAPE, mp=mp, dp=n // mp)
            params = wl.total_weight_bytes() / 2
            base.append(model_state_bytes(params, n // mp, 0))
            z3.append(model_state_bytes(params, n // mp, 3))
        assert base[0] < base[1] < base[2] < base[3]   # exponential growth
        assert max(z3[1:]) / min(z3[1:]) < 1.2         # ~flat

    def test_mp8_dp128_footprint_matches_paper(self):
        """Paper: MP8_DP128 needs ~250GB (3x+ the 80GB A100)."""
        cfg = get_config("transformer-1t")
        wl = decompose(cfg, SHAPE, mp=8, dp=128)
        rep = per_node_footprint(wl, BASELINE_DGX_A100.node, zero_stage=2)
        assert 200 * GB < rep.total < 350 * GB
        assert not rep.fits_local

    def test_mp64_fits_80gb(self):
        cfg = get_config("transformer-1t")
        wl = decompose(cfg, SHAPE, mp=64, dp=16)
        rep = per_node_footprint(wl, BASELINE_DGX_A100.node, zero_stage=2)
        assert rep.fits_local


class TestCollectives:
    def test_placement_mp_fills_pods(self):
        pl = placement("mp", mp=4, dp=2, pod_size=8)
        assert (pl.intra, pl.inter) == (4, 1)
        pl = placement("mp", mp=16, dp=2, pod_size=8)
        assert (pl.intra, pl.inter) == (8, 2)

    def test_placement_dp_strides(self):
        pl = placement("dp", mp=8, dp=128, pod_size=8)
        assert (pl.intra, pl.inter) == (1, 128)
        pl = placement("dp", mp=2, dp=8, pod_size=8)
        assert (pl.intra, pl.inter) == (4, 2)

    def test_allreduce_linear_in_size(self):
        cm = CollectiveModel(BASELINE_DGX_A100, mp=8, dp=128)
        t1 = cm.time("all-reduce", 1e9, "mp")
        t2 = cm.time("all-reduce", 2e9, "mp")
        assert t2 == pytest.approx(2 * t1, rel=0.01)

    def test_intra_pod_faster_than_cross_pod(self):
        cm_small = CollectiveModel(BASELINE_DGX_A100, mp=8, dp=1)
        cm_big = CollectiveModel(BASELINE_DGX_A100, mp=64, dp=1)
        assert cm_small.time("all-reduce", 1e9, "mp") < \
            cm_big.time("all-reduce", 1e9, "mp")

    def test_torus_and_switch_models(self):
        cm = CollectiveModel(TPU_V4, mp=4096, dp=1)
        assert cm.time("all-reduce", 1e9, "mp") > 0
        cm = CollectiveModel(DOJO, mp=64, dp=1)
        assert cm.time("all-reduce", 1e9, "mp") > 0

    def test_ag_rs_half_of_ar(self):
        cm = CollectiveModel(DOJO, mp=64, dp=1)
        ar = cm.time("all-reduce", 1e9, "mp")
        ag = cm.time("all-gather", 1e9, "mp")
        assert ag == pytest.approx(ar / 2, rel=0.05)


class TestSimulator:
    def test_breakdown_sums_to_total(self):
        cfg = get_config("transformer-1t")
        wl = decompose(cfg, SHAPE, mp=8, dp=128)
        br = simulate_iteration(wl, BASELINE_DGX_A100)
        d = br.as_dict()
        parts = sum(v for k, v in d.items() if k != "total")
        assert d["total"] == pytest.approx(parts, rel=1e-6)

    def test_wg_comm_overlaps(self):
        """Paper Fig 8a: WG DP collectives largely hidden at MP64_DP16."""
        cfg = get_config("transformer-1t")
        wl = decompose(cfg, SHAPE, mp=64, dp=16)
        br = simulate_iteration(wl, BASELINE_DGX_A100,
                                mem_bw_override=BASELINE_DGX_A100.node.local_bw)
        assert br.wg.exposed_comm < 0.05 * br.total

    def test_more_bandwidth_never_slower(self):
        cfg = get_config("transformer-1t")
        wl = decompose(cfg, SHAPE, mp=64, dp=16)
        slow = simulate_iteration(wl, BASELINE_DGX_A100)
        fast_topo = BASELINE_DGX_A100.topology.scaled(intra=2, inter=2)
        fast = simulate_iteration(wl, BASELINE_DGX_A100.with_topology(fast_topo))
        assert fast.total <= slow.total

    def test_dlrm_decomposition_runs(self):
        from repro.configs import get_dlrm_config
        wl = decompose_dlrm(get_dlrm_config(), 4096, 64)
        br = simulate_iteration(wl, BASELINE_DGX_A100)
        assert br.total > 0


def test_cluster_registry():
    for name in ("dgx-a100-1k", "A0", "B1", "C2", "dojo", "tpu-v4",
                 "tpu-v5e-pod", "tpu-v5e-2pod"):
        cl = get_cluster(name)
        assert cl.num_nodes > 0
    with pytest.raises(KeyError):
        get_cluster("nope")
