"""Equivalence suite for the compiled study engine (ISSUE 5).

``engine="compiled"`` lowers each decomposed workload to flat NumPy arrays
and times it against whole batches of cluster cells; it must reproduce the
reference event-loop engine within 1e-9 relative on every record of every
study.  Locked here:

  * goldens — all 7 figure studies plus the pp_ep / placement /
    multi-tenant studies run under both engines, records compared
    column by column;
  * simulator-level equivalence across topology families, PP/EP
    strategies, schedules, memory expansion, overrides and require_fit
    (parametrized grid + a hypothesis property when available);
  * the strategy-major fork path: serial == fork records for both
    engines, chunks partition the cells, and a raising metric fn leaves
    ``run_study`` reusable (the PR-5 fork-globals regression);
  * the batched collective models against their scalar counterparts.
"""

import dataclasses
import math
import unittest.mock

import pytest

from repro.configs import get_config, get_dlrm_config
from repro.configs.base import ShapeConfig
from repro.core import compiled as compiled_mod
from repro.core import dse
from repro.core.cluster import (
    BASELINE_DGX_A100,
    ClusterConfig,
    HierarchicalSwitch,
    NodeConfig,
    SingleSwitch,
    Torus,
)
from repro.core.collectives import CollectiveModel
from repro.core.simulator import (
    _SCOPES,
    group_breakdowns,
    group_breakdowns_compiled,
    simulate_iteration,
    simulate_iteration_compiled,
)
from repro.core.study import (
    Axis,
    GridSpace,
    ParallelSpec,
    StudySpec,
    _strategy_chunks,
    _workload_key,
    run_study,
)
from repro.core.topology import placement as paper_placement
from repro.core.workload import decompose

GB = 1e9
REL = 1e-9
SHAPE = ShapeConfig("paper", 2048, 1024, "train")
SMALL_SHAPE = ShapeConfig("small", 512, 64, "train")


def assert_close(a: float, b: float, rel: float = REL, ctx: str = "") -> None:
    if isinstance(a, float) and (math.isnan(a) or math.isinf(a)):
        assert str(a) == str(b), ctx
        return
    assert a == pytest.approx(b, rel=rel, abs=1e-12), ctx


def assert_records_equivalent(ref, comp, rel: float = REL) -> None:
    """Records equal: non-floats exactly, floats within ``rel``."""
    assert len(ref) == len(comp)
    for ra, rb in zip(ref.records, comp.records):
        assert set(ra) == set(rb)
        for k, va in ra.items():
            vb = rb[k]
            if isinstance(va, float) and isinstance(vb, float):
                assert_close(va, vb, rel, ctx=f"{k}: {va} vs {vb}")
            else:
                assert va == vb, f"{k}: {va!r} vs {vb!r}"


def both_engines(spec):
    # engine="reference" is now the explicit escape hatch — run_study
    # defaults to "compiled" since ISSUE 8.
    return (run_study(spec, engine="reference"),
            run_study(spec, engine="compiled"))


def assert_breakdowns_equivalent(a, b, rel: float = REL) -> None:
    for k, va in a.as_dict().items():
        assert_close(va, b.as_dict()[k], rel, ctx=k)
    assert a.feasible == b.feasible
    assert_close(a.mem_bw, b.mem_bw, rel, ctx="mem_bw")
    assert_close(a.bubble_fraction, b.bubble_fraction, rel, ctx="bubble")
    assert_close(a.footprint.total, b.footprint.total, rel, ctx="footprint")
    assert a.footprint.fits_total == b.footprint.fits_total
    assert a.footprint.fits_local == b.footprint.fits_local


@pytest.fixture(scope="module")
def tcfg():
    return get_config("transformer-1t")


@pytest.fixture(scope="module")
def small_cfg():
    return get_config("smollm-135m")


# ===================================================================== #
# Figure-study goldens: compiled == reference on every record
# ===================================================================== #

class TestFigureStudyGoldens:
    def test_fig8_mpdp(self, tcfg):
        assert_records_equivalent(
            *both_engines(dse.mpdp_study(tcfg, SHAPE, BASELINE_DGX_A100)))

    def test_fig9_memory_expansion(self, tcfg):
        spec = dse.memory_expansion_study(
            tcfg, SHAPE, BASELINE_DGX_A100,
            em_bandwidths_gbs=(100, 500, 2000),
            strategies=[(32, 32), (8, 128)])
        assert_records_equivalent(*both_engines(spec))

    def test_fig10_compute_scaling(self, tcfg):
        spec = dse.compute_scaling_study(
            tcfg, SHAPE, BASELINE_DGX_A100, 8, 128,
            compute_factors=(0.5, 1.0, 4.0),
            em_bandwidths_gbs=(500, 2000))
        assert_records_equivalent(*both_engines(spec))

    def test_fig11_network_scaling(self, tcfg):
        spec = dse.network_scaling_study(
            tcfg, SHAPE, BASELINE_DGX_A100, 64, 16,
            intra_factors=(0.5, 2.0), inter_factors=(1.0, 4.0))
        assert_records_equivalent(*both_engines(spec))

    def test_fig12_bandwidth_rebalance(self, tcfg):
        spec = dse.bandwidth_rebalance_study(
            tcfg, SHAPE, BASELINE_DGX_A100, 8, 128, ratios=(1, 4, 9.6))
        assert_records_equivalent(*both_engines(spec))

    def test_fig13a_dlrm_cluster_size(self):
        spec = dse.dlrm_cluster_size_study(
            get_dlrm_config(), BASELINE_DGX_A100, global_batch=65536)
        assert_records_equivalent(*both_engines(spec))

    def test_fig13b_dlrm_memory_expansion(self):
        spec = dse.dlrm_memory_expansion_study(
            get_dlrm_config(), BASELINE_DGX_A100, global_batch=65536,
            em_bandwidths_gbs=(500, 1500), nodes_per_instance_opts=(64, 8))
        assert_records_equivalent(*both_engines(spec))

    def test_fig15_cluster_comparison(self, tcfg):
        t_study, d_study = dse.cluster_comparison_studies(
            tcfg, SHAPE, get_dlrm_config(), 65536)
        assert_records_equivalent(*both_engines(t_study))
        assert_records_equivalent(*both_engines(d_study))


class TestBeyondPaperStudyGoldens:
    def test_pp_ep_study(self):
        spec = dse.pp_ep_study(mp=(8, 16), dp=(4, 8, 16, 32), pp=(1, 2),
                               ep=(1, 2), clusters=("A0", "B1"))
        assert_records_equivalent(*both_engines(spec))

    def test_placement_study(self, tcfg):
        spec = dse.placement_study(
            cfg=tcfg, em_pod_fractions=(0.0, 0.5),
            strategies=GridSpace(mp=(16,), dp=(16, 32), pp=(2, 4)))
        assert_records_equivalent(*both_engines(spec))

    def test_multi_tenant_study(self):
        spec = dse.multi_tenant_study(nodes_per_instance_opts=(64, 16))
        assert_records_equivalent(*both_engines(spec))

    def test_hetero_cost_study(self, tcfg):
        spec = dse.hetero_cost_study(
            tcfg, SHAPE, em_pod_fractions=(0.0, 0.5, 1.0),
            strategies=[(64, 16), (8, 128)])
        assert_records_equivalent(*both_engines(spec))


# ===================================================================== #
# Simulator-level equivalence grid
# ===================================================================== #

SMALL_NODE = NodeConfig("sim", peak_flops=100e12, local_cap=16 * GB,
                        local_bw=1000 * GB, sram_bytes=20e6, tdp_watts=300)
EM_NODE = dataclasses.replace(SMALL_NODE, local_cap=0.2 * GB,
                              exp_cap=64 * GB, exp_bw=250 * GB)
TINY_NODE = dataclasses.replace(SMALL_NODE, local_cap=0.05 * GB)

TOPOLOGIES = {
    "hier": HierarchicalSwitch(pod_size=4, intra_bw=200 * GB,
                               inter_bw=25 * GB),
    "torus": Torus(dims=(4, 4), link_bw=40 * GB),
    "torus-dcn": Torus(dims=(2, 2), link_bw=40 * GB, dcn_bw=10 * GB),
    "switch": SingleSwitch(bw=300 * GB),
}

SIM_CASES = [
    # (model, topo key, node, mp, dp, pp, ep, schedule, override, req_fit)
    ("smollm-135m", "hier", SMALL_NODE, 4, 4, 1, 1, "1f1b", None, False),
    ("smollm-135m", "hier", SMALL_NODE, 2, 2, 4, 1, "gpipe", None, False),
    ("smollm-135m", "hier", SMALL_NODE, 2, 2, 4, 1, "interleaved", None,
     False),
    ("smollm-135m", "torus", SMALL_NODE, 4, 4, 1, 1, "1f1b", "local",
     False),
    ("smollm-135m", "torus-dcn", SMALL_NODE, 2, 4, 2, 1, "1f1b", None,
     False),
    ("smollm-135m", "switch", SMALL_NODE, 8, 2, 1, 1, "1f1b", 500 * GB,
     False),
    ("smollm-135m", "hier", EM_NODE, 2, 8, 1, 1, "1f1b", None, False),
    ("smollm-135m", "hier", TINY_NODE, 1, 16, 1, 1, "1f1b", None, True),
    ("smollm-135m", "hier", TINY_NODE, 1, 8, 2, 1, "1f1b", None, True),
    ("granite-moe-3b-a800m", "hier", SMALL_NODE, 2, 2, 1, 4, "1f1b", None,
     False),
    ("granite-moe-3b-a800m", "torus", SMALL_NODE, 2, 2, 2, 2, "gpipe",
     None, False),
    ("mamba2-780m", "hier", SMALL_NODE, 2, 8, 1, 1, "1f1b", None, False),
]


class TestSimulatorEquivalence:
    @pytest.mark.parametrize("case", SIM_CASES,
                             ids=[f"{c[0]}-{c[1]}-mp{c[3]}dp{c[4]}"
                                  f"pp{c[5]}ep{c[6]}-{c[7]}"
                                  for c in SIM_CASES])
    def test_grid(self, case):
        arch, topo_key, node, mp, dp, pp, ep, sched, override, req = case
        wl = decompose(get_config(arch), SMALL_SHAPE, mp=mp, dp=dp, pp=pp,
                       ep=ep, schedule=sched)
        cluster = ClusterConfig("sim", node, mp * dp * pp * ep,
                                TOPOLOGIES[topo_key])
        ref = simulate_iteration(wl, cluster, mem_bw_override=override,
                                 require_fit=req)
        comp = simulate_iteration_compiled(
            wl.compiled(), cluster, mem_bw_override=override,
            require_fit=req)
        assert_breakdowns_equivalent(ref, comp)

    def test_zero_stages(self, small_cfg):
        wl = decompose(small_cfg, SMALL_SHAPE, mp=2, dp=8)
        cluster = ClusterConfig("sim", SMALL_NODE, 16, TOPOLOGIES["hier"])
        for z in (0, 1, 2, 3):
            assert_breakdowns_equivalent(
                simulate_iteration(wl, cluster, zero_stage=z),
                simulate_iteration_compiled(wl.compiled(), cluster,
                                            zero_stage=z))

    def test_heterogeneous_flat_and_groups(self, small_cfg):
        from repro.core.cluster import B_HYBRID_EM
        wl = decompose(small_cfg, SMALL_SHAPE, mp=4, dp=4)
        assert_breakdowns_equivalent(
            simulate_iteration(wl, B_HYBRID_EM),
            simulate_iteration_compiled(wl.compiled(), B_HYBRID_EM))
        for a, b in zip(group_breakdowns(wl, B_HYBRID_EM),
                        group_breakdowns_compiled(wl.compiled(),
                                                  B_HYBRID_EM)):
            assert_breakdowns_equivalent(a, b)

    def test_placement_assigned_pipeline_runs_compiled(self, tcfg):
        # Mixed fleet + pp>1 + explicit placement: the path that used to
        # delegate to the reference event loop now runs fully compiled
        # (per-stage environments through _time_compiled_assigned) and
        # matches within the engine-equivalence envelope.
        from repro.core.cluster import B_HYBRID_EM
        from repro.core.placement import EM_AWARE_PLACEMENT
        from repro.core.simulator import compiled_stage_assignment
        wl = decompose(tcfg, SHAPE, mp=16, dp=16, pp=4)
        assert compiled_stage_assignment(
            wl, B_HYBRID_EM, EM_AWARE_PLACEMENT) is not None
        ref = simulate_iteration(wl, B_HYBRID_EM,
                                 placement=EM_AWARE_PLACEMENT)
        with unittest.mock.patch(
                "repro.core.simulator.simulate_iteration",
                side_effect=AssertionError(
                    "assigned-pipeline cell fell back to the "
                    "reference event loop")):
            comp = simulate_iteration_compiled(
                wl.compiled(), B_HYBRID_EM, placement=EM_AWARE_PLACEMENT)
        assert_breakdowns_equivalent(ref, comp)

    def test_placement_override_and_fit_variants_run_compiled(self, tcfg):
        from repro.core.cluster import B_HYBRID_EM
        from repro.core.placement import EM_AWARE_PLACEMENT
        wl = decompose(tcfg, SHAPE, mp=16, dp=16, pp=4)
        cw = wl.compiled()
        for ov in (None, "local", 500e9):
            for rf in (False, True):
                ref = simulate_iteration(
                    wl, B_HYBRID_EM, mem_bw_override=ov, require_fit=rf,
                    placement=EM_AWARE_PLACEMENT)
                comp = simulate_iteration_compiled(
                    cw, B_HYBRID_EM, mem_bw_override=ov, require_fit=rf,
                    placement=EM_AWARE_PLACEMENT)
                assert_breakdowns_equivalent(ref, comp)

    def test_scope_codes_agree(self):
        assert compiled_mod.SCOPES == _SCOPES


# ===================================================================== #
# Batched collective models == scalar collective models
# ===================================================================== #

class TestCollectiveBatch:
    @pytest.mark.parametrize("topo_key", sorted(TOPOLOGIES))
    def test_time_batch_matches_scalar(self, topo_key):
        topo = TOPOLOGIES[topo_key]
        model = CollectiveModel(topo, mp=4, dp=4, pp=2, ep=2)
        events = [(c, s, sc)
                  for c in ("all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "p2p")
                  for s in (0.0, 1e6, 3e9)
                  for sc in ("mp", "dp", "ep", "pp", "edp")]
        kinds = [e[0] for e in events]
        sizes = [e[1] for e in events]
        scopes = [e[2] for e in events]
        batch = model.time_batch(kinds, sizes, scopes)
        for (c, s, sc), t in zip(events, batch):
            assert t == pytest.approx(model.time(c, s, sc), rel=1e-12,
                                      abs=0.0), (c, s, sc)

    def test_fallback_without_batch_method(self):
        class MinimalTopo:
            pod_size = 4
            links_per_node = 1

            def collective_time(self, collective, size, scope, mp, dp,
                                pp=1, ep=1, placement=None):
                return 0.5 * size if size > 0 else 0.0

        model = CollectiveModel(MinimalTopo(), mp=2, dp=2)
        out = model.time_batch(["all-reduce", "all-reduce"], [2.0, 4.0],
                               ["mp", "dp"])
        assert list(out) == [1.0, 2.0]


# ===================================================================== #
# Strategy-major fork path
# ===================================================================== #

def _small_spec(small_cfg, metrics=None):
    return StudySpec(
        name="fork-equiv", model=small_cfg, shape=SMALL_SHAPE,
        cluster=dataclasses.replace(BASELINE_DGX_A100, num_nodes=8),
        strategies=GridSpace(mp=(1, 2, 4, 8), dp=(1, 2, 4, 8)),
        axes=[Axis("bw_x", (0.5, 1.0), path="node.local_bw",
                   mode="scale")],
        metrics=metrics or {})


class TestForkPath:
    def test_chunks_partition_cells_by_workload_key(self, small_cfg):
        spec = _small_spec(small_cfg)
        from repro.core.study import _cells
        cells = _cells(spec)
        chunks = _strategy_chunks(spec, cells, processes=3)
        flat = sorted(i for ch in chunks for i in ch)
        assert flat == list(range(len(cells)))
        # No workload key is split while more chunks than workers exist.
        keys_per_chunk = [{_workload_key(spec, *cells[i][:2])
                           for i in ch} for ch in chunks]
        assert all(len(ks) == 1 for ks in keys_per_chunk)

    def test_chunks_split_when_fewer_groups_than_workers(self, small_cfg):
        spec = StudySpec(name="one-strategy", model=small_cfg,
                         shape=SMALL_SHAPE,
                         cluster=dataclasses.replace(BASELINE_DGX_A100,
                                                     num_nodes=8),
                         strategies=ParallelSpec(mp=2, dp=4),
                         axes=[Axis("bw_x", (0.5, 1.0, 2.0, 4.0),
                                    path="node.local_bw", mode="scale")])
        from repro.core.study import _cells
        cells = _cells(spec)
        chunks = _strategy_chunks(spec, cells, processes=4)
        assert len(chunks) == 4
        assert sorted(i for ch in chunks for i in ch) == \
            list(range(len(cells)))

    def test_empty_cell_list_with_processes(self, small_cfg):
        # No strategy fills the 8-node cluster -> zero cells; the chunked
        # fork path must return an empty result, not crash on max([]).
        spec = StudySpec(
            name="empty", model=small_cfg, shape=SMALL_SHAPE,
            cluster=dataclasses.replace(BASELINE_DGX_A100, num_nodes=8),
            strategies=GridSpace(mp=(3,), dp=(3,)))
        assert len(run_study(spec, processes=4)) == 0

    @pytest.mark.parametrize("engine", ["reference", "compiled"])
    def test_fork_equals_serial(self, small_cfg, engine):
        spec = _small_spec(small_cfg)
        serial = run_study(spec, engine=engine)
        forked = run_study(spec, processes=2, engine=engine)
        assert serial.records == forked.records

    @pytest.mark.parametrize("engine", ["reference", "compiled"])
    def test_raising_metric_leaves_run_study_reusable(self, small_cfg,
                                                      engine):
        # PR-5 regression: a worker raising mid-map must not poison
        # module state for later serial or parallel runs.
        import repro.core.study as study_mod

        def boom(ctx):
            raise RuntimeError("metric exploded")

        bad = _small_spec(small_cfg, metrics={"boom": boom})
        with pytest.raises(RuntimeError, match="metric exploded"):
            run_study(bad, processes=2, engine=engine)
        assert study_mod._FORK_STATE is None
        good = _small_spec(small_cfg)
        again = run_study(good, engine=engine)
        assert run_study(good, processes=2, engine=engine).records == \
            again.records


# ===================================================================== #
# Hop-resolution memo (satellite): placement() is cached and consistent
# ===================================================================== #

class TestPlacementMemo:
    def test_cached_and_identical(self):
        paper_placement.cache_clear()
        a = paper_placement("dp", 8, 16, 8, 1, 1)
        b = paper_placement("dp", 8, 16, 8, 1, 1)
        assert a is b
        info = paper_placement.cache_info()
        assert info.hits >= 1 and info.misses >= 1

    def test_values_unchanged(self):
        for scope in ("mp", "dp", "ep", "pp", "edp"):
            pl = paper_placement(scope, 4, 8, 8, 2, 2)
            assert pl.intra >= 1 and pl.inter >= 1


# ===================================================================== #
# Hypothesis property: random strategies / topologies agree
# ===================================================================== #

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:               # dev container without hypothesis: the
    HAVE_HYPOTHESIS = False       # parametrized grid above still runs.

if HAVE_HYPOTHESIS:
    @st.composite
    def sim_inputs(draw):
        mp = draw(st.sampled_from([1, 2, 4]))
        dp = draw(st.sampled_from([1, 2, 4]))
        pp = draw(st.sampled_from([1, 2, 4]))
        ep = 1
        schedule = draw(st.sampled_from(["1f1b", "gpipe", "interleaved"]))
        fam = draw(st.sampled_from(["hier", "torus", "switch"]))
        if fam == "hier":
            topo = HierarchicalSwitch(
                pod_size=draw(st.sampled_from([2, 4, 8])),
                intra_bw=draw(st.floats(50, 500)) * GB,
                inter_bw=draw(st.floats(5, 50)) * GB)
        elif fam == "torus":
            topo = Torus(dims=(4, 4),
                         link_bw=draw(st.floats(10, 100)) * GB)
        else:
            topo = SingleSwitch(bw=draw(st.floats(50, 500)) * GB)
        node = dataclasses.replace(
            SMALL_NODE,
            peak_flops=draw(st.floats(20, 500)) * 1e12,
            local_bw=draw(st.floats(200, 3000)) * GB,
            local_cap=draw(st.floats(0.5, 64)) * GB,
            exp_cap=draw(st.sampled_from([0.0, 64 * GB])),
            exp_bw=draw(st.floats(100, 1000)) * GB)
        zero = draw(st.sampled_from([0, 2, 3]))
        return mp, dp, pp, ep, schedule, topo, node, zero

    class TestHypothesisEquivalence:
        @settings(max_examples=25, deadline=None)
        @given(sim_inputs())
        def test_compiled_matches_reference(self, inputs):
            mp, dp, pp, ep, schedule, topo, node, zero = inputs
            cfg = get_config("smollm-135m")
            wl = decompose(cfg, SMALL_SHAPE, mp=mp, dp=dp, pp=pp, ep=ep,
                           schedule=schedule)
            cluster = ClusterConfig("h", node, mp * dp * pp * ep, topo)
            ref = simulate_iteration(wl, cluster, zero_stage=zero)
            comp = simulate_iteration_compiled(wl.compiled(), cluster,
                                               zero_stage=zero)
            assert_breakdowns_equivalent(ref, comp)
