"""repro.parallel.compression: int8 error-feedback gradient reduction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.compression import (
    compressed_psum,
    compression_ratio,
    dequantize_int8,
    quantize_int8,
)


class TestQuantize:
    def test_roundtrip_error_bound(self, rng):
        x = jax.random.normal(rng, (256,)) * 3.0
        q, scale = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
        # Round-to-nearest on a symmetric grid: at most half a step off.
        assert err.max() <= float(scale) / 2 + 1e-7

    def test_preserves_extremes(self):
        x = jnp.array([-4.0, 0.0, 4.0])
        q, scale = quantize_int8(x)
        assert int(q[0]) == -127 and int(q[2]) == 127
        np.testing.assert_allclose(np.asarray(dequantize_int8(q, scale)),
                                   np.asarray(x), rtol=1e-6)

    def test_zero_tensor_is_stable(self):
        q, scale = quantize_int8(jnp.zeros((8,)))
        assert float(jnp.abs(dequantize_int8(q, scale)).max()) == 0.0


class TestCompressedPsum:
    N = 4

    def _psum(self, xs, errors=None):
        """Run compressed_psum across a vmapped 'dp' axis of size N."""
        if errors is None:
            fn = jax.vmap(lambda x: compressed_psum(x, "dp"),
                          axis_name="dp")
            return fn(xs)
        fn = jax.vmap(lambda x, e: compressed_psum(x, "dp", e),
                      axis_name="dp")
        return fn(xs, errors)

    def test_matches_exact_sum(self, rng):
        xs = jax.random.normal(rng, (self.N, 64))
        total, _ = self._psum(xs)
        exact = np.asarray(xs).sum(axis=0)
        scale = np.abs(np.asarray(xs)).max() / 127.0
        np.testing.assert_allclose(np.asarray(total[0]), exact,
                                   atol=self.N * scale)

    def test_all_shards_receive_same_total(self, rng):
        xs = jax.random.normal(rng, (self.N, 32))
        total, _ = self._psum(xs)
        for i in range(1, self.N):
            np.testing.assert_array_equal(np.asarray(total[0]),
                                          np.asarray(total[i]))

    def test_new_error_is_quantization_residual(self, rng):
        xs = jax.random.normal(rng, (self.N, 32))
        _, new_err = self._psum(xs)
        for i in range(self.N):
            q, scale = quantize_int8(xs[i])
            expect = np.asarray(xs[i] - dequantize_int8(q, scale))
            np.testing.assert_allclose(np.asarray(new_err[i]), expect,
                                       atol=1e-6)

    def test_error_feedback_removes_accumulated_bias(self, rng):
        """Summing the same gradient for many steps: with error feedback
        the accumulated output tracks the accumulated true sum to within
        one quantization step; without it the bias grows linearly."""
        xs = jax.random.normal(rng, (self.N, 16)) * 0.37
        exact = np.asarray(xs).sum(axis=0)
        steps = 50

        acc_fb = np.zeros(16)
        errors = jnp.zeros_like(xs)
        for _ in range(steps):
            total, errors = self._psum(xs, errors)
            acc_fb += np.asarray(total[0])

        total_nofb, _ = self._psum(xs)
        acc_nofb = steps * np.asarray(total_nofb[0])

        err_fb = np.abs(acc_fb - steps * exact).max()
        err_nofb = np.abs(acc_nofb - steps * exact).max()
        one_step = self.N * np.abs(np.asarray(xs)).max() / 127.0
        assert err_fb <= 2 * one_step
        # The uncompensated bias is the per-step error amplified by the
        # step count; feedback must beat it decisively.
        if err_nofb > 4 * one_step:
            assert err_fb < err_nofb / 4

    def test_dtype_preserved(self, rng):
        xs = jax.random.normal(rng, (self.N, 8)).astype(jnp.bfloat16)
        total, _ = self._psum(xs)
        assert total.dtype == jnp.bfloat16


class TestCompressionRatio:
    @pytest.mark.parametrize("dtype,ratio", [
        (jnp.bfloat16, 2.0), (jnp.float32, 4.0), (jnp.float16, 2.0)])
    def test_wire_ratio(self, dtype, ratio):
        assert compression_ratio(dtype) == ratio
