"""Golden lockdown of the analytical decomposition (ISSUE 3 satellite).

``decompose(cfg, shape, mp, dp)`` with default ``pp=1, ep=1`` must stay
bit-for-bit identical to the pre-PP/EP implementation for every registry
model.  ``tests/golden_decompose.json`` holds SHA-256 digests of exact
structural fingerprints (every op dim, comm event, and byte count) captured
from the pre-change code; regenerate (only after an *intentional* model
change) with:

    PYTHONPATH=src:tests python tests/test_decompose_golden.py --regen
"""

import hashlib
import json
import os

import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_dlrm_config
from repro.configs.base import SHAPES, ShapeConfig
from repro.core.gemm import CommEvent, ExplicitOp, Gemm
from repro.core.workload import decompose, decompose_dlrm

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden_decompose.json")

PAPER_SHAPE = ShapeConfig("paper", 2048, 1024, "train")

# (model, shape, mp, dp) cells fingerprinted; every registry arch appears.
CASES = [(arch, "train_4k", mp, dp)
         for arch in ASSIGNED_ARCHS for (mp, dp) in ((1, 1), (8, 4))]
CASES += [("transformer-1t", "paper", 8, 128),
          ("transformer-1t", "paper", 64, 16)]


def _op_fp(op):
    if isinstance(op, Gemm):
        return ["gemm", op.m, op.k, op.n, op.batch, op.bytes_per_element]
    if isinstance(op, ExplicitOp):
        return ["explicit", op.flops, op.bytes_moved]
    raise TypeError(type(op))


def _comm_fp(e: CommEvent):
    return [e.collective, e.size_bytes, e.scope, e.blocking]


def fingerprint(wl):
    """Exact structural fingerprint of a Workload: every op dim, every comm
    event, every byte count — JSON-stable, no floats beyond ints."""
    return {
        "name": wl.name,
        "mp": wl.mp, "dp": wl.dp,
        "per_replica_batch": wl.per_replica_batch,
        "seq_len": wl.seq_len,
        "layers": [{
            "name": ly.name,
            "repeat": ly.repeat,
            "weight_bytes": ly.weight_bytes,
            "act_out_bytes": ly.act_out_bytes,
            "optim_bytes": ly.optim_bytes,
            "fwd": [_op_fp(o) for o in ly.fwd],
            "ig": [_op_fp(o) for o in ly.ig],
            "wg": [_op_fp(o) for o in ly.wg],
            "comm_fwd": [_comm_fp(e) for e in ly.comm_fwd],
            "comm_ig": [_comm_fp(e) for e in ly.comm_ig],
            "comm_wg": [_comm_fp(e) for e in ly.comm_wg],
        } for ly in wl.layers],
    }


def digest(wl) -> str:
    blob = json.dumps(fingerprint(wl), sort_keys=True,
                      separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def _shape(name: str) -> ShapeConfig:
    return PAPER_SHAPE if name == "paper" else SHAPES[name]


def _build_all():
    out = {}
    for arch, shape_name, mp, dp in CASES:
        key = f"{arch}@{shape_name}[mp{mp}_dp{dp}]"
        wl = decompose(get_config(arch), _shape(shape_name), mp=mp, dp=dp)
        out[key] = digest(wl)
    out["dlrm-1p2t[n64]"] = digest(
        decompose_dlrm(get_dlrm_config(), 65536, 64))
    return out


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


class TestDecomposeGolden:
    @pytest.mark.parametrize("arch,shape_name,mp,dp", CASES)
    def test_default_decompose_matches_pre_change(self, golden, arch,
                                                  shape_name, mp, dp):
        key = f"{arch}@{shape_name}[mp{mp}_dp{dp}]"
        wl = decompose(get_config(arch), _shape(shape_name), mp=mp, dp=dp)
        assert digest(wl) == golden[key]

    def test_pp1_ep1_explicit_matches_default(self):
        """Passing pp=1, ep=1 explicitly is the identity."""
        cfg = get_config("transformer-1t")
        a = fingerprint(decompose(cfg, PAPER_SHAPE, mp=8, dp=128))
        b = fingerprint(decompose(cfg, PAPER_SHAPE, mp=8, dp=128,
                                  pp=1, ep=1))
        assert a == b

    def test_dlrm_golden(self, golden):
        wl = decompose_dlrm(get_dlrm_config(), 65536, 64)
        assert digest(wl) == golden["dlrm-1p2t[n64]"]


class TestPpEpDecomposition:
    """Unit coverage for the new PP/EP surface (beyond the goldens)."""

    def test_pp_partitions_all_stages_nonempty(self):
        cfg = get_config("transformer-1t")
        wl = decompose(cfg, PAPER_SHAPE, mp=8, dp=16, pp=8)
        stages = wl.stage_layers()
        assert len(stages) == 8 and all(stages)
        assert stages[0][0].name == "input_embedding"
        assert stages[-1][-1].name == "output_embedding"

    def test_p2p_events_sit_at_stage_boundaries(self):
        cfg = get_config("transformer-1t")
        pp = 4
        wl = decompose(cfg, PAPER_SHAPE, mp=8, dp=32, pp=pp)
        stages = wl.stage_layers()
        fwd_p2p = [e for ly in wl.layers for e in ly.comm_fwd
                   if e.collective == "p2p"]
        ig_p2p = [e for ly in wl.layers for e in ly.comm_ig
                  if e.collective == "p2p"]
        assert len(fwd_p2p) == len(ig_p2p) == pp - 1
        assert all(e.scope == "pp" and e.blocking for e in fwd_p2p + ig_p2p)
        for s in range(pp - 1):
            assert any(e.collective == "p2p"
                       for e in stages[s][-1].comm_fwd)      # send fwd act
            assert any(e.collective == "p2p"
                       for e in stages[s + 1][0].comm_ig)    # send bwd grad

    def test_pp_conserves_weights_and_flops(self):
        cfg = get_config("transformer-1t")
        flat = decompose(cfg, PAPER_SHAPE, mp=8, dp=16)
        piped = decompose(cfg, PAPER_SHAPE, mp=8, dp=16, pp=8)
        assert piped.total_weight_bytes() == flat.total_weight_bytes()
        assert piped.total_flops() == flat.total_flops()

    def test_pp_exceeding_layers_raises(self):
        cfg = get_config("smollm-135m")
        with pytest.raises(ValueError, match="exceeds"):
            decompose(cfg, SHAPES["train_4k"], pp=10_000)

    def test_ep_requires_divisible_experts(self):
        moe = get_config("granite-moe-3b-a800m")   # 40 experts
        with pytest.raises(ValueError, match="divisible"):
            decompose(moe, SHAPES["train_4k"], ep=3)

    def test_ep_emits_all_to_all_on_ep_scope(self):
        moe = get_config("granite-moe-3b-a800m")
        wl = decompose(moe, SHAPES["train_4k"], mp=2, dp=2, ep=2)
        a2a = [e for ly in wl.layers for e in ly.comm_fwd
               if e.collective == "all-to-all"]
        assert a2a and all(e.scope == "ep" for e in a2a)
        # Expert gradients sync over DP only; dense ones over DP x EP.
        scopes = {e.scope for ly in wl.layers for e in ly.comm_wg}
        assert scopes == {"dp", "edp"}

    def test_ep_divides_per_replica_batch(self):
        cfg = get_config("smollm-135m")
        wl1 = decompose(cfg, SHAPES["train_4k"], dp=4)
        wl2 = decompose(cfg, SHAPES["train_4k"], dp=2, ep=2)
        assert wl2.per_replica_batch == wl1.per_replica_batch

    def test_microbatch_resolution_order(self):
        cfg = get_config("smollm-135m")
        shape = SHAPES["train_4k"]
        auto = decompose(cfg, shape, pp=2)
        assert auto.num_microbatches == 8                    # 4 * pp
        explicit = decompose(cfg, shape, pp=2, num_microbatches=5)
        assert explicit.num_microbatches == 5
        import dataclasses
        shaped = dataclasses.replace(shape, num_microbatches=6)
        assert decompose(cfg, shaped, pp=2).num_microbatches == 6
        # capped at the per-replica batch
        capped = decompose(cfg, shape, dp=64, pp=2, num_microbatches=999)
        assert capped.num_microbatches == capped.per_replica_batch

    def test_invalid_schedule_and_degrees_raise(self):
        cfg = get_config("smollm-135m")
        with pytest.raises(ValueError, match="schedule"):
            decompose(cfg, SHAPES["train_4k"], pp=2, schedule="pipedream")
        with pytest.raises(ValueError, match="pp"):
            decompose(cfg, SHAPES["train_4k"], pp=0)


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        goldens = _build_all()
        with open(GOLDEN_PATH, "w") as f:
            json.dump(goldens, f, indent=1, sort_keys=True)
        print(f"wrote {GOLDEN_PATH} ({len(goldens)} fingerprints)")
    else:
        print(__doc__)
