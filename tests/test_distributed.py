"""Multi-device tests (8 host CPU devices via subprocess — the main pytest
process must keep seeing 1 device)."""

import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(script: str, n: int = 8) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                          capture_output=True, text=True, env=env,
                          timeout=600)


def check(proc):
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"


def test_dp_tp_grad_equivalence():
    """One train step on a (2,2) mesh == the same step on one device."""
    check(run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.parallel import build_mesh, plan_memory
        from repro.train.train_step import (jit_train_step, init_train_state,
                                            make_train_step)
        from repro.launch.specs import input_specs
        import dataclasses

        cfg = get_config("smollm-135m", reduced=True)
        plan = dataclasses.replace(plan_memory(cfg, 2, 2), microbatches=2)
        rng = jax.random.PRNGKey(0)
        state = init_train_state(cfg, plan, rng, dtype=jnp.float32)
        tokens = jax.random.randint(rng, (4, 16), 0, cfg.vocab_size)
        batch = {"tokens": tokens, "targets": tokens}
        step_rng = jax.random.PRNGKey(1)

        # single-device reference
        ref_step = jax.jit(make_train_step(cfg, plan))
        ref_state, ref_metrics = ref_step(state, batch, step_rng)

        # (2 data, 2 model) mesh
        mesh = build_mesh((2, 2), ("data", "model"))
        with mesh:
            shapes = jax.eval_shape(lambda: state)
            bshapes = jax.eval_shape(lambda: batch)
            step = jit_train_step(cfg, plan, mesh, shapes, bshapes,
                                  donate=False)
            out_state, metrics = step(state, batch, step_rng)
        np.testing.assert_allclose(float(metrics["loss"]),
                                   float(ref_metrics["loss"]),
                                   rtol=2e-4, atol=2e-4)
        for a, b in zip(jax.tree.leaves(out_state["params"]),
                        jax.tree.leaves(ref_state["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-3, atol=5e-3)
        print("OK")
        """))


def test_moe_ep_equivalence():
    """MoE forward on a (2,4) mesh (EP over model) == single device."""
    check(run_devices("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.models import get_model
        from repro.parallel import build_mesh, param_shardings, batch_shardings
        cfg = get_config("llama4-maverick-400b-a17b", reduced=True)
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, num_experts=4, capacity_factor=4.0))
        mod = get_model(cfg)
        rng = jax.random.PRNGKey(0)
        params = mod.init_params(rng, cfg, dtype=jnp.float32)
        tokens = jax.random.randint(rng, (4, 16), 0, cfg.vocab_size)
        ref, _, _ = mod.forward(params, cfg, tokens)
        mesh = build_mesh((2, 4), ("data", "model"))
        with mesh:
            p_sh = param_shardings(cfg, params, mesh)
            fn = jax.jit(lambda p, t: mod.forward(p, cfg, t)[0],
                         in_shardings=(p_sh, None))
            out = fn(params, tokens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        print("OK")
        """))


def test_zero_sharding_reduces_per_device_bytes():
    """ZeRO-1: optimizer states sharded over data -> per-device shard is
    1/dp of the full tensor."""
    check(run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.parallel import build_mesh, plan_memory
        from repro.train.train_step import init_train_state, state_shardings
        cfg = get_config("smollm-135m", reduced=True)
        plan = plan_memory(cfg, 2, 4)
        mesh = build_mesh((4, 2), ("data", "model"))
        rng = jax.random.PRNGKey(0)
        state = init_train_state(cfg, plan, rng, dtype=jnp.float32)
        sh = state_shardings(cfg, plan, jax.eval_shape(lambda: state), mesh)
        m_sh = sh["opt"]["m"]["layers"]["attn"]["wq"]
        m = state["opt"]["m"]["layers"]["attn"]["wq"]
        placed = jax.device_put(m, m_sh)
        shard_bytes = placed.addressable_shards[0].data.nbytes
        assert shard_bytes <= m.nbytes // 4 + 1024, (shard_bytes, m.nbytes)
        print("OK")
        """))


def test_gpipe_matches_sequential():
    check(run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel import build_mesh
        from repro.parallel.pipeline import gpipe
        mesh = build_mesh((4,), ("pipe",))
        def stage(p, x):
            return jnp.tanh(x @ p["w"])
        S, M, mb, d = 4, 8, 2, 16
        key = jax.random.PRNGKey(0)
        ws = {"w": jax.random.normal(key, (S, d, d)) * 0.5}
        x = jax.random.normal(key, (M, mb, d))
        y = gpipe(stage, ws, x, mesh=mesh)
        ref = x
        for i in range(S):
            ref = jax.vmap(lambda xm: stage({"w": ws["w"][i]}, xm))(ref)
        np.testing.assert_allclose(y, ref, atol=1e-5)
        # differentiability: grads flow through ppermute
        def loss(ws):
            return gpipe(stage, ws, x, mesh=mesh).sum()
        g = jax.grad(loss)(ws)
        assert np.isfinite(np.asarray(g["w"])).all()
        assert float(np.abs(np.asarray(g["w"])).sum()) > 0
        print("OK")
        """))


def test_compressed_psum_accuracy():
    check(run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.parallel import build_mesh
        from repro.parallel.compression import compressed_psum
        mesh = build_mesh((8,), ("data",))
        key = jax.random.PRNGKey(0)
        g = jax.random.normal(key, (8, 64))
        def red(x):
            s, e = compressed_psum(x, "data")
            return s
        out = shard_map(red, mesh=mesh, in_specs=P("data"),
                        out_specs=P("data"))(g)
        ref = jnp.broadcast_to(g.sum(0, keepdims=True), g.shape)
        rel = float(jnp.max(jnp.abs(out - ref))) / float(jnp.max(jnp.abs(ref)))
        assert rel < 0.05, rel
        # error feedback: repeated reductions with feedback converge
        err = jnp.zeros_like(g)
        print("OK")
        """))


def test_elastic_reshard_restore():
    """Save on a (2,2) mesh, restore onto (4,1) — state identical."""
    check(run_devices("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.configs import get_config
        from repro.parallel import build_mesh, plan_memory
        from repro.train.train_step import init_train_state, state_shardings
        from repro.checkpoint import Checkpointer
        cfg = get_config("smollm-135m", reduced=True)
        plan = plan_memory(cfg, 2, 2)
        rng = jax.random.PRNGKey(0)
        state = init_train_state(cfg, plan, rng, dtype=jnp.float32)
        mesh_a = build_mesh((2, 2), ("data", "model"))
        sh_a = state_shardings(cfg, plan, jax.eval_shape(lambda: state), mesh_a)
        state_a = jax.device_put(state, sh_a)
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(7, state_a, {"step": 7})
            mesh_b = build_mesh((4, 1), ("data", "model"))
            sh_b = state_shardings(cfg, plan, jax.eval_shape(lambda: state), mesh_b)
            restored, extra = ck.restore(target=state, shardings=sh_b)
            assert extra["step"] == 7
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("OK")
        """))


def test_multipod_mesh_axes():
    """pod axis present and shardable on a small 3-axis mesh."""
    check(run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.parallel import build_mesh, dp_axes, dp_size, mp_size
        mesh = build_mesh((2, 2, 2), ("pod", "data", "model"))
        assert dp_axes(mesh) == ("pod", "data")
        assert dp_size(mesh) == 4 and mp_size(mesh) == 2
        x = jnp.arange(8.0).reshape(8, 1)
        sh = NamedSharding(mesh, P(("pod", "data"), None))
        y = jax.device_put(x, sh)
        assert y.addressable_shards[0].data.shape == (2, 1)
        print("OK")
        """))
