"""Serving engine: continuous batching correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.serve import Engine, EngineConfig, Request

KEY = jax.random.PRNGKey(0)


def _direct_greedy(mod, cfg, params, prompt, n):
    cache = mod.init_cache(cfg, 1, 64, dtype=jnp.float32)
    lg, cache = mod.prefill(params, cfg, jnp.asarray(prompt)[None], cache)
    toks = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(n - 1):
        lg, cache = mod.decode_step(params, cfg, cache,
                                    jnp.array([[toks[-1]]]))
        toks.append(int(jnp.argmax(lg[0, 0])))
    return toks


def test_engine_matches_direct_decode_mixed_prompts():
    cfg = get_config("smollm-135m", reduced=True)
    mod = get_model(cfg)
    params = mod.init_params(KEY, cfg, dtype=jnp.float32)
    prompts = [np.array([1, 2, 3, 4, 5]), np.array([7, 8]),
               np.array([9, 10, 11])]
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=64),
                 dtype=jnp.float32)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=5))
    done = {r.uid: r for r in eng.run_until_drained()}
    assert len(done) == 3
    for i, p in enumerate(prompts):
        want = _direct_greedy(mod, cfg, params, p, 5)
        assert done[i].out_tokens == want, (i, done[i].out_tokens, want)


def test_engine_slot_reuse():
    cfg = get_config("smollm-135m", reduced=True)
    mod = get_model(cfg)
    params = mod.init_params(KEY, cfg, dtype=jnp.float32)
    eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=64),
                 dtype=jnp.float32)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=np.array([i + 1, i + 2]),
                           max_new_tokens=3))
    done = eng.run_until_drained()
    assert sorted(r.uid for r in done) == [0, 1, 2]
    assert all(len(r.out_tokens) == 3 for r in done)


def test_engine_decode_respects_request_temperature():
    """Decode ticks sample at each request's own temperature: a very hot
    request must diverge from the greedy continuation (the old engine
    forced temperature=0.0 for every decode step), while a greedy request
    sharing the batch stays bit-for-bit greedy."""
    cfg = get_config("smollm-135m", reduced=True)
    mod = get_model(cfg)
    params = mod.init_params(KEY, cfg, dtype=jnp.float32)
    prompt = np.array([1, 2, 3, 4, 5])
    want = _direct_greedy(mod, cfg, params, prompt, 24)

    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=64),
                 dtype=jnp.float32)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=24,
                       temperature=50.0))
    eng.submit(Request(uid=1, prompt=prompt.copy(), max_new_tokens=24,
                       temperature=0.0))
    done = {r.uid: r for r in eng.run_until_drained()}
    assert done[0].out_tokens != want, \
        "hot request reproduced the greedy continuation exactly"
    assert done[1].out_tokens == want, \
        "greedy request in a mixed-temperature batch must stay greedy"


def test_engine_all_greedy_unchanged_by_sampler():
    """All-greedy batches never consume RNG, so two engines with
    different seeds emit identical tokens."""
    cfg = get_config("smollm-135m", reduced=True)
    mod = get_model(cfg)
    params = mod.init_params(KEY, cfg, dtype=jnp.float32)
    outs = []
    for seed in (0, 123):
        eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=64,
                                               seed=seed),
                     dtype=jnp.float32)
        eng.submit(Request(uid=0, prompt=np.array([1, 2, 3]),
                           max_new_tokens=6))
        outs.append(eng.run_until_drained()[0].out_tokens)
    assert outs[0] == outs[1]


def test_engine_submit_rejects_cache_overflow():
    """prompt_len + max_new_tokens > max_seq must fail at submit time,
    not corrupt the decode cache mid-generation."""
    cfg = get_config("smollm-135m", reduced=True)
    mod = get_model(cfg)
    params = mod.init_params(KEY, cfg, dtype=jnp.float32)
    eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=16),
                 dtype=jnp.float32)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request(uid=0, prompt=np.arange(10, dtype=np.int32),
                           max_new_tokens=7))
    assert not eng.queue
    eng.submit(Request(uid=1, prompt=np.arange(10, dtype=np.int32),
                       max_new_tokens=6))
    assert len(eng.run_until_drained()) == 1


def test_engine_mamba_family():
    cfg = get_config("mamba2-780m", reduced=True)
    mod = get_model(cfg)
    params = mod.init_params(KEY, cfg, dtype=jnp.float32)
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=64),
                 dtype=jnp.float32)
    prompts = [np.array([1, 2, 3]), np.array([4, 5])]
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    done = {r.uid: r for r in eng.run_until_drained()}
    for i, p in enumerate(prompts):
        want = _direct_greedy(mod, cfg, params, p, 4)
        assert done[i].out_tokens == want
