"""Tests for ``repro.fleet`` (ISSUE 9): the discrete-time elastic fleet
simulator and its study wiring.

Lockdown: a static single-job no-event trace reproduces
``ScheduleModel.schedule`` bit-for-bit (makespan AND feasibility) on
fig13b/fig15 record-equivalent cells.  New behavior: priority preemption
priced by the checkpoint write, elastic DP grow/shrink priced by the
``remesh_state`` checkpoint+reshard formula, burst parallelism with
lend/return hand-offs, the ``FleetSpec`` -> ``run_study`` lowering with
timeline-native columns, the F1xx rule pack, and the >= 1.3x
elastic+burst-vs-static headline claim on the mixed EM/plain fleet.
"""

import dataclasses
import math

import pytest

from repro.analysis import AnalysisError, analyze_fleet
from repro.configs import get_config, get_dlrm_config
from repro.core import dse
from repro.core.cluster import TABLE_III_CLUSTERS
from repro.core.placement import JobSpec, ScheduleModel, get_placement
from repro.core.simulator import group_breakdowns_compiled
from repro.core.study import Axis, run_study
from repro.fleet import (
    FLEET_COLUMNS,
    FleetJob,
    FleetJobSpec,
    FleetModel,
    FleetSimulator,
    FleetSpec,
    FleetTrace,
    WidthProfile,
    build_workload,
    checkpoint_delay,
    fleet_record,
    instance_state_bytes,
    remesh_delay,
)


def _prof(times, fits=None, sb=8e9):
    """{width: (t_g0, t_g1, ...)} -> per-width WidthProfile map."""
    out = {}
    for w, ts in times.items():
        ts = ts if isinstance(ts, tuple) else (ts,)
        ft = fits[w] if fits else (True,) * len(ts)
        out[w] = WidthProfile(iter_times=ts, fits=ft, state_bytes=sb)
    return out


def _job(uid=0, width=8, iters=1, caps_groups=1, it=1.0, **kw):
    spec = FleetJobSpec(name=kw.pop("name", f"j{uid}"),
                        nodes_per_instance=width, iterations=iters, **kw)
    times = {w: (it,) * caps_groups for w in spec.width_menu}
    return FleetJob(spec=spec, profiles=_prof(times), uid=uid)


STATIC = FleetModel(policy="static")
ELASTIC = FleetModel(policy="elastic")
BURSTY = FleetModel(policy="elastic+burst")


# ===================================================================== #
# Specs, traces, and the resize-cost formula
# ===================================================================== #

class TestFleetJobSpec:
    def test_width_menu_and_elastic(self):
        s = FleetJobSpec(name="a", nodes_per_instance=16, widths=(8, 32))
        assert s.base_width == 16
        assert s.width_menu == (8, 16, 32)
        assert s.elastic
        assert not FleetJobSpec(name="b", nodes_per_instance=8).elastic

    def test_validation(self):
        with pytest.raises(ValueError):
            FleetJobSpec(name="x", nodes_per_instance=0)
        with pytest.raises(ValueError):
            FleetJobSpec(name="x", arrival=-1.0)
        with pytest.raises(ValueError):
            FleetJobSpec(name="x", iterations=0)
        with pytest.raises(ValueError):
            FleetJobSpec(name="x", widths=(0,))
        with pytest.raises(ValueError):
            FleetJobSpec(name="x", burst_iters=-1)
        with pytest.raises(ValueError):
            FleetJobSpec(name="x", mp=0)

    def test_fleet_job_needs_full_menu(self):
        spec = FleetJobSpec(name="a", nodes_per_instance=8, widths=(16,))
        with pytest.raises(ValueError, match="WidthProfile"):
            FleetJob(spec=spec, profiles=_prof({8: 1.0}))

    def test_width_profile_validation(self):
        with pytest.raises(ValueError):
            WidthProfile(iter_times=(1.0, 2.0), fits=(True,))


class TestFleetTrace:
    def test_static_replays_templates_verbatim(self):
        tpl = (FleetJobSpec(name="a", nodes_per_instance=8, arrival=3.0),)
        assert FleetTrace(kind="static").materialize(tpl) == tpl

    def test_poisson_deterministic_per_seed(self):
        t = FleetTrace(kind="poisson", rate=0.01, num_jobs=6, seed=7)
        again = FleetTrace(kind="poisson", rate=0.01, num_jobs=6, seed=7)
        assert t.arrivals == again.arrivals
        other = FleetTrace(kind="poisson", rate=0.01, num_jobs=6, seed=8)
        assert t.arrivals != other.arrivals
        assert t.arrivals[0] == 0.0
        assert all(b >= a for a, b in zip(t.arrivals, t.arrivals[1:]))

    def test_uniform_spacing(self):
        t = FleetTrace(kind="uniform", rate=0.5, num_jobs=4)
        assert t.arrivals == (0.0, 2.0, 4.0, 6.0)

    def test_materialize_cycles_and_stamps(self):
        tpl = (FleetJobSpec(name="a", nodes_per_instance=8),
               FleetJobSpec(name="b", nodes_per_instance=4))
        jobs = FleetTrace(kind="uniform", rate=1.0,
                          num_jobs=4).materialize(tpl)
        assert [j.name for j in jobs] == ["a#0", "b#1", "a#2", "b#3"]
        assert [j.arrival for j in jobs] == [0.0, 1.0, 2.0, 3.0]

    def test_mean_iterations_stamps_durations(self):
        tpl = (FleetJobSpec(name="a", nodes_per_instance=8,
                            iterations=5),)
        jobs = FleetTrace(kind="uniform", rate=1.0, num_jobs=8, seed=3,
                          mean_iterations=40).materialize(tpl)
        assert all(j.iterations >= 1 for j in jobs)
        assert len({j.iterations for j in jobs}) > 1

    def test_validation(self):
        with pytest.raises(ValueError):
            FleetTrace(kind="weird")
        with pytest.raises(ValueError):
            FleetTrace(kind="poisson", rate=0.0).materialize(
                (FleetJobSpec(name="a", nodes_per_instance=1),))
        with pytest.raises(ValueError):
            FleetTrace(kind="static").materialize(())


class TestResizeCostModel:
    """Satellite 2: the documented remesh formula, end to end."""

    def test_formula(self):
        sb = 64e9
        assert checkpoint_delay(sb, 40e9) == sb / 40e9
        assert remesh_delay(sb, 40e9, 100e9) == sb / 40e9 + sb / 100e9
        with pytest.raises(ValueError):
            checkpoint_delay(sb, 0.0)
        with pytest.raises(ValueError):
            remesh_delay(sb, 40e9, -1.0)

    def test_state_bytes_matches_memory_model(self):
        """(FP16+GRAD+OPTIM)/FP16 x one replica's weight bytes: the
        ZeRO-gathered tensors ``remesh_state`` moves per instance."""
        from repro.core.memory import FP16, GRAD, OPTIM
        spec = FleetJobSpec(name="t", model="chatglm3-6b", mp=2,
                            global_batch=256, nodes_per_instance=8)
        wl = build_workload(spec, 8)
        shard = sum(ly.weight_bytes * ly.repeat for ly in wl.layers) / FP16
        expect = (FP16 + GRAD + OPTIM) * shard * wl.mp
        assert instance_state_bytes(wl) == expect

    def test_simulator_resize_delay_matches_formula_registry_model(self):
        """A registry-model grow pays exactly checkpoint + reshard: the
        makespan is remesh_delay + remaining x the wide iteration time."""
        from repro.fleet.spec import _profiles
        cluster = dse.mixed_dlrm_fleet()
        spec = FleetJobSpec(name="chat", model="chatglm3-6b", mp=2,
                            global_batch=256, nodes_per_instance=8,
                            widths=(8, 16, 32), iterations=100)
        profiles = _profiles(spec, cluster, 2, get_placement("em-aware"),
                             {})
        job = FleetJob(spec=spec, profiles=profiles)
        model = FleetModel(policy="elastic", checkpoint_bw=40e9,
                           reshard_bw=100e9)
        res = FleetSimulator([g.num_nodes for g in cluster.node_groups],
                             model=model).run([job])
        sb = instance_state_bytes(build_workload(spec, 8))
        assert job.state_bytes == sb
        grow = [e for e in res.events if e.kind == "grow"]
        assert len(grow) == 1 and grow[0].width == 32
        cost = remesh_delay(sb, 40e9, 100e9)
        wide_it = profiles[32].iter_times[grow[0].group]
        assert res.makespan == cost + 100 * wide_it
        assert res.resize_events == 1

    def test_preemption_pays_checkpoint_then_restore(self):
        """The victim's nodes free one checkpoint write after the
        preemption; its rerun is delayed by the restore charge."""
        sb = 80e9
        low = FleetJob(FleetJobSpec(name="low", nodes_per_instance=8,
                                    iterations=10),
                       _prof({8: 5.0}, sb=sb), uid=0)
        hi = FleetJob(FleetJobSpec(name="hi", nodes_per_instance=8,
                                   iterations=2, priority=5, arrival=12.0),
                      _prof({8: 1.0}, sb=sb), uid=1)
        res = FleetSimulator((8,), model=ELASTIC).run([low, hi])
        ck = checkpoint_delay(sb, ELASTIC.checkpoint_bw)
        # victim checkpoints at t=12 (2 iters credited), nodes free at
        # 12+ck, hi runs 2 iters, victim restarts after its restore
        # charge and reruns 8 iters.
        hi_out = next(o for o in res.outcomes if o.name == "hi")
        assert hi_out.first_start == 12.0 + ck
        assert hi_out.finish == 12.0 + ck + 2 * 1.0
        low_out = next(o for o in res.outcomes if o.name == "low")
        assert low_out.preemptions == 1
        assert low_out.finish == hi_out.finish + ck + 8 * 5.0
        assert res.feasible


# ===================================================================== #
# Degenerate equivalence: static single-job traces == ScheduleModel
# ===================================================================== #

class TestDegenerateEquivalence:
    MODEL = ScheduleModel()

    def _check(self, caps, iter_times, fits, instances, npi,
               max_nodes=0, placement=None):
        sched = self.MODEL.schedule(
            JobSpec(instances=instances, nodes_per_instance=npi,
                    max_nodes=max_nodes),
            [_GroupStub(n) for n in caps],
            iter_times, fits=fits, placement=placement)
        job = FleetJob(
            FleetJobSpec(name="j", instances=instances,
                         nodes_per_instance=npi, max_nodes=max_nodes,
                         iterations=1),
            _prof({npi: tuple(iter_times)},
                  fits={npi: tuple(fits)} if fits else None))
        res = FleetSimulator(caps, model=STATIC,
                             placement=placement).run([job])
        assert res.makespan == sched.makespan          # bit-for-bit
        assert res.feasible == sched.feasible
        assert res.jobs_completed == 1
        assert res.preemptions == res.resize_events == 0
        return res

    def test_synthetic_grid(self):
        cases = [
            ((32, 32), (1.0, 3.0), None, 8, 8, 0),
            ((64,), (0.1,), None, 8, 8, 0),
            ((64,), (0.7,), None, 10, 16, 64),
            ((32, 32), (0.31, 0.17), None, 8, 16, 48),
            ((12, 8), (1.0, 2.0), None, 3, 16, 0),   # legacy fallback
            ((32, 32), (0.5, 0.5), (False, True), 8, 16, 0),
        ]
        for caps, its, fits, inst, npi, cap in cases:
            self._check(caps, its, fits, inst, npi, max_nodes=cap)

    @pytest.mark.parametrize("npi", (64, 32, 16))
    def test_fig13b_record_equivalent(self, npi):
        """The Fig. 13b cells: N DLRM instances on the half-EM fleet,
        timed by the compiled engine — the fleet timeline must equal the
        ScheduleModel makespan exactly, both placements."""
        cluster = dse.mixed_dlrm_fleet()
        wl = decompose_dlrm_cached(npi)
        per = group_breakdowns_compiled(wl.compiled(), cluster,
                                        zero_stage=2, env_cache={})
        its = [b.total for b in per]
        fits = [b.feasible for b in per]
        for pl in ("paper", "em-aware"):
            self._check(tuple(g.num_nodes for g in cluster.node_groups),
                        its, fits, 8, npi, placement=get_placement(pl))

    @pytest.mark.parametrize("cluster_name,mp,dp", [("B0", 8, 128),
                                                    ("B1", 64, 16)])
    def test_fig15_record_equivalent(self, cluster_name, mp, dp):
        """fig15-style transformer cells, multi-instance on one group."""
        from repro.configs.base import ShapeConfig
        from repro.core.workload import decompose
        cluster = TABLE_III_CLUSTERS[cluster_name]
        wl = decompose(get_config("transformer-1t"),
                       ShapeConfig("paper", 2048, 1024, "train"),
                       mp=mp, dp=dp)
        per = group_breakdowns_compiled(wl.compiled(), cluster,
                                        zero_stage=2, env_cache={})
        its = [b.total for b in per]
        fits = [b.feasible for b in per]
        for instances, npi in ((1, cluster.num_nodes), (4, 256), (9, 512)):
            self._check((cluster.num_nodes,), its, fits, instances, npi)

    def test_multi_iteration_scales_linearly(self):
        job = _job(width=8, iters=7, it=0.31)
        res = FleetSimulator((8,), model=STATIC).run([job])
        assert res.makespan == 7 * 0.31      # one multiply, no drift


class _GroupStub:
    def __init__(self, num_nodes):
        self.num_nodes = num_nodes


def decompose_dlrm_cached(npi, _memo={}):
    from repro.core.workload import decompose_dlrm
    if npi not in _memo:
        _memo[npi] = decompose_dlrm(get_dlrm_config(), 4096, npi)
    return _memo[npi]


# ===================================================================== #
# Timeline behavior: waiting, preemption, elastic resize, burst
# ===================================================================== #

class TestTimeline:
    def test_infeasible_on_free_waits_for_fitting_group(self):
        """A job whose only fitting group is busy queues for it instead
        of squatting infeasibly on a non-fitting one."""
        fits = {8: (False, True)}
        blocker = FleetJob(
            FleetJobSpec(name="blk", nodes_per_instance=8, iterations=3),
            _prof({8: (1.0, 1.0)}), uid=0)
        picky = FleetJob(
            FleetJobSpec(name="picky", nodes_per_instance=8,
                         iterations=1, arrival=0.5),
            _prof({8: (0.1, 2.0)}, fits=fits), uid=1)
        res = FleetSimulator((8, 8), model=STATIC).run([blocker, picky])
        # blocker lands on g0 (fastest); picky fits only g1 -> starts
        # there immediately; no infeasible squat on g0.
        out = next(o for o in res.outcomes if o.name == "picky")
        assert out.feasible and res.feasible

    def test_never_feasible_job_adopts_legacy_fallback(self):
        job = _job(width=16, caps_groups=1)    # wider than the fleet
        res = FleetSimulator((8,), model=STATIC).run([job])
        assert res.jobs_completed == 1 and not res.feasible

    def test_unplannable_job_fails_cleanly(self):
        """A job whose profile does not match the fleet's group count
        can never be planned: it fails, the rest of the trace runs."""
        spec = FleetJobSpec(name="j", nodes_per_instance=8, iterations=1)
        job = FleetJob(spec, _prof({8: (1.0, 1.0)}))   # 2 groups
        ok = _job(uid=1, width=8, iters=2, it=0.5, caps_groups=1)
        res = FleetSimulator((8,), model=STATIC).run([job, ok])
        assert not res.feasible
        assert any(e.kind == "fail" for e in res.events)
        assert next(o for o in res.outcomes if o.uid == 1).completed

    def test_profiles_reject_nan_iteration_times(self):
        with pytest.raises(ValueError, match="NaN"):
            WidthProfile(iter_times=(float("nan"),), fits=(True,))

    def test_static_policy_never_preempts_or_resizes(self):
        jobs = [_job(uid=0, width=8, iters=5, it=2.0, caps_groups=1),
                _job(uid=1, width=8, iters=1, it=1.0, caps_groups=1,
                     priority=9, arrival=3.0, widths=(8, 16))]
        res = FleetSimulator((16,), model=STATIC).run(jobs)
        assert res.preemptions == res.resize_events == 0
        assert res.feasible

    def test_elastic_grow_beats_static_makespan(self):
        spec = FleetJobSpec(name="el", nodes_per_instance=8,
                            iterations=100, widths=(8, 32))
        profiles = _prof({8: 4.0, 32: 1.0})
        stat = FleetSimulator((32,), model=STATIC).run(
            [FleetJob(spec, profiles)])
        elas = FleetSimulator((32,), model=ELASTIC).run(
            [FleetJob(spec, profiles)])
        assert elas.resize_events == 1
        assert elas.makespan < stat.makespan
        cost = remesh_delay(8e9, ELASTIC.checkpoint_bw,
                            ELASTIC.reshard_bw)
        assert elas.makespan == cost + 100 * 1.0

    def test_grow_skipped_when_remesh_outweighs_gain(self):
        spec = FleetJobSpec(name="el", nodes_per_instance=8,
                            iterations=2, widths=(8, 32))
        res = FleetSimulator((32,), model=ELASTIC).run(
            [FleetJob(spec, _prof({8: 1.0, 32: 0.9}, sb=400e9))])
        assert res.resize_events == 0
        assert res.makespan == 2 * 1.0

    def test_shrink_frees_nodes_for_higher_priority(self):
        low = FleetJob(FleetJobSpec(name="low", nodes_per_instance=32,
                                    iterations=40, widths=(8, 32)),
                       _prof({8: 4.0, 32: 1.0}), uid=0)
        hi = FleetJob(FleetJobSpec(name="hi", nodes_per_instance=16,
                                   iterations=4, priority=5, arrival=10.0),
                      _prof({16: 1.0}), uid=1)
        res = FleetSimulator((32,), model=ELASTIC).run([low, hi])
        assert any(e.kind == "shrink" for e in res.events)
        lo = next(o for o in res.outcomes if o.name == "low")
        assert lo.resizes >= 1 and lo.preemptions == 0
        assert res.feasible

    def test_burst_borrows_and_returns(self):
        lenders = [FleetJob(FleetJobSpec(name=f"l{i}",
                                         nodes_per_instance=16,
                                         iterations=50),
                            _prof({16: 2.0}), uid=i) for i in (0, 1)]
        burst = FleetJob(
            FleetJobSpec(name="b", nodes_per_instance=8, iterations=20,
                         priority=5, arrival=10.0, widths=(8, 32),
                         burst_iters=16, preemptible=False),
            _prof({8: 4.0, 32: 0.5}), uid=2)
        res = FleetSimulator((32,), model=BURSTY).run(lenders + [burst])
        kinds = [e.kind for e in res.events]
        assert "lend" in kinds and "return" in kinds
        bo = next(o for o in res.outcomes if o.name == "b")
        assert bo.bursts == 1
        stat = FleetSimulator((32,), model=STATIC).run(lenders + [burst])
        so = next(o for o in stat.outcomes if o.name == "b")
        assert bo.turnaround < so.turnaround
        assert res.feasible and stat.feasible

    def test_result_percentiles_and_util(self):
        jobs = [_job(uid=i, width=8, iters=1, it=float(i + 1),
                     caps_groups=1) for i in range(4)]
        res = FleetSimulator((32,), model=STATIC).run(jobs)
        assert res.turnaround_p50 == 2.0
        assert res.turnaround_p99 == 4.0
        assert 0.0 < res.fleet_util <= 1.0
        # 4 jobs x 8 nodes x i seconds of busy time over 32 x makespan
        assert res.fleet_util == pytest.approx(
            8 * (1 + 2 + 3 + 4) / (32 * 4.0))

    def test_model_validation(self):
        with pytest.raises(ValueError):
            FleetModel(policy="greedy")
        assert not STATIC.elastic and not STATIC.preempt
        assert ELASTIC.preempt and not ELASTIC.burst
        assert BURSTY.burst
        assert not FleetModel(policy="elastic",
                              preemption=False).preempt


# ===================================================================== #
# Hypothesis properties
# ===================================================================== #

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                # dev container without hypothesis:
    HAVE_HYPOTHESIS = False        # the deterministic suite still runs.

if HAVE_HYPOTHESIS:
    _iters = st.integers(min_value=1, max_value=20)
    _durs = st.floats(min_value=0.05, max_value=30.0, allow_nan=False)


if HAVE_HYPOTHESIS:
    class TestFleetProperties:
        @given(caps=st.lists(st.integers(min_value=4, max_value=48),
                             min_size=1, max_size=3),
               jobs=st.lists(st.tuples(st.integers(2, 32), _iters, _durs,
                                       st.integers(0, 3),
                                       st.floats(0.0, 50.0)),
                             min_size=1, max_size=6),
               policy=st.sampled_from(("static", "elastic", "elastic+burst")))
        @settings(max_examples=60, deadline=None)
        def test_capacity_conserved_at_every_event(self, caps, jobs, policy):
            """No event may observe more allocated nodes than a group has,
            and the fleet must be empty again after the last completion."""
            fleet = []
            for uid, (w, it_n, dur, pr, arr) in enumerate(jobs):
                widths = (w, min(2 * w, max(caps))) if uid % 2 else ()
                spec = FleetJobSpec(
                    name=f"j{uid}", nodes_per_instance=w, iterations=it_n,
                    priority=pr, arrival=arr, widths=widths,
                    burst_iters=it_n // 2 if uid % 3 == 0 else 0)
                times = {x: (dur,) * len(caps) for x in spec.width_menu}
                fleet.append(FleetJob(spec, _prof(times), uid=uid))
            res = FleetSimulator(caps, model=FleetModel(policy=policy)).run(
                fleet)
            for ev in res.events:
                assert all(0 <= a <= c for a, c in zip(ev.alloc, caps)), ev
            assert res.events[-1].alloc == tuple(0 for _ in caps)
            assert res.jobs_completed == len(fleet)
            assert 0.0 <= res.fleet_util <= 1.0 + 1e-12

        @given(base=st.integers(min_value=1, max_value=4),
               extra=st.integers(min_value=1, max_value=4),
               durs=st.lists(_durs, min_size=1, max_size=6))
        @settings(max_examples=60, deadline=None)
        def test_turnaround_monotone_in_fleet_size(self, base, extra, durs):
            """Adding nodes to a single-group static fleet never worsens any
            job's turnaround (all jobs same width, batch arrival)."""
            w = 8

            def turns(cap):
                jobs = [_job(uid=i, width=w, iters=1, it=d, caps_groups=1)
                        for i, d in enumerate(durs)]
                res = FleetSimulator((cap,), model=STATIC).run(jobs)
                return [o.turnaround for o in res.outcomes]

            small = turns(w * base)
            big = turns(w * (base + extra))
            assert all(b <= s + 1e-9 for s, b in zip(small, big))

        @given(low_iters=st.integers(2, 15), low_dur=_durs,
               hi_iters=_iters, hi_dur=_durs,
               frac=st.floats(0.05, 0.95))
        @settings(max_examples=60, deadline=None)
        def test_preemption_never_helps_the_victim(self, low_iters, low_dur,
                                                   hi_iters, hi_dur, frac):
            """The victim's own turnaround with preemption enabled is never
            better than when the high-priority job must wait."""
            arrival = frac * low_iters * low_dur

            def run(preemption):
                low = FleetJob(FleetJobSpec(name="low", nodes_per_instance=8,
                                            iterations=low_iters),
                               _prof({8: low_dur}), uid=0)
                hi = FleetJob(FleetJobSpec(name="hi", nodes_per_instance=8,
                                           iterations=hi_iters, priority=5,
                                           arrival=arrival),
                              _prof({8: hi_dur}), uid=1)
                model = FleetModel(policy="elastic", preemption=preemption)
                res = FleetSimulator((8,), model=model).run([low, hi])
                return next(o for o in res.outcomes if o.name == "low")

            with_p = run(True)
            without = run(False)
            assert with_p.turnaround >= without.turnaround - 1e-9


# ===================================================================== #
# Study integration, rules, and the headline claim
# ===================================================================== #

def _tiny_fleet_spec(**kw):
    jobs = kw.pop("jobs", (
        FleetJobSpec(name="chat", model="chatglm3-6b", mp=2,
                     global_batch=256, nodes_per_instance=8,
                     widths=(8, 16, 32), iterations=10),))
    defaults = dict(name="tiny-fleet", jobs=jobs,
                    cluster=dse.mixed_dlrm_fleet(),
                    ftrace=FleetTrace(kind="static"),
                    placement="em-aware")
    defaults.update(kw)
    return FleetSpec(**defaults)


class TestFleetStudy:
    def test_run_study_emits_fleet_columns(self):
        res = run_study(_tiny_fleet_spec(), processes=1)
        assert len(res) == 1
        rec = res.records[0]
        for col in FLEET_COLUMNS:
            assert col in rec, col
        assert rec["feasible"]
        assert rec["jobs_completed"] == 1
        assert rec["total"] == rec["makespan"] > 0
        assert rec["perf_per_dollar"] > 0
        assert rec["n_events"] > 0

    def test_policy_axis_sweeps_fleet_point(self):
        spec = _tiny_fleet_spec(axes=[
            Axis("policy", ("static", "elastic"), path="fleet.policy")])
        res = run_study(spec, processes=1)
        by = {r["policy"]: r for r in res.records}
        assert set(by) == {"static", "elastic"}
        assert by["static"]["resize_events"] == 0
        assert by["elastic"]["resize_events"] >= 1
        assert by["elastic"]["makespan"] < by["static"]["makespan"]

    def test_ftrace_axis_sweeps_trace(self):
        spec = _tiny_fleet_spec(
            ftrace=FleetTrace(kind="uniform", rate=1 / 500.0, num_jobs=2),
            axes=[Axis("njobs", (1, 3), path="ftrace.num_jobs")])
        res = run_study(spec, processes=1)
        done = sorted(r["jobs_completed"] for r in res.records)
        assert done == [1, 3]

    def test_unknown_fleet_axis_path_fails_fast(self):
        with pytest.raises((AttributeError, ValueError)):
            _tiny_fleet_spec(axes=[Axis("x", (1,), path="fleet.nope")])

    def test_spec_needs_jobs_and_cluster(self):
        with pytest.raises(ValueError):
            _tiny_fleet_spec(jobs=())
        rec = fleet_record(None, _tiny_fleet_spec(),
                           _tiny_fleet_spec().point(), "paper")
        assert not rec["feasible"] and rec["total"] == float("inf")

    def test_validate_gate_raises_on_fleet_errors(self):
        bad = _tiny_fleet_spec(fleet=FleetModel(policy="elastic",
                                                checkpoint_bw=0.0))
        with pytest.raises(AnalysisError, match="F104"):
            run_study(bad, validate="error", processes=1)
        ok = _tiny_fleet_spec()
        assert len(run_study(ok, validate="error", processes=1)) == 1


class TestFleetRules:
    def _diag_codes(self, spec):
        return {d.code for d in analyze_fleet(spec)}

    def test_clean_default_study(self):
        assert analyze_fleet(dse.fleet_study()) == []

    def test_f101_job_wider_than_every_group(self):
        spec = _tiny_fleet_spec(jobs=(
            FleetJobSpec(name="wide", model="chatglm3-6b", mp=2,
                         nodes_per_instance=64),))
        assert "F101" in self._diag_codes(spec)
        capped = _tiny_fleet_spec(jobs=(
            FleetJobSpec(name="c", model="chatglm3-6b", mp=2,
                         nodes_per_instance=16, max_nodes=8),))
        assert "F101" in self._diag_codes(capped)

    def test_f102_bad_trace(self):
        spec = _tiny_fleet_spec(
            ftrace=FleetTrace(kind="poisson", rate=-1.0))
        assert "F102" in self._diag_codes(spec)

    def test_f103_burst_sanity(self):
        spec = _tiny_fleet_spec(jobs=(
            FleetJobSpec(name="b", model="chatglm3-6b", mp=2,
                         nodes_per_instance=8, iterations=4,
                         burst_iters=9),))
        codes = self._diag_codes(spec)
        assert "F103" in codes
        odd = _tiny_fleet_spec(jobs=(
            FleetJobSpec(name="o", model="chatglm3-6b", mp=2,
                         nodes_per_instance=8, widths=(9,)),))
        assert "F103" in self._diag_codes(odd)

    def test_f104_bad_costs(self):
        spec = _tiny_fleet_spec(
            fleet=FleetModel(policy="elastic", reshard_bw=float("inf")))
        assert "F104" in self._diag_codes(spec)
        spec = _tiny_fleet_spec(
            fleet=FleetModel(policy="elastic", lend_overhead=-2.0))
        assert "F104" in self._diag_codes(spec)


class TestHeadlineClaim:
    def test_elastic_burst_beats_static_by_1_3x(self):
        """ISSUE 9 acceptance: on the mixed EM/plain fleet the
        elastic+burst policy wins >= 1.3x over the static ScheduleModel
        allocation on turnaround-p99 or perf-per-dollar."""
        ranked = dse.fleet_ranking()
        assert {r["policy"] for r in ranked} == {
            "static", "elastic", "elastic+burst"}
        head = dse.fleet_headline(ranked)
        assert max(head["turnaround_p99_ratio"],
                   head["perf_per_dollar_ratio"]) >= 1.3
        stat = next(r for r in ranked if r["policy"] == "static")
        eb = next(r for r in ranked if r["policy"] == "elastic+burst")
        assert eb["resize_events"] > 0 and eb["burst_events"] > 0
        assert stat["resize_events"] == stat["burst_events"] == 0
        assert all(math.isfinite(r["turnaround_p99"]) for r in ranked)

    def test_fleet_study_spec_is_analyzable_and_swept(self):
        spec = dse.fleet_study()
        assert analyze_fleet(spec) == []
        study = spec.to_study()
        assert study.fleet is spec
        assert [a.name for a in study.axes] == ["policy"]
