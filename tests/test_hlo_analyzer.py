"""Trip-count-weighted HLO analysis: the measured-COMET frontend."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.hlo import RooflineTerms, shape_bytes
from repro.core.hlo_analyzer import analyze_hlo

N = 256
W = jnp.zeros((N, N), jnp.float32)
X = jnp.zeros((N, N), jnp.float32)


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


class TestFlopCounting:
    def test_flat_matmul(self):
        c = analyze_hlo(_compile(lambda x: x @ W, X))
        assert c.flops == pytest.approx(2 * N ** 3, rel=0.02)

    def test_scan_multiplies_trip_count(self):
        def body(c, _):
            return c @ W, None
        c = analyze_hlo(_compile(
            lambda x: jax.lax.scan(body, x, None, length=10)[0], X))
        assert c.flops == pytest.approx(20 * N ** 3, rel=0.02)

    def test_nested_scans(self):
        def body(c, _):
            return c @ W, None
        def outer(c, _):
            c, _ = jax.lax.scan(body, c, None, length=4)
            return c, None
        c = analyze_hlo(_compile(
            lambda x: jax.lax.scan(outer, x, None, length=4)[0], X))
        assert c.flops == pytest.approx(32 * N ** 3, rel=0.02)

    def test_remat_increases_flops(self):
        """Remat recompute persists inside scans (outside, XLA CSEs it)."""
        def layer(x):
            return jnp.tanh(x @ W) @ W

        def make(f):
            def body(c, _):
                return f(c), None
            return lambda x: jax.grad(
                lambda x: jax.lax.scan(body, x, None, length=8)[0].sum())(x)

        base = analyze_hlo(_compile(make(layer), X))
        re = analyze_hlo(_compile(make(jax.checkpoint(layer)), X))
        assert re.flops > base.flops * 1.1

    def test_slice_of_stacked_params_not_full_reads(self):
        """dynamic-slice inside a scan reads one layer, not the stack."""
        ws = jnp.zeros((100, N, N), jnp.float32)
        def body(c, w):
            return c @ w, None
        c = analyze_hlo(_compile(
            lambda x, ws: jax.lax.scan(body, x, ws)[0], X, ws))
        # if the full stack were charged per step: 100 * 100 * N*N*4 = 2.6e10
        assert c.bytes < 100 * (3 * N * N * 4) * 4


class TestOldParser:
    def test_shape_bytes(self):
        assert shape_bytes("bf16[4,128]{1,0}") == 4 * 128 * 2
        assert shape_bytes("(f32[8], s32[2,2])") == 8 * 4 + 4 * 4
        assert shape_bytes("f32[]") == 4

    def test_roofline_terms_math(self):
        t = RooflineTerms(flops=197e12 * 256, hbm_bytes=819e9 * 256,
                          coll_bytes=50e9 * 256, chips=256)
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(1.0)
        assert t.collective_s == pytest.approx(1.0)
        assert t.roofline_fraction() == pytest.approx(1.0)

    def test_dominant_term(self):
        t = RooflineTerms(flops=1, hbm_bytes=1e15, coll_bytes=1, chips=1)
        assert t.dominant == "memory"
