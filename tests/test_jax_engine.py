"""JAX-native batch evaluator (ISSUE 8): the jit/vmap kernel in
``repro.core.jax_engine`` must agree with the NumPy compiled engine and
the reference event loop within 1e-9 relative, everywhere:

  * a parametrized grid across all four topology families x PP/EP x
    schedules x EM nodes x bandwidth overrides x require_fit;
  * ``run_study(engine="jax")`` record-for-record against both other
    engines;
  * a hypothesis property over random topologies/strategies/overrides
    when hypothesis is installed (the grid still runs without it);
  * the NumPy fallback path (jax absent -> one RuntimeWarning, identical
    records);
  * x64 scoping: the engine computes in float64 without flipping the
    process-global JAX default (the repro.kernels/models f32 stack runs
    in the same process).

``jax`` itself is importorskip-ed so a NumPy-only environment (the CI
bench-smoke lane installs just numpy) skips cleanly.
"""

import dataclasses
import math
import warnings

import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.cluster import (
    BASELINE_DGX_A100,
    ClusterConfig,
    HierarchicalSwitch,
    NodeConfig,
    SingleSwitch,
    Torus,
)
from repro.core.simulator import (
    simulate_iteration,
    simulate_iteration_compiled,
    time_compiled,
)
from repro.core.study import Axis, PowerOfTwoSpace, StudySpec, run_study
from repro.core.workload import decompose

GB = 1e9
REL = 1e-9
SMALL_SHAPE = ShapeConfig("small", 512, 64, "train")

SMALL_NODE = NodeConfig("sim", peak_flops=100e12, local_cap=16 * GB,
                        local_bw=1000 * GB, sram_bytes=20e6, tdp_watts=300)
EM_NODE = dataclasses.replace(SMALL_NODE, local_cap=0.2 * GB,
                              exp_cap=64 * GB, exp_bw=250 * GB)

TOPOLOGIES = {
    "hier": HierarchicalSwitch(pod_size=4, intra_bw=200 * GB,
                               inter_bw=25 * GB),
    "torus": Torus(dims=(4, 4), link_bw=40 * GB),
    "torus-dcn": Torus(dims=(2, 2), link_bw=40 * GB, dcn_bw=10 * GB),
    "switch": SingleSwitch(bw=300 * GB),
}


def assert_breakdowns_equivalent(a, b, rel: float = REL) -> None:
    for k, va in a.as_dict().items():
        vb = b.as_dict()[k]
        if isinstance(va, float) and (math.isnan(va) or math.isinf(va)):
            assert str(va) == str(vb), k
        else:
            assert va == pytest.approx(vb, rel=rel, abs=1e-12), k
    assert a.feasible == b.feasible
    assert a.mem_bw == pytest.approx(b.mem_bw, rel=rel)
    assert a.bubble_fraction == pytest.approx(b.bubble_fraction, rel=rel,
                                              abs=1e-12)


# ===================================================================== #
# Fallback path: no jax needed (and must not break without it)
# ===================================================================== #

class TestNumpyFallback:
    def test_fallback_warns_once_and_matches(self, monkeypatch):
        from repro.core import jax_engine, simulator
        wl = decompose(get_config("smollm-135m"), SMALL_SHAPE, mp=4, dp=4)
        cluster = ClusterConfig("sim", SMALL_NODE, 16, TOPOLOGIES["hier"])
        monkeypatch.setattr(jax_engine, "HAVE_JAX", False)
        monkeypatch.setattr(simulator, "_warned_no_jax", False)
        with pytest.warns(RuntimeWarning, match="falling back"):
            via_jax = simulate_iteration_compiled(wl.compiled(), cluster,
                                                  backend="jax")
        with warnings.catch_warnings():
            warnings.simplefilter("error")   # second call: no re-warn
            again = simulate_iteration_compiled(wl.compiled(), cluster,
                                                backend="jax")
        plain = simulate_iteration_compiled(wl.compiled(), cluster)
        assert via_jax.as_dict() == plain.as_dict()
        assert again.as_dict() == plain.as_dict()


# ===================================================================== #
# Everything below drives the real jit/vmap kernel
# ===================================================================== #

jax = pytest.importorskip("jax")


JAX_CASES = [
    # (model, topo key, node, mp, dp, pp, ep, schedule, override, req_fit)
    ("smollm-135m", "hier", SMALL_NODE, 4, 4, 1, 1, "1f1b", None, False),
    ("smollm-135m", "hier", SMALL_NODE, 2, 2, 4, 1, "gpipe", None, False),
    ("smollm-135m", "hier", SMALL_NODE, 2, 2, 4, 1, "interleaved", None,
     False),
    ("smollm-135m", "torus", SMALL_NODE, 4, 4, 1, 1, "1f1b", "local",
     False),
    ("smollm-135m", "torus-dcn", SMALL_NODE, 2, 4, 2, 1, "1f1b", None,
     False),
    ("smollm-135m", "switch", SMALL_NODE, 8, 2, 1, 1, "1f1b", 500 * GB,
     False),
    ("smollm-135m", "hier", EM_NODE, 2, 8, 1, 1, "1f1b", None, False),
    ("smollm-135m", "hier", EM_NODE, 2, 8, 1, 1, "1f1b", None, True),
    ("granite-moe-3b-a800m", "hier", SMALL_NODE, 2, 2, 1, 4, "1f1b", None,
     False),
    ("granite-moe-3b-a800m", "torus", SMALL_NODE, 2, 2, 2, 2, "gpipe",
     None, False),
]


class TestJaxEquivalence:
    @pytest.mark.parametrize("case", JAX_CASES,
                             ids=[f"{c[0]}-{c[1]}-mp{c[3]}dp{c[4]}"
                                  f"pp{c[5]}ep{c[6]}-{c[7]}"
                                  for c in JAX_CASES])
    def test_grid(self, case):
        arch, topo_key, node, mp, dp, pp, ep, sched, override, req = case
        wl = decompose(get_config(arch), SMALL_SHAPE, mp=mp, dp=dp, pp=pp,
                       ep=ep, schedule=sched)
        cluster = ClusterConfig("sim", node, mp * dp * pp * ep,
                                TOPOLOGIES[topo_key])
        ref = simulate_iteration(wl, cluster, mem_bw_override=override,
                                 require_fit=req)
        for backend in ("numpy", "jax"):
            comp = simulate_iteration_compiled(
                wl.compiled(), cluster, mem_bw_override=override,
                require_fit=req, backend=backend)
            assert_breakdowns_equivalent(ref, comp)

    def test_batched_envs_match_numpy(self):
        """One vmapped call over several environments at once — the shape
        the study prefetch uses — against per-env NumPy results."""
        wl = decompose(get_config("smollm-135m"), SMALL_SHAPE, mp=4, dp=4)
        cw = wl.compiled()
        envs = [(SMALL_NODE, TOPOLOGIES["hier"]),
                (EM_NODE, TOPOLOGIES["hier"]),
                (SMALL_NODE, TOPOLOGIES["torus"]),
                (SMALL_NODE, TOPOLOGIES["switch"])]
        via_np = time_compiled(cw, envs, backend="numpy")
        via_jax = time_compiled(cw, envs, backend="jax")
        for a, b in zip(via_np, via_jax):
            assert_breakdowns_equivalent(a, b)

    def test_assigned_placement_pipeline(self):
        from repro.core.cluster import B_HYBRID_EM
        from repro.core.placement import EM_AWARE_PLACEMENT
        cfg = get_config("transformer-1t")
        wl = decompose(cfg, ShapeConfig("p", 2048, 1024, "train"),
                       mp=16, dp=16, pp=4)
        ref = simulate_iteration(wl, B_HYBRID_EM,
                                 placement=EM_AWARE_PLACEMENT)
        comp = simulate_iteration_compiled(wl.compiled(), B_HYBRID_EM,
                                           placement=EM_AWARE_PLACEMENT,
                                           backend="jax")
        assert_breakdowns_equivalent(ref, comp)

    def test_x64_stays_scoped(self):
        """The engine must compute in f64 without flipping the process
        default: the repo's f32 kernel/model tests share this process."""
        import jax.numpy as jnp
        wl = decompose(get_config("smollm-135m"), SMALL_SHAPE, mp=4, dp=4)
        cluster = ClusterConfig("sim", SMALL_NODE, 16, TOPOLOGIES["hier"])
        simulate_iteration_compiled(wl.compiled(), cluster, backend="jax")
        assert jnp.ones(3).dtype == jnp.float32


class TestJaxStudyEngine:
    def test_engine_jax_matches_other_engines(self):
        spec = StudySpec(
            name="jax-study",
            model=get_config("smollm-135m"), shape=SMALL_SHAPE,
            cluster=dataclasses.replace(BASELINE_DGX_A100, num_nodes=8),
            strategies=PowerOfTwoSpace(),
            axes=[Axis("f", (1.0, 2.0), path="node.peak_flops",
                       mode="scale")])
        ref = run_study(spec, engine="reference")
        via_jax = run_study(spec, engine="jax")
        assert len(ref) == len(via_jax)
        for ra, rb in zip(ref.records, via_jax.records):
            assert set(ra) == set(rb)
            for k, va in ra.items():
                vb = rb[k]
                if isinstance(va, float) and isinstance(vb, float):
                    if math.isnan(va) or math.isinf(va):
                        assert str(va) == str(vb), k
                    else:
                        assert va == pytest.approx(vb, rel=REL,
                                                   abs=1e-12), k
                else:
                    assert va == vb, k

    def test_unknown_engine_rejected(self):
        spec = StudySpec(name="bad", evaluate=lambda ctx: {})
        with pytest.raises(ValueError, match="engine"):
            run_study(spec, engine="cuda")


# ===================================================================== #
# Hypothesis property (skipped without hypothesis; the grid above runs)
# ===================================================================== #

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def jax_inputs(draw):
        mp = draw(st.sampled_from([1, 2, 4]))
        dp = draw(st.sampled_from([1, 2, 4]))
        pp = draw(st.sampled_from([1, 2, 4]))
        schedule = draw(st.sampled_from(["1f1b", "gpipe", "interleaved"]))
        fam = draw(st.sampled_from(["hier", "torus", "torus-dcn",
                                    "switch"]))
        if fam == "hier":
            topo = HierarchicalSwitch(
                pod_size=draw(st.sampled_from([2, 4, 8])),
                intra_bw=draw(st.floats(50, 500)) * GB,
                inter_bw=draw(st.floats(5, 50)) * GB)
        elif fam == "torus":
            topo = Torus(dims=(4, 4),
                         link_bw=draw(st.floats(10, 100)) * GB)
        elif fam == "torus-dcn":
            topo = Torus(dims=(2, 2),
                         link_bw=draw(st.floats(10, 100)) * GB,
                         dcn_bw=draw(st.floats(2, 20)) * GB)
        else:
            topo = SingleSwitch(bw=draw(st.floats(50, 500)) * GB)
        node = dataclasses.replace(
            SMALL_NODE,
            peak_flops=draw(st.floats(20, 500)) * 1e12,
            local_bw=draw(st.floats(200, 3000)) * GB,
            local_cap=draw(st.floats(0.5, 64)) * GB,
            exp_cap=draw(st.sampled_from([0.0, 64 * GB])),
            exp_bw=draw(st.floats(100, 1000)) * GB)
        override = draw(st.sampled_from([None, "local", 500 * GB]))
        zero = draw(st.sampled_from([0, 2, 3]))
        return mp, dp, pp, schedule, topo, node, override, zero

    class TestHypothesisJaxEquivalence:
        @settings(max_examples=25, deadline=None)
        @given(jax_inputs())
        def test_jax_matches_numpy_and_reference(self, inputs):
            mp, dp, pp, schedule, topo, node, override, zero = inputs
            cfg = get_config("smollm-135m")
            wl = decompose(cfg, SMALL_SHAPE, mp=mp, dp=dp, pp=pp,
                           schedule=schedule)
            cluster = ClusterConfig("h", node, mp * dp * pp, topo)
            ref = simulate_iteration(wl, cluster, zero_stage=zero,
                                     mem_bw_override=override)
            for backend in ("numpy", "jax"):
                comp = simulate_iteration_compiled(
                    wl.compiled(), cluster, zero_stage=zero,
                    mem_bw_override=override, backend=backend)
                assert_breakdowns_equivalent(ref, comp)
